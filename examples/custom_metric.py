"""Bring your own metric: register a distance, get every engine.

The registry (``repro.api.metrics``, DESIGN.md §10) is the single
capability source — registering a name makes it admissible everywhere
its flags allow, with no edits to ``repro`` internals. Two patterns:

1. **Vector-backed** (the common case): a jnp-traceable
   ``pairwise_fn(a, b) -> (A, B)`` over row coordinates. Chebyshev
   (L-inf) below is a true metric, so ``has_triangle=True`` unlocks
   the exact bound-driven engines, not just the quadratic scan.
2. **Oracle-backed**: no coordinate formula — distances come from an
   oracle object with ``.row(i)``/``.n`` passed as the query input.
   The built-in ``"graph"`` metric (shortest paths on a
   ``GraphOracle``) is the worked example; see
   ``examples/medoid_network.py`` and ``repro.api.metrics``'
   module docstring.

    PYTHONPATH=src python examples/custom_metric.py
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.api import MedoidQuery, available_metrics, register_metric, solve


def chebyshev(a, b):
    """max_k |a_k - b_k| — a true metric (triangle holds per-coordinate)."""
    return jnp.max(jnp.abs(a[:, None, :] - b[None, :, :]), axis=-1)


register_metric("chebyshev", chebyshev, has_triangle=True,
                description="L-inf distance")
print(f"registered; admissible exact metrics: "
      f"{available_metrics(require_triangle=True)}")

X = np.random.default_rng(0).random((4096, 3)).astype(np.float32)
r = solve(MedoidQuery(X, metric="chebyshev"))
print(f"chebyshev medoid={r.index} [{r.plan.engine}] "
      f"energy={r.energy:.4f} computed={r.elements_computed:.0f} "
      f"of {len(X)} rows ({len(X) / r.elements_computed:.0f}x saved)")

# exactness check: the bound-driven engine must match the full scan
r_scan = solve(MedoidQuery(X, metric="chebyshev"), plan="scan")
assert r.index == r_scan.index, (r.index, r_scan.index)
print(f"parity with full scan at index {r_scan.index}: OK")

# non-metric distances stay honest: has_triangle=False names the
# admissible engines in the error instead of silently going inexact
register_metric("dot_gap", lambda a, b: -(a @ b.T), has_triangle=False,
                description="negative inner product (not a metric)")
r_dot = solve(MedoidQuery(X, metric="dot_gap"))
print(f"dot_gap routed to [{r_dot.plan.engine}] (no triangle bound)")
