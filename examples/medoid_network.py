"""Network centrality: exact medoid (closeness-centrality argmax) of
spatial networks — the paper's Table-1 setting, served two ways:

* ``metric="graph"`` — the device graph engine: batched Bellman-Ford
  SSSP sweeps + landmark (ALT) elimination bounds (DESIGN.md §16);
* the host sequential engine (trimed over per-row Dijkstra), the
  paper-faithful baseline, which also certifies the device result.

Also demos the distributed sharded trimed on a host mesh.

    PYTHONPATH=src python examples/medoid_network.py
"""
import os, sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.api import MedoidQuery, solve
from repro.core import GraphOracle, grid_network, sensor_network

# --- device graph engine: metric="graph" routes to batched
# Bellman-Ford sweeps with landmark bounds; exact and certified ---
g, pts = grid_network(4096, seed=0)          # jittered road-style lattice
r = solve(MedoidQuery(g, metric="graph", seed=0))
info = r.extras["graph"]
print(f"grid network: |V|={g.n}, medoid node={r.index} "
      f"[{r.plan.engine}], energy={r.energy:.4f}, SSSP sweeps="
      f"{r.elements_computed:.0f} ({info['landmark_sweeps']} landmark "
      f"+ {info['pivot_sweeps']} pivot + {info['certify_rows']} certify"
      f", {g.n / r.elements_computed:.0f}x fewer than brute force)")

# --- host sequential engine (the default for oracle inputs without
# metric="graph"): trimed + per-row Dijkstra, paper-faithful ---
s, _ = sensor_network(3000, seed=0, radius_scale=1.6)
rh = solve(MedoidQuery(s, seed=0))
print(f"sensor network: |V|={s.n}, medoid node={rh.index} "
      f"[{rh.plan.engine}], energy={rh.energy:.4f}, "
      f"Dijkstra sweeps={rh.elements_computed:.0f} "
      f"({s.n / rh.elements_computed:.0f}x fewer than brute force)")

# the two engines agree bit-for-bit on the same graph
s2 = GraphOracle(s.adj, s.n)
rg = solve(MedoidQuery(s2, metric="graph", seed=0))
assert rg.index == rh.index, (rg.index, rh.index)
print(f"device/host parity on the sensor graph at node {rg.index}: OK")

# --- distributed vector medoid on an 8-way data-parallel mesh
# (DESIGN.md §11: a production mesh axis named "data") ---
mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
X = np.random.default_rng(0).random((65536, 3)).astype(np.float32)
rs = solve(MedoidQuery(X, block=128, device_policy="sharded", mesh=mesh,
                       engine_opts={"axis": "data"}))
print(f"sharded trimed over {rs.plan.params['n_shards']} devices: "
      f"medoid={rs.index} computed={rs.elements_computed:.0f} "
      f"rounds={rs.n_rounds} "
      f"per-shard={rs.plan.params['per_shard_elements']}")
