"""Network centrality: exact medoid (closeness-centrality argmax) of a
spatial sensor network via trimed + Dijkstra — the paper's Table-1
setting. Also demos the distributed sharded trimed on a host mesh.

    PYTHONPATH=src python examples/medoid_network.py
"""
import os, sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.api import MedoidQuery, solve
from repro.core import sensor_network

# --- graph medoid (shortest-path metric, Dijkstra oracle): an oracle
# input routes to the paper-faithful host sequential engine ---
g, pts = sensor_network(3000, seed=0, radius_scale=1.6)
r = solve(MedoidQuery(g, seed=0))
print(f"sensor network: |V|={g.n}, medoid node={r.index} "
      f"[{r.plan.engine}], energy={r.energy:.4f}, "
      f"Dijkstra sweeps={r.elements_computed:.0f} "
      f"({g.n / r.elements_computed:.0f}x fewer than brute force)")

# --- distributed vector medoid on an 8-way data-parallel mesh
# (DESIGN.md §11: a production mesh axis named "data") ---
mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
X = np.random.default_rng(0).random((65536, 3)).astype(np.float32)
rs = solve(MedoidQuery(X, block=128, device_policy="sharded", mesh=mesh,
                       engine_opts={"axis": "data"}))
print(f"sharded trimed over {rs.plan.params['n_shards']} devices: "
      f"medoid={rs.index} computed={rs.elements_computed:.0f} "
      f"rounds={rs.n_rounds} "
      f"per-shard={rs.plan.params['per_shard_elements']}")
