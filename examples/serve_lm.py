"""Batched serving with continuous batching + medoid KV compression demo.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import model as M
from repro.serve.engine import Request, ServeEngine
from repro.serve.kv_compress import (compress_cache,
                                     compressed_decode_attention)

cfg = get_smoke_config("qwen3_4b")
params = M.init_params(cfg, jax.random.PRNGKey(0))

# --- continuous-batching engine ---
eng = ServeEngine(cfg, params, n_slots=4, max_len=128)
rng = np.random.default_rng(0)
for i in range(6):
    eng.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab, 12 + i),
                       max_new_tokens=8))
done = eng.run()
print(f"served {len(done)} requests, e.g. req0 -> {done[0].out_tokens}")

# --- medoid KV compression (beyond-paper, repro.serve.kv_compress) ---
# Long-context KV caches cluster (attention sinks, local topics): model
# that with prototype-structured keys; compression is near-exact when
# the structure exists and degrades gracefully when it doesn't.
B, S, KV, HD = 1, 256, cfg.n_kv_heads, cfg.head_dim_
kproto = jax.random.normal(jax.random.PRNGKey(4), (16, KV, HD)) * 2.0
assign = jax.random.randint(jax.random.PRNGKey(5), (S,), 0, 16)
keys = (kproto[assign]
        + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (S, KV, HD)))[None]
vals = (kproto[assign] * 0.5)[None]
q = jax.random.normal(jax.random.PRNGKey(3), (B, 1, cfg.n_heads, HD))

from repro.models.attention import decode_attention
exact = decode_attention(q, keys, vals,
                         q_position=None, kv_len=jnp.array([S]))
med_k, mean_v, logm = compress_cache(keys, vals, k=32, n_iter=8)
approx = compressed_decode_attention(q, med_k, mean_v, logm)
err = float(jnp.mean(jnp.abs(exact - approx)) / jnp.mean(jnp.abs(exact)))
print(f"medoid KV compression 256->32 clusters: rel-L1 err {err:.3f}, "
      f"decode attention cost 8x lower")
assert err < 0.2, err
print("OK")
