"""Quickstart: find the exact medoid of a point set four ways.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (exact_medoid, trimed_block, trimed_pipelined,
                        trimed_sequential, toprank)
from repro.kernels.ops import fused_round

rng = np.random.default_rng(0)
X = rng.random((20_000, 2)).astype(np.float32)

# 1) paper-faithful sequential trimed (host)
r1 = trimed_sequential(X, seed=0)
print(f"trimed(seq)    medoid={r1.index} energy={r1.energy:.5f} "
      f"computed={r1.n_computed} of N={len(X)}")

# 2) TPU block-synchronous trimed (device, jit)
r2 = trimed_block(X, block=128)
print(f"trimed(block)  medoid={r2.index} energy={r2.energy:.5f} "
      f"computed={r2.n_computed} rounds={r2.n_rounds}")

# 3) Pallas fused kernels (distance block never materialised)
r3 = trimed_block(X, block=128, fused_round_fn=fused_round)
print(f"trimed(pallas) medoid={r3.index} energy={r3.energy:.5f} "
      f"computed={r3.n_computed}")

# 4) survivor-compacted pipelined engine (DESIGN.md §4): one X-stream
#    per round, working set shrinks with the survivor set; the geometric
#    block schedule warms the incumbent before wide blocks commit
r5 = trimed_pipelined(X, block=128, block_schedule="geometric")
print(f"trimed(pipe)   medoid={r5.index} energy={r5.energy:.5f} "
      f"computed={r5.n_computed} rounds={r5.n_rounds} "
      f"stages={r5.n_stages} "
      f"x-streams/round={r5.x_cols_streamed / (r5.n_rounds * len(X)):.2f}")

# baseline comparison (the paper's headline)
r4 = toprank(X, seed=0)
print(f"TOPRANK        medoid={r4.index} computed={r4.n_computed} "
      f"({r4.n_computed / max(r2.n_computed,1):.1f}x more than trimed)")

assert r1.index == r2.index == r3.index == r4.index == r5.index
ti, _ = exact_medoid(X[:2000])  # brute-force check on a subset
print("OK — all methods agree")
