"""Quickstart: one front door — MedoidQuery -> planner -> SolveReport.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import MedoidQuery, solve
from repro.core import exact_medoid
from repro.core.baselines import toprank

rng = np.random.default_rng(0)
X = rng.random((20_000, 2)).astype(np.float32)

# 1) let the planner pick (N=20k -> survivor-compacted pipelined engine);
#    explain=True shows the choice without computing anything
plan = solve(MedoidQuery(X), explain=True)
print(f"planner chose {plan.engine!r}: {'; '.join(plan.reasons)}")
r = solve(MedoidQuery(X))
print(f"solve(auto)    medoid={r.index} energy={r.energy:.5f} "
      f"computed={r.elements_computed:.0f} of N={len(X)} "
      f"certified={r.certified}")

# 2) power users can force any engine with plan=
r1 = solve(MedoidQuery(X, seed=0), plan="sequential")   # paper Alg. 1, host
r2 = solve(MedoidQuery(X, block=128), plan="block")     # block-synchronous
r3 = solve(MedoidQuery(X, block=128, use_kernels=True), plan="block")
print(f"sequential     medoid={r1.index} computed={r1.elements_computed:.0f}")
print(f"block          medoid={r2.index} rounds={r2.n_rounds}")
print(f"block+pallas   medoid={r3.index} computed={r3.elements_computed:.0f}")

# 3) pipelined engine with the geometric warm-up schedule
r5 = solve(MedoidQuery(X, block=128, block_schedule="geometric"),
           plan="pipelined")
raw = r5.extras["raw"]          # the engine's native MedoidResult
print(f"pipelined      medoid={r5.index} rounds={r5.n_rounds} "
      f"stages={raw.n_stages} "
      f"x-streams/round={raw.x_cols_streamed / (raw.n_rounds * len(X)):.2f}")

# 4) anytime / budgeted query — bandit race + exact finisher (DESIGN.md §9)
rb = solve(MedoidQuery(X, budget=600.0))
print(f"anytime        medoid={rb.index} ci={rb.ci:.5f} "
      f"computed={rb.elements_computed:.0f} certified={rb.certified}")

# baseline comparison (the paper's headline)
r4 = toprank(X, seed=0)
print(f"TOPRANK        medoid={r4.index} computed={r4.n_computed:.0f} "
      f"({r4.n_computed / max(r2.elements_computed, 1):.1f}x more "
      "than trimed)")

assert r.index == r1.index == r2.index == r3.index == r4.index == r5.index
ti, _ = exact_medoid(X[:2000])  # brute-force check on a subset
print("OK — all methods agree")
