"""K-medoids clustering with trikmeds: KMEDS-quality clusters at a
fraction of the distance computations, plus the eps-relaxation knob.

    PYTHONPATH=src python examples/kmedoids_clustering.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import kmeds, trikmeds

rng = np.random.default_rng(1)
centers = rng.random((12, 2)) * 10
X = centers[rng.integers(0, 12, 3000)] + rng.standard_normal((3000, 2)) * 0.4

K = 12
init = rng.choice(len(X), size=K, replace=False)

base = kmeds(X, K, init_medoids=init, seed=1)
print(f"KMEDS      energy={base.energy:.2f} distances={base.n_distances:,}")

for eps in (0.0, 0.01, 0.1):
    r = trikmeds(X, K, eps=eps, seed=1, init_medoids=init)
    print(f"trikmeds-{eps:<4} energy={r.energy:.2f} "
          f"distances={r.n_distances:,} "
          f"({base.n_distances / r.n_distances:.1f}x fewer) "
          f"iters={r.n_iterations}")

# medoids are actual data points — print them
r = trikmeds(X, K, seed=1, init_medoids=init)
print("medoid coordinates (first 4):")
print(np.asarray(X[r.medoids[:4]]).round(2))
