"""K-medoids clustering with trikmeds: KMEDS-quality clusters at a
fraction of the distance computations, plus the eps-relaxation knob —
and the device-side batched engine doing the same trick under jit.

    PYTHONPATH=src python examples/kmedoids_clustering.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import MedoidQuery, solve
from repro.core import kmedoids_batched, kmeds, trikmeds

rng = np.random.default_rng(1)
centers = rng.random((12, 2)) * 10
X = centers[rng.integers(0, 12, 3000)] + rng.standard_normal((3000, 2)) * 0.4

K = 12
init = rng.choice(len(X), size=K, replace=False)

base = kmeds(X, K, init_medoids=init, seed=1)
print(f"KMEDS      energy={base.energy:.2f} distances={base.n_distances:,}")

for eps in (0.0, 0.01, 0.1):
    r = trikmeds(X, K, eps=eps, seed=1, init_medoids=init)
    print(f"trikmeds-{eps:<4} energy={r.energy:.2f} "
          f"distances={r.n_distances:,} "
          f"({base.n_distances / r.n_distances:.1f}x fewer) "
          f"iters={r.n_iterations}")

# medoids are actual data points — print them
r = trikmeds(X, K, seed=1, init_medoids=init)
print("medoid coordinates (first 4):")
print(np.asarray(X[r.medoids[:4]]).round(2))

# --- device-side path: batched multi-cluster trimed engine (DESIGN.md §3)
# One jitted program runs all K per-cluster searches concurrently; the
# quadratic "scan" path is the same Voronoi iteration with a brute-force
# medoid update, for comparison.
Xf = X.astype(np.float32)
dev_t = kmedoids_batched(Xf, K, seed=1, n_iter=8, medoid_update="trimed")
dev_s = kmedoids_batched(Xf, K, seed=1, n_iter=8, medoid_update="scan")
# the survivor-compacted pipelined engine (DESIGN.md §4) as the update
# step: one X-stream per round, shrinking working set
dev_p = kmedoids_batched(Xf, K, seed=1, n_iter=8, medoid_update="pipelined")
print(f"\ndevice trimed engine: energy={dev_t.energy:.2f} "
      f"distances={dev_t.n_distances:,}")
print(f"device pipelined engine: energy={dev_p.energy:.2f} "
      f"distances={dev_p.n_distances:,}")
print(f"device quadratic scan: energy={dev_s.energy:.2f} "
      f"distances={dev_s.n_distances:,} "
      f"({dev_s.n_distances / dev_t.n_distances:.1f}x more)")

# per-cluster medoids of any fixed assignment go through the front door
# too (the planner picks the batched engine) — with the adaptive
# geometric block schedule warming the incumbents (clustered data is
# where the warm-up pays, DESIGN.md §4)
eng = solve(MedoidQuery(Xf, k=K, assignments=dev_t.assignment,
                        block_schedule="geometric"))
print(f"standalone engine [{eng.plan.engine}]: computed "
      f"{eng.elements_computed:.0f}/{len(X)} rows "
      f"in {eng.n_rounds} rounds; medoids match: "
      f"{np.array_equal(np.sort(eng.indices), np.sort(dev_t.medoids))}")

# --- anytime / budgeted queries: the bandit subsystem (DESIGN.md §9).
# budget= (or mode="anytime") routes the query to the sampled-column race
# with the exact pipelined finisher; the SolveReport carries the residual
# CI and the certificate flag.
q = solve(MedoidQuery(Xf, budget=150.0, seed=1))
print(f"\nbandit hybrid (budget 150) [{q.plan.engine}]: index={q.index} "
      f"energy={q.energy:.3f} ci={q.ci:.3f} certified={q.certified} "
      f"elements={q.elements_computed:.0f}")
q = solve(MedoidQuery(Xf, mode="anytime", seed=1))
print(f"bandit hybrid (unbudgeted): certified={q.certified} "
      f"elements={q.elements_computed:.0f}")

# a nested anytime MedoidQuery as the medoid_update is the paper's
# relaxed K-medoids (§5): each cluster's update runs the budgeted race
# instead of an exact engine — minor quality loss, large cost savings,
# any metric.
dev_b = kmedoids_batched(Xf, K, seed=1, n_iter=8,
                         medoid_update=MedoidQuery(None, mode="anytime"))
print(f"device bandit update: energy={dev_b.energy:.2f} "
      f"distances={dev_b.n_distances:,} "
      f"({dev_s.n_distances / dev_b.n_distances:.0f}x fewer than scan, "
      f"energy +{100 * (dev_b.energy / dev_t.energy - 1):.2f}%)")
