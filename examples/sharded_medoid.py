"""Sharded medoid search across devices (DESIGN.md §11).

Shards X's columns over a 1-axis mesh, runs the survivor-compacted
pipelined round per shard, and psum/all_gather-reduces only the tiny
replicated state — the answer is bit-identical to the single-device
engine. On a machine with one real device, simulate a pod first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/sharded_medoid.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import jax

from repro.api import MedoidQuery, solve
from repro.compat import make_1d_mesh

rng = np.random.default_rng(0)
X = rng.random((20_000, 4)).astype(np.float32)

print(f"{jax.device_count()} device(s) visible")

# 1) device_policy="sharded" forces the sharded engine (a default mesh
#    over all local devices is built for you); with >1 device and large
#    N the planner picks it on its own under device_policy="auto".
plan = solve(MedoidQuery(X, device_policy="sharded"), explain=True)
print(f"planner chose {plan.engine!r} on {plan.params['n_shards']} "
      f"shard(s): {'; '.join(plan.reasons)}")
rep = solve(MedoidQuery(X, device_policy="sharded"))
per = rep.plan.params["per_shard_elements"]
print(f"sharded        medoid={rep.index} energy={rep.energy:.5f} "
      f"computed={rep.elements_computed:.0f} per-shard={per}")

# 2) bit-identical to the single-device pipelined engine — same pivot
#    sequence, same energies, same computed-element count
ref = solve(MedoidQuery(X), plan="pipelined")
assert rep.index == ref.index
assert rep.energy == ref.energy
assert rep.elements_computed == ref.elements_computed
print(f"single-device  medoid={ref.index} energy={ref.energy:.5f} — "
      "bit-identical")

# 3) explicit meshes work too (any shard count dividing 48)
mesh = make_1d_mesh(min(2, jax.device_count()))
r2 = solve(MedoidQuery(X, device_policy="sharded", mesh=mesh))
assert r2.energy == ref.energy

# 4) K-medoids with the sharded medoid-update: K concurrent per-cluster
#    searches, columns sharded across the mesh each iteration
rk = solve(MedoidQuery(X[:4000], k=8, n_iter=3, device_policy="sharded"))
print(f"kmedoids       update={rk.plan.params['medoid_update']!r} "
      f"energy={rk.extras['total_energy']:.1f} "
      f"computed={rk.elements_computed:.0f}")

# 5) non-triangle metrics fall back to a row-sharded exact scan
rc = solve(MedoidQuery(X[:4000], metric="cosine", device_policy="sharded"))
print(f"cosine scan    medoid={rc.index} shards="
      f"{rc.plan.params['n_shards']}")
print("OK")
