"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on CPU, with checkpoint/restart mid-run (fault-tolerance
drill) and loss-curve verification.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import shutil

ap = argparse.ArgumentParser()
# CPU-feasible defaults (~2-5 min). For the full ~100M-param run on real
# hardware: --d-model 768 --layers 12 --batch 32 --seq 512 --vocab 32000.
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--d-model", type=int, default=192)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--vocab", type=int, default=1024)
args = ap.parse_args()

from repro.configs.base import ShapeSpec, get_config
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

# qwen3-family member (qk-norm GQA + SwiGLU); ~100M at --d-model 768
cfg = get_config("qwen3_4b").replace(
    n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=4,
    head_dim=48, d_ff=args.d_model * 4, vocab=args.vocab, dtype="float32",
    attn_chunk=128,
)
n_params = (cfg.vocab * cfg.d_model * 2
            + cfg.n_layers * (cfg.d_model * (8 + 4 + 4) * 48
                              + 8 * 48 * cfg.d_model
                              + 3 * cfg.d_model * cfg.d_ff))
print(f"model: {cfg.n_layers}L d={cfg.d_model} ~{n_params/1e6:.1f}M params")

shape = ShapeSpec("e2e", args.seq, args.batch, "train")
ckpt_dir = "checkpoints/train_lm_example"
shutil.rmtree(ckpt_dir, ignore_errors=True)

opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
tc = TrainerConfig(steps=args.steps, log_every=5,
                   ckpt_every=args.steps // 3, ckpt_dir=ckpt_dir)

# phase 1: train to ~2/3, then simulate a crash
trainer = Trainer(cfg, shape, opt, tc, seed=0)
log1 = trainer.run(steps=2 * args.steps // 3)

# phase 2: new process would restore from checkpoint — emulate that
print("--- simulated restart: restoring latest checkpoint ---")
trainer2 = Trainer(cfg, shape, opt, tc, seed=0)
resumed = trainer2.maybe_restore()
print(f"resumed at step {resumed}")
log2 = trainer2.run()

first = log1[0]["loss"]
last = log2[-1]["loss"]
print(f"loss: {first:.3f} -> {last:.3f}")
# threshold scaled to run length (default 120 steps drops ~>0.8 nats)
min_drop = 0.1 if args.steps < 100 else 0.5
assert last < first - min_drop, "training did not reduce loss"
print("OK — end-to-end training with restart works")
