"""Paper-technique-in-the-loop: HuBERT-style masked prediction where the
training targets are trikmeds MEDOID cluster codes of frame embeddings
(upstream HuBERT uses k-means — medoids are metric-general and robust).

    PYTHONPATH=src python examples/hubert_pseudolabel.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.data.pseudolabel import assign_targets, build_codebook
from repro.models import model as M
from repro.optim import adamw

cfg = get_smoke_config("hubert_xlarge").replace(vocab=32)
rng = np.random.default_rng(0)

# 1) calibration pass: pool frame embeddings, build the medoid codebook
calib = rng.standard_normal((2000, M.FRAME_DIM)).astype(np.float32)
codebook, med_idx = build_codebook(calib, k=cfg.vocab, seed=0)
print(f"codebook: {codebook.shape[0]} medoid codes "
      f"(elements {med_idx[:6]}...)")

# 2) label a training batch by nearest-medoid assignment
B, S = 4, 128
frames = rng.standard_normal((B, S, M.FRAME_DIM)).astype(np.float32)
targets = assign_targets(frames, codebook)
print(f"targets: shape={targets.shape}, "
      f"{len(np.unique(targets))} distinct codes used")

# 3) masked-prediction training steps
params = M.init_params(cfg, jax.random.PRNGKey(0))
opt_cfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=5, total_steps=60)
opt_state = adamw.init_state(params)


@jax.jit
def step(params, opt_state, frames, mask, targets):
    def loss_fn(p):
        return M.train_loss(cfg, p, {"frames": frames, "mask": mask,
                                     "targets": targets})
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params, opt_state, _ = adamw.apply_updates(opt_cfg, params, grads,
                                               opt_state)
    return params, opt_state, loss


mask = jnp.asarray(rng.random((B, S)) < 0.4)
losses = []
for i in range(60):
    params, opt_state, loss = step(params, opt_state,
                                   jnp.asarray(frames), mask,
                                   jnp.asarray(targets))
    losses.append(float(loss))
    if i % 10 == 0:
        print(f"step {i:3d} masked-prediction loss {loss:.4f}")
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
assert losses[-1] < losses[0]
print("OK — trikmeds pseudo-labels train the encoder")
