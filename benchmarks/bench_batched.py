"""Batched multi-cluster trimed engine vs the quadratic medoid-update
scan (EXPERIMENTS.md §Batched).

Runs the device-side K-medoids (`core.trikmeds.kmedoids_batched`) twice
per cell — once with ``medoid_update="trimed"`` (the engine,
DESIGN.md §3) and once with ``medoid_update="scan"`` (blockwise
quadratic) — and records the distance-computation counts, their ratio,
and the final energies. Both paths run the identical assignment step, so
the ratio isolates the medoid-update cost, the quantity the paper's §5
application is about. Energies must agree: both updates are exact per
iteration, so any gap beyond fp32 noise is a bug."""
from __future__ import annotations

import numpy as np

from repro.core import kmedoids_batched

from .common import save_csv, timed


def _clustered(n, d, k_true, seed):
    rng = np.random.default_rng(seed)
    centers = rng.random((k_true, d)) * 10
    idx = rng.integers(0, k_true, n)
    return (centers[idx]
            + rng.standard_normal((n, d)) * 0.5).astype(np.float32)


def run(quick: bool = True):
    sizes = [2048, 4096] if quick else [4096, 8192, 16384]
    ks = [8, 32]
    n_iter = 5 if quick else 8
    rows = []
    for n in sizes:
        # 3-d, matching the paper's low-intrinsic-dimension regime (the
        # bound machinery weakens as intrinsic dimension grows — Fig. 3)
        X = _clustered(n, 3, max(ks), seed=n)
        for k in ks:
            rt, t_tri = timed(kmedoids_batched, X, k, seed=0,
                              n_iter=n_iter, medoid_update="trimed")
            rs, t_scan = timed(kmedoids_batched, X, k, seed=0,
                               n_iter=n_iter, medoid_update="scan")
            ratio = rs.n_distances / rt.n_distances
            rows.append([
                n, k, n_iter, rt.n_distances, rs.n_distances,
                round(ratio, 2), round(rt.energy, 2), round(rs.energy, 2),
                round(t_tri * 1e3), round(t_scan * 1e3),
            ])
            print(f"batched N={n} K={k}: engine={rt.n_distances:,} "
                  f"scan={rs.n_distances:,} ({ratio:.1f}x fewer) "
                  f"E_engine={rt.energy:.1f} E_scan={rs.energy:.1f}")
            assert rt.n_distances < rs.n_distances, (
                f"engine must beat the quadratic scan at N={n}")
    path = save_csv("batched", ["N", "K", "iters", "dist_engine",
                                "dist_scan", "ratio", "E_engine", "E_scan",
                                "ms_engine", "ms_scan"], rows)
    return rows, path
