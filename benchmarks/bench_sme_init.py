"""Paper SM-E (Table 3): Park-Jun init vs uniform random init for KMEDS.

Gaussian-mixture proxies for the S/A-set datasets. Reports
mu_uniform / mu_parkjun (mean final energy ratio over `reps` uniform
runs; < 1 means uniform wins — the paper's finding for larger K)."""
from __future__ import annotations

import numpy as np

from repro.core import kmeds

from .common import save_csv


def _mixture(n, k_true, d, spread, seed):
    rng = np.random.default_rng(seed)
    centers = rng.random((k_true, d)) * 10
    idx = rng.integers(0, k_true, n)
    return centers[idx] + rng.standard_normal((n, d)) * spread


def run(quick: bool = True):
    n = 1000 if quick else 5000
    reps = 3 if quick else 10
    datasets = {
        "s1_like": _mixture(n, 15, 2, 0.35, 0),
        "a1_like": _mixture(n, 20, 2, 0.25, 1),
        "gauss8d": _mixture(n, 10, 8, 0.5, 2),
    }
    rows = []
    for name, X in datasets.items():
        for k in (10, int(np.ceil(np.sqrt(n)))):
            park = kmeds(X, k, init="parkjun", seed=0)
            unis = [kmeds(X, k, init="uniform", seed=s).energy
                    for s in range(reps)]
            ratio = float(np.mean(unis)) / park.energy
            rows.append([name, n, k, round(park.energy, 3),
                         round(float(np.mean(unis)), 3), round(ratio, 3)])
            print(f"sme {name:10s} K={k:3d}: mu_u/mu_park={ratio:.3f}")
    path = save_csv("sme_init", ["dataset", "N", "K", "parkjun_E",
                                 "uniform_E_mean", "ratio_u_over_park"],
                    rows)
    return rows, path


if __name__ == "__main__":
    run()
