"""Fault-tolerance overhead: what does resumability cost? (DESIGN.md §13)

Three numbers per size, all on the single-device pipelined engine:

* ``wall_plain_s`` — the straight-through solve (one host loop, no
  segmentation; ``seg_cap`` is traced so this shares its compiled
  program with the segmented runs);
* ``wall_segmented_s`` — segmented at round granularity with a
  ``SolveState`` checkpoint written every segment (the fully paranoid
  configuration; real deployments amortise with ``checkpoint_every``);
* ``wall_resume_s`` — kill the solve mid-flight (injected
  ``fail_round`` at roughly half the round count) and resume from the
  checkpoint to completion: the *recovery* cost, which bounds how much
  work a preemption can waste.

``identical`` asserts the tentpole invariant along the way: plain,
segmented, and killed-and-resumed runs report the same index, energy
and element count. Not part of the CI smoke/regression set — the
overhead ratio is host- and filesystem-dependent; run it where you
deploy.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from .common import save_csv

SIZES_QUICK = [(1025, 8), (4097, 8)]
SIZES_FULL = [(4097, 16), (16385, 16), (65537, 16)]

HEADER = ["n", "d", "rounds", "wall_plain_s", "wall_segmented_s",
          "wall_resume_s", "segment_overhead_x", "identical"]


def _sig(r):
    return (r.index, r.energy, r.n_computed)


def run(quick: bool = True, mode: str | None = None):
    from repro.core.pipelined import _trimed_pipelined
    from repro.runtime import faults

    rows = []
    for n, d in (SIZES_QUICK if quick else SIZES_FULL):
        X = np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)
        _trimed_pipelined(X)                              # compile, warm
        t0 = time.perf_counter()
        ref = _trimed_pipelined(X)
        wall_plain = time.perf_counter() - t0

        with tempfile.TemporaryDirectory() as td:
            t0 = time.perf_counter()
            seg = _trimed_pipelined(X, checkpoint=td, checkpoint_every=1)
            wall_seg = time.perf_counter() - t0

        kill = max(int(ref.n_rounds) // 2, 1)
        with tempfile.TemporaryDirectory() as td:
            try:
                with faults.inject(faults.FaultSpec(fail_round=kill)):
                    _trimed_pipelined(X, checkpoint=td, checkpoint_every=1)
            except faults.FaultError:
                pass
            t0 = time.perf_counter()
            res = _trimed_pipelined(X, checkpoint=td, checkpoint_every=1,
                                    resume="require")
            wall_resume = time.perf_counter() - t0

        identical = _sig(ref) == _sig(seg) == _sig(res)
        rows.append([n, d, int(ref.n_rounds), f"{wall_plain:.4f}",
                     f"{wall_seg:.4f}", f"{wall_resume:.4f}",
                     f"{wall_seg / max(wall_plain, 1e-9):.2f}",
                     identical])
        assert identical, f"fault-tolerance parity broke at n={n}"
    path = save_csv("bench_faults", HEADER, rows)
    return rows, path
