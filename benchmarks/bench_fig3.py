"""Paper Figure 3 (+ SM-F Figure 4): computed elements vs N and d.

Left: uniform [0,1]^d for d in {2,...,6}; right: shell-weighted unit
ball for d in {2, 6}. Reports n_computed for trimed (sequential
paper-faithful AND block TPU variant) vs TOPRANK, and the sqrt(N) fit
constant xi = n_computed / sqrt(N)."""
from __future__ import annotations

import numpy as np

from repro.core import toprank

from .common import save_csv, shell_ball, timed, timed_solve


def run(quick: bool = True):
    ns = [1000, 4000, 16000] if quick else [1000, 4000, 16000, 64000]
    dims = [2, 4, 6]
    rows = []
    for dist in ("uniform", "shell"):
        for d in dims if dist == "uniform" else [2, 6]:
            for n in ns:
                rng = np.random.default_rng(n + d)
                X = (rng.random((n, d)) if dist == "uniform"
                     else shell_ball(n, d, seed=n + d))
                X = X.astype(np.float32)
                from repro.api import MedoidQuery
                r_seq, t_seq = timed_solve(MedoidQuery(X, seed=0),
                                           plan="sequential", warm=False)
                r_blk, t_blk = timed_solve(MedoidQuery(X, seed=0, block=128),
                                           plan="block")
                r_top, t_top = timed(toprank, X, seed=0)
                assert r_seq.index == r_blk.index == r_top.index
                n_seq = int(r_seq.elements_computed)
                n_blk = int(r_blk.elements_computed)
                xi = n_blk / np.sqrt(n)
                rows.append([
                    dist, d, n, n_seq, n_blk,
                    r_top.n_computed, round(xi, 2),
                    round(t_seq * 1e6 / n), round(t_blk * 1e6 / n),
                ])
                print(f"fig3 {dist} d={d} N={n}: seq={n_seq} "
                      f"blk={n_blk} toprank={r_top.n_computed} "
                      f"xi={xi:.1f}")
    path = save_csv("fig3", ["dist", "d", "N", "ncomp_seq", "ncomp_block",
                             "ncomp_toprank", "xi_sqrtN",
                             "us_per_elem_seq", "us_per_elem_block"], rows)
    return rows, path


if __name__ == "__main__":
    import sys

    if "--graph" in sys.argv:
        # graph-mode scaling sweep (sweeps vs N, xi fit): delegate to
        # bench_graph, which emits the BENCH_graph.json artifact
        from . import bench_graph

        bench_graph.run(quick="--full" not in sys.argv,
                        mode="smoke" if "--smoke" in sys.argv else None)
    else:
        run()
