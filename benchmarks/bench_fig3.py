"""Paper Figure 3 (+ SM-F Figure 4): computed elements vs N and d.

Left: uniform [0,1]^d for d in {2,...,6}; right: shell-weighted unit
ball for d in {2, 6}. Reports n_computed for trimed (sequential
paper-faithful AND block TPU variant) vs TOPRANK, and the sqrt(N) fit
constant xi = n_computed / sqrt(N)."""
from __future__ import annotations

import numpy as np

from repro.core import toprank, trimed_block, trimed_sequential

from .common import save_csv, shell_ball, timed


def run(quick: bool = True):
    ns = [1000, 4000, 16000] if quick else [1000, 4000, 16000, 64000]
    dims = [2, 4, 6]
    rows = []
    for dist in ("uniform", "shell"):
        for d in dims if dist == "uniform" else [2, 6]:
            for n in ns:
                rng = np.random.default_rng(n + d)
                X = (rng.random((n, d)) if dist == "uniform"
                     else shell_ball(n, d, seed=n + d))
                X = X.astype(np.float32)
                r_seq, t_seq = timed(trimed_sequential, X, seed=0)
                r_blk, t_blk = timed(trimed_block, X, block=128, seed=0)
                r_top, t_top = timed(toprank, X, seed=0)
                assert r_seq.index == r_blk.index == r_top.index
                xi = r_blk.n_computed / np.sqrt(n)
                rows.append([
                    dist, d, n, r_seq.n_computed, r_blk.n_computed,
                    r_top.n_computed, round(xi, 2),
                    round(t_seq * 1e6 / n), round(t_blk * 1e6 / n),
                ])
                print(f"fig3 {dist} d={d} N={n}: seq={r_seq.n_computed} "
                      f"blk={r_blk.n_computed} toprank={r_top.n_computed} "
                      f"xi={xi:.1f}")
    path = save_csv("fig3", ["dist", "d", "N", "ncomp_seq", "ncomp_block",
                             "ncomp_toprank", "xi_sqrtN",
                             "us_per_elem_seq", "us_per_elem_block"], rows)
    return rows, path


if __name__ == "__main__":
    run()
