"""CI perf-regression gate over the benchmark smoke outputs.

    PYTHONPATH=src python -m benchmarks.check_regression

Compares the smoke-mode benchmark JSONs (written under ``results/`` by
``python -m benchmarks.run --smoke``) against the committed baselines in
``benchmarks/baselines/`` and **fails** (exit 1) when a tracked cost
counter regresses by more than ``TOLERANCE``. Wall-clock is deliberately
not gated (CI machines are noisy); the gated fields are the
deterministic work counters the engines are built around:

* ``bench_trimed``: ``full_x_streams_per_round`` (the HBM-traffic model
  — the pipelined engine's 1-stream-per-round claim) and ``n_computed``
  (computed elements, the paper's cost axis);
* ``bench_bandit``: ``elements`` (unified computed elements per engine
  cell);
* ``bench_serve``: ``elements_total`` (the packed path's summed
  per-query accounting — deterministic for the seeded batch, so growth
  means the packed engine started doing extra work) and, in the
  *opposite direction*, ``speedup_vs_sequential`` (batch throughput
  relative to a sequential ``solve()`` loop — a higher-is-better field
  that fails when it *drops* more than ``TOLERANCE`` below the
  committed baseline; wall-clock ratios wash out machine speed, and the
  committed baseline is deliberately conservative to keep the gate
  deflaked);
* ``bench_obs``: ``elements`` (the traced solve must do identical
  work) and the **absolute** ceiling ``trace_overhead_ratio <=
  OBS_OVERHEAD_MAX`` — tracing on may cost at most 5% of solve
  wall-clock over tracing off. This one is a ratio of two walls on the
  *same* machine in the *same* process, so it is gated absolutely, not
  against a committed baseline.
* ``bench_stream``: ``amortized_elements_per_op`` / ``repair_elements``
  (the streaming index's churn-repair cost) against the baseline, plus
  two **absolute** gates — ``exact == 1`` (every record must match a
  fresh solve bit-for-bit) and ``vs_fresh_ratio <=
  STREAM_VS_FRESH_MAX`` (repair must stay under 15% of re-solving at
  every query). Both are properties of the run itself, deterministic
  for the seeded stream.
* ``bench_graph``: ``sweeps`` (SSSP sweeps, the paper's distance-
  calculation unit mapped to graphs) against the baseline, plus two
  **absolute** gates — ``exact == 1`` for every record (graph-engine
  index must match the certified sequential host solve) and, on grid
  networks with ``n >= GRAPH_GATE_MIN_N`` (the N=2048 acceptance
  cell), ``sweep_frac <= GRAPH_SWEEP_FRAC_MAX`` — the exact graph
  medoid must cost at most half a brute-force scan.

Records are matched by their identity fields; a record present in the
baseline but missing from the current run also fails (an engine cell
silently dropping out of the sweep is a regression of coverage, not a
win). Regenerate the baselines deliberately with::

    PYTHONPATH=src python -m benchmarks.run --smoke
    cp results/BENCH_trimed_smoke.json results/BENCH_bandit_smoke.json \\
        results/BENCH_serve_smoke.json results/BENCH_obs_smoke.json \\
        results/BENCH_stream_smoke.json results/BENCH_graph_smoke.json \\
        benchmarks/baselines/
    cp results/TRACE_smoke.jsonl benchmarks/baselines/TRACE_golden.jsonl

(then halve the serve baseline's speedup field by hand if the run was on
an unusually fast machine — see ``serve_smoke.json`` provenance note).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
BASELINE_DIR = Path(__file__).resolve().parent / "baselines"
RESULTS_DIR = ROOT / "results"

TOLERANCE = 0.10          # >10% growth of a cost counter fails the gate
OBS_OVERHEAD_MAX = 1.05   # tracing on must stay within 5% of tracing off
STREAM_VS_FRESH_MAX = 0.15  # streaming repair <= 15% of re-solve/query
GRAPH_SWEEP_FRAC_MAX = 0.5  # exact graph medoid <= 0.5 N sweeps (grid,
GRAPH_GATE_MIN_N = 2000     # ... at the N=2048 acceptance cell)

# file -> (identity fields, lower-is-better cost fields,
#          higher-is-better throughput fields)
GATES = {
    "BENCH_trimed_smoke.json": (("engine", "n", "d"),
                                ("full_x_streams_per_round", "n_computed"),
                                ()),
    "BENCH_bandit_smoke.json": (("engine", "n", "d", "budget_elements"),
                                ("elements",),
                                ()),
    "BENCH_serve_smoke.json": (("config", "batch", "d"),
                               ("elements_total",),
                               ("speedup_vs_sequential",)),
    "BENCH_obs_smoke.json": (("config", "n", "d"),
                             ("elements",),
                             ()),
    "BENCH_stream_smoke.json": (("config", "n", "d", "metric",
                                 "turnover"),
                                ("amortized_elements_per_op",
                                 "repair_elements"),
                                ()),
    "BENCH_graph_smoke.json": (("config", "network", "n", "n_landmarks"),
                               ("sweeps",),
                               ()),
}


def check_obs_overhead() -> list[str]:
    """Absolute gate: smoke ``trace_overhead_ratio <= OBS_OVERHEAD_MAX``
    for every record (no baseline involved — same-machine ratio)."""
    cur_path = RESULTS_DIR / "BENCH_obs_smoke.json"
    if not cur_path.exists():
        return [f"BENCH_obs_smoke.json: missing {cur_path} "
                "(run `python -m benchmarks.run --smoke` first)"]
    failures = []
    for r in json.loads(cur_path.read_text()).get("records", []):
        ratio = r.get("trace_overhead_ratio")
        if ratio is None:
            failures.append(f"BENCH_obs_smoke.json: {r.get('config')} "
                            "missing trace_overhead_ratio")
        elif float(ratio) > OBS_OVERHEAD_MAX:
            failures.append(
                f"BENCH_obs_smoke.json: {r.get('config')} tracing "
                f"overhead {ratio}x exceeds the {OBS_OVERHEAD_MAX}x "
                "ceiling (tracing must stay <=5% of solve wall-clock)")
    return failures


def check_stream_economy() -> list[str]:
    """Absolute gates on the streaming index smoke: every record must
    be ``exact`` (bit-for-bit fresh-solve parity — economy numbers
    from an inexact index are meaningless) and serve churn at
    ``vs_fresh_ratio <= STREAM_VS_FRESH_MAX`` (no baseline involved —
    both are properties of the run itself)."""
    cur_path = RESULTS_DIR / "BENCH_stream_smoke.json"
    if not cur_path.exists():
        return [f"BENCH_stream_smoke.json: missing {cur_path} "
                "(run `python -m benchmarks.run --smoke` first)"]
    failures = []
    for r in json.loads(cur_path.read_text()).get("records", []):
        cfg = r.get("config")
        if r.get("exact") != 1:
            failures.append(
                f"BENCH_stream_smoke.json: {cfg} is NOT exact — "
                "streaming query() diverged from a fresh solve")
        ratio = r.get("vs_fresh_ratio")
        if ratio is None:
            failures.append(f"BENCH_stream_smoke.json: {cfg} missing "
                            "vs_fresh_ratio")
        elif float(ratio) > STREAM_VS_FRESH_MAX:
            failures.append(
                f"BENCH_stream_smoke.json: {cfg} repair cost "
                f"{ratio}x of a fresh solve exceeds the "
                f"{STREAM_VS_FRESH_MAX}x ceiling")
    return failures


def check_graph_gates() -> list[str]:
    """Absolute gates on the graph-engine smoke: every record must be
    ``exact`` (graph-engine index == certified sequential host solve),
    and the grid acceptance cells (``network == "grid"``, ``n >=
    GRAPH_GATE_MIN_N``) must finish within ``GRAPH_SWEEP_FRAC_MAX`` of
    a brute-force scan's sweeps (no baseline involved — both are
    properties of the seeded run itself)."""
    cur_path = RESULTS_DIR / "BENCH_graph_smoke.json"
    if not cur_path.exists():
        return [f"BENCH_graph_smoke.json: missing {cur_path} "
                "(run `python -m benchmarks.run --smoke` first)"]
    failures = []
    for r in json.loads(cur_path.read_text()).get("records", []):
        cfg = r.get("config")
        if r.get("exact") != 1:
            failures.append(
                f"BENCH_graph_smoke.json: {cfg} is NOT exact — graph "
                "engine diverged from the sequential host solve")
        frac = r.get("sweep_frac")
        if frac is None:
            failures.append(f"BENCH_graph_smoke.json: {cfg} missing "
                            "sweep_frac")
        elif (r.get("network") == "grid"
              and int(r.get("n", 0)) >= GRAPH_GATE_MIN_N
              and float(frac) > GRAPH_SWEEP_FRAC_MAX):
            failures.append(
                f"BENCH_graph_smoke.json: {cfg} sweep fraction {frac} "
                f"exceeds the {GRAPH_SWEEP_FRAC_MAX} ceiling (exact "
                "graph medoid must beat half a brute-force scan)")
    return failures


def _index(records, id_fields):
    return {tuple(r.get(f) for f in id_fields): r for r in records}


def check_file(name: str, id_fields, cost_fields,
               throughput_fields=()) -> list[str]:
    failures: list[str] = []
    base_path = BASELINE_DIR / name
    cur_path = RESULTS_DIR / name
    if not base_path.exists():
        return [f"{name}: missing committed baseline {base_path}"]
    if not cur_path.exists():
        return [f"{name}: missing current smoke output {cur_path} "
                "(run `python -m benchmarks.run --smoke` first)"]
    base = json.loads(base_path.read_text())
    cur = json.loads(cur_path.read_text())
    if base.get("schema") != cur.get("schema"):
        failures.append(f"{name}: schema drift "
                        f"{base.get('schema')} -> {cur.get('schema')}")
    cur_by_id = _index(cur.get("records", []), id_fields)
    for key, b in _index(base.get("records", []), id_fields).items():
        c = cur_by_id.get(key)
        ident = dict(zip(id_fields, key))
        if c is None:
            failures.append(f"{name}: baseline record {ident} missing "
                            "from the current run")
            continue
        for f in cost_fields + tuple(throughput_fields):
            bv, cv = b.get(f), c.get(f)
            if bv is None or cv is None:
                failures.append(f"{name}: {ident} field {f!r} absent "
                                f"(baseline={bv}, current={cv})")
                continue
            if f in cost_fields:
                if float(cv) > float(bv) * (1.0 + TOLERANCE) + 1e-12:
                    failures.append(
                        f"{name}: {ident} {f} regressed "
                        f"{bv} -> {cv} (>{TOLERANCE:.0%} over baseline)")
            elif float(cv) < float(bv) * (1.0 - TOLERANCE) - 1e-12:
                failures.append(
                    f"{name}: {ident} {f} (higher is better) dropped "
                    f"{bv} -> {cv} (>{TOLERANCE:.0%} below baseline)")
    return failures


def main(argv=None) -> int:
    del argv
    failures: list[str] = []
    for name, (id_fields, cost_fields, tp_fields) in GATES.items():
        failures.extend(check_file(name, id_fields, cost_fields, tp_fields))
    failures.extend(check_obs_overhead())
    failures.extend(check_stream_economy())
    failures.extend(check_graph_gates())
    if failures:
        print("PERF REGRESSION GATE: FAIL")
        for f in failures:
            print(f"  - {f}")
        return 1
    n = len(GATES)
    print(f"PERF REGRESSION GATE: OK ({n} benchmark files within "
          f"{TOLERANCE:.0%} of committed baselines)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
