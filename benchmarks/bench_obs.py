"""Tracing overhead: solve() with telemetry on vs off (DESIGN.md §14).

The observability contract is that tracing rides the engine's existing
host-visible segment boundaries — zero extra device→host syncs, and the
``trace=None`` path compiles to the exact same program as before the
subsystem existed. This bench measures what the *enabled* path costs:
the same pipelined solve twice, trace off then trace on (JSONL file
exporter recording every round), timed in order-alternating adjacent
pairs with ``min(on)/min(off)`` as the gated number — both solves are
deterministic work, so scheduler noise is additive and min-of-k
converges on the true cost from above.

``trace_overhead_ratio = wall_on / wall_off`` is the gated number:
``check_regression.py`` fails CI when the smoke value exceeds the
absolute ``1.05`` ceiling (tracing must stay ≤ 5% of solve wall-clock).
``elements`` is asserted identical across the two runs — the traced
solve must do bit-identical work, not just return the same index.

Smoke mode also writes ``results/TRACE_smoke.jsonl`` — the real trace
from the traced run — which ``run.py --smoke`` validates against the
committed golden trace (``benchmarks/baselines/TRACE_golden.jsonl``)
structurally, and CI uploads as an artifact next to the BENCH JSONs.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from .common import RESULTS_DIR, save_csv

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_obs.json"

FIELDS = ["config", "n", "d", "repeats", "wall_off_s", "wall_on_s",
          "trace_overhead_ratio", "events", "rounds", "elements"]

REPEATS = 24


def json_path_for(mode: str | None) -> Path:
    """Smoke runs must not clobber the committed perf-trajectory file."""
    if mode == "smoke":
        return RESULTS_DIR / "BENCH_obs_smoke.json"
    return JSON_PATH


def trace_path_for(mode: str | None) -> Path:
    name = "TRACE_smoke.jsonl" if mode == "smoke" else "TRACE_obs.jsonl"
    return RESULTS_DIR / name


def _bench_config(config, n, d, trace_path, seed=0):
    from repro.api import MedoidQuery, solve

    X = np.random.default_rng(seed).standard_normal((n, d)).astype(
        np.float32)
    q_off = MedoidQuery(X)
    q_on = MedoidQuery(X, trace=str(trace_path))

    # warm both compiled programs, then measure in *adjacent pairs*
    # whose order flips every iteration (off/on, on/off, ...) so drift
    # hits both sides equally. Both solves are deterministic work, so
    # scheduler noise is purely additive — min-of-k is the standard
    # estimator (cf. timeit), and the gated ratio is min(on)/min(off).
    rep_off = solve(q_off, plan="pipelined")
    rep_on = solve(q_on, plan="pipelined")
    offs, ons = [], []
    for i in range(REPEATS):
        first_off = i % 2 == 0
        for off_side in (first_off, not first_off):
            t0 = time.perf_counter()
            if off_side:
                rep_off = solve(q_off, plan="pipelined")
                offs.append(time.perf_counter() - t0)
            else:
                rep_on = solve(q_on, plan="pipelined")
                ons.append(time.perf_counter() - t0)
    wall_off, wall_on = min(offs), min(ons)
    ratio = wall_on / wall_off

    assert rep_on.index == rep_off.index
    assert rep_on.elements_computed == rep_off.elements_computed, \
        "traced solve did different work"
    events = rep_on.extras["obs"]["trace"]["n_events"]
    return {
        "config": config, "n": n, "d": d, "repeats": REPEATS,
        "wall_off_s": round(wall_off, 5),
        "wall_on_s": round(wall_on, 5),
        "trace_overhead_ratio": round(ratio, 4),
        "events": events,
        "rounds": int(rep_on.n_rounds),
        "elements": rep_on.elements_computed,
    }


def run(quick: bool = True, mode: str | None = None):
    """Returns ``(rows, csv_path)`` like every bench; also writes the
    ``bench_obs/v1`` JSON and the traced run's JSONL."""
    if mode == "smoke":
        # big enough that per-round compute (~ms) dominates the fixed
        # per-round telemetry dispatch cost (~tens of µs) — the regime
        # the 5% gate is about; at 4k the ratio sits right on the gate
        configs = [("smoke-8k", 8192, 32)]
    elif quick:
        configs = [("quick-4k", 4096, 32)]
    else:
        configs = [("full-4k", 4096, 32), ("full-16k", 16384, 32)]

    RESULTS_DIR.mkdir(exist_ok=True)
    trace_path = trace_path_for(mode)
    rows, records = [], []
    for config, n, d in configs:
        rec = _bench_config(config, n, d, trace_path)
        records.append(rec)
        rows.append([rec[f] for f in FIELDS])
        print(f"  {config}: n={n} overhead "
              f"{rec['trace_overhead_ratio']:.3f}x "
              f"({rec['events']} events over {rec['rounds']} rounds)")

    payload = {"schema": "bench_obs/v1", "fields": FIELDS,
               "records": records,
               "methodology": "warm; %d order-alternating off/on pairs; "
                              "ratio = min(on)/min(off); trace on = "
                              "JSONL exporter, per-round events; "
                              "identical elements asserted" % REPEATS}
    out_json = json_path_for(mode)
    out_json.parent.mkdir(exist_ok=True)
    out_json.write_text(json.dumps(payload, indent=1) + "\n")
    csv_name = "obs_smoke" if mode == "smoke" else "obs"
    path = save_csv(csv_name, FIELDS, rows)
    return rows, path


if __name__ == "__main__":
    import sys

    rows, path = run(quick="--full" not in sys.argv,
                     mode="smoke" if "--smoke" in sys.argv else None)
    print(f"{len(rows)} rows -> {path} and {JSON_PATH}")
