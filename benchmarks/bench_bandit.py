"""Regret-vs-budget sweep for the bandit medoid subsystem (DESIGN.md §9).

Emits machine-readable ``BENCH_bandit.json`` at the repo root (plus the
usual CSV under ``results/``). Per N, the exact pipelined engine sets the
cost yardstick; the bandit engines (UCB race, correlated sequential
halving) and the budget-capped hybrid (``bandit_medoid(exact="trimed")``)
are swept over budgets expressed as fractions of the pipelined element
count, next to the paper's approximate baselines RAND and TOPRANK. All
costs are *unified computed elements* (``distances.elements_computed``:
full rows = 1, sampled partial columns fractional), so
bandit-vs-trimed-vs-TOPRANK numbers are apples-to-apples; regret is
``(E(found) - E*) / E*`` in float64.

The hybrid's headline cell (tracked across PRs): at ``N = 8192`` the
budget-capped hybrid must compute ``<= 0.5x`` the elements of
``trimed_pipelined`` with energy regret ``< 1e-3``.

``mode="smoke"`` (``benchmarks/run.py --smoke``) runs a tiny sweep,
validating the JSON schema and every engine entrypoint in CI.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from .common import RESULTS_DIR, save_csv, timed, timed_solve

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_bandit.json"

FIELDS = ["engine", "n", "d", "budget_elements", "elements", "regret",
          "index_match", "certified", "wall_s"]

BUDGET_FRACS = (0.15, 0.3, 0.45)


def json_path_for(mode: str | None) -> Path:
    """Smoke runs must not clobber the committed perf-trajectory file."""
    if mode == "smoke":
        return RESULTS_DIR / "BENCH_bandit_smoke.json"
    return JSON_PATH


def _exact_energies64(X):
    """Float64 energies (S/N), blockwise so N=16384 stays in memory."""
    X = np.asarray(X, np.float64)
    n = X.shape[0]
    sq = np.einsum("nd,nd->n", X, X)
    out = np.zeros(n)
    blk = 1024
    for s in range(0, n, blk):
        xb = X[s:s + blk]
        d2 = sq[s:s + blk][:, None] + sq[None, :] - 2.0 * (xb @ X.T)
        out[s:s + blk] = np.sqrt(np.maximum(d2, 0.0)).sum(axis=1)
    return out / n


def _cell(engine, n, d, budget, elements, regret, match, certified, wall):
    return {"engine": engine, "n": n, "d": d,
            "budget_elements": None if budget is None else round(budget, 2),
            "elements": round(float(elements), 2),
            "regret": float(regret), "index_match": bool(match),
            "certified": bool(certified), "wall_s": round(wall, 4)}


def run(quick: bool = True, mode: str | None = None):
    """Returns ``(rows, csv_path)`` like every bench; also writes
    ``BENCH_bandit.json``."""
    from repro.api import MedoidQuery
    from repro.core.baselines import rand_medoid, toprank

    if mode == "smoke":
        sizes, d = [256], 3
    elif quick:
        sizes, d = [2048, 8192], 3
    else:
        sizes, d = [2048, 8192, 16384], 3

    rng = np.random.default_rng(0)
    records = []
    for n in sizes:
        X = rng.random((n, d)).astype(np.float32)
        e64 = _exact_energies64(X)
        ti, e_star = int(np.argmin(e64)), float(e64.min())

        def regret_of(idx):
            return (float(e64[idx]) - e_star) / e_star

        # exact yardstick -------------------------------------------------
        p, dt = timed_solve(MedoidQuery(X), plan="pipelined")
        p_elems = float(p.elements_computed)
        records.append(_cell("pipelined", n, d, None, p_elems,
                             regret_of(p.index), p.index == ti, True, dt))

        # budget sweep: pure bandits + the hybrid -------------------------
        for frac in BUDGET_FRACS:
            budget = max(frac * p_elems, 16.0)
            for name, plan, opts in (
                ("bandit-ucb", "bandit", {"engine": "ucb"}),
                ("bandit-halving", "bandit", {"engine": "halving"}),
                ("hybrid", "hybrid", {}),
            ):
                q = MedoidQuery(X, budget=budget, seed=0, engine_opts=opts)
                r, dt = timed_solve(q, plan=plan, warm=False)
                records.append(_cell(name, n, d, budget,
                                     r.elements_computed,
                                     regret_of(r.index), r.index == ti,
                                     r.certified, dt))

        # unbudgeted hybrid: the certified anytime path -------------------
        r, dt = timed_solve(MedoidQuery(X, mode="anytime", seed=0),
                            plan="hybrid", warm=False)
        records.append(_cell("hybrid-certified", n, d, None,
                             r.elements_computed, regret_of(r.index),
                             r.index == ti, r.certified, dt))

        # the paper's approximate baselines (host-side) -------------------
        if mode == "smoke" or n <= 8192:
            r, dt = timed(rand_medoid, X, epsilon=0.1, seed=0)
            records.append(_cell("RAND", n, d, None, r.n_computed,
                                 regret_of(r.index), r.index == ti,
                                 False, dt))
            r, dt = timed(toprank, X, seed=0)
            records.append(_cell("TOPRANK", n, d, None, r.n_computed,
                                 regret_of(r.index), r.index == ti,
                                 False, dt))

    # the tracked acceptance cell: budget-capped hybrid at the largest N
    n_head = max(sizes)
    head = [r for r in records
            if r["engine"] == "hybrid" and r["n"] == n_head]
    p_head = next(r for r in records
                  if r["engine"] == "pipelined" and r["n"] == n_head)
    headline = {
        "n": n_head,
        "best_hybrid_elements": min(r["elements"] for r in head),
        "pipelined_elements": p_head["elements"],
        "element_ratio": round(min(r["elements"] for r in head)
                               / p_head["elements"], 4),
        "max_hybrid_regret": max(r["regret"] for r in head),
    }

    payload = {"schema": "bench_bandit/v1", "budget_fracs": list(BUDGET_FRACS),
               "fields": FIELDS, "headline": headline, "records": records}
    out_json = json_path_for(mode)
    out_json.parent.mkdir(exist_ok=True)
    out_json.write_text(json.dumps(payload, indent=1) + "\n")

    rows = [[rec[f] for f in FIELDS] for rec in records]
    csv_name = "bandit_regret_smoke" if mode == "smoke" else "bandit_regret"
    path = save_csv(csv_name, FIELDS, rows)
    return rows, path


if __name__ == "__main__":
    import sys

    rows, path = run(quick="--full" not in sys.argv,
                     mode="smoke" if "--smoke" in sys.argv else None)
    print(f"{len(rows)} rows -> {path} and {JSON_PATH}")
