"""Paper Table 1: medoid algorithms on real/simulated dataset proxies.

The paper's offline datasets (Birch, Europe, road/rail networks, MNIST,
Gnutella) are not available in this container, so each is replaced by a
structurally matched synthetic proxy (documented in EXPERIMENTS.md):

  birch1-like   2-d grid of gaussian clusters (10x10)
  europe-like   2-d boundary-curve point cloud
  u-sensor      undirected random geometric graph (largest component)
  d-sensor      directed random geometric graph (largest SCC)
  rail-like     2-d graph: grid roads + long-range rail edges
  mnist-like    784-d: random 10-prototype mixture, heavy overlap (high d)
  gnutella-like small-world graph (high intrinsic dimension)

Reported: mean computed elements (n_hat) over `seeds` runs per
algorithm, matching the paper's cost unit."""
from __future__ import annotations

import numpy as np

from repro.core import toprank, toprank2
from repro.core.graph import GraphOracle, largest_component, sensor_network

from .common import save_csv


def _birch_like(n, seed):
    rng = np.random.default_rng(seed)
    g = 10
    centers = np.stack(np.meshgrid(np.arange(g), np.arange(g)),
                       -1).reshape(-1, 2).astype(float)
    idx = rng.integers(0, g * g, n)
    return centers[idx] + rng.standard_normal((n, 2)) * 0.15


def _europe_like(n, seed):
    rng = np.random.default_rng(seed)
    t = rng.random(n) * 2 * np.pi
    r = 1.0 + 0.35 * np.sin(3 * t) + 0.15 * np.sin(7 * t)
    pts = np.stack([r * np.cos(t), 0.7 * r * np.sin(t)], 1)
    return pts + rng.standard_normal((n, 2)) * 0.02


def _mnist_like(n, seed, d=784):
    rng = np.random.default_rng(seed)
    protos = rng.standard_normal((10, d)) * 1.2
    idx = rng.integers(0, 10, n)
    return protos[idx] + rng.standard_normal((n, d))


def _rail_like(n, seed):
    """2-d spatial graph: local geometric edges + sparse long edges."""
    g, pts = sensor_network(n, seed=seed, radius_scale=1.6)
    rng = np.random.default_rng(seed + 1)
    adj = {k: list(v) for k, v in g.adj.items()}
    m = g.n
    for _ in range(m // 50):  # express links
        i, j = rng.integers(0, m, 2)
        w = float(np.linalg.norm(pts[i] - pts[j])) * 0.3
        adj[i].append((j, w))
        adj[j].append((i, w))
    return GraphOracle(adj, m)


def _smallworld(n, seed, k=6, p=0.1):
    rng = np.random.default_rng(seed)
    adj = {i: [] for i in range(n)}
    for i in range(n):
        for off in range(1, k // 2 + 1):
            j = (i + off) % n
            if rng.random() < p:
                j = int(rng.integers(0, n))
            adj[i].append((j, 1.0))
            adj[j].append((i, 1.0))
    adj, keep = largest_component(adj, n)
    return GraphOracle(adj, len(keep))


def run(quick: bool = True):
    # quick sizes keep TOPRANK's ~N Dijkstra sweeps CPU-feasible
    n = 2000 if quick else 20000
    seeds = 2 if quick else 10
    datasets = {
        "birch1_like": lambda s: _birch_like(n, s),
        "europe_like": lambda s: _europe_like(n, s),
        "u_sensor": lambda s: sensor_network(n, seed=s,
                                             radius_scale=1.6)[0],
        "d_sensor": lambda s: sensor_network(n, seed=s, directed=True,
                                             radius_scale=2.0)[0],
        "rail_like": lambda s: _rail_like(n, s),
        "mnist_like": lambda s: _mnist_like(min(n, 2000), s),
        "gnutella_like": lambda s: _smallworld(min(n, 2000), s),
    }
    rows = []
    for name, make in datasets.items():
        counts = {"trimed": [], "toprank": [], "toprank2": []}
        size = None
        for s in range(seeds):
            data = make(s)
            size = data.n if isinstance(data, GraphOracle) else len(data)
            if isinstance(data, GraphOracle):
                oracles = [GraphOracle(data.adj, data.n) for _ in range(3)]
            else:
                from repro.core.distances import VectorOracle
                oracles = [VectorOracle(data) for _ in range(3)]
            from repro.api import MedoidQuery, solve
            r_tr = solve(MedoidQuery(oracles[0], seed=s),
                         plan="sequential").extras["raw"]
            r_tp = toprank(oracles[1], seed=s)
            r_t2 = toprank2(oracles[2], seed=s)
            assert r_tr.index == r_tp.index == r_t2.index, name
            counts["trimed"].append(r_tr.n_computed)
            counts["toprank"].append(r_tp.n_computed)
            counts["toprank2"].append(r_t2.n_computed)
        rows.append([name, size,
                     round(np.mean(counts["toprank"])),
                     round(np.mean(counts["toprank2"])),
                     round(np.mean(counts["trimed"]))])
        print(f"table1 {name:15s} N={size}: toprank="
              f"{rows[-1][2]} toprank2={rows[-1][3]} trimed={rows[-1][4]}")
    path = save_csv("table1", ["dataset", "N", "toprank_nhat",
                               "toprank2_nhat", "trimed_nhat"], rows)
    return rows, path


if __name__ == "__main__":
    import sys

    if "--graph" in sys.argv:
        # network-experiments protocol: delegate to bench_graph, which
        # emits the BENCH_graph.json artifact EXPERIMENTS.md tabulates
        from . import bench_graph

        bench_graph.run(quick="--full" not in sys.argv,
                        mode="smoke" if "--smoke" in sys.argv else None)
    else:
        run()
