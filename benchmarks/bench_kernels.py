"""Kernel micro-bench: trimed round variants.

On this CPU container the Pallas kernels run in interpret mode (Python),
so wall-clock is reported for the jnp/XLA-CPU paths; the Pallas paths
are validated for correctness and their HBM-traffic *model* is reported
(the quantity that matters on the TPU target): materialised round moves
(B*N + N*d + B*d) * 4 bytes through HBM, the fused round moves
(2*N*d + 2*N) * 4 (no D block) — the ratio is the predicted TPU win for
memory-bound regimes."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.distances import pairwise, sq_norms

from .common import save_csv, timed


def run(quick: bool = True):
    rows = []
    cases = [(128, 65536, 8), (128, 262144, 8), (128, 65536, 128)]
    if not quick:
        cases.append((128, 1048576, 8))
    for b, n, d in cases:
        rng = np.random.default_rng(0)
        xb = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
        xsq = sq_norms(x)

        @jax.jit
        def jnp_round(xb, x, xsq):
            dblk = pairwise(xb, x, "l2", b_sq=xsq)
            e = dblk.sum(axis=1) / x.shape[0]
            gap = jnp.abs(e[:, None] - dblk)
            return e, gap.max(axis=0)

        jnp_round(xb, x, xsq)[0].block_until_ready()
        _, dt = timed(lambda: jax.block_until_ready(jnp_round(xb, x, xsq)),
                      repeats=3)
        mat_bytes = (b * n + n * d + b * d + n) * 4
        fused_bytes = (2 * n * d + 2 * n + 2 * b * d) * 4
        rows.append([f"round_b{b}_n{n}_d{d}", round(dt * 1e6),
                     mat_bytes, fused_bytes,
                     round(mat_bytes / fused_bytes, 2)])
        print(f"kernels b={b} n={n} d={d}: {dt*1e3:.1f} ms/round, "
              f"HBM model {mat_bytes/1e6:.0f}MB -> {fused_bytes/1e6:.0f}MB "
              f"({mat_bytes/fused_bytes:.1f}x)")
    path = save_csv("kernels", ["name", "us_per_call", "hbm_bytes_mat",
                                "hbm_bytes_fused", "predicted_tpu_win"],
                    rows)
    return rows, path


if __name__ == "__main__":
    run()
