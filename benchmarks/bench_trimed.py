"""Trimed engine sweep: scan vs block vs pipelined (DESIGN.md §4).

Emits machine-readable ``BENCH_trimed.json`` at the repo root (plus the
usual CSV under ``results/``) so the perf trajectory is tracked across
PRs. Per engine and N: wall-clock, computed rows, scalar distances, and
the HBM-model X-streams per round (full passes over ``X`` plus the
compacted fold columns, normalised by ``N``; the block engine's fused
kernels cost exactly 2.0 on this model, the pipelined engine 1 + M/N).

At ``N >= 4096`` the sweep additionally times both engines through the
Pallas kernels on the **interpret path** (``block-kernels`` /
``pipelined-kernels`` rows) — there the kernel/tile count dominates, so
the one-stream round shows up directly as wall-clock.

``mode="smoke"`` (``benchmarks/run.py --smoke``) runs a tiny sweep that
also exercises the interpret path, validating the JSON schema and every
engine entrypoint in CI.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from .common import RESULTS_DIR, save_csv, timed_solve

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_trimed.json"


def json_path_for(mode: str | None) -> Path:
    """Smoke runs must not clobber the committed perf-trajectory file."""
    if mode == "smoke":
        return RESULTS_DIR / "BENCH_trimed_smoke.json"
    return JSON_PATH

FIELDS = ["engine", "n", "d", "wall_s", "n_computed", "n_rounds",
          "n_distances", "full_x_streams_per_round", "x_streams_per_round",
          "index"]


def _run_scan(X, block):
    from repro.core.distances import exact_energies

    t0 = time.perf_counter()
    e = np.asarray(exact_energies(X))
    dt = time.perf_counter() - t0
    n = len(X)
    return dict(wall_s=dt, n_computed=n, n_rounds=1, n_distances=n * n,
                full_x_streams_per_round=float(n),
                x_streams_per_round=float(n), index=int(np.argmin(e)))


def _run_block(X, block, kernels=False):
    from repro.api import MedoidQuery

    q = MedoidQuery(X, block=block, use_kernels=kernels)
    rep, dt = timed_solve(q, plan="block")
    r = rep.extras["raw"]
    return dict(wall_s=dt, n_computed=r.n_computed, n_rounds=r.n_rounds,
                n_distances=r.n_distances,
                full_x_streams_per_round=2.0,              # fused-kernel model
                x_streams_per_round=2.0,
                index=r.index)


def _run_pipelined(X, block, kernels=False, schedule=None):
    from repro.api import MedoidQuery

    q = MedoidQuery(X, block=block, use_kernels=kernels,
                    block_schedule=schedule)
    rep, dt = timed_solve(q, plan="pipelined")
    r = rep.extras["raw"]
    # every pipelined round issues exactly ONE full pass over X (the
    # energy floor); x_streams_per_round adds the compacted fold columns
    spr = r.x_cols_streamed / max(r.n_rounds * len(X), 1)
    return dict(wall_s=dt, n_computed=r.n_computed, n_rounds=r.n_rounds,
                n_distances=r.n_distances,
                full_x_streams_per_round=1.0,
                x_streams_per_round=round(spr, 4), index=r.index)


def _run_pipelined_warm(X, block, kernels=False):
    """The adaptive geometric warm-up schedule, tracked separately."""
    return _run_pipelined(X, block, kernels, schedule="geometric")


def _run_sharded(X, block, kernels=False):
    """The multi-device sharded engine (DESIGN.md §11); only swept when
    more than one device is visible (e.g. under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``). Counters
    are bit-identical to the pipelined row — that equality is itself
    part of the bench contract (the index assert below)."""
    from repro.api import MedoidQuery

    q = MedoidQuery(X, block=block, use_kernels=kernels,
                    device_policy="sharded")
    rep, dt = timed_solve(q, plan="sharded")
    r = rep.extras["raw"]
    spr = r.x_cols_streamed / max(r.n_rounds * len(X), 1)
    return dict(wall_s=dt, n_computed=r.n_computed, n_rounds=r.n_rounds,
                n_distances=r.n_distances,
                full_x_streams_per_round=1.0,
                x_streams_per_round=round(spr, 4), index=r.index)


def run(quick: bool = True, mode: str | None = None):
    """Returns ``(rows, csv_path)`` like every bench; also writes
    ``BENCH_trimed.json``."""
    if mode == "smoke":
        sizes, d, block, kernel_min = [512], 3, 32, 0
    elif quick:
        sizes, d, block, kernel_min = [1024, 2048, 4096, 8192], 3, 128, 4096
    else:
        sizes, d, block, kernel_min = ([1024, 2048, 4096, 8192, 16384,
                                        32768], 3, 128, 4096)

    rng = np.random.default_rng(0)
    rows, records = [], []
    for n in sizes:
        X = rng.random((n, d)).astype(np.float32)
        blk = min(block, n)
        cells = [("scan", _run_scan, False),
                 ("block", _run_block, False),
                 ("pipelined", _run_pipelined, False),
                 ("pipelined-warm", _run_pipelined_warm, False)]
        if n >= kernel_min:                    # Pallas interpret path
            cells += [("block-kernels", _run_block, True),
                      ("pipelined-kernels", _run_pipelined, True)]
        import jax
        if jax.device_count() > 1:             # multi-device hosts only
            cells += [("sharded", _run_sharded, False)]
        indices = {}
        for name, fn, kernels in cells:
            rec = {"engine": name, "n": n, "d": d,
                   **(fn(X, blk, kernels) if fn is not _run_scan
                      else fn(X, blk))}
            indices[name] = rec["index"]
            records.append(rec)
            rows.append([rec[f] for f in FIELDS])
        # exactness across engines is part of the bench contract
        assert len(set(indices.values())) == 1, indices

    payload = {"schema": "bench_trimed/v1", "block": block,
               "fields": FIELDS, "records": records}
    out_json = json_path_for(mode)
    out_json.parent.mkdir(exist_ok=True)
    out_json.write_text(json.dumps(payload, indent=1) + "\n")
    csv_name = "trimed_engines_smoke" if mode == "smoke" else "trimed_engines"
    path = save_csv(csv_name, FIELDS, rows)
    return rows, path


if __name__ == "__main__":
    import sys

    rows, path = run(quick="--full" not in sys.argv,
                     mode="smoke" if "--smoke" in sys.argv else None)
    print(f"{len(rows)} rows -> {path} and {JSON_PATH}")
