"""Streaming index economy: amortised repair cost vs fresh re-solve.

The streaming index (``repro.stream``, DESIGN.md §15) claims that a
churning dataset can be served exactly — every ``query()`` bit-for-bit
a fresh ``solve()`` — at a fraction of re-solve cost. This bench
measures that fraction: starting from a solved index, a stream of
single-point op+query cycles (delete one random row, insert one random
row, query) runs until ``turnover`` of the dataset has churned, and
the repair cost is read off the index's own accounting (the unified
computed-row currency every engine reports).

``vs_fresh_ratio`` is the headline: mean repair elements per *query*
over the elements a fresh pipelined solve of the same set computes —
i.e. what serving the stream cost relative to re-solving at every
query. ``check_regression.py`` gates it absolutely (``<= 0.15`` at 1%
turnover) and gates ``amortized_elements_per_op`` against the
committed baseline; ``exact`` (final query vs fresh solve parity,
index/energy/certificate) is gated at exactly 1 — economy numbers from
an inexact index would be meaningless.

The first cycles after a build pay a warm-up slab: rows compacted away
by the sub-quadratic build carry only the incumbent-energy bound, so
the first deletes re-admit a slab whose exact energies the repair then
caches — visible as ``full_resolves``/high early cost, amortised out
by steady state (~1 row per op). The turnover sweep in full mode shows
where amortisation stops winning.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from .common import RESULTS_DIR, save_csv

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_stream.json"

FIELDS = ["config", "n", "d", "metric", "turnover", "ops", "queries",
          "repair_elements", "fresh_elements",
          "amortized_elements_per_op", "vs_fresh_ratio",
          "full_resolves", "invalidated", "exact"]


def json_path_for(mode: str | None) -> Path:
    """Smoke runs must not clobber the committed perf-trajectory file."""
    if mode == "smoke":
        return RESULTS_DIR / "BENCH_stream_smoke.json"
    return JSON_PATH


def _bench_config(config, n, d, metric, turnover, seed=0):
    from repro.core.pipelined import _trimed_pipelined
    from repro.stream import MedoidIndex

    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    idx = MedoidIndex.from_data(X, metric=metric)
    idx.query()                       # the build itself is not churn

    cycles = max(1, int(round(turnover * n)))
    before = idx.stats["elements_total"]
    resolves0 = idx.stats["full_resolves"]
    for _ in range(cycles):           # one op = one single-point change
        pos = int(rng.integers(0, idx.n))
        X = np.delete(X, pos, axis=0)
        idx.delete([pos])
        row = rng.standard_normal((1, d)).astype(np.float32)
        X = np.concatenate([X, row])
        idx.insert(row)
        idx.query()
    repair = float(idx.stats["elements_total"] - before)

    fresh = _trimed_pipelined(X, metric=metric)
    res = idx.query()
    exact = int((res.index, res.energy, res.certified)
                == (fresh.index, fresh.energy, fresh.certified))
    ops = 2 * cycles
    return {
        "config": config, "n": n, "d": d, "metric": metric,
        "turnover": turnover, "ops": ops, "queries": cycles,
        "repair_elements": round(repair, 1),
        "fresh_elements": int(fresh.n_computed),
        "amortized_elements_per_op": round(repair / ops, 3),
        "vs_fresh_ratio": round(repair / cycles / fresh.n_computed, 4),
        "full_resolves": int(idx.stats["full_resolves"] - resolves0),
        "invalidated": int(idx.stats["invalidated"]),
        "exact": exact,
    }


def run(quick: bool = True, mode: str | None = None):
    """Returns ``(rows, csv_path)`` like every bench; also writes the
    ``bench_stream/v1`` JSON."""
    if mode == "smoke":
        configs = [("smoke-1k", 1024, 3, "l2", 0.01),
                   ("smoke-1k-2pct", 1024, 3, "l2", 0.02)]
    elif quick:
        configs = [("quick-4k", 4096, 2, "l2", 0.01)]
    else:
        # the acceptance cell (8192, d=2, l2, 1%) plus a turnover sweep
        configs = [("full-8k", 8192, 2, "l2", t)
                   for t in (0.005, 0.01, 0.02, 0.05)]

    RESULTS_DIR.mkdir(exist_ok=True)
    rows, records = [], []
    for config, n, d, metric, turnover in configs:
        rec = _bench_config(config, n, d, metric, turnover)
        records.append(rec)
        rows.append([rec[f] for f in FIELDS])
        print(f"  {config}: n={n} turnover={turnover:.1%} repair "
              f"{rec['repair_elements']:.0f} vs fresh "
              f"{rec['fresh_elements']}/query "
              f"({rec['vs_fresh_ratio']:.3f}x, exact={rec['exact']})")

    payload = {"schema": "bench_stream/v1", "fields": FIELDS,
               "records": records,
               "methodology": "warm index; turnover*n single-point "
                              "delete+insert cycles, query after each; "
                              "repair cost from the index's computed-"
                              "row accounting; vs_fresh = mean repair "
                              "elements/query over fresh n_computed; "
                              "exactness asserted against a fresh "
                              "pipelined solve of the final set"}
    out_json = json_path_for(mode)
    out_json.parent.mkdir(exist_ok=True)
    out_json.write_text(json.dumps(payload, indent=1) + "\n")
    csv_name = "stream_smoke" if mode == "smoke" else "stream"
    path = save_csv(csv_name, FIELDS, rows)
    return rows, path


if __name__ == "__main__":
    import sys

    rows, path = run(quick="--full" not in sys.argv,
                     mode="smoke" if "--smoke" in sys.argv else None)
    print(f"wrote {path}")
