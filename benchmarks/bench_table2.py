"""Paper Table 2: trikmeds-eps vs KMEDS distance-calculation counts.

Columns mirror the paper: N_c/N^2 (trikmeds-0 distances relative to
KMEDS's N^2), then phi_c (distances vs eps=0) and phi_E (final energy vs
eps=0) for eps in {0.01, 0.1}, at K = 10 and K = ceil(sqrt(N))."""
from __future__ import annotations

import numpy as np

from repro.core import trikmeds

from .common import save_csv


def _datasets(n, quick):
    rng = np.random.default_rng(0)
    out = {
        "europe_like2d": rng.random((n, 2)),
        "conflong_like3d": rng.random((n, 3)),
        "colormo_like9d": rng.standard_normal((n, 9)),
    }
    if not quick:
        out["mnist50_like"] = rng.standard_normal((n, 50))
    return out


def run(quick: bool = True):
    n = 2000 if quick else 10000
    rows = []
    for name, X in _datasets(n, quick).items():
        for k in (10, int(np.ceil(np.sqrt(n)))):
            init = np.random.default_rng(7).choice(len(X), size=k,
                                                   replace=False)
            res = {}
            for eps in (0.0, 0.01, 0.1):
                res[eps] = trikmeds(X, k, eps=eps, seed=7,
                                    init_medoids=init)
            nc_n2 = res[0.0].n_distances / (len(X) ** 2)
            phi_c1 = res[0.01].n_distances / res[0.0].n_distances
            phi_e1 = res[0.01].energy / res[0.0].energy
            phi_c2 = res[0.1].n_distances / res[0.0].n_distances
            phi_e2 = res[0.1].energy / res[0.0].energy
            rows.append([name, len(X), k, round(nc_n2, 4),
                         round(phi_c1, 3), round(phi_e1, 4),
                         round(phi_c2, 3), round(phi_e2, 4)])
            print(f"table2 {name:16s} K={k:3d}: Nc/N^2={nc_n2:.3f} "
                  f"phi_c(.01)={phi_c1:.2f} phi_E(.01)={phi_e1:.3f} "
                  f"phi_c(.1)={phi_c2:.2f} phi_E(.1)={phi_e2:.3f}")
    path = save_csv("table2", ["dataset", "N", "K", "Nc_over_N2",
                               "phi_c_001", "phi_E_001", "phi_c_01",
                               "phi_E_01"], rows)
    return rows, path


if __name__ == "__main__":
    run()
