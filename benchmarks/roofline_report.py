"""Render results/dryrun.json into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
from pathlib import Path

from .common import RESULTS_DIR


def load(path=None):
    path = Path(path or RESULTS_DIR / "dryrun.json")
    return json.loads(path.read_text())


def table(results: dict, mesh: str = "single") -> str:
    lines = [
        "| arch | shape | kind | compute_s | memory_s | collective_s |"
        " dominant | useful | roofline | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key, v in sorted(results.items()):
        if v.get("mesh") != mesh:
            continue
        if v["status"] == "skipped":
            lines.append(f"| {v['arch']} | {v['shape']} | — | — | — | — | "
                         f"SKIP: {v['reason']} | — | — | — |")
            continue
        if v["status"] != "ok":
            lines.append(f"| {v['arch']} | {v['shape']} | — | — | — | — | "
                         f"ERROR | — | — | — |")
            continue
        r = v["roofline"]
        peak = v["memory"]["peak_bytes"] / 2**30
        lines.append(
            f"| {v['arch']} | {v['shape']} | {v['kind']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} "
            f"| {r['useful_flop_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {peak:.2f} |")
    return "\n".join(lines)


def run(quick: bool = True):
    try:
        res = load()
    except FileNotFoundError:
        print("roofline: results/dryrun.json missing — run "
              "`python -m repro.launch.dryrun` first")
        return [], None
    ok = sum(1 for v in res.values() if v["status"] == "ok")
    skip = sum(1 for v in res.values() if v["status"] == "skipped")
    err = sum(1 for v in res.values() if v["status"] == "error")
    print(f"roofline cells: {ok} ok, {skip} skipped, {err} error")
    for mesh in ("single", "multi"):
        t = table(res, mesh)
        out = RESULTS_DIR / f"roofline_{mesh}.md"
        out.write_text(t + "\n")
        print(f"wrote {out}")
    return [[k, v["status"]] for k, v in res.items()], None


if __name__ == "__main__":
    run()
