"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints a ``name,us_per_call,derived`` CSV summary line per benchmark and
writes detailed CSVs under results/.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny trimed + bandit + serve + obs "
                         "sweeps (interpret path), validates the BENCH_* "
                         "JSON schemas + imports and the JSONL solve "
                         "trace against the committed golden trace; the "
                         "smoke JSONs land in results/ and feed the "
                         "benchmarks.check_regression CI gate")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    quick = not args.full

    from . import (bench_bandit, bench_batched, bench_faults, bench_fig3,
                   bench_graph, bench_kernels, bench_obs, bench_serve,
                   bench_sme_init, bench_stream, bench_table1,
                   bench_table2, bench_trimed, roofline_report)

    if args.smoke:
        # the benches now route every engine through repro.api.solve;
        # check the front door itself first (planner + report schema)
        import numpy as np

        from repro.api import MedoidQuery, solve

        X = np.random.default_rng(0).random((256, 3)).astype(np.float32)
        plan = solve(MedoidQuery(X), explain=True)
        rep = solve(MedoidQuery(X))
        assert rep.plan.engine == plan.engine and rep.certified, rep
        print(f"smoke OK [repro.api]: plan={plan.engine} "
              f"index={rep.index} elements={rep.elements_computed:.0f}")

        checks = [(bench_trimed, "bench_trimed/v1"),
                  (bench_bandit, "bench_bandit/v1"),
                  (bench_serve, "bench_serve/v1"),
                  (bench_obs, "bench_obs/v1"),
                  (bench_stream, "bench_stream/v1"),
                  (bench_graph, "bench_graph/v1")]
        for bench, schema in checks:
            rows, path = bench.run(quick=True, mode="smoke")
            json_path = bench.json_path_for("smoke")
            payload = json.loads(json_path.read_text())
            assert payload["schema"] == schema, payload.get("schema")
            missing = [f for r in payload["records"]
                       for f in payload["fields"] if f not in r]
            assert not missing, f"schema drift: missing {missing}"
            print(f"smoke OK [{schema}]: {len(rows)} rows; "
                  f"json={json_path}; csv={path}")

        # golden-trace schema validation: the smoke trace must validate
        # against the tracer's own invariants AND match the committed
        # golden trace structurally (per-kind key sets, bracketing)
        from pathlib import Path

        from repro.obs.trace import (compare_structure, load_jsonl,
                                     validate_events)

        golden_path = (Path(__file__).resolve().parent / "baselines"
                       / "TRACE_golden.jsonl")
        trace = load_jsonl(bench_obs.trace_path_for("smoke"))
        errs = validate_events(trace)
        assert not errs, f"smoke trace invalid: {errs}"
        errs = compare_structure(trace, load_jsonl(golden_path))
        assert not errs, f"smoke trace drifted from golden: {errs}"
        print(f"smoke OK [{trace[0]['schema']}]: {len(trace)} events "
              f"validate against {golden_path.name}")
        return 0

    benches = {
        "fig3_scaling": bench_fig3.run,
        "table1_datasets": bench_table1.run,
        "table2_trikmeds": bench_table2.run,
        "trimed_engines": bench_trimed.run,
        "bandit_regret": bench_bandit.run,
        "batched_kmedoids": bench_batched.run,
        "serve_throughput": bench_serve.run,
        "fault_overhead": bench_faults.run,
        "obs_overhead": bench_obs.run,
        "stream_churn": bench_stream.run,
        "graph_networks": bench_graph.run,
        "sme_init": bench_sme_init.run,
        "kernels": bench_kernels.run,
        "roofline": roofline_report.run,
    }
    if args.only:
        benches = {k: v for k, v in benches.items() if args.only in k}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        t0 = time.perf_counter()
        try:
            rows, path = fn(quick=quick)
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{name},{dt:.0f},rows={len(rows)};csv={path}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},FAILED,{type(e).__name__}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
