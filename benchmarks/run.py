"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints a ``name,us_per_call,derived`` CSV summary line per benchmark and
writes detailed CSVs under results/.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny trimed sweep (interpret path), "
                         "validates BENCH_trimed.json schema + imports")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    quick = not args.full

    from . import (bench_batched, bench_fig3, bench_kernels, bench_sme_init,
                   bench_table1, bench_table2, bench_trimed,
                   roofline_report)

    if args.smoke:
        rows, path = bench_trimed.run(quick=True, mode="smoke")
        json_path = bench_trimed.json_path_for("smoke")
        payload = json.loads(json_path.read_text())
        assert payload["schema"] == "bench_trimed/v1", payload.get("schema")
        missing = [f for r in payload["records"]
                   for f in payload["fields"] if f not in r]
        assert not missing, f"schema drift: missing {missing}"
        print(f"smoke OK: {len(rows)} rows; json={json_path}; csv={path}")
        return 0

    benches = {
        "fig3_scaling": bench_fig3.run,
        "table1_datasets": bench_table1.run,
        "table2_trikmeds": bench_table2.run,
        "trimed_engines": bench_trimed.run,
        "batched_kmedoids": bench_batched.run,
        "sme_init": bench_sme_init.run,
        "kernels": bench_kernels.run,
        "roofline": roofline_report.run,
    }
    if args.only:
        benches = {k: v for k, v in benches.items() if args.only in k}

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches.items():
        t0 = time.perf_counter()
        try:
            rows, path = fn(quick=quick)
            dt = (time.perf_counter() - t0) * 1e6
            print(f"{name},{dt:.0f},rows={len(rows)};csv={path}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},FAILED,{type(e).__name__}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
