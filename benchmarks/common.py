"""Shared benchmark utilities."""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

RESULTS_DIR = Path(__file__).resolve().parent.parent / "results"


def save_csv(name: str, header: list[str], rows: list[list]):
    RESULTS_DIR.mkdir(exist_ok=True)
    path = RESULTS_DIR / f"{name}.csv"
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


def timed_solve(query, plan=None, repeats: int = 1, warm: bool = True):
    """Time ``repro.api.solve`` — the benchmarks' engine entry point
    since the API redesign (every engine through the front door). One
    unmeasured warm call first so jit compilation stays out of the
    numbers. Returns ``(SolveReport, seconds)``."""
    from repro.api import solve

    if warm:
        solve(query, plan=plan)
    return timed(solve, query, plan=plan, repeats=repeats)


def shell_ball(n: int, d: int, seed: int = 0, inner_prob: float = 1 / 20):
    """Paper SM-F distribution 2: unit ball with density ~19x higher
    beyond radius (1/2)^(1/d)."""
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((n, d))
    g /= np.linalg.norm(g, axis=1, keepdims=True)
    u = rng.random(n) ** (1.0 / d)
    x = g * u[:, None]
    r_in = 0.5 ** (1.0 / d)
    inside = np.linalg.norm(x, axis=1) < r_in
    resample = inside & (rng.random(n) > inner_prob * 10)
    m = resample.sum()
    if m:
        g2 = rng.standard_normal((m, d))
        g2 /= np.linalg.norm(g2, axis=1, keepdims=True)
        u2 = (r_in ** d + rng.random(m) * (1 - r_in ** d)) ** (1.0 / d)
        x[resample] = g2 * u2[:, None]
    return x
