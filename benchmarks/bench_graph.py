"""Graph engine economy: SSSP sweeps vs N on synthetic networks.

The paper's network experiments (Table 1 u-sensor/d-sensor rows, and
the Fig-3 scaling protocol) measure "distance calculations" — for graph
datasets one distance calculation is one single-source shortest-path
(SSSP) *sweep*, the graph analogue of computing a full distance row
(EXPERIMENTS.md §Networks). This bench runs the device graph engine
(``metric="graph"``: batched Bellman-Ford sweeps + landmark bounds,
DESIGN.md §16) against the host ``sequential`` engine (trimed over
per-row Dijkstra, the paper-faithful baseline) and the implied full
scan (``n`` sweeps) on the synthetic generators:

* ``grid``   — jittered 4-neighbour lattice, road-network proxy;
* ``sensor`` — random geometric graph, largest component (paper's
  u-sensor protocol).

Reported per cell: the engine's sweep breakdown (landmark / pivot /
certify), ``sweep_frac = sweeps / N`` (the acceptance axis — the CI
gate requires ``exact == 1`` and ``sweep_frac <= 0.5`` on the N=2048
grid), and the Fig-3 fit constant ``xi = sweeps / sqrt(N)``. ``exact``
asserts index parity between the graph engine and the sequential host
solve — both are certified exact, so disagreement is a bug, not noise.

Full mode (``BENCH_graph.json`` at the repo root, the committed
artifact EXPERIMENTS.md §Networks tabulates) adds larger N for the
scaling fit and a landmark-count sweep at the gate size.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from .common import RESULTS_DIR, save_csv, timed

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_graph.json"

FIELDS = ["config", "network", "n", "n_landmarks", "sweeps",
          "landmark_sweeps", "pivot_sweeps", "certify_rows",
          "relax_iters", "sweep_frac", "xi_sqrtN", "seq_elements",
          "scan_sweeps", "exact", "wall_s"]


def json_path_for(mode: str | None) -> Path:
    """Smoke runs must not clobber the committed perf-trajectory file."""
    if mode == "smoke":
        return RESULTS_DIR / "BENCH_graph_smoke.json"
    return JSON_PATH


def _bench_config(network, n, nl, seed=0):
    from repro.api import MedoidQuery, solve
    from repro.core.graph import GraphOracle, grid_network, sensor_network

    gen = grid_network if network == "grid" else sensor_network
    g, _ = gen(n, seed=seed)
    g_seq = GraphOracle(g.adj, g.n)

    q = MedoidQuery(g, metric="graph", seed=seed,
                    engine_opts={"n_landmarks": nl})
    rep, wall = timed(solve, q)       # wall includes the per-graph jit
    r_seq, _ = timed(solve, MedoidQuery(g_seq, seed=seed),
                     plan="sequential")
    info = rep.extras["graph"]
    sweeps = int(rep.elements_computed)
    return {
        "config": f"{network}-{g.n}-L{nl}", "network": network,
        "n": g.n, "n_landmarks": nl, "sweeps": sweeps,
        "landmark_sweeps": int(info["landmark_sweeps"]),
        "pivot_sweeps": int(info["pivot_sweeps"]),
        "certify_rows": int(info["certify_rows"]),
        "relax_iters": int(info["relax_iters"]),
        "sweep_frac": round(sweeps / g.n, 4),
        "xi_sqrtN": round(sweeps / np.sqrt(g.n), 2),
        "seq_elements": int(r_seq.elements_computed),
        "scan_sweeps": g.n,           # full scan: one SSSP per node
        "exact": int(rep.index == r_seq.index),
        "wall_s": round(wall, 3),
    }


def run(quick: bool = True, mode: str | None = None):
    """Returns ``(rows, csv_path)`` like every bench; also writes the
    ``bench_graph/v1`` JSON."""
    if mode == "smoke":
        # grid-2048 is the acceptance cell the CI gate reads
        configs = [("grid", 512, 8), ("grid", 2048, 8),
                   ("sensor", 600, 8)]
    elif quick:
        configs = [("grid", 512, 8), ("grid", 1024, 8),
                   ("grid", 2048, 8), ("sensor", 800, 8),
                   ("sensor", 1600, 8)]
    else:
        # Fig-3-style N sweep + a landmark-count sweep at the gate size
        configs = ([("grid", n, 8)
                    for n in (512, 1024, 2048, 4096, 8192)]
                   + [("sensor", n, 8) for n in (800, 1600, 3200)]
                   + [("grid", 2048, nl) for nl in (1, 4, 16)])

    RESULTS_DIR.mkdir(exist_ok=True)
    rows, records = [], []
    for network, n, nl in configs:
        rec = _bench_config(network, n, nl)
        records.append(rec)
        rows.append([rec[f] for f in FIELDS])
        print(f"  {rec['config']}: sweeps={rec['sweeps']} "
              f"({rec['sweep_frac']:.3f}N, xi={rec['xi_sqrtN']}) "
              f"seq={rec['seq_elements']} scan={rec['scan_sweeps']} "
              f"exact={rec['exact']}")

    payload = {"schema": "bench_graph/v1", "fields": FIELDS,
               "records": records,
               "methodology": "one distance calculation = one SSSP "
                              "sweep (full source row), the paper's "
                              "cost unit mapped to graphs; graph "
                              "engine = device Bellman-Ford sweeps + "
                              "landmark (ALT) bounds, exactness "
                              "checked against the certified "
                              "sequential host solve; scan_sweeps = n "
                              "is the brute-force reference; "
                              "generators are synthetic proxies "
                              "(EXPERIMENTS.md §Networks documents "
                              "the gap to the paper's OSM data)"}
    out_json = json_path_for(mode)
    out_json.parent.mkdir(exist_ok=True)
    out_json.write_text(json.dumps(payload, indent=1) + "\n")
    csv_name = "graph_smoke" if mode == "smoke" else "graph"
    path = save_csv(csv_name, FIELDS, rows)
    return rows, path


if __name__ == "__main__":
    import sys

    rows, path = run(quick="--full" not in sys.argv,
                     mode="smoke" if "--smoke" in sys.argv else None)
    print(f"wrote {path}")
