"""Many-query serving throughput: solve_many vs sequential solve().

The regime the packed path exists for (DESIGN.md §12): a serving loop of
small medoid queries pays per-call planning, dispatch and host-loop
synchronisation on every ``solve()``; ``solve_many`` amortises all three
across shape buckets. Emits ``BENCH_serve.json`` (schema
``bench_serve/v1``) at the repo root; smoke mode writes
``results/BENCH_serve_smoke.json`` for the CI regression gate.

Two baselines are recorded per config, because the speedup is
regime-dependent and quoting one number would mislead:

* ``speedup_vs_sequential`` — against ``solve()`` in a loop with the
  planner's per-query engine choice (host numpy for tiny N). This is
  the end-to-end serving comparison.
* ``speedup_vs_unpacked`` — against the *same* pipelined engine run one
  query at a time (the bit-identical counterpart every packed report
  records). This isolates pure packing amortisation: identical math,
  shared vs per-query programs.

On accelerator backends, where per-call dispatch (~ms) dwarfs per-query
compute (~us), the unpacked ratio is the >100x headline regime; on a
single-core CPU CI container both paths saturate the core and the
measured ratio is FLOP-bound (single digits). The numbers below are
whatever the current host gives — recorded honestly, gated
conservatively.

Methodology, recorded in the payload:

* both paths are timed **warm** (one unmeasured call per compiled shape
  first) — steady-state serving throughput, not cold start;
* the sequential baselines time a per-shape subsample and extrapolate
  to the full batch (``seq_sampled`` records the subsample size); the
  subsample covers every distinct query shape;
* ``elements_total`` (summed per-query ``elements_computed``) is
  deterministic for the seeded draw and doubles as the accounting gate:
  the packed path must report exactly the work it did.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from .common import RESULTS_DIR, save_csv

JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

FIELDS = ["config", "batch", "d", "shapes", "wall_many_s", "wall_seq_s",
          "wall_unpacked_s", "seq_sampled", "queries_per_s",
          "elements_total", "speedup_vs_sequential", "speedup_vs_unpacked",
          "certified_frac"]


def json_path_for(mode: str | None) -> Path:
    """Smoke runs must not clobber the committed perf-trajectory file."""
    if mode == "smoke":
        return RESULTS_DIR / "BENCH_serve_smoke.json"
    return JSON_PATH


def _make_batch(shapes, batch, d, seed):
    """A seeded batch cycling through the shape list (deterministic, so
    elements_total is an exact regression gate)."""
    from repro.api import MedoidQuery

    rng = np.random.default_rng(seed)
    queries = []
    for i in range(batch):
        n = shapes[i % len(shapes)]
        queries.append(MedoidQuery(
            rng.standard_normal((n, d)).astype(np.float32)))
    return queries


def _time_loop(fn, items):
    t0 = time.perf_counter()
    for it in items:
        fn(it)
    return time.perf_counter() - t0


def _bench_config(config, shapes, batch, d, seq_sample, seed=0):
    from repro.api import solve, solve_many

    queries = _make_batch(shapes, batch, d, seed)

    reports = solve_many(queries)             # warm: compile every bucket
    t0 = time.perf_counter()
    reports = solve_many(queries)
    wall_many = time.perf_counter() - t0

    # subsample covering every distinct shape, scaled back to the batch
    sample = queries[:max(seq_sample, len(shapes))]
    scale = batch / len(sample)

    for q in sample[:len(shapes)]:
        solve(q)                              # warm the per-shape programs
    wall_seq = _time_loop(solve, sample) * scale

    # unpacked counterpart: the bit-identical single-query pipelined run
    # each packed report records (same math, per-query programs)
    def _unpacked(q_and_rep):
        q, rep = q_and_rep
        eq = rep.plan.params["equivalent"]
        return solve(q.with_(engine_opts=eq["engine_opts"]), plan=eq["plan"])

    pairs = list(zip(sample, reports[:len(sample)]))
    for p in pairs[:len(shapes)]:
        _unpacked(p)
    wall_unpacked = _time_loop(_unpacked, pairs) * scale

    elements = sum(r.elements_computed for r in reports)
    return {
        "config": config, "batch": batch, "d": d,
        "shapes": "x".join(str(s) for s in shapes),
        "wall_many_s": round(wall_many, 4),
        "wall_seq_s": round(wall_seq, 4),
        "wall_unpacked_s": round(wall_unpacked, 4),
        "seq_sampled": len(sample),
        "queries_per_s": round(batch / wall_many, 1),
        "elements_total": elements,
        "speedup_vs_sequential": round(wall_seq / wall_many, 2),
        "speedup_vs_unpacked": round(wall_unpacked / wall_many, 2),
        "certified_frac": sum(r.certified for r in reports) / batch,
    }


def run(quick: bool = True, mode: str | None = None):
    """Returns ``(rows, csv_path)`` like every bench; also writes the
    ``bench_serve/v1`` JSON."""
    if mode == "smoke":
        configs = [("smoke-mix", [96, 128], 24, 3, 8)]
    elif quick:
        configs = [("tiny-uniform", [64], 512, 3, 16),
                   ("small-mix", [256, 512], 256, 3, 16)]
    else:
        configs = [("headline-1k", [256, 512, 1024], 1000, 3, 24),
                   ("tiny-uniform", [64], 1024, 3, 24),
                   ("small-uniform", [256], 1000, 3, 24)]

    rows, records = [], []
    for config, shapes, batch, d, seq_sample in configs:
        rec = _bench_config(config, shapes, batch, d, seq_sample)
        records.append(rec)
        rows.append([rec[f] for f in FIELDS])
        print(f"  {config}: batch={batch} "
              f"{rec['queries_per_s']:.0f} q/s, "
              f"{rec['speedup_vs_sequential']:.1f}x vs sequential, "
              f"{rec['speedup_vs_unpacked']:.1f}x vs unpacked")

    payload = {"schema": "bench_serve/v1", "fields": FIELDS,
               "records": records,
               "methodology": "warm steady-state; both sequential "
                              "baselines extrapolated from seq_sampled "
                              "queries covering every distinct shape"}
    out_json = json_path_for(mode)
    out_json.parent.mkdir(exist_ok=True)
    out_json.write_text(json.dumps(payload, indent=1) + "\n")
    csv_name = "serve_smoke" if mode == "smoke" else "serve"
    path = save_csv(csv_name, FIELDS, rows)
    return rows, path


if __name__ == "__main__":
    import sys

    rows, path = run(quick="--full" not in sys.argv,
                     mode="smoke" if "--smoke" in sys.argv else None)
    print(f"{len(rows)} rows -> {path} and {JSON_PATH}")
