"""Bandit medoid subsystem (DESIGN.md §9): hybrid exactness parity with
the sequential oracle, halving recovery on generous budgets, sampled-
column kernel parity, budget-cap semantics, and unified cost accounting."""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.bandit import (bandit_medoid, sequential_halving, ucb_race)
from repro.core import exact_medoid, kmedoids_batched, trimed_pipelined, \
    trimed_sequential
from repro.core.distances import VectorOracle, elements_computed
from repro.kernels import ops, sample_stats
from repro.kernels.ref import sample_stats_ref


def _data(n, d, seed=0):
    return np.random.default_rng(seed).random((n, d))


def _energies64(X, metric="l2"):
    X = np.asarray(X, np.float64)
    if metric == "l2":
        D = np.sqrt(np.maximum(
            ((X[:, None, :] - X[None, :, :]) ** 2).sum(-1), 0))
    else:
        D = np.abs(X[:, None, :] - X[None, :, :]).sum(-1)
    return D.sum(1) / len(X)


# ---------------------------------------------------------------------------
# (1) hybrid exactness: identical index to the sequential oracle
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(16, 400),
    d=st.integers(1, 5),
    seed=st.integers(0, 10_000),
    metric=st.sampled_from(["l2", "l1"]),
    engine=st.sampled_from(["ucb", "halving"]),
    dup=st.booleans(),
)
def test_property_hybrid_matches_sequential(n, d, seed, metric, engine, dup):
    """Property: ``exact="trimed"`` (unbudgeted) returns the true medoid
    — parity with the sequential oracle up to fp32 near-ties, accepted
    by energy — across metrics, engines, seeds and duplicate-heavy
    inputs."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d))
    if dup:                                    # force heavy duplication
        X = X[rng.integers(0, max(2, n // 4), n)]
    e = _energies64(X, metric)
    r = bandit_medoid(X, exact="trimed", engine=engine, metric=metric,
                      seed=seed, block=32)
    rs = trimed_sequential(X, seed=seed, metric=metric)
    assert r.certified
    assert r.exact_energy
    assert e[r.index] <= e.min() * (1 + 1e-5) + 1e-7
    assert abs(e[r.index] - e[rs.index]) <= e.min() * 1e-5 + 1e-7


def test_hybrid_exact_medium_n():
    X = _data(1500, 3, seed=2).astype(np.float32)
    ti, _ = exact_medoid(X)
    r = bandit_medoid(X, exact="trimed", seed=0)
    assert r.index == ti and r.certified and r.ci == 0.0
    # energy is reported on the paper's S/(N-1) scale (distances.py)
    ref = trimed_pipelined(X)
    np.testing.assert_allclose(r.energy, ref.energy, rtol=1e-5)


def test_hybrid_seed_bounds_probabilistic_certificate():
    X = _data(1500, 3, seed=3).astype(np.float32)
    ti, _ = exact_medoid(X)
    r = bandit_medoid(X, exact="trimed", seed_bounds=True, seed=0)
    assert r.index == ti
    assert not r.certified            # 1-delta certificate, flagged as such
    assert r.extras["finisher_certified"]


# ---------------------------------------------------------------------------
# (2) sequential halving: generous budget recovers the true medoid
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [1, 3, 4])
def test_halving_generous_budget_recovers(seed):
    """Fixed seeds (deterministic: numpy data seed + jax threefry sample
    stream): a generous budget returns the exact medoid index."""
    rng = np.random.default_rng(seed)
    X = rng.random((600, 3)).astype(np.float32)
    ti, _ = exact_medoid(X)
    h = sequential_halving(X, budget=350.0, seed=seed)
    assert h.index == ti
    assert h.n_computed < 600          # and still cheaper than one scan


@pytest.mark.parametrize("seed", [0, 2, 5])
def test_halving_near_tie_regret_bounded(seed):
    """Seeds where an early-round coin flip between energy near-ties can
    drop the true medoid: the returned arm's regret stays tiny (SH is a
    w.h.p. method; these are its misses and they must be benign)."""
    rng = np.random.default_rng(seed)
    X = rng.random((600, 3)).astype(np.float32)
    e = _energies64(X)
    h = sequential_halving(X, budget=350.0, seed=seed)
    assert (e[h.index] - e.min()) / e.min() < 5e-3


def test_halving_budget_respected():
    X = _data(512, 2, seed=5)
    h = sequential_halving(X, budget=40.0, seed=0)
    # first round is always granted; beyond that the budget binds
    assert h.n_computed <= 2 * 40.0
    assert len(h.survivors) >= 1


# ---------------------------------------------------------------------------
# (3) sampled-column kernels match the jnp reference
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 300),
    s=st.integers(1, 150),
    d=st.integers(1, 140),
    seed=st.integers(0, 1000),
    metric=st.sampled_from(["l2", "l1", "sqeuclidean"]),
)
def test_property_sample_stats_kernel_matches_ref(m, s, d, seed, metric):
    rng = np.random.default_rng(seed)
    xa = rng.standard_normal((m, d)).astype(np.float32)
    xs = rng.standard_normal((s, d)).astype(np.float32)
    got = sample_stats(jnp.asarray(xa), jnp.asarray(xs), metric=metric)
    want = sample_stats_ref(jnp.asarray(xa), jnp.asarray(xs), metric)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=3e-5, atol=3e-5)


def test_race_kernel_path_matches_jnp_decisions():
    """Same seed, kernel vs jnp sampled stats: identical survivor sets
    (the kernel is numerically equivalent on the interpret path)."""
    X = _data(900, 4, seed=7).astype(np.float32)
    r1 = ucb_race(X, budget=80.0, target=32, seed=11)
    r2 = ucb_race(X, budget=80.0, target=32, seed=11, use_kernels=True)
    assert set(r1.survivors.tolist()) == set(r2.survivors.tolist())
    np.testing.assert_allclose(r1.means, r2.means, rtol=1e-4)


# ---------------------------------------------------------------------------
# budget-cap / anytime semantics
# ---------------------------------------------------------------------------
def test_budget_capped_hybrid_reports_uncertainty():
    X = _data(2000, 3, seed=0).astype(np.float32)
    r = bandit_medoid(X, budget=250.0, exact="trimed", seed=0)
    assert not r.certified
    assert r.exact_energy             # the incumbent's row was computed
    assert r.ci > 0.0                 # residual (index, energy, CI) triple
    assert r.n_computed <= 250.0 + 2 * 128   # soft cap: block-granular
    e = _energies64(X)
    assert e[r.index] <= e.min() + 1e-3 * max(e.min(), 1.0)


def test_pure_bandit_triple():
    X = _data(1024, 3, seed=1).astype(np.float32)
    r = bandit_medoid(X, budget=120.0, exact=None, seed=0)
    assert not r.certified and not r.exact_energy
    assert r.ci > 0.0 and np.isfinite(r.energy)
    e = _energies64(X)
    # estimate within a few CI of the truth
    assert abs(r.energy - e[r.index] * 1024 / 1023) <= 4 * r.ci


def test_tiny_n_falls_back_to_exact():
    X = _data(40, 2, seed=4)
    ti, _ = exact_medoid(X)
    r = bandit_medoid(X, budget=5.0, exact=None)
    assert r.index == ti and r.certified
    assert r.extras["fallback"] == "trimed_pipelined"


def test_non_triangle_metric_rules():
    X = _data(300, 3, seed=6)
    with pytest.raises(ValueError):
        bandit_medoid(X, exact="trimed", metric="cosine")
    r = bandit_medoid(X, exact=None, metric="cosine", budget=50.0)
    assert 0 <= r.index < 300
    # the sampled-column kernel has no cosine tile: the engines must
    # auto-fall back to the jnp path rather than crash
    rk = bandit_medoid(X, exact=None, metric="cosine", budget=50.0,
                       use_kernels=True)
    assert rk.index == r.index


def test_halving_ci_is_nan_and_seed_bounds_rejected():
    X = _data(300, 3, seed=7)
    h = bandit_medoid(X, exact=None, engine="halving", budget=40.0)
    assert np.isnan(h.ci)             # unknown uncertainty, not "certified"
    with pytest.raises(ValueError):
        bandit_medoid(X, exact="trimed", engine="halving", seed_bounds=True)


# ---------------------------------------------------------------------------
# finisher plumbing in the pipelined engine
# ---------------------------------------------------------------------------
def test_pipelined_budget_cap_and_certified_flag():
    X = _data(3000, 2, seed=8)
    full = trimed_pipelined(X, block=64)
    assert full.certified
    capped = trimed_pipelined(X, block=64, max_computed=full.n_computed // 3)
    assert not capped.certified
    assert capped.n_computed <= full.n_computed // 3
    warm = trimed_pipelined(X, block=64, warm_idx=np.array([full.index]))
    assert warm.certified and warm.index == full.index


# ---------------------------------------------------------------------------
# unified cost accounting (distances.elements_computed everywhere)
# ---------------------------------------------------------------------------
def test_elements_computed_definition():
    assert elements_computed(1000, 100) == 10.0
    assert elements_computed(50, 100) == 0.5       # fractional partial rows


def test_oracle_elements_match_rows_for_full_rows():
    X = _data(64, 3, seed=9)
    o = VectorOracle(X)
    for i in range(5):
        o.row(i)
    assert o.elements == o.rows_computed == 5


def test_oracle_elements_fractional_for_subrows():
    X = _data(64, 3, seed=10)
    o = VectorOracle(X)
    o.subrow(0, np.arange(16))                     # quarter row
    assert o.elements == pytest.approx(0.25)


def test_race_and_engine_accounting_agree():
    """Bandit scalars / N must equal its reported elements, and the
    exact engines' row counts are the same unit (rows = scalars / N)."""
    X = _data(1024, 3, seed=11).astype(np.float32)
    r = ucb_race(X, budget=60.0, target=64, seed=0)
    assert r.n_computed == pytest.approx(
        elements_computed(r.n_scalars, 1024), rel=1e-6)
    p = trimed_pipelined(X)
    assert p.n_computed == elements_computed(p.n_distances, 1024)


# ---------------------------------------------------------------------------
# K-medoids bandit update (the paper's relaxed trikmeds on device)
# ---------------------------------------------------------------------------
def test_kmedoids_bandit_update_quality_and_cost():
    rng = np.random.default_rng(12)
    centers = rng.random((5, 2)) * 10
    X = (centers[rng.integers(0, 5, 1000)]
         + rng.standard_normal((1000, 2)) * 0.3).astype(np.float32)
    r_exact = kmedoids_batched(X, 5, n_iter=4, medoid_update="trimed")
    r_band = kmedoids_batched(X, 5, n_iter=4, medoid_update="bandit")
    assert r_band.energy <= r_exact.energy * 1.05   # minor quality loss
    assert r_band.n_rows < r_exact.n_rows           # at a fraction of cost


def test_kmedoids_bandit_update_non_triangle_metric():
    X = _data(400, 3, seed=13).astype(np.float32)
    r = kmedoids_batched(X, 4, n_iter=2, medoid_update="bandit",
                         metric="cosine")
    assert len(np.unique(r.medoids)) >= 1
    assert np.isfinite(r.energy)
