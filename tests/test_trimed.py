"""Correctness of the paper's core: trimed (sequential & block)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    exact_energies,
    exact_medoid,
    trimed_block,
    trimed_sequential,
)
from repro.core.graph import GraphOracle, sensor_network
from repro.kernels.ops import fused_round, make_pallas_distance_fn


def _data(n, d, seed=0, dist="uniform"):
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        return rng.random((n, d))
    if dist == "gauss":
        return rng.standard_normal((n, d))
    if dist == "clusters":
        c = rng.standard_normal((8, d)) * 4
        return (c[rng.integers(0, 8, n)] + rng.standard_normal((n, d)))
    raise ValueError(dist)


def _energies64(X):
    """fp64 reference energies (sum/N convention) — device code is fp32,
    so index comparisons must tolerate fp32-scale near-ties."""
    X = np.asarray(X, np.float64)
    d2 = ((X[:, None, :] - X[None, :, :]) ** 2).sum(-1)
    return np.sqrt(np.maximum(d2, 0)).sum(1) / len(X)


@pytest.mark.parametrize("dist", ["uniform", "gauss", "clusters"])
@pytest.mark.parametrize("d", [1, 2, 5])
def test_sequential_exact(dist, d):
    X = _data(500, d, seed=d, dist=dist)
    ti, _ = exact_medoid(X)
    r = trimed_sequential(X, seed=1)
    assert r.index == ti
    assert r.n_computed <= 500


@pytest.mark.parametrize("block", [1, 7, 32, 128])
def test_block_exact_any_blocksize(block):
    X = _data(400, 2, seed=3)
    ti, _ = exact_medoid(X)
    r = trimed_block(X, block=block, seed=0)
    assert r.index == ti


@pytest.mark.parametrize("policy", ["lowest_bound", "random"])
def test_block_policies(policy):
    X = _data(600, 3, seed=5)
    ti, _ = exact_medoid(X)
    r = trimed_block(X, block=32, policy=policy, seed=0)
    assert r.index == ti


def test_block_matches_pallas_paths():
    X = _data(1200, 4, seed=7).astype(np.float32)
    ti, _ = exact_medoid(X)
    r_jnp = trimed_block(X, block=64)
    r_mat = trimed_block(X, block=64, distance_fn=make_pallas_distance_fn())
    r_fus = trimed_block(X, block=64, fused_round_fn=fused_round)
    assert r_jnp.index == r_mat.index == r_fus.index == ti
    assert r_jnp.n_computed == r_mat.n_computed == r_fus.n_computed


def test_energy_normalisation_matches_paper():
    X = _data(100, 2)
    r = trimed_sequential(X, seed=0)
    e = _energies64(X)                         # S / N convention, fp64
    expected = e.min() * 100 / 99              # paper's S / (N-1)
    assert abs(r.energy - expected) < 1e-9


def test_eps_relaxation_bounds_energy():
    X = _data(800, 2, seed=11)
    exact = trimed_sequential(X, seed=0)
    for eps in (0.01, 0.1, 0.5):
        r = trimed_sequential(X, seed=0, eps=eps)
        assert r.energy <= exact.energy * (1 + eps) + 1e-9
        assert r.n_computed <= exact.n_computed


def test_subquadratic_scaling():
    """Paper Fig. 3 claim: computed elements ~ O(sqrt(N)) in low d."""
    counts = {}
    for n in (1000, 4000, 16000):
        X = _data(n, 2, seed=n)
        r = trimed_block(X, block=64, seed=0)
        counts[n] = r.n_computed
    # quadrupling N should roughly double computed count; allow 3.2x slack
    assert counts[4000] <= counts[1000] * 3.2 + 64
    assert counts[16000] <= counts[4000] * 3.2 + 64
    assert counts[16000] < 16000 / 4          # far below N


def test_graph_medoid():
    g, _ = sensor_network(700, seed=2)
    e = np.array([GraphOracle(g.adj, g.n).row(i).sum() for i in range(g.n)])
    r = trimed_sequential(g, seed=0)
    assert r.index == int(np.argmin(e))
    assert r.n_computed < g.n / 3


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(10, 200),
    d=st.integers(1, 6),
    seed=st.integers(0, 10_000),
)
def test_property_block_always_exact(n, d, seed):
    """Property: for any data, block-trimed returns the true medoid —
    exact up to fp32 arithmetic (near-ties below fp32 resolution may
    return the other tied element; accept by energy)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d))
    e = _energies64(X)
    r = trimed_block(X, block=16, seed=seed)
    assert e[r.index] <= e.min() * (1 + 1e-5) + 1e-7


@settings(max_examples=15, deadline=None)
@given(n=st.integers(5, 120), seed=st.integers(0, 10_000))
def test_property_bounds_are_lower_bounds(n, seed):
    """Invariant behind Thm 3.1: every bound trimed produces is a valid
    lower bound on the true energy (checked via the sequential oracle)."""
    rng = np.random.default_rng(seed)
    X = rng.random((n, 3))
    e = _energies64(X)
    # replicate the sequential algorithm, checking l <= E throughout
    from repro.core.distances import VectorOracle

    oracle = VectorOracle(X)
    l = np.zeros(n)
    e_cl = np.inf
    for i in rng.permutation(n):
        if l[i] < e_cl:
            drow = oracle.row(i)
            ei = drow.sum() / n
            e_cl = min(e_cl, ei)
            l = np.maximum(l, np.abs(ei - drow))
            l[i] = ei
        assert np.all(l <= e + 1e-9)


@pytest.mark.parametrize("k", [1, 3, 10])
def test_topk_ranking_exact(k):
    """§6 extension: exact k lowest-energy elements (TOPRANK's task)."""
    from repro.core import trimed_topk

    X = _data(1500, 2, seed=21)
    e = _energies64(X)
    want = np.argsort(e)[:k]
    r = trimed_topk(X, k, seed=0)
    assert set(r.indices) == set(want)
    assert r.n_computed < 1500 / 2
    # energies ascending and correctly normalised
    np.testing.assert_allclose(r.energies,
                               np.sort(e)[:k] * 1500 / 1499, rtol=1e-6)


def test_topk_k1_matches_medoid():
    from repro.core import trimed_topk

    X = _data(800, 3, seed=9)
    r1 = trimed_topk(X, 1, seed=4)
    r2 = trimed_sequential(X, seed=4)
    assert r1.indices[0] == r2.index
