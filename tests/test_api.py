"""The front door: MedoidQuery -> planner -> SolveReport (DESIGN.md §10).

Covers the acceptance criteria of the API redesign:

* planner golden tests across the (N, metric, budget, mode) grid;
* ``solve`` reaches every engine, with parity against the legacy
  entrypoints (which must warn exactly once per call and return
  bit-identical results — they are shims over ``solve``);
* ``explain=True`` returns the chosen plan and why, without executing;
* a ``register_metric``-defined Chebyshev metric runs through multiple
  engines without touching repro internals;
* the public-API snapshot (``repro.__all__`` + api signatures) so
  surface changes are deliberate.
"""
import inspect
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

import repro
from repro.api import (ENGINES, MedoidQuery, Plan, SolveReport,
                       available_metrics, get_metric, plan_query,
                       register_metric, require_metric, solve,
                       unregister_metric)


def _X(n, d=3, seed=0):
    return np.random.default_rng(seed).random((n, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# planner golden tests — pure decisions, no engine executes (np.empty)
# ---------------------------------------------------------------------------
GOLDEN = [
    # (n, query-kwargs, expected engine)
    (128, {}, "sequential"),                       # tiny: host wins
    (256, {}, "sequential"),                       # boundary inclusive
    (1024, {}, "block"),                           # mid: block round
    (2048, {}, "block"),                           # boundary inclusive
    (4096, {}, "pipelined"),                       # large: compaction pays
    (100_000, {}, "pipelined"),
    (128, {"device_policy": "device"}, "block"),   # forced off host
    (100_000, {"device_policy": "host"}, "sequential"),
    (1024, {"metric": "cosine"}, "scan"),          # no triangle -> scan
    (1024, {"metric": "sqeuclidean"}, "scan"),
    (4096, {"budget": 200.0}, "hybrid"),           # budget -> anytime
    (4096, {"mode": "anytime"}, "hybrid"),
    (4096, {"budget": 200.0, "metric": "cosine"}, "bandit"),
    (4096, {"mode": "anytime", "metric": "sqeuclidean"}, "bandit"),
    (1024, {"topk": 5}, "topk"),
    (1024, {"topk": 5, "metric": "cosine"}, "scan"),
    (1024, {"k": 4}, "kmedoids"),
    (1024, {"k": 4, "update": MedoidQuery(None, mode="anytime")},
     "kmedoids"),
]


@pytest.mark.parametrize("n,kw,engine", GOLDEN)
def test_planner_golden(n, kw, engine, monkeypatch):
    from repro.api import planner
    monkeypatch.setattr(planner, "_device_count", lambda: 1)
    X = np.empty((n, 3), np.float32)       # planning must not touch values
    plan = plan_query(MedoidQuery(X, **kw))
    assert plan.engine == engine, plan
    assert plan.reasons                     # every choice carries a why


GOLDEN_MULTIDEVICE = [
    # (n, query-kwargs, expected engine) with 8 devices visible
    (4096, {}, "pipelined"),                       # threshold is strict >
    (8192, {}, "sharded"),                         # auto-shard kicks in
    (100_000, {}, "sharded"),
    (8192, {"device_policy": "host"}, "sequential"),
    (1024, {"device_policy": "sharded"}, "sharded"),   # forced at any N
    (1024, {"device_policy": "sharded", "metric": "cosine"}, "scan"),
    (8192, {"budget": 100.0}, "hybrid"),           # anytime never shards
]


@pytest.mark.parametrize("n,kw,engine", GOLDEN_MULTIDEVICE)
def test_planner_golden_multidevice(n, kw, engine, monkeypatch):
    """Auto-selection: jax.device_count() > 1 and N > SHARDED_N routes
    exact single-medoid queries to the sharded engine (DESIGN.md §11)."""
    from repro.api import planner
    monkeypatch.setattr(planner, "_device_count", lambda: 8)
    X = np.empty((n, 3), np.float32)
    plan = plan_query(MedoidQuery(X, **kw))
    assert plan.engine == engine, plan
    if engine in ("sharded", "batched_sharded"):
        assert plan.params["n_shards"] == 8


# ---------------------------------------------------------------------------
# cost-model calibration — plan.cost_estimate vs engine-reported accounting
# ---------------------------------------------------------------------------
_CALIBRATION = [(n, kw) for n, kw, _e in GOLDEN if n <= 4096]


@pytest.mark.parametrize("n,kw", _CALIBRATION,
                         ids=[f"n{n}-{'-'.join(kw) or 'plain'}"
                              for n, kw in _CALIBRATION])
def test_cost_estimate_calibrated(n, kw, monkeypatch):
    """Every plan's predicted element count lands within 2x of what the
    engine actually reports, over the same golden grid the planner tests
    pin. ``scan`` is deterministic-by-construction (always exactly N
    rows) so its estimate must be *equal*, not just close; elimination
    engines (sequential included) are data-dependent — how many rows the
    triangle bound prunes varies with the draw — so exactness is
    impossible there and the contract is the 2x band."""
    from repro.api import planner
    monkeypatch.setattr(planner, "_device_count", lambda: 1)
    q = MedoidQuery(_X(n), **kw)
    plan = plan_query(q)
    assert plan.cost_estimate is not None and plan.cost_estimate > 0
    report = solve(q)
    assert report.plan.cost_estimate == plan.cost_estimate
    actual = report.elements_computed
    if plan.engine == "scan":
        assert plan.cost_estimate == actual == float(n)
    else:
        assert actual / 2 <= plan.cost_estimate <= actual * 2, (
            f"{plan.engine}: estimate {plan.cost_estimate} vs "
            f"reported {actual}")


def test_cost_estimate_budget_capped():
    """A budgeted anytime query's estimate is the budget itself (floored
    at one block) — and the engine never exceeds it by more than one
    round of slack."""
    q = MedoidQuery(_X(4096), budget=200.0)
    plan = plan_query(q)
    assert plan.cost_estimate >= 200.0
    report = solve(q)
    assert report.elements_computed <= plan.cost_estimate * 2


def test_planner_sharded_rejections():
    X = np.empty((1024, 3), np.float32)
    with pytest.raises(ValueError, match="sharded"):
        plan_query(MedoidQuery(X, device_policy="sharded", mode="anytime"))
    with pytest.raises(ValueError, match="sharded"):
        plan_query(MedoidQuery(X, device_policy="sharded", topk=3))
    from repro.core import VectorOracle
    with pytest.raises(ValueError, match="sharded"):
        plan_query(MedoidQuery(VectorOracle(_X(64)),
                               device_policy="sharded"))
    with pytest.raises(ValueError, match="bandit"):
        plan_query(MedoidQuery(X, k=4, device_policy="sharded",
                               update=MedoidQuery(None, mode="anytime")))


def test_planner_golden_assignments():
    a = np.zeros(1024, np.int64)
    p = plan_query(MedoidQuery(np.empty((1024, 3), np.float32),
                               k=2, assignments=a))
    assert p.engine == "batched"
    a = np.zeros(8192, np.int64)
    p = plan_query(MedoidQuery(np.empty((8192, 3), np.float32),
                               k=2, assignments=a))
    assert p.engine == "batched_pipelined"


def test_planner_oracle_input_goes_sequential():
    from repro.core import VectorOracle
    p = plan_query(MedoidQuery(VectorOracle(_X(64))))
    assert p.engine == "sequential"


def test_oracle_with_non_triangle_metric_scans():
    from repro.core import VectorOracle
    X = _X(80, seed=11)
    q = MedoidQuery(VectorOracle(X, "cosine"), metric="cosine")
    assert plan_query(q).engine == "scan"
    rep = solve(q)
    Xn = X.astype(np.float64)
    Xn /= np.linalg.norm(Xn, axis=1, keepdims=True)
    e = np.maximum(1.0 - Xn @ Xn.T, 0.0).sum(1)
    assert rep.index == int(e.argmin())


def test_scan_plan_keeps_shims_working():
    """The dispatcher shim returns extras['raw'] — the scan executor
    must provide one (MedoidResult / TopKResult)."""
    from repro.core import MedoidResult, medoid, trimed_topk
    X = _X(120)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        r = medoid(X, metric="sqeuclidean")          # auto -> scan
    assert isinstance(r, MedoidResult) and r.certified
    rep = solve(MedoidQuery(X, metric="cosine", topk=3))
    assert rep.plan.engine == "scan"
    assert rep.extras["raw"].indices.shape == (3,)


def test_tpu_auto_kernels_respects_engine_hooks(monkeypatch):
    """use_kernels=None auto-resolution: on TPU, hook-replacement engines
    (block/batched/kmedoids) need the fused-round hooks, not just the
    distance tile; explicit False always wins."""
    import jax
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    X = np.empty((1024, 3), np.float32)
    assert plan_query(MedoidQuery(X)).params["use_kernels"] is True
    assert plan_query(MedoidQuery(X, metric="l1")).params["use_kernels"] \
        is True                                   # l1 has the hooks
    # sqeuclidean has a tile but no fused-round hook: auto stays off for
    # the block engine, on for the tile-only pipelined path
    p = solve(MedoidQuery(X, metric="sqeuclidean"), plan="block",
              explain=True)
    assert p.params["use_kernels"] is False
    p = solve(MedoidQuery(X, metric="sqeuclidean"), plan="bandit",
              explain=True)
    assert p.params["use_kernels"] is True
    # shims pin use_kernels=False — TPU auto must not flip them
    p = plan_query(MedoidQuery(X, use_kernels=False))
    assert p.params["use_kernels"] is False


def test_l1_fused_round_hooks_execute():
    """The l1 Metric registers the fused-round kernel hooks; run them
    (interpret path on CPU) and check parity with the jnp round."""
    X = _X(260, seed=13)
    r_jnp = solve(MedoidQuery(X, metric="l1", block=32), plan="block")
    r_ker = solve(MedoidQuery(X, metric="l1", block=32, use_kernels=True),
                  plan="block")
    assert r_jnp.index == r_ker.index


def test_nested_update_unsupported_fields_rejected():
    with pytest.raises(ValueError, match="does not support overriding"):
        plan_query(MedoidQuery(
            _X(64), k=2,
            update=MedoidQuery(None, mode="anytime", delta=0.1)))
    with pytest.raises(ValueError, match="does not support overriding"):
        plan_query(MedoidQuery(
            _X(64), k=2,
            update=MedoidQuery(None, engine_opts={"samples_per_round": 8})))


def test_kmedoids_toplevel_budget_rejected_anytime_maps_to_bandit():
    X = _X(200)
    with pytest.raises(ValueError, match="nested update query"):
        plan_query(MedoidQuery(X, k=4, budget=100.0))
    p = plan_query(MedoidQuery(X, k=4, mode="anytime"))
    assert p.engine == "kmedoids"
    assert p.params["medoid_update"] == "bandit"


def test_explain_returns_plan_without_executing():
    # N large enough that execution would be very noticeable; empty data
    # would also produce garbage answers — explain must not compute.
    q = MedoidQuery(np.empty((10_000_000, 8), np.float32))
    p = solve(q, explain=True)
    assert isinstance(p, Plan) and p.engine == "pipelined" and p.reasons


def test_plan_override_and_unknown_plan():
    X = _X(300)
    rep = solve(MedoidQuery(X), plan="sequential")
    assert rep.plan.engine == "sequential"
    with pytest.raises(ValueError, match="unknown plan"):
        solve(MedoidQuery(X), plan="warp-drive")


def test_query_validation():
    with pytest.raises(ValueError, match="mode"):
        MedoidQuery(None, mode="fast")
    with pytest.raises(ValueError, match="assignments requires k"):
        MedoidQuery(None, assignments=np.zeros(4))
    with pytest.raises(ValueError, match="topk is exclusive"):
        MedoidQuery(None, topk=3, k=2)
    with pytest.raises(ValueError, match="unknown metric"):
        plan_query(MedoidQuery(_X(32), metric="warp"))


# ---------------------------------------------------------------------------
# solve reaches every engine; parity with the legacy entrypoints
# ---------------------------------------------------------------------------
def test_solve_reaches_every_engine():
    X = _X(300)
    a = np.random.default_rng(1).integers(0, 3, 300)
    reached = set()
    cases = [
        (MedoidQuery(X[:64]), None),                      # sequential
        (MedoidQuery(X), None),                           # block
        (MedoidQuery(X), "pipelined"),
        (MedoidQuery(X, device_policy="sharded"), None),  # sharded
        (MedoidQuery(X, k=3, assignments=a), None),       # batched
        (MedoidQuery(X, k=3, assignments=a), "batched_pipelined"),
        (MedoidQuery(X, k=3, assignments=a,
                     device_policy="sharded"), None),     # batched_sharded
        (MedoidQuery(X, budget=64.0), None),              # hybrid
        (MedoidQuery(X, budget=64.0, metric="cosine"), None),  # bandit
        (MedoidQuery(X, k=3, n_iter=2), None),            # kmedoids
        (MedoidQuery(X, topk=4), None),                   # topk
        (MedoidQuery(X, metric="sqeuclidean"), None),     # scan
    ]
    from repro.core.graph import grid_network
    cases.append((MedoidQuery(grid_network(64, seed=2)[0],
                              metric="graph"), None))     # graph
    for q, plan in cases:
        rep = solve(q, plan=plan)
        assert isinstance(rep, SolveReport)
        reached.add(rep.plan.engine)
        assert rep.indices.shape == rep.energies.shape
        assert rep.elements_computed >= 0
    assert reached == set(ENGINES)


def test_exact_engines_agree_and_match_bruteforce():
    X = _X(300)
    e = np.asarray(
        np.abs(X[:, None, :] - X[None, :, :]) ** 2).sum(-1) ** 0.5
    ti = int(e.sum(1).argmin())
    for plan in ("sequential", "block", "pipelined", "scan"):
        rep = solve(MedoidQuery(X), plan=plan)
        assert rep.index == ti, plan
        assert rep.certified
        assert rep.ci == 0.0


def test_hybrid_certified_matches_exact():
    X = _X(512, seed=5)
    exact = solve(MedoidQuery(X), plan="pipelined")
    hyb = solve(MedoidQuery(X, mode="anytime"), plan="hybrid")
    assert hyb.index == exact.index
    assert hyb.certified
    assert hyb.extras["exact_energy"]


def test_internal_paths_emit_no_legacy_warnings():
    """No in-repo code may route through the deprecated shims."""
    X = _X(300)
    a = np.random.default_rng(1).integers(0, 3, 300)
    with warnings.catch_warnings():
        warnings.filterwarnings("error", message="repro legacy entrypoint")
        solve(MedoidQuery(X))
        solve(MedoidQuery(X), plan="pipelined")
        solve(MedoidQuery(X, budget=64.0))
        solve(MedoidQuery(X, k=3, assignments=a))
        solve(MedoidQuery(X, k=3, n_iter=2))
        solve(MedoidQuery(
            X, k=3, n_iter=2,
            update=MedoidQuery(None, mode="anytime", budget=0.5)))


# --- shim layer -------------------------------------------------------------
def _assert_warns_once(w):
    msgs = [x for x in w if issubclass(x.category, DeprecationWarning)
            and "repro legacy entrypoint" in str(x.message)]
    assert len(msgs) == 1, [str(x.message) for x in w]


def test_shim_trimed_sequential():
    from repro.core import trimed_sequential
    X = _X(96)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = trimed_sequential(X, seed=3)
    _assert_warns_once(w)
    rep = solve(MedoidQuery(X, seed=3,
                            engine_opts={"eps": 0.0, "order": None}),
                plan="sequential")
    assert r == rep.extras["raw"]


def test_shim_trimed_block():
    from repro.core import trimed_block
    X = _X(300)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = trimed_block(X, block=32, seed=1)
    _assert_warns_once(w)
    rep = solve(MedoidQuery(X, block=32, seed=1,
                            engine_opts={"policy": "lowest_bound"}),
                plan="block")
    assert r == rep.extras["raw"]


def test_shim_trimed_pipelined():
    from repro.core import trimed_pipelined
    X = _X(300)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = trimed_pipelined(X, block=32)
    _assert_warns_once(w)
    rep = solve(MedoidQuery(X, block=32), plan="pipelined")
    assert r == rep.extras["raw"]


def test_shim_trimed_topk():
    from repro.core import trimed_topk
    X = _X(200)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = trimed_topk(X, 5, seed=2)
    _assert_warns_once(w)
    rep = solve(MedoidQuery(X, topk=5, seed=2), plan="topk")
    raw = rep.extras["raw"]
    assert np.array_equal(r.indices, raw.indices)
    assert np.array_equal(r.energies, raw.energies)
    assert r.n_computed == raw.n_computed


def test_shim_batched_medoids():
    from repro.core import batched_medoids
    X = _X(256)
    a = np.random.default_rng(2).integers(0, 4, 256)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = batched_medoids(X, a, 4, block=32)
    _assert_warns_once(w)
    rep = solve(MedoidQuery(X, k=4, assignments=a, block=32), plan="batched")
    raw = rep.extras["raw"]
    assert np.array_equal(r.medoids, raw.medoids)
    assert np.array_equal(r.sums, raw.sums)
    assert r.n_computed == raw.n_computed


def test_shim_batched_medoids_pipelined():
    from repro.core import batched_medoids_pipelined
    X = _X(256)
    a = np.random.default_rng(2).integers(0, 4, 256)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = batched_medoids_pipelined(X, a, 4, block=32)
    _assert_warns_once(w)
    rep = solve(MedoidQuery(X, k=4, assignments=a, block=32),
                plan="batched_pipelined")
    raw = rep.extras["raw"]
    assert np.array_equal(r.medoids, raw.medoids)
    assert np.array_equal(r.sums, raw.sums)


def test_shim_bandit_medoid():
    from repro.bandit import bandit_medoid
    X = _X(400, seed=7)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = bandit_medoid(X, budget=80.0, seed=4)
    _assert_warns_once(w)
    rep = solve(MedoidQuery(X, budget=80.0, seed=4,
                            engine_opts={"engine": "ucb",
                                         "samples_per_round": 64,
                                         "survivor_target": None,
                                         "bandit_frac": 0.5,
                                         "seed_bounds": False,
                                         "interpret": None}),
                plan="hybrid")
    raw = rep.extras["raw"]
    assert r.index == raw.index and r.energy == raw.energy
    assert r.n_computed == raw.n_computed and r.certified == raw.certified


def test_shim_medoid_dispatcher_backends():
    from repro.bandit.api import BanditMedoidResult
    from repro.core import MedoidResult, medoid
    X = _X(300)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r_auto = medoid(X)
        r_pipe = medoid(X, backend="pipelined")
        r_band = medoid(X, backend="bandit", budget=64.0)
    assert isinstance(r_auto, MedoidResult)
    assert isinstance(r_pipe, MedoidResult)
    assert isinstance(r_band, BanditMedoidResult)     # new: anytime backend
    assert r_auto.index == r_pipe.index
    assert sum("repro legacy entrypoint" in str(x.message) for x in w) == 3
    with pytest.raises(ValueError, match="unknown backend"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            medoid(X, backend="warp")


# ---------------------------------------------------------------------------
# metric registry
# ---------------------------------------------------------------------------
def test_registry_capabilities_are_single_source():
    assert set(available_metrics()) >= {"l2", "l1", "sqeuclidean", "cosine",
                                        "graph"}
    assert set(available_metrics(require_triangle=True)) == \
        {"graph", "l1", "l2"}
    assert get_metric("l2").kernel and get_metric("l2").has_triangle
    assert not get_metric("cosine").has_triangle
    # matching error messages from the one gate, everywhere
    from repro.core import VectorOracle
    from repro.core.distances import pairwise
    with pytest.raises(ValueError, match="unknown metric 'warp'"):
        VectorOracle(_X(8), "warp")
    with pytest.raises(ValueError, match="unknown metric 'warp'"):
        pairwise(jnp.ones((2, 2)), jnp.ones((2, 2)), "warp")
    with pytest.raises(ValueError, match="triangle"):
        solve(MedoidQuery(_X(32), metric="cosine"), plan="pipelined")
    with pytest.raises(ValueError, match="triangle"):
        solve(MedoidQuery(_X(32), metric="sqeuclidean", mode="anytime"),
              plan="hybrid")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_metric("l2", lambda a, b: None)
    with pytest.raises(ValueError, match="built-in"):
        unregister_metric("l2")


@pytest.fixture
def chebyshev_metric():
    def chebyshev(a, b):
        return jnp.max(jnp.abs(a[:, None, :] - b[None, :, :]), axis=-1)
    register_metric("chebyshev", chebyshev, has_triangle=True,
                    description="L-infinity")
    yield "chebyshev"
    unregister_metric("chebyshev")


def test_user_metric_through_engines(chebyshev_metric):
    """A user-registered metric runs through multiple engines via the
    public surface only — no repro internals touched."""
    X = _X(220, d=4, seed=9)
    D = np.abs(X[:, None, :] - X[None, :, :]).max(-1)
    ti = int(D.sum(1).argmin())
    r_seq = solve(MedoidQuery(X, metric="chebyshev"), plan="sequential")
    r_blk = solve(MedoidQuery(X, metric="chebyshev", block=32), plan="block")
    r_pipe = solve(MedoidQuery(X, metric="chebyshev", block=32),
                   plan="pipelined")
    assert r_seq.index == r_blk.index == r_pipe.index == ti
    # planner treats it like any triangle metric
    assert plan_query(MedoidQuery(X, metric="chebyshev")).engine == \
        "sequential"
    assert "chebyshev" in available_metrics(require_triangle=True)


def test_user_metric_non_triangle_gets_scan(chebyshev_metric):
    register_metric("halfsq", lambda a, b: jnp.sum(
        (a[:, None, :] - b[None, :, :]) ** 2, -1), has_triangle=False)
    try:
        p = plan_query(MedoidQuery(_X(100), metric="halfsq"))
        assert p.engine == "scan"
    finally:
        unregister_metric("halfsq")


# ---------------------------------------------------------------------------
# K-medoids nested update query
# ---------------------------------------------------------------------------
def test_kmedoids_nested_anytime_update():
    X = _X(400, seed=3)
    rep = solve(MedoidQuery(
        X, k=4, n_iter=3,
        update=MedoidQuery(None, mode="anytime", budget=0.5)))
    assert rep.plan.params["medoid_update"] == "bandit"
    assert not rep.certified and np.isnan(rep.ci)
    assert rep.assignment is not None and rep.assignment.shape == (400,)
    exact = solve(MedoidQuery(X, k=4, n_iter=3))
    assert exact.certified and exact.plan.params["medoid_update"] == "trimed"
    # the relaxation trades a little energy for fewer computed elements
    assert rep.extras["total_energy"] <= 1.10 * exact.extras["total_energy"]


def test_kmedoids_legacy_string_update_still_works():
    from repro.core import kmedoids_batched
    X = _X(256)
    r1 = kmedoids_batched(X, 3, n_iter=2, medoid_update="trimed")
    r2 = kmedoids_batched(
        X, 3, n_iter=2,
        medoid_update=MedoidQuery(None))       # nested exact template
    assert np.array_equal(r1.medoids, r2.medoids)


# ---------------------------------------------------------------------------
# public-API snapshot — surface changes must be deliberate
# ---------------------------------------------------------------------------
EXPECTED_TOP_LEVEL = {
    "ENGINES", "MedoidQuery", "Metric", "Plan", "SolveReport",
    "available_metrics", "get_metric", "plan_query", "register_metric",
    "solve", "solve_many", "unregister_metric",
}

EXPECTED_SIGNATURES = {
    "solve": "(query, plan=None, explain=False)",
    "solve_many": "(queries, max_queries_per_program=None)",
    "plan_query": "(query: 'MedoidQuery') -> 'Plan'",
    "require_metric": ("(name: 'str', need_triangle: 'bool' = False, "
                       "caller: 'str | None' = None) -> 'Metric'"),
}

EXPECTED_QUERY_FIELDS = [
    "X", "metric", "k", "assignments", "topk", "mode", "budget", "delta",
    "warm_idx", "device_policy", "mesh", "seed", "block", "block_schedule",
    "use_kernels", "n_iter", "update", "deadline_s", "on_error",
    "nonfinite", "trace", "engine_opts",
]

EXPECTED_REPORT_FIELDS = [
    "indices", "energies", "certified", "elements_computed", "n_distances",
    "n_rounds", "ci", "plan", "assignment", "extras",
]


def test_public_api_snapshot():
    assert set(repro.__all__) == EXPECTED_TOP_LEVEL
    for name in EXPECTED_TOP_LEVEL:
        assert getattr(repro, name) is not None
    assert str(inspect.signature(solve)) == EXPECTED_SIGNATURES["solve"]
    assert str(inspect.signature(repro.solve_many)) == \
        EXPECTED_SIGNATURES["solve_many"]
    assert str(inspect.signature(plan_query)) == \
        EXPECTED_SIGNATURES["plan_query"]
    assert str(inspect.signature(require_metric)) == \
        EXPECTED_SIGNATURES["require_metric"]
    assert list(inspect.signature(MedoidQuery).parameters) == \
        EXPECTED_QUERY_FIELDS
    assert list(inspect.signature(SolveReport).parameters) == \
        EXPECTED_REPORT_FIELDS
    assert ENGINES == ("sequential", "block", "pipelined", "sharded",
                       "batched", "batched_pipelined", "batched_sharded",
                       "bandit", "hybrid", "kmedoids", "topk", "scan",
                       "graph")


def test_query_is_a_pytree():
    import jax
    q = MedoidQuery(jnp.ones((8, 2)), metric="l1", block=64)
    leaves = jax.tree_util.tree_leaves(q)
    assert any(getattr(x, "shape", None) == (8, 2) for x in leaves)
    q2 = jax.tree_util.tree_map(lambda x: x, q)
    assert q2.metric == "l1" and q2.block == 64
