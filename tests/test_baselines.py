"""Baselines: RAND / TOPRANK / TOPRANK2 / KMEDS (+ Park-Jun init)."""
import numpy as np
import pytest

from repro.core import (
    exact_medoid,
    kmeds,
    parkjun_init,
    rand_medoid,
    toprank,
    toprank2,
    trikmeds,
)


def _data(n, d=2, seed=0):
    return np.random.default_rng(seed).random((n, d))


def test_toprank_returns_medoid():
    X = _data(1500)
    ti, _ = exact_medoid(X)
    for seed in range(3):
        assert toprank(X, seed=seed).index == ti


def test_toprank2_returns_medoid():
    X = _data(1500)
    ti, _ = exact_medoid(X)
    for seed in range(3):
        assert toprank2(X, seed=seed).index == ti


def test_trimed_beats_toprank_on_low_d():
    """Paper Table 1 headline: trimed computes far fewer elements."""
    from repro.core import trimed_sequential

    X = _data(4000, 2, seed=1)
    tr = trimed_sequential(X, seed=0)
    tp = toprank(X, seed=0)
    assert tr.index == tp.index
    assert tr.n_computed < tp.n_computed / 5


def test_rand_energy_close():
    X = _data(2000, 2, seed=2)
    ti, te_over_n = exact_medoid(X)
    r = rand_medoid(X, epsilon=0.02, seed=0)
    te = te_over_n * 2000 / 1999
    assert r.energy < te * 1.1 + 0.05


def test_parkjun_init_well_centred():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((300, 2))
    from repro.core.distances import VectorOracle

    o = VectorOracle(X)
    D = np.stack([o.row(i) for i in range(300)])
    init = parkjun_init(D, 5)
    # Park-Jun picks central elements: their mean energy is below average
    assert D[init].sum(axis=1).mean() < D.sum(axis=1).mean()


@pytest.mark.parametrize("init", ["parkjun", "uniform"])
def test_kmeds_converges(init):
    X = _data(400, 2, seed=3)
    r = kmeds(X, 5, init=init, seed=0)
    assert r.n_iterations < 100
    assert len(np.unique(r.medoids)) == 5
    # every element assigned to its nearest medoid
    from repro.core.distances import VectorOracle

    o = VectorOracle(X)
    D = np.stack([o.row(int(m)) for m in r.medoids])
    assert np.array_equal(np.argmin(D, axis=0), r.assignment)


def test_trikmeds_matches_kmeds_energy():
    """trikmeds-0 returns exactly the KMEDS clustering (same init)."""
    X = _data(500, 2, seed=4)
    init = np.random.default_rng(9).choice(500, size=6, replace=False)
    rk = kmeds(X, 6, init="uniform", seed=9)
    rt = trikmeds(X, 6, seed=9, init_medoids=init)
    assert abs(rk.energy - rt.energy) < 1e-8
    assert rt.n_distances < rk.n_distances


def test_trikmeds_eps_tradeoff():
    X = _data(600, 2, seed=5)
    init = np.random.default_rng(1).choice(600, size=8, replace=False)
    r0 = trikmeds(X, 8, eps=0.0, seed=1, init_medoids=init)
    r1 = trikmeds(X, 8, eps=0.1, seed=1, init_medoids=init)
    assert r1.n_distances <= r0.n_distances
    assert r1.energy <= r0.energy * 1.15 + 1e-9
