"""Survivor-compacted pipelined engine (DESIGN.md §4): exactness parity
against the sequential oracle, schedule/compaction behaviour, and the
one-X-stream-per-round regression."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    batched_medoids,
    batched_medoids_pipelined,
    exact_medoid,
    kmedoids_batched,
    trimed_block,
    trimed_pipelined,
    trimed_sequential,
    warmup_schedule,
)
from repro.core.pipelined import resolve_schedule
from repro.kernels import ops


def _data(n, d, seed=0):
    return np.random.default_rng(seed).random((n, d))


def _energies64(X, metric="l2"):
    X = np.asarray(X, np.float64)
    if metric == "l2":
        d2 = ((X[:, None, :] - X[None, :, :]) ** 2).sum(-1)
        D = np.sqrt(np.maximum(d2, 0))
    else:
        D = np.abs(X[:, None, :] - X[None, :, :]).sum(-1)
    return D.sum(1) / len(X)


# ---------------------------------------------------------------------------
# exactness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("block", [1, 7, 32, 128])
def test_pipelined_exact_any_blocksize(block):
    X = _data(400, 2, seed=3)
    ti, _ = exact_medoid(X)
    r = trimed_pipelined(X, block=block)
    assert r.index == ti
    assert r.n_computed <= 400


@pytest.mark.parametrize("schedule", [None, "geometric", (4, 9, 17)])
def test_pipelined_schedules_exact(schedule):
    X = _data(700, 3, seed=5)
    ti, _ = exact_medoid(X)
    r = trimed_pipelined(X, block=64, block_schedule=schedule)
    assert r.index == ti


def test_pipelined_kernel_path_matches_jnp():
    X = _data(900, 4, seed=7).astype(np.float32)
    ti, _ = exact_medoid(X)
    r_jnp = trimed_pipelined(X, block=64)
    r_ker = trimed_pipelined(X, block=64, use_kernels=True)
    assert r_jnp.index == r_ker.index == ti
    np.testing.assert_allclose(r_jnp.energy, r_ker.energy, rtol=1e-5)


def test_pipelined_ladder_compacts():
    """At N >> ladder_min the engine must actually descend the ladder,
    and every compaction must preserve the exact answer."""
    X = _data(4000, 2, seed=11)
    ti, _ = exact_medoid(X)
    r = trimed_pipelined(X, block=64, ladder_min=128)
    assert r.index == ti
    assert r.n_stages >= 2
    # steady-state HBM model: one full X-stream per round plus the
    # (geometrically shrinking) fold columns — strictly below the block
    # engine's two full streams
    assert r.x_cols_streamed < 2 * r.n_rounds * 4000


def test_medoid_dispatcher_backend():
    X = _data(300, 2, seed=1)
    from repro.core import medoid

    r = medoid(X, backend="pipelined", block=32)
    ti, _ = exact_medoid(X)
    assert r.index == ti


def test_pipelined_rejects_non_triangle_metric():
    with pytest.raises(ValueError):
        trimed_pipelined(_data(32, 2), metric="sqeuclidean")


def test_duplicate_points_terminate_exactly():
    """All-duplicate and heavily-tied inputs must terminate and agree
    with the sequential oracle by energy."""
    rng = np.random.default_rng(0)
    base = rng.random((7, 3))
    X = base[rng.integers(0, 7, 500)]          # 500 points, 7 distinct
    e = _energies64(X)
    for schedule in (None, "geometric"):
        r = trimed_pipelined(X, block=16, block_schedule=schedule)
        assert e[r.index] <= e.min() * (1 + 1e-6) + 1e-9
    X1 = np.zeros((200, 2))                    # fully degenerate
    r = trimed_pipelined(X1, block=16)
    assert r.n_computed >= 1


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(16, 300),
    d=st.integers(1, 5),
    block=st.integers(1, 48),
    seed=st.integers(0, 10_000),
    metric=st.sampled_from(["l2", "l1"]),
    schedule=st.sampled_from([None, "geometric", (3, 11)]),
    dup=st.booleans(),
)
def test_property_pipelined_matches_sequential(n, d, block, seed, metric,
                                               schedule, dup):
    """Property: the compacted+pipelined engine returns the true medoid
    (up to fp32 near-ties, accepted by energy) for arbitrary data, block
    schedules, metrics, and duplicate-heavy inputs — parity with the
    sequential oracle."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d))
    if dup:                                    # force heavy duplication
        X = X[rng.integers(0, max(2, n // 4), n)]
    e = _energies64(X, metric)
    r = trimed_pipelined(X, block=block, metric=metric,
                         block_schedule=schedule, ladder_min=32)
    rs = trimed_sequential(X, seed=seed, metric=metric)
    assert e[r.index] <= e.min() * (1 + 1e-5) + 1e-7
    assert abs(e[r.index] - e[rs.index]) <= e.min() * 1e-5 + 1e-7
    assert r.n_computed <= n


@settings(max_examples=10, deadline=None)
@given(n=st.integers(16, 150), seed=st.integers(0, 1000))
def test_property_pipelined_kernel_parity(n, seed):
    """Property: Pallas (interpret) and jnp paths agree on the result."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, 3)).astype(np.float32)
    e = _energies64(X)
    r = trimed_pipelined(X, block=16, use_kernels=True, ladder_min=32)
    assert e[r.index] <= e.min() * (1 + 1e-5) + 1e-7


# ---------------------------------------------------------------------------
# batched multi-cluster engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("use_kernels", [False, True])
def test_batched_pipelined_matches_batched(use_kernels):
    rng = np.random.default_rng(2)
    n, k = 1500, 5
    X = rng.standard_normal((n, 3)).astype(np.float32)
    a = rng.integers(0, k, n)
    ref = batched_medoids(X, a, k, block=64)
    got = batched_medoids_pipelined(X, a, k, block=64,
                                    use_kernels=use_kernels,
                                    ladder_min=128)
    assert np.array_equal(ref.medoids, got.medoids)
    np.testing.assert_allclose(ref.sums, got.sums, rtol=1e-5)


def test_batched_pipelined_empty_and_oob_clusters():
    rng = np.random.default_rng(3)
    n, k = 600, 6
    X = rng.standard_normal((n, 2)).astype(np.float32)
    a = rng.integers(0, 4, n)                  # clusters 4, 5 empty
    a[:5] = -1                                 # out-of-range labels
    ref = batched_medoids(X, a, k, block=32)
    got = batched_medoids_pipelined(X, a, k, block=32, ladder_min=64)
    assert np.array_equal(ref.medoids, got.medoids)
    assert got.medoids[4] == -1 and got.medoids[5] == -1


def test_negative_labels_do_not_wrap_into_cluster_sizes():
    """Regression: a raw scatter-add wraps label -1 into cluster k-1's
    size (mode=\"drop\" only drops too-large indices), inflating the
    size-scaled triangle bound and over-eliminating. Negative labels
    must be excluded from a NON-empty cluster's size."""
    rng = np.random.default_rng(7)
    k = 1
    X = np.concatenate([
        rng.standard_normal((1, 3)) * 50,          # far outlier, labeled -1
        rng.standard_normal((100, 3)),             # cluster 0
        rng.standard_normal((400, 3)) * 30,        # excluded, labeled -1
    ]).astype(np.float32)
    a = np.full(len(X), -1)
    a[1:101] = 0
    members = np.flatnonzero(a == 0)
    D = np.sqrt((((X[members][:, None] - X[members][None]) ** 2)
                 .sum(-1)).clip(0))
    true_m = members[np.argmin(D.sum(1))]
    for engine in (batched_medoids, batched_medoids_pipelined):
        r = engine(X, a, k, block=8)
        assert r.medoids[0] == true_m, (engine.__name__, r.medoids, true_m)


def test_resolve_schedule_rejects_unknown_string():
    with pytest.raises(ValueError):
        resolve_schedule("Geometric", 128)


def test_batched_pipelined_warm_idx():
    rng = np.random.default_rng(4)
    n, k = 900, 4
    X = rng.standard_normal((n, 3)).astype(np.float32)
    a = rng.integers(0, k, n)
    ref = batched_medoids(X, a, k, block=32)
    got = batched_medoids_pipelined(X, a, k, block=32,
                                    warm_idx=ref.medoids, ladder_min=64)
    assert np.array_equal(ref.medoids, got.medoids)


def test_kmedoids_pipelined_update_matches_trimed():
    rng = np.random.default_rng(5)
    X = rng.standard_normal((1200, 4)).astype(np.float32)
    r_tri = kmedoids_batched(X, 6, n_iter=3, medoid_update="trimed")
    r_pip = kmedoids_batched(X, 6, n_iter=3, medoid_update="pipelined")
    assert np.array_equal(r_tri.medoids, r_pip.medoids)
    assert abs(r_tri.energy - r_pip.energy) < 1e-3


# ---------------------------------------------------------------------------
# adaptive block schedule
# ---------------------------------------------------------------------------
def test_warmup_schedule_shapes():
    assert warmup_schedule(128) == (8, 16, 32, 64)
    assert warmup_schedule(8) == ()
    assert resolve_schedule(None, 128) == ()
    assert resolve_schedule("geometric", 64) == (8, 16, 32)
    assert resolve_schedule((4, 64, 200), 128) == (4, 64)


def test_block_engine_schedule_exact():
    X = _data(800, 3, seed=9)
    ti, _ = exact_medoid(X)
    r = trimed_block(X, block=64, block_schedule="geometric")
    assert r.index == ti


def test_batched_schedule_exact():
    rng = np.random.default_rng(6)
    n, k = 700, 4
    X = rng.standard_normal((n, 2)).astype(np.float32)
    a = rng.integers(0, k, n)
    ref = batched_medoids(X, a, k, block=32)
    got = batched_medoids(X, a, k, block=32, block_schedule="geometric")
    assert np.array_equal(ref.medoids, got.medoids)


# ---------------------------------------------------------------------------
# one X-stream per round (the HBM-traffic regression, interpret path)
# ---------------------------------------------------------------------------
def test_pipelined_round_streams_x_once(monkeypatch):
    """Count the Pallas kernel invocations that stream the (padded) full
    X operand inside one round: the fused block round issues TWO
    (energy + bound update), the pipelined round exactly ONE. Unique
    shapes force a fresh trace so the jitted wrappers re-enter the
    counting kernels on the interpret path."""
    import jax.numpy as jnp
    from repro.kernels import pairwise as pk

    n, b, d = 617, 24, 5           # shapes unused elsewhere in the suite
    rng = np.random.default_rng(17)
    X = rng.standard_normal((n, d)).astype(np.float32)
    xb, xbp = X[:b], X[b:2 * b]
    l = np.zeros(n, np.float32)
    valid = np.ones(b, bool)
    calls = []

    def rec(name):
        orig = getattr(pk, name)

        def wrapped(*args, **kw):
            if any(getattr(a, "ndim", 0) == 2 and a.shape[0] >= n
                   for a in args):
                calls.append(name)
            return orig(*args, **kw)
        return wrapped

    for nm in ("pipelined_kernel", "energy_kernel", "bound_update_kernel"):
        monkeypatch.setattr(pk, nm, rec(nm))

    e, _ = ops.fused_round(jnp.asarray(xb), jnp.asarray(X),
                           jnp.asarray(l), jnp.asarray(valid))
    assert calls == ["energy_kernel", "bound_update_kernel"]   # 2 streams

    calls.clear()
    e_sums, l_new = ops.pipelined_round(
        jnp.asarray(xb), jnp.asarray(xbp), jnp.asarray(X),
        jnp.asarray(np.asarray(e)), jnp.asarray(valid), jnp.asarray(l))
    assert calls == ["pipelined_kernel"]                       # 1 stream
    assert e_sums.shape == (b,) and l_new.shape == (n,)


def test_engine_stream_accounting():
    """Engine-level HBM model: total X columns streamed must equal one
    full stream per round plus the compacted fold columns — i.e. the
    2-streams-per-round cost of the block engine is gone."""
    n = 3000
    X = _data(n, 2, seed=19)
    r = trimed_pipelined(X, block=64, ladder_min=128)
    assert r.n_stages >= 1
    fold_cols = r.x_cols_streamed - r.n_rounds * n
    assert 0 <= fold_cols < r.n_rounds * n
    # steady state: strictly fewer columns than two full streams/round
    assert r.x_cols_streamed < 2 * r.n_rounds * n
