"""Graph-distance subsystem (DESIGN.md §16).

Acceptance coverage for the shortest-path oracle + device sweep engine:

* device Bellman-Ford vs host Dijkstra parity on random graphs,
  including unreachable-node ``inf`` handling (property test);
* exact-medoid parity of ``metric="graph"`` against the brute-force
  full-scan oracle reference across an N x landmark-count grid;
* landmark energy bounds are valid lower bounds (property test);
* planner golden rows (graph engine, directed reroute, rejections) and
  cost-estimate calibration within the planner's 2x contract;
* the disconnected-component edge case (engine refuses loudly, sweeps
  keep ``inf``, ``largest_component`` restores solvability);
* the ``pair()``/``subrow()`` early-exit accounting fix (charged by
  settled nodes, consistent with ``distances.elements_computed``).
"""
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.api import MedoidQuery, plan_query, solve
from repro.core.graph import (GraphOracle, graph_medoid, grid_network,
                              landmark_energy_bounds, largest_component,
                              sensor_network, sweep_distances)


def _random_graph(n, extra_edges, seed, connected):
    """Random weighted undirected graph; ``connected=True`` threads a
    random spanning tree first, otherwise components arise naturally."""
    rng = np.random.default_rng(seed)
    adj = {i: [] for i in range(n)}

    def link(u, v):
        w = float(rng.uniform(0.1, 2.0))
        adj[u].append((v, w))
        adj[v].append((u, w))

    if connected:
        for v in range(1, n):
            link(int(rng.integers(v)), v)
    for _ in range(extra_edges):
        u, v = (int(x) for x in rng.integers(n, size=2))
        if u != v:
            link(u, v)
    return GraphOracle(adj, n)


def _scan_reference(g):
    """Brute-force reference: one host Dijkstra row sum per node."""
    ref = GraphOracle(g.adj, g.n)
    e = np.array([ref.row(i).sum() for i in range(ref.n)]) / ref.n
    return e


# ---------------------------------------------------------------------------
# device Bellman-Ford vs host Dijkstra
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 120), extra=st.integers(0, 200),
       seed=st.integers(0, 10_000), connected=st.booleans())
def test_bellman_ford_matches_dijkstra(n, extra, seed, connected):
    g = _random_graph(n, extra, seed, connected)
    rng = np.random.default_rng(seed + 1)
    sources = rng.integers(n, size=min(4, n))
    D, iters = sweep_distances(g, sources)
    assert iters >= 1
    for b, s in enumerate(sources):
        ref = g.row(int(s))
        finite = np.isfinite(ref)
        # identical reachable sets: unreachable nodes stay inf on device
        assert np.array_equal(np.isfinite(D[b]), finite)
        np.testing.assert_allclose(D[b][finite], ref[finite],
                                   rtol=1e-5, atol=1e-6)


def test_sweep_accounting_charges_one_element_per_source():
    g, _ = grid_network(100, seed=0)
    sweep_distances(g, [0, 1, 2])
    assert g.rows_computed == 3
    assert g.scalar_distances == 3 * g.n
    assert g.elements == 3.0


# ---------------------------------------------------------------------------
# landmark (ALT) bounds — DESIGN.md §16
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 80), seed=st.integers(0, 1000))
def test_landmark_energy_bounds_are_valid(n, seed):
    g = _random_graph(n, 3 * n, seed, connected=True)
    rows = np.stack([g.row(i) for i in range(g.n)])
    e = rows.sum(axis=1) / g.n
    lm = np.random.default_rng(seed).integers(g.n, size=3)
    l0 = landmark_energy_bounds(rows[lm])
    assert (l0 <= e + 1e-9).all()       # never above the true energy
    assert (l0 >= 0).all()


# ---------------------------------------------------------------------------
# exact-medoid parity vs the full-scan reference — N x landmark grid
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("gen,n,nl", [
    (grid_network, 200, 1),
    (grid_network, 500, 4),
    (grid_network, 1100, 8),
    (sensor_network, 300, 2),
    (sensor_network, 700, 8),
    (sensor_network, 700, 16),
])
def test_graph_medoid_parity(gen, n, nl):
    g, _ = gen(n, seed=7)
    e = _scan_reference(g)
    want = int(e.argmin())
    r, info = graph_medoid(g, n_landmarks=nl, seed=3)
    assert r.index == want
    assert r.certified
    np.testing.assert_allclose(r.energy, e[want] * g.n / (g.n - 1),
                               rtol=1e-12)
    # accounting: result counters, info breakdown and oracle agree
    assert r.n_computed == (info["landmark_sweeps"] + info["pivot_sweeps"]
                            + info["certify_rows"])
    assert g.elements == float(r.n_computed)
    assert r.n_distances == r.n_computed * g.n
    # sub-linear sweeps: strictly cheaper than the full scan
    assert r.n_computed < g.n


def test_graph_engine_through_solve_matches_sequential():
    g, _ = sensor_network(400, seed=11)
    g2 = GraphOracle(g.adj, g.n)
    r1 = solve(MedoidQuery(g, metric="graph"))
    r2 = solve(MedoidQuery(g2), plan="sequential")
    assert r1.plan.engine == "graph"
    assert r1.index == r2.index
    assert r1.certified and r1.ci == 0.0
    assert r1.extras["graph"]["pivot_sweeps"] >= 0
    # engine sweeps beat the sequential host scan's computed elements
    assert r1.elements_computed < g.n


def test_graph_sweep_budget_at_n2048_grid():
    """The CI gate's acceptance shape: exact index with sweeps
    <= 0.5 N on the N=2048 grid network (bench_graph gates the
    committed numbers; this is the in-suite guard)."""
    g, _ = grid_network(2048, seed=0)
    e = _scan_reference(g)
    r, _ = graph_medoid(g, seed=0)
    assert r.index == int(e.argmin())
    assert r.n_computed <= 0.5 * g.n


# ---------------------------------------------------------------------------
# planner golden rows + cost calibration
# ---------------------------------------------------------------------------
def test_planner_graph_golden_rows():
    g, _ = grid_network(400, seed=0)
    p = plan_query(MedoidQuery(g, metric="graph"))
    assert p.engine == "graph" and p.reasons
    assert p.cost_estimate is not None and p.cost_estimate > 0
    # directed oracle: quasi-metric, landmark bounds inadmissible
    d, _ = sensor_network(300, seed=2, directed=True)
    p2 = plan_query(MedoidQuery(d, metric="graph"))
    assert p2.engine == "sequential"
    assert any("directed" in r for r in p2.reasons)
    # a GraphOracle under the default metric keeps the seed routing
    p3 = plan_query(MedoidQuery(g))
    assert p3.engine == "sequential"


def test_planner_graph_rejections():
    g, _ = grid_network(100, seed=0)
    X = np.empty((64, 3), np.float32)
    with pytest.raises(ValueError, match="oracle-backed"):
        plan_query(MedoidQuery(X, metric="graph"))
    with pytest.raises(ValueError, match="single-medoid"):
        plan_query(MedoidQuery(g, metric="graph", topk=3))
    with pytest.raises(ValueError, match="single-medoid"):
        plan_query(MedoidQuery(g, metric="graph", k=2))
    with pytest.raises(ValueError, match="anytime"):
        plan_query(MedoidQuery(g, metric="graph", budget=10.0))
    # the registered pairwise_fn is the canonical routing error
    import jax.numpy as jnp
    from repro.core.distances import pairwise
    with pytest.raises(ValueError, match="oracle-backed"):
        pairwise(jnp.ones((2, 2)), jnp.ones((2, 2)), "graph")


def test_graph_cost_estimate_calibrated():
    """plan.cost_estimate within the planner's 2x contract on the gate's
    own workload (the vector golden grid cannot cover oracle inputs)."""
    g, _ = grid_network(2048, seed=0)
    q = MedoidQuery(g, metric="graph")
    plan = plan_query(q)
    rep = solve(MedoidQuery(GraphOracle(g.adj, g.n), metric="graph"))
    actual = rep.elements_computed
    assert actual / 2 <= plan.cost_estimate <= actual * 2, (
        plan.cost_estimate, actual)


def test_graph_degrades_to_sequential():
    g, _ = grid_network(150, seed=4)
    rep = solve(MedoidQuery(g, metric="graph", on_error="degrade",
                            engine_opts={"bogus_option": 1}))
    assert rep.plan.engine == "sequential"
    assert rep.certified
    assert any("degrade" in r for r in rep.plan.reasons)


# ---------------------------------------------------------------------------
# disconnected components
# ---------------------------------------------------------------------------
def _two_components():
    g1, _ = grid_network(64, seed=0)
    g2, _ = grid_network(64, seed=1)
    adj = {u: list(edges) for u, edges in g1.adj.items()}
    off = g1.n
    for u, edges in g2.adj.items():
        adj[u + off] = [(v + off, w) for v, w in edges]
    return GraphOracle(adj, g1.n + g2.n), off


def test_disconnected_component_edge_case():
    g, off = _two_components()
    # the sweep itself is well-defined: unreachable nodes stay inf
    D, _ = sweep_distances(g, [0])
    assert np.isfinite(D[0, :off]).all()
    assert np.isinf(D[0, off:]).all()
    # the engine refuses loudly (every energy is infinite)
    with pytest.raises(ValueError, match="disconnected"):
        graph_medoid(GraphOracle(g.adj, g.n))
    # largest_component restores a solvable graph
    adj2, keep = largest_component(g.adj, g.n)
    r, _ = graph_medoid(GraphOracle(adj2, len(keep)), n_landmarks=4)
    assert r.certified and 0 <= r.index < len(keep)


# ---------------------------------------------------------------------------
# host oracle accounting — the pair()/subrow() early-exit fix
# ---------------------------------------------------------------------------
def test_pair_early_exit_accounting():
    from repro.core.distances import elements_computed
    g, _ = sensor_network(250, seed=5)
    ref = g.row(0)
    # pair charges the settled-node count: at least 1, at most a sweep
    before = g.scalar_distances
    d = g.pair(0, 1)
    assert d == pytest.approx(ref[1])
    assert 1 <= g.scalar_distances - before <= g.n
    # a nearby target settles a small fraction of the graph
    j = int(np.argsort(ref)[1])
    before = g.scalar_distances
    g.pair(0, j)
    near_cost = g.scalar_distances - before
    assert near_cost < g.n // 2
    assert g.elements == elements_computed(g.scalar_distances, g.n)


def test_pair_unreachable_returns_inf():
    adj = {0: [(1, 1.0)], 1: [(0, 1.0)], 2: []}
    g = GraphOracle(adj, 3)
    assert g.pair(0, 2) == float("inf")
    assert g.pair(0, 1) == 1.0
    assert g.scalar_distances <= 2 * g.n


def test_subrow_settled_accounting():
    g, _ = sensor_network(250, seed=5)
    ref = g.row(0)
    g2 = GraphOracle(g.adj, g.n)
    idx = np.array([1, 5, 9])
    np.testing.assert_allclose(g2.subrow(0, idx), ref[idx])
    assert 0 < g2.scalar_distances <= g2.n      # never more than one sweep
    assert g2.elements <= 1.0


# ---------------------------------------------------------------------------
# OSM-style loader stub (repro.data.osm)
# ---------------------------------------------------------------------------
def test_osm_parser_roundtrip_and_errors(tmp_path):
    from repro.data.osm import load_osm_graph, parse_osm_text

    txt = ("node 10 0 0\nnode 20 3 4\nnode 30 0 4\n"
           "edge 10 20\n"          # implied Euclidean weight 5
           "edge 20 30 1.5\nedge 30 10\n")
    g, coords = parse_osm_text(txt)
    assert g.n == 3 and coords.shape == (3, 2)
    np.testing.assert_allclose(g.row(0), [0.0, 5.0, 4.0])
    r = solve(MedoidQuery(g, metric="graph"))
    assert r.plan.engine == "graph" and r.certified

    with pytest.raises(ValueError, match="expected"):
        parse_osm_text("node 1 0\n")
    with pytest.raises(ValueError, match="non-negative"):
        parse_osm_text("node 1 0 0\nnode 2 1 0\nedge 1 2 -3\n")
    with pytest.raises(ValueError, match="undeclared"):
        parse_osm_text("node 1 0 0\nedge 1 9\n")
    with pytest.raises(ValueError, match="duplicate"):
        parse_osm_text("node 1 0 0\nnode 1 1 1\n")
    # the missing-data error states the reproduction gap honestly
    with pytest.raises(FileNotFoundError, match="no OSM extract"):
        load_osm_graph(tmp_path / "missing.osm")
    p = tmp_path / "tiny.osm"
    p.write_text(txt)
    g2, _ = load_osm_graph(p)
    assert g2.n == 3


# ---------------------------------------------------------------------------
# observability: repro_obs_graph_* counters
# ---------------------------------------------------------------------------
def test_graph_obs_counters_track_sweeps():
    from repro.obs.metrics import REGISTRY

    def sweeps_total():
        return sum(row["value"] for row in REGISTRY.snapshot()
                   if row["name"] == "repro_obs_graph_sweeps_total")

    g, _ = grid_network(300, seed=9)
    before = sweeps_total()
    r, _ = graph_medoid(g, n_landmarks=4)
    assert sweeps_total() - before == r.n_computed
