"""Sharded engine parity (DESIGN.md §11).

The sharded pipelined engine must be *bit-identical* — medoid index,
energy, computed-element count — to the single-device pipelined engine
for any shard count dividing the fixed reduction grid, including ragged
N (tail-shard padding). Shard counts above ``jax.device_count()`` skip;
the CI multi-device job (``XLA_FLAGS=--xla_force_host_platform_device_
count=8``) runs the full grid, the single-device tier-1 job still
exercises the whole engine stack at P=1.
"""
import warnings

import numpy as np
import pytest

import jax

from _hyp import given, settings, st, watchdog

from repro.api import MedoidQuery, plan_query, solve
from repro.compat import make_1d_mesh

DEVICES = jax.device_count()
SHARD_COUNTS = [p for p in (1, 2, 8) if p <= DEVICES]

need8 = pytest.mark.skipif(
    DEVICES < 8,
    reason="needs 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
need2 = pytest.mark.skipif(DEVICES < 2, reason="needs >= 2 devices")


def _X(n, d=3, seed=0):
    return np.random.default_rng(seed).random((n, d)).astype(np.float32)


def _single_device_report(X, metric, block=128):
    """The sharded engines' parity oracle: pipelined for triangle
    metrics, the blockwise scan otherwise."""
    from repro.api import get_metric
    plan = "pipelined" if get_metric(metric).has_triangle else "scan"
    return solve(MedoidQuery(X, metric=metric, block=block), plan=plan)


# ---------------------------------------------------------------------------
# acceptance grid: 8 simulated devices, l2/l1/cosine, N in {1024, 4097}
# ---------------------------------------------------------------------------
@need8
@pytest.mark.parametrize("metric", ["l2", "l1", "cosine"])
@pytest.mark.parametrize("n", [1024, 4097])
def test_acceptance_bit_identical_on_8_devices(n, metric):
    X = _X(n, seed=n)
    q = MedoidQuery(X, metric=metric, device_policy="sharded")
    rep = solve(q)
    ref = _single_device_report(X, metric)
    assert rep.plan.params["n_shards"] == 8
    assert rep.index == ref.index
    assert rep.energy == ref.energy                 # bit-identical
    per_shard = rep.plan.params["per_shard_elements"]
    assert len(per_shard) == 8
    assert sum(per_shard) == rep.elements_computed


@pytest.mark.parametrize("p", SHARD_COUNTS)
def test_sharded_explicit_mesh_bit_identical(p):
    """Explicit mesh at every available shard count (P=1 runs in the
    single-device tier-1 job, covering the whole engine stack)."""
    X = _X(1024, seed=3)
    q = MedoidQuery(X, device_policy="sharded", mesh=make_1d_mesh(p))
    rep = solve(q)
    ref = _single_device_report(X, "l2")
    assert rep.plan.engine == "sharded"
    assert rep.plan.params["n_shards"] == p
    assert rep.index == ref.index
    assert rep.energy == ref.energy
    assert rep.elements_computed == ref.elements_computed
    assert rep.certified and rep.ci == 0.0


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(64, 220),
    d=st.integers(1, 4),
    block=st.integers(1, 8),
    seed=st.integers(0, 10_000),
    metric=st.sampled_from(["l2", "l1"]),
    p=st.sampled_from(SHARD_COUNTS),
    dup=st.booleans(),
)
def test_property_sharded_matches_single_device(n, d, block, seed, metric,
                                                p, dup):
    """Property: identical medoid index, energy and computed-element
    count across metrics, shard counts and ragged N (the tail shard is
    padded and masked — N is almost never divisible by P here)."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d)).astype(np.float32)
    if dup:                                    # force heavy duplication
        X = X[rng.integers(0, max(2, n // 4), n)]
    q = MedoidQuery(X, metric=metric, block=block,
                    device_policy="sharded", mesh=make_1d_mesh(p))
    rep = solve(q)
    ref = solve(MedoidQuery(X, metric=metric, block=block),
                plan="pipelined")
    assert rep.index == ref.index
    assert rep.energy == ref.energy
    assert rep.elements_computed == ref.elements_computed
    assert rep.extras["raw"].n_rounds == ref.extras["raw"].n_rounds


def test_sharded_block_wider_than_shard_stays_exact():
    """When block > per-shard column count the sharded engine clamps its
    round width (round structure diverges from single-device) but the
    deviation is loud — a UserWarning from the engine, the clamped width
    in ``plan.params['block_effective']`` — and exactness must hold:
    same medoid, same exact energy."""
    from repro.core.distributed import effective_block
    p = max(SHARD_COUNTS)
    X = _X(333, seed=11)
    q = MedoidQuery(X, block=128, device_policy="sharded",
                    mesh=make_1d_mesh(p))
    eff = effective_block(333, p, 128)
    if p > 1:
        assert eff < 128
        assert plan_query(q).params["block_effective"] == eff
        with pytest.warns(UserWarning, match="round width clamped"):
            rep = solve(q)
    else:                          # P=1: no clamp, no warning, no param
        assert eff == 128
        assert "block_effective" not in plan_query(q).params
        with warnings.catch_warnings():
            warnings.simplefilter("error", UserWarning)
            rep = solve(q)
    ref = _single_device_report(X, "l2")
    assert rep.index == ref.index
    assert rep.energy == ref.energy


# ---------------------------------------------------------------------------
# skewed survivor distributions (sorted / clustered inputs)
# ---------------------------------------------------------------------------
def _blob_X(n=4097, d=3, seed=7):
    """Tight, well-separated Gaussian blobs laid out contiguously, so
    survivors concentrate in the medoid blob's column shard(s)."""
    rng = np.random.default_rng(seed)
    centers = 50.0 * rng.standard_normal((8, d)).astype(np.float32)
    sizes = np.full(8, n // 8)
    sizes[: n - sizes.sum()] += 1
    return np.concatenate(
        [c + 0.01 * rng.standard_normal((s, d)).astype(np.float32)
         for c, s in zip(centers, sizes)])


@need2
@pytest.mark.parametrize("kind", ["sorted", "blobs"])
def test_sharded_skewed_survivors_terminate_and_match(kind):
    """Contiguous column shards of sorted or clustered data put most
    survivors in one or two shards (max per-shard live >> mean) — the
    regime where a compaction-ladder gate comparing the *global* live
    total against the max-sized rung goes false at stage entry and the
    host rebuilds a zero-round stage forever. The watchdog turns a
    regression into a failure instead of a hung CI job; parity with the
    single-device engine must still be bit-exact."""
    rng = np.random.default_rng(7)
    if kind == "sorted":
        X = rng.standard_normal((4097, 3)).astype(np.float32)
        X = X[np.argsort(X[:, 0], kind="stable")]
    else:
        X = _blob_X()

    with watchdog(
            300, "sharded compaction ladder stalled (zero-round stage)"):
        rep = solve(MedoidQuery(X, device_policy="sharded",
                                mesh=make_1d_mesh(max(SHARD_COUNTS))))
    ref = _single_device_report(X, "l2")
    assert rep.index == ref.index
    assert rep.energy == ref.energy
    assert rep.elements_computed == ref.elements_computed


# ---------------------------------------------------------------------------
# sharded scan fallback: non-triangle and registered user metrics
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("metric", ["cosine", "sqeuclidean"])
def test_sharded_scan_fallback_bit_identical(metric):
    X = _X(777, d=4, seed=7)
    q = MedoidQuery(X, metric=metric, device_policy="sharded")
    plan = plan_query(q)
    assert plan.engine == "scan" and plan.params["sharded"]
    rep = solve(q)
    ref = solve(MedoidQuery(X, metric=metric), plan="scan")
    assert rep.index == ref.index
    assert rep.energy == ref.energy
    assert sum(rep.plan.params["per_shard_elements"]) == len(X)


def test_sharded_scan_registered_user_metric():
    """A register_metric-defined metric runs through the sharded scan
    via its pairwise_fn inside shard_map — no repro internals touched."""
    import jax.numpy as jnp
    from repro.api import register_metric, unregister_metric

    def chebyshev(a, b):
        return jnp.max(jnp.abs(a[:, None, :] - b[None, :, :]), axis=-1)

    register_metric("chebyshev_sharded", chebyshev, has_triangle=False)
    try:
        X = _X(300, d=4, seed=9)
        D = np.abs(X[:, None, :] - X[None, :, :]).max(-1)
        ti = int(D.sum(1).argmin())
        rep = solve(MedoidQuery(X, metric="chebyshev_sharded",
                                device_policy="sharded"))
        assert rep.plan.engine == "scan" and rep.plan.params["sharded"]
        assert rep.index == ti
    finally:
        unregister_metric("chebyshev_sharded")


# ---------------------------------------------------------------------------
# batched multi-cluster variant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("p", SHARD_COUNTS)
def test_batched_sharded_matches_batched_pipelined(p):
    rng = np.random.default_rng(2)
    n, k = 1500, 5
    X = rng.standard_normal((n, 3)).astype(np.float32)
    a = rng.integers(0, k, n)
    q = MedoidQuery(X, k=k, assignments=a, block=24,
                    device_policy="sharded", mesh=make_1d_mesh(p))
    rep = solve(q)
    ref = solve(MedoidQuery(X, k=k, assignments=a, block=24),
                plan="batched_pipelined")
    assert rep.plan.engine == "batched_sharded"
    assert np.array_equal(rep.indices, ref.indices)
    assert np.array_equal(rep.energies, ref.energies)   # bit-identical
    assert rep.elements_computed == ref.elements_computed


def test_batched_sharded_warm_empty_and_oob_clusters():
    p = max(SHARD_COUNTS)
    rng = np.random.default_rng(3)
    n, k = 600, 6
    X = rng.standard_normal((n, 2)).astype(np.float32)
    a = rng.integers(0, 4, n)                  # clusters 4, 5 empty
    a[:5] = -1                                 # out-of-range labels
    ref = solve(MedoidQuery(X, k=k, assignments=a, block=32),
                plan="batched_pipelined")
    rep = solve(MedoidQuery(X, k=k, assignments=a, block=32,
                            device_policy="sharded", mesh=make_1d_mesh(p)))
    assert np.array_equal(rep.indices, ref.indices)
    assert rep.indices[4] == -1 and rep.indices[5] == -1
    # warm start from the known answer terminates and stays exact
    warm = solve(MedoidQuery(X, k=k, assignments=a, block=32,
                             warm_idx=ref.indices, device_policy="sharded",
                             mesh=make_1d_mesh(p)))
    assert np.array_equal(warm.indices, ref.indices)


def test_kmedoids_sharded_update_matches_pipelined():
    from repro.core import kmedoids_batched
    rng = np.random.default_rng(5)
    X = rng.standard_normal((900, 4)).astype(np.float32)
    r_pip = kmedoids_batched(X, 5, n_iter=3, medoid_update="pipelined")
    r_sh = kmedoids_batched(X, 5, n_iter=3, medoid_update="sharded",
                            mesh=make_1d_mesh(max(SHARD_COUNTS)))
    assert np.array_equal(r_pip.medoids, r_sh.medoids)
    assert np.array_equal(r_pip.assignment, r_sh.assignment)
    assert abs(r_pip.energy - r_sh.energy) < 1e-3


def test_kmedoids_sharded_non_triangle_reports_scan_update():
    """device_policy='sharded' with a non-triangle metric cannot use the
    sharded elimination update; the plan must record the driver's exact
    host-scan fallback honestly — no 'sharded' label, no phantom
    n_shards — instead of claiming a sharded update the driver silently
    downgrades."""
    q = MedoidQuery(_X(300, seed=23), k=3, n_iter=2, metric="cosine",
                    device_policy="sharded")
    plan = plan_query(q)
    assert plan.engine == "kmedoids"
    assert plan.params["medoid_update"] == "scan"
    assert "n_shards" not in plan.params
    assert any("non-triangle" in r for r in plan.reasons)
    rep = solve(q)
    assert rep.extras["medoid_update"] == "scan"


def test_kmedoids_sharded_via_query():
    X = _X(400, seed=13)
    rep = solve(MedoidQuery(X, k=3, n_iter=2, device_policy="sharded"))
    assert rep.plan.params["medoid_update"] == "sharded"
    ref = solve(MedoidQuery(X, k=3, n_iter=2,
                            update=MedoidQuery(
                                None, engine_opts={"engine": "pipelined"})))
    assert np.array_equal(rep.indices, ref.indices)


# ---------------------------------------------------------------------------
# kernel path (Pallas interpret on CPU) — exact, index-level parity
# ---------------------------------------------------------------------------
def test_sharded_kernel_path_matches_jnp():
    p = max(SHARD_COUNTS)
    X = _X(500, d=4, seed=17)
    mesh = make_1d_mesh(p)
    r_jnp = solve(MedoidQuery(X, block=32, device_policy="sharded",
                              mesh=mesh))
    r_ker = solve(MedoidQuery(X, block=32, device_policy="sharded",
                              mesh=mesh, use_kernels=True))
    assert r_jnp.index == r_ker.index
    np.testing.assert_allclose(r_jnp.energy, r_ker.energy, rtol=1e-5)


# ---------------------------------------------------------------------------
# plumbing: accounting, mesh validation, deprecation shim
# ---------------------------------------------------------------------------
def test_sharded_plan_records_shard_accounting():
    rep = solve(MedoidQuery(_X(512, seed=1), device_policy="sharded"))
    p = rep.plan
    assert p.params["n_shards"] >= 1
    per = p.params["per_shard_elements"]
    assert len(per) == p.params["n_shards"]
    assert sum(per) == rep.elements_computed
    assert np.array_equal(rep.extras["per_shard_elements"], per)


def test_sharded_rejects_non_dividing_mesh():
    from repro.core.distributed import _resolve_mesh
    if DEVICES < 5:
        pytest.skip("needs >= 5 devices for a non-dividing axis size")
    with pytest.raises(ValueError, match="does not divide"):
        _resolve_mesh(make_1d_mesh(5), "shard")


def test_shard_count_for_picks_largest_divisor():
    from repro.core.distances import REDUCE_CHUNKS
    from repro.core.distributed import shard_count_for
    assert shard_count_for(1) == 1
    assert shard_count_for(8) == 8
    assert shard_count_for(5) == 4
    assert shard_count_for(16) == 16
    assert shard_count_for(10**6) == REDUCE_CHUNKS


def test_trimed_sharded_shim_warns_and_matches_solve():
    from repro.core.distributed import trimed_sharded
    X = _X(400, seed=21)
    mesh = make_1d_mesh(max(SHARD_COUNTS), "data")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = trimed_sharded(X, mesh, axis="data", block=32)
    msgs = [x for x in w if issubclass(x.category, DeprecationWarning)
            and "repro legacy entrypoint" in str(x.message)]
    assert len(msgs) == 1
    rep = solve(MedoidQuery(X, block=32, device_policy="sharded",
                            mesh=mesh, engine_opts={"axis": "data"}),
                plan="sharded")
    assert r == rep.extras["raw"]


@need2
def test_sharded_ragged_tail_multi_device():
    """N chosen so the tail shard is mostly padding."""
    for n in (1001, 4097):
        X = _X(n, seed=n)
        rep = solve(MedoidQuery(X, device_policy="sharded",
                                mesh=make_1d_mesh(2)))
        ref = _single_device_report(X, "l2")
        assert rep.index == ref.index and rep.energy == ref.energy
