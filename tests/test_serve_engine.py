"""MedoidServer: budget-aware admission over solve_many (DESIGN.md §12).

Pins the scheduler's contract: shape-bucketing is deterministic (same
submissions → same buckets, same packed plans), admission against the
global element budget is FIFO (the exact prefix is the longest prefix
whose cumulative ``plan.cost_estimate`` fits — later requests never
leapfrog an earlier overflow, even when they would fit), and over-budget
traffic is *degraded, never dropped*: every request comes back with a
report, the over-budget ones as ``mode="anytime"`` with
``certified=False`` and a recorded deterministic CI. The shared
``watchdog`` (``tests/_hyp.py``, same pattern as ``test_sharded.py``)
turns a scheduler stall into a test failure instead of a hung CI job.
"""
import numpy as np
import pytest

from _hyp import watchdog

from repro import MedoidQuery
from repro.serve.engine import MedoidServer


def _X(n, d=3, seed=0):
    return np.random.default_rng(seed).standard_normal((n, d)).astype(
        np.float32)


def _mixed_queries():
    return ([MedoidQuery(_X(256, seed=s)) for s in range(4)]
            + [MedoidQuery(_X(512, seed=s)) for s in range(3)]
            + [MedoidQuery(_X(256, seed=10 + s), metric="l1")
               for s in range(2)])


# ---------------------------------------------------------------------------
# bucketing determinism
# ---------------------------------------------------------------------------
def test_bucketing_deterministic():
    """Two servers fed identical submissions pack identical buckets and
    produce bit-identical reports, uid for uid."""
    outs = []
    for _run in range(2):
        srv = MedoidServer(budget=1e9)
        for q in _mixed_queries():
            srv.submit(q)
        served = srv.step()
        outs.append((srv.steps[0]["buckets"],
                     [(r.uid, r.admitted_mode,
                       int(r.report.indices[0]),
                       float(r.report.energies[0]),
                       r.report.elements_computed,
                       r.report.plan.params["solve_many"]["bucket"])
                      for r in served]))
    assert outs[0] == outs[1]
    buckets = outs[0][0]
    assert len(buckets) == 3          # (256,l2), (512,l2), (256,l1)


# ---------------------------------------------------------------------------
# budget admission: degrade, never drop
# ---------------------------------------------------------------------------
def test_over_budget_degrades_to_anytime_with_ci():
    srv = MedoidServer(budget=500.0, anytime_floor=16)
    uids = [srv.submit(q) for q in _mixed_queries()]
    served = srv.step()
    assert [r.uid for r in served] == uids       # nothing dropped, FIFO
    assert not srv.queue
    modes = [r.admitted_mode for r in served]
    assert "exact" in modes and "anytime" in modes
    for r in served:
        assert r.report is not None
        assert r.cost_estimate > 0
        if r.admitted_mode == "exact":
            assert r.report.certified and r.report.ci == 0.0
        else:
            assert not r.report.certified
            assert 0.0 < r.report.ci < np.inf
    stats = srv.steps[0]
    assert stats["n_exact"] + stats["n_anytime"] == len(uids)
    assert stats["spent_elements"] == sum(
        r.report.elements_computed for r in served)


def test_everything_fits_stays_exact():
    srv = MedoidServer(budget=1e9)
    for q in _mixed_queries():
        srv.submit(q)
    served = srv.step()
    assert all(r.admitted_mode == "exact" for r in served)
    assert all(r.report.certified for r in served)
    assert srv.steps[0]["n_anytime"] == 0


# ---------------------------------------------------------------------------
# FIFO fairness
# ---------------------------------------------------------------------------
def test_fifo_exact_prefix_no_leapfrog():
    """Admission is the FIFO prefix by cumulative estimate: once one
    request overflows, a later *smaller* request is not admitted exact
    ahead of it, even though it would fit the leftover budget."""
    big, small = MedoidQuery(_X(512, seed=1)), MedoidQuery(_X(64, seed=2))
    probe = MedoidServer(budget=1e9)
    probe.submit(big)
    est_big = probe.step()[0].cost_estimate

    srv = MedoidServer(budget=est_big * 0.5, anytime_floor=8)
    srv.submit(big)
    srv.submit(small)
    served = srv.step()
    assert [r.admitted_mode for r in served] == ["anytime", "anytime"]
    # flipped order, the small one fits and runs exact
    srv2 = MedoidServer(budget=est_big * 0.5, anytime_floor=8)
    srv2.submit(small)
    srv2.submit(big)
    modes = [r.admitted_mode for r in srv2.step()]
    assert modes == ["exact", "anytime"]


def test_run_drains_queue_in_order():
    srv = MedoidServer(budget=1e9, max_batch=3)
    uids = [srv.submit(MedoidQuery(_X(128, seed=s))) for s in range(7)]
    finished = srv.run()
    assert [r.uid for r in finished] == uids
    assert [s["n_requests"] for s in srv.steps] == [3, 3, 1]
    assert all(r.step == i // 3 for i, r in enumerate(finished))


# ---------------------------------------------------------------------------
# validation + watchdog
# ---------------------------------------------------------------------------
def test_submit_rejects_unpackable_queries():
    srv = MedoidServer()
    with pytest.raises(ValueError, match="single-medoid"):
        srv.submit(MedoidQuery(_X(64), k=4))
    with pytest.raises(ValueError, match="triangle"):
        srv.submit(MedoidQuery(_X(64), metric="cosine"))
    assert not srv.queue                     # rejected at the door


def test_server_under_watchdog():
    """A full submit/step/drain cycle with mixed shapes and a tight
    budget completes well under the alarm — a scheduler livelock (e.g.
    an admission loop that re-queues overflow forever) fails loudly."""
    with watchdog(300, "MedoidServer stalled draining its queue"):
        srv = MedoidServer(budget=300.0, anytime_floor=8, max_batch=4)
        for q in _mixed_queries():
            srv.submit(q)
        finished = srv.run()
    assert len(finished) == len(_mixed_queries())
    assert all(r.report is not None for r in finished)


# ---------------------------------------------------------------------------
# observability: calibration metric, metrics endpoint, structured events
# ---------------------------------------------------------------------------
def test_cost_estimate_error_within_2x():
    """The step summary's ``cost_estimate_error`` (engine-reported spent
    elements over the planner's admission estimate, exact-admitted
    requests only) stays within the cost model's calibrated 2x bound —
    the same bound tests/test_api.py pins per-engine."""
    srv = MedoidServer(budget=1e9)
    for q in _mixed_queries():
        srv.submit(q)
    srv.step()
    summary = srv.steps[0]
    err = summary["cost_estimate_error"]
    assert err is not None
    assert 0.5 <= err <= 2.0, (
        f"cost model drifted: spent/estimated = {err}")
    # the ratio is consistent with the raw step accounting
    assert summary["estimated_elements"] > 0
    assert summary["spent_elements"] > 0


def test_metrics_text_endpoint():
    srv = MedoidServer(budget=1e9)
    for s in range(3):
        srv.submit(MedoidQuery(_X(128, seed=s)))
    srv.step()
    text = srv.metrics_text()
    assert "# TYPE repro_obs_serve_requests_total counter" in text
    assert 'repro_obs_serve_requests_total{mode="exact"} 3' in text
    assert "# TYPE repro_obs_serve_queue_depth gauge" in text
    assert "repro_obs_serve_queue_depth 0" in text
    assert "repro_obs_serve_budget_utilisation_count 1" in text
    assert "repro_obs_serve_cost_estimate_error_sum" in text


def test_structured_events_replace_decisions():
    """Failure handling emits typed events (schema repro.obs.serve/v1)
    whose human-readable mirror is what lands in ``req.decisions`` —
    the audit trail keeps its strings, the event log carries the
    structure."""
    from repro.runtime import faults
    from repro.serve.engine import SERVE_EVENTS_SCHEMA

    srv = MedoidServer(budget=1e9, max_retries=0)
    X_bad = _X(128, seed=0)
    srv.submit(MedoidQuery(X_bad))
    with faults.inject(faults.FaultSpec()):
        faults.mark_poison(X_bad)
        with watchdog(300, "poisoned step stalled"):
            served = srv.step()
    kinds = [e["kind"] for e in srv.events]
    assert "failure" in kinds and "quarantine" in kinds
    assert kinds[-1] == "step"
    assert all(e["schema"] == SERVE_EVENTS_SCHEMA for e in srv.events)
    fail = next(e for e in srv.events if e["kind"] == "failure")
    assert fail["uid"] == served[0].uid and fail["attempt"] == 1
    # the human strings the fault tests pin are still on the request
    assert any("attempt 1 failed" in d for d in served[0].decisions)
    assert any("quarantined after" in d for d in served[0].decisions)
    text = srv.metrics_text()
    assert "repro_obs_serve_failures_total 1" in text
    assert "repro_obs_serve_quarantined_total 1" in text


def test_backoff_events_and_counters():
    from repro.runtime import faults

    srv = MedoidServer(budget=1e9, max_retries=2, backoff_base=1)
    X_bad = _X(128, seed=1)
    srv.submit(MedoidQuery(X_bad))
    with faults.inject(faults.FaultSpec()):
        faults.mark_poison(X_bad)
        with watchdog(300, "backoff step stalled"):
            srv.step()
    backs = [e for e in srv.events if e["kind"] == "backoff"]
    assert len(backs) == 1 and backs[0]["backoff_steps"] == 1
    text = srv.metrics_text()
    assert "repro_obs_serve_retries_total 1" in text
    assert "repro_obs_serve_backoff_steps_total 1" in text


def test_stateful_index_mode():
    """attach_index makes a streaming index resident: churn + query go
    through the server, land in the serve event log, and the stream
    instrument family registers on the server's scrape endpoint."""
    from repro.core.pipelined import _trimed_pipelined
    from repro.serve.engine import SERVE_EVENTS_SCHEMA
    from repro.stream import MedoidIndex

    X = _X(300, seed=5)
    srv = MedoidServer()
    srv.attach_index(MedoidIndex.from_data(X))
    srv.index_query()
    rows = _X(4, seed=6)
    srv.index_insert(rows)
    srv.index_delete([5, 9])
    X = np.delete(np.concatenate([X, rows]), [5, 9], axis=0)
    res = srv.index_query()
    ref = _trimed_pipelined(X, metric="l2")
    assert (res.index, res.energy, res.certified) == (
        ref.index, ref.energy, ref.certified)
    kinds = [e["kind"] for e in srv.events]
    assert kinds == ["index_attach", "index_query", "index_churn",
                     "index_churn", "index_query"]
    assert all(e["schema"] == SERVE_EVENTS_SCHEMA for e in srv.events)
    q = srv.events[-1]
    assert q["index"] == ref.index and q["elements"] > 0
    text = srv.metrics_text()
    assert 'repro_obs_stream_ops_total{op="insert"} 1' in text
    assert "repro_obs_stream_repairs_total" in text
    with pytest.raises(KeyError, match="attach_index"):
        srv.index_query("nope")
