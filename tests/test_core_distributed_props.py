"""Additional property tests on the core invariants (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import exact_energies, trimed_block, trimed_sequential
from repro.core.distances import VectorOracle


@settings(max_examples=20, deadline=None)
@given(n=st.integers(8, 150), d=st.integers(1, 5),
       eps=st.floats(0.0, 0.6), seed=st.integers(0, 9999))
def test_property_eps_energy_guarantee(n, d, eps, seed):
    """trimed-eps returns an element within (1+eps) of the optimum —
    the paper's §4 guarantee, for arbitrary data/eps. fp64 reference:
    the jnp one is fp32 and its rounding breaks exact-eps comparisons."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, d))
    d2 = ((X[:, None, :] - X[None, :, :]) ** 2).sum(-1)
    e = np.sqrt(np.maximum(d2, 0)).sum(1) / (n - 1)
    r = trimed_sequential(X, seed=seed, eps=eps)
    assert r.energy <= e.min() * (1 + eps) + 1e-9


@settings(max_examples=20, deadline=None)
@given(n=st.integers(4, 100), seed=st.integers(0, 9999))
def test_property_metric_axioms_hold_for_oracle(n, seed):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, 4))
    o = VectorOracle(X)
    i, j, k = rng.integers(0, n, 3)
    dij, djk, dik = o.pair(i, j), o.pair(j, k), o.pair(i, k)
    assert dik <= dij + djk + 1e-9
    assert abs(o.pair(i, j) - o.pair(j, i)) < 1e-9
    assert o.pair(i, i) < 1e-12


@settings(max_examples=10, deadline=None)
@given(n=st.integers(64, 300), block=st.integers(1, 64),
       seed=st.integers(0, 999))
def test_property_block_counts_bounded(n, block, seed):
    """Computed elements never exceed N, and the block variant's waste
    over the whole run is bounded by block-1 per round."""
    rng = np.random.default_rng(seed)
    X = rng.random((n, 2)).astype(np.float32)
    r = trimed_block(X, block=block, seed=seed)
    assert r.n_computed <= n
    assert r.n_computed <= r.n_rounds * min(block, n)


def test_counts_monotone_in_dimension():
    """Thm 3.2's d-dependence: higher d computes more (fixed N, dist)."""
    rng = np.random.default_rng(0)
    counts = []
    for d in (2, 4, 8):
        X = rng.random((4000, d))
        counts.append(trimed_sequential(X, seed=0).n_computed)
    assert counts[0] < counts[1] < counts[2]
