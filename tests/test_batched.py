"""Correctness of the batched multi-cluster trimed engine (DESIGN.md §3)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import batched_medoids, kmedoids_batched, kmedoids_jax
from repro.core.trimed import trimed_sequential
from repro.kernels import ops, ref
from repro.kernels.ops import fused_masked_round


def _clustered(n, d, k_true, seed=0, spread=0.5):
    rng = np.random.default_rng(seed)
    centers = rng.random((k_true, d)) * 10
    idx = rng.integers(0, k_true, n)
    return (centers[idx]
            + rng.standard_normal((n, d)) * spread).astype(np.float32)


def _per_cluster_expected(X, a, k):
    """fp64 per-cluster exact medoids via the sequential oracle."""
    want = np.full(k, -1)
    for kk in range(k):
        members = np.flatnonzero(a == kk)
        if len(members) == 0:
            continue
        r = trimed_sequential(np.asarray(X[members], np.float64), seed=1)
        want[kk] = members[r.index]
    return want


# ---------------------------------------------------------------------------
# engine exactness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,d,k,block", [
    (400, 2, 5, 64), (600, 3, 7, 32), (300, 5, 1, 16), (257, 2, 9, 128),
])
def test_engine_matches_sequential_per_cluster(n, d, k, block):
    rng = np.random.default_rng(n)
    X = rng.random((n, d)).astype(np.float32)
    a = rng.integers(0, k, n)
    r = batched_medoids(X, a, k, block=block)
    want = _per_cluster_expected(X, a, k)
    np.testing.assert_array_equal(r.medoids, want)
    assert r.n_computed <= n


def test_engine_fused_path_matches_dense():
    X = _clustered(500, 3, 6, seed=2)
    rng = np.random.default_rng(2)
    a = rng.integers(0, 6, 500)
    dense = batched_medoids(X, a, 6, block=32)
    fused = batched_medoids(X, a, 6, block=32,
                            fused_round_fn=fused_masked_round)
    np.testing.assert_array_equal(dense.medoids, fused.medoids)
    np.testing.assert_array_equal(dense.medoids,
                                  _per_cluster_expected(X, a, 6))


def test_engine_empty_cluster_reports_minus_one():
    rng = np.random.default_rng(4)
    X = rng.random((200, 2)).astype(np.float32)
    a = rng.integers(0, 3, 200)          # clusters 3, 4 stay empty
    r = batched_medoids(X, a, 5, block=32)
    assert r.medoids[3] == -1 and r.medoids[4] == -1
    np.testing.assert_array_equal(r.medoids[:3],
                                  _per_cluster_expected(X, a, 3))


def test_engine_warm_start_stays_exact():
    """Warm seeding changes the exploration order, never the answer.
    (It is not guaranteed to reduce rows: an optimal threshold steers
    selection toward central, weakly-tightening pivots — exploration
    cost is a heuristic property, exactness is the invariant.)"""
    X = _clustered(1000, 2, 6, seed=6)
    rng = np.random.default_rng(6)
    a = rng.integers(0, 6, 1000)
    want = _per_cluster_expected(X, a, 6)
    cold = batched_medoids(X, a, 6, block=64)
    warm = batched_medoids(X, a, 6, block=64,
                           warm_idx=np.asarray(want))
    np.testing.assert_array_equal(cold.medoids, want)
    np.testing.assert_array_equal(warm.medoids, want)
    assert warm.n_computed < len(X)


# ---------------------------------------------------------------------------
# masked kernels vs pure-jnp references
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,n,d,k", [(8, 300, 5, 4), (16, 1000, 37, 7),
                                     (1, 130, 1, 1), (32, 512, 128, 5)])
def test_masked_kernels_match_ref(b, n, d, k):
    rng = np.random.default_rng(b + n)
    x = rng.standard_normal((n, d)).astype(np.float32)
    xb_idx = rng.integers(0, n, b)
    xb = x[xb_idx]
    a_x = rng.integers(0, k, n).astype(np.int32)
    a_piv = a_x[xb_idx]
    v = np.bincount(a_x, minlength=k)
    v_piv = v[a_piv].astype(np.float32)
    l = np.abs(rng.standard_normal(n)).astype(np.float32)
    valid = rng.random(b) > 0.3
    if not valid.any():
        valid[0] = True
    args = [jnp.asarray(v) for v in (xb, x, l, valid, a_piv, a_x, v_piv)]
    s_got, l_got = ops.fused_masked_round(*args)
    s_want, l_want = ref.fused_masked_round_ref(*args)
    # rtol 1e-3: the bound gap |v*D - S| amplifies fp32 summation-order
    # differences by the cluster size v
    np.testing.assert_allclose(s_got, s_want, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(l_got, l_want, rtol=1e-3, atol=1e-3)


def test_masked_energy_equals_unmasked_single_cluster():
    """With one cluster the masked kernels degenerate to the plain ones."""
    rng = np.random.default_rng(9)
    x = rng.standard_normal((400, 7)).astype(np.float32)
    xb = x[:16]
    zeros = jnp.zeros(400, jnp.int32)
    s = ops.masked_energies(jnp.asarray(xb), jnp.asarray(x),
                            jnp.zeros(16, jnp.int32), zeros)
    e = ops.block_energies(jnp.asarray(xb), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(s), np.asarray(e),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# K-medoids integration: exactness and the sub-quadratic regression
# ---------------------------------------------------------------------------
def test_kmedoids_trimed_matches_scan():
    """Both medoid updates are exact per iteration, so the trajectories
    are identical on well-separated data."""
    X = _clustered(1500, 3, 8, seed=11, spread=0.3)
    rt = kmedoids_batched(X, 8, seed=0, n_iter=5, medoid_update="trimed")
    rs = kmedoids_batched(X, 8, seed=0, n_iter=5, medoid_update="scan")
    np.testing.assert_array_equal(rt.medoids, rs.medoids)
    np.testing.assert_array_equal(rt.assignment, rs.assignment)
    assert abs(rt.energy - rs.energy) <= 1e-3 * max(1.0, abs(rs.energy))


def test_engine_fewer_distances_than_quadratic_regression():
    """At N >= 2048 the engine must compute strictly fewer distances than
    the quadratic medoid-update scan (the PR's reason to exist)."""
    n = 2048
    X = _clustered(n, 3, 8, seed=13)
    rt = kmedoids_batched(X, 8, seed=0, n_iter=4, medoid_update="trimed")
    rs = kmedoids_batched(X, 8, seed=0, n_iter=4, medoid_update="scan")
    assert rt.n_distances < rs.n_distances
    assert abs(rt.energy - rs.energy) <= 1e-3 * max(1.0, abs(rs.energy))


def test_engine_rejects_non_triangle_metrics():
    """The elimination bound is the triangle bound; sqeuclidean/cosine
    violate it and must be rejected, not silently mis-answered."""
    X = np.random.default_rng(0).random((50, 2)).astype(np.float32)
    a = np.zeros(50, dtype=np.int32)
    for metric in ("sqeuclidean", "cosine"):
        with pytest.raises(ValueError):
            batched_medoids(X, a, 1, metric=metric)


def test_kmedoids_non_triangle_metric_falls_back_to_scan():
    """kmedoids_jax stays exact for sqeuclidean by auto-selecting the
    quadratic scan (identical rows/medoids to explicit scan)."""
    X = _clustered(400, 3, 4, seed=21)
    rt = kmedoids_batched(X, 4, n_iter=3, metric="sqeuclidean",
                          medoid_update="trimed")
    rs = kmedoids_batched(X, 4, n_iter=3, metric="sqeuclidean",
                          medoid_update="scan")
    np.testing.assert_array_equal(rt.medoids, rs.medoids)
    assert rt.n_rows == rs.n_rows


def test_kmedoids_rejects_bad_medoid_update():
    X = np.random.default_rng(0).random((64, 2)).astype(np.float32)
    with pytest.raises(ValueError):
        kmedoids_batched(X, 4, medoid_update="trimedd")


def test_kmedoids_use_kernels_matches_jnp_round():
    X = _clustered(600, 3, 5, seed=23)
    mk, ak, _ = kmedoids_jax(X, 5, n_iter=3, use_kernels=True)
    mj, aj, _ = kmedoids_jax(X, 5, n_iter=3, use_kernels=False)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mj))
    np.testing.assert_array_equal(np.asarray(ak), np.asarray(aj))


def test_standalone_engine_strictly_sub_n_rows():
    """On a fixed assignment at N=2048 the engine explores well under N
    rows (sub-quadratic in scalar distances)."""
    n = 2048
    X = _clustered(n, 3, 8, seed=17)
    rng = np.random.default_rng(17)
    a = rng.integers(0, 8, n)
    r = batched_medoids(X, a, 8, block=128)
    assert r.n_computed < n
    assert r.n_distances == r.n_computed * n