"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finite checks; decode consistency for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch
from repro.configs.base import ARCHS, SHAPES, get_config, get_smoke_config, shape_applicable
from repro.models import model as M


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = make_batch(cfg, key, batch=2, seq=64)
    loss, metrics = M.train_loss(cfg, params, batch)
    assert jnp.isfinite(loss), arch
    # one SGD step must also be finite (checks the backward pass)
    grads = jax.grad(lambda p: M.train_loss(cfg, p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_logit_shapes(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    batch = make_batch(cfg, key, batch=2, seq=32)
    logits, _, _ = M.forward(cfg, params, batch)
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    assert logits.shape == (2, 32 + extra, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if get_smoke_config(a).supports_decode])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    B, S, extra_steps = 2, 17, 3
    toks = jax.random.randint(key, (B, S + extra_steps), 0, cfg.vocab)
    if cfg.family == "moe":
        # dropless serving path vs dropless reference
        ref_last, _ = M.prefill(
            cfg, params, {"tokens": toks},
            M.init_cache(cfg, B, S + extra_steps))
    else:
        logits_full, _, _ = M.forward(cfg, params, {"tokens": toks})
        ref_last = logits_full[:, -1]
    cache = M.init_cache(cfg, B, S + extra_steps)
    lg, cache = M.prefill(cfg, params, {"tokens": toks[:, :S]}, cache)
    for i in range(extra_steps):
        lg, cache = M.decode_step(cfg, params, toks[:, S + i:S + i + 1],
                                  cache, jnp.asarray(S + i, jnp.int32))
    err = float(jnp.max(jnp.abs(lg - ref_last)))
    assert err < 5e-3, (arch, err)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact published numbers."""
    cfg = get_config(arch)
    expected = {
        "minicpm3_4b": (62, 2560, 40, 40, 6400, 73448),
        "starcoder2_7b": (32, 4608, 36, 4, 18432, 49152),
        "codeqwen15_7b": (32, 4096, 32, 32, 13440, 92416),
        "qwen3_4b": (36, 2560, 32, 8, 9728, 151936),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
        "internvl2_26b": (48, 6144, 48, 8, 16384, 92553),
        "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
        "zamba2_1_2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_cell_accounting():
    """40 cells total: 31 lowered + 9 documented skips (DESIGN.md §7)."""
    runs, skips = 0, 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            if ok:
                runs += 1
            else:
                skips += 1
                assert why
    assert runs + skips == 40
    assert runs == 31 and skips == 9


def test_moe_dropless_matches_capacity_when_no_drops():
    """With generous capacity, the two MoE paths agree."""
    from repro.models import moe as moe_mod

    cfg = get_smoke_config("qwen2_moe_a2_7b")
    cfg = cfg.replace(moe=cfg.moe.__class__(
        n_experts=8, top_k=2, d_expert=96, n_shared=1, capacity_factor=8.0))
    key = jax.random.PRNGKey(3)
    p = moe_mod.init_moe(cfg, key)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    y_cap, _ = moe_mod.moe_fwd(cfg, p, x, dropless=False)
    y_dl, _ = moe_mod.moe_fwd(cfg, p, x, dropless=True)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_dl),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_chunked_matches_stepwise():
    """Chunked SSD == step-by-step recurrence (the decode path)."""
    cfg = get_smoke_config("zamba2_1_2b")
    from repro.models import mamba2

    key = jax.random.PRNGKey(4)
    p = mamba2.init_mamba2_layer(cfg, key)
    x = jax.random.normal(key, (2, 24, cfg.d_model), jnp.float32) * 0.3
    y_chunk, _ = mamba2.mamba2_layer_fwd(cfg, p, x)
    st = mamba2.init_mamba2_state(cfg, 2)
    outs = []
    for t in range(24):
        y, st = mamba2.mamba2_layer_fwd(cfg, p, x[:, t:t + 1], state=st)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_scan_matches_stepwise():
    cfg = get_smoke_config("rwkv6_7b")
    from repro.models import rwkv6

    key = jax.random.PRNGKey(5)
    p = rwkv6.init_rwkv_layer(cfg, key)
    x = jax.random.normal(key, (2, 12, cfg.d_model), jnp.float32) * 0.3
    y_full, _ = rwkv6.rwkv_layer_fwd(cfg, p, x)
    st = rwkv6.init_rwkv_state(cfg, 2)
    outs = []
    for t in range(12):
        y, st = rwkv6.rwkv_layer_fwd(cfg, p, x[:, t:t + 1], state=st)
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               rtol=2e-3, atol=2e-3)
