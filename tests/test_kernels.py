"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps + properties."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref

SHAPES = [
    (8, 100, 3),       # tiny, unaligned everything
    (16, 1000, 17),    # unaligned d
    (128, 2048, 128),  # fully aligned
    (32, 513, 260),    # unaligned N and d
    (1, 64, 1),        # degenerate
    (64, 4096, 512),   # large-d
]


@pytest.mark.parametrize("b,n,d", SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_pairwise_kernel(b, n, d, dtype):
    rng = np.random.default_rng(b * n + d)
    xb = rng.standard_normal((b, d)).astype(dtype)
    x = rng.standard_normal((n, d)).astype(dtype)
    got = ops.pairwise_distances(xb, x)
    want = ref.pairwise_ref(xb, x)
    np.testing.assert_allclose(got, want, rtol=3e-3 if dtype == np.float16 else 2e-5,
                               atol=3e-3 if dtype == np.float16 else 2e-5)


@pytest.mark.parametrize("b,n,d", SHAPES)
def test_energy_kernel(b, n, d):
    rng = np.random.default_rng(b + n + d)
    xb = rng.standard_normal((b, d)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    got = ops.block_energies(xb, x)
    want = ref.energy_ref(xb, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("b,n,d", SHAPES)
def test_bound_update_kernel(b, n, d):
    rng = np.random.default_rng(b * 7 + n + d)
    xb = rng.standard_normal((b, d)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    l = np.abs(rng.standard_normal(n)).astype(np.float32)
    valid = rng.random(b) > 0.3
    if not valid.any():
        valid[0] = True
    e = np.asarray(ref.energy_ref(xb, x)) / n
    got = ops.bound_update(xb, x, jnp.asarray(e), jnp.asarray(valid),
                           jnp.asarray(l))
    want = ref.bound_update_ref(xb, x, e, l, valid)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_sqeuclidean_metric():
    rng = np.random.default_rng(0)
    xb = rng.standard_normal((8, 19)).astype(np.float32)
    x = rng.standard_normal((200, 19)).astype(np.float32)
    got = ops.pairwise_distances(xb, x, metric="sqeuclidean")
    want = ref.pairwise_ref(xb, x, metric="sqeuclidean")
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 32),
    n=st.integers(2, 600),
    d=st.integers(1, 80),
    seed=st.integers(0, 1000),
)
def test_property_fused_round_matches_ref(b, n, d, seed):
    """Property: fused round == reference round for arbitrary shapes."""
    rng = np.random.default_rng(seed)
    xb = rng.standard_normal((b, d)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    l = np.abs(rng.standard_normal(n)).astype(np.float32)
    valid = rng.random(b) > 0.2
    if not valid.any():
        valid[0] = True
    e_got, l_got = ops.fused_round(jnp.asarray(xb), jnp.asarray(x),
                                   jnp.asarray(l), jnp.asarray(valid))
    e_want, l_want = ref.fused_round_ref(xb, x, l, valid)
    np.testing.assert_allclose(e_got, e_want, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(l_got, l_want, rtol=1e-4, atol=1e-4)


def test_kernel_distance_properties():
    """Metric axioms on kernel output: symmetry, identity, triangle."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal((60, 5)).astype(np.float32)
    D = np.asarray(ops.pairwise_distances(x, x))
    np.testing.assert_allclose(D, D.T, atol=1e-4)
    # the kernel computes d^2 = bsq + xsq - 2*prod; on the diagonal the
    # three fp32 terms cancel, leaving rounding noise of order
    # eps * ||x||^2 ~ 1e-6 in d^2, i.e. ~1e-3 in d after the sqrt (the
    # max(d2, 0) clamp only removes the negative half of the noise).
    # Diagonal-only tolerance is therefore sqrt-of-cancellation scale.
    assert np.all(np.abs(np.diag(D)) < 5e-3)
    i, j, k = 3, 17, 42
    assert D[i, k] <= D[i, j] + D[j, k] + 1e-4
