"""Observability subsystem (DESIGN.md §14): traces, metrics, profiling.

The two contracts everything else hangs off:

* **bit-neutrality** — a traced solve returns the exact same answer
  (index, energy, computed elements, rounds, certificate) as the same
  solve untraced, for every engine; with ``trace=None`` the engine
  compiles the exact same program as before the subsystem existed;
* **byte-determinism** — the same query + seed yields a byte-identical
  JSONL trace across runs, and a solve killed at any segment boundary
  and resumed converges on the byte-identical trace of the
  uninterrupted run (the trace rides PR 7's checkpoint-before-kill
  ordering).
"""
import numpy as np
import pytest

from _hyp import given, settings, st, watchdog

from repro.api import MedoidQuery, solve, solve_many
from repro.core.pipelined import _trimed_pipelined
from repro.obs import (REGISTRY, MetricsRegistry, SolveTracer,
                       profile_kernels, repro_warn, resolve_trace,
                       validate_events)
from repro.obs.trace import compare_structure, dump_event, load_jsonl
from repro.runtime import faults

METRICS = ("l2", "l1")


def _X(n, d=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def _sig(rep):
    """The bit-identity signature of a SolveReport."""
    return (rep.index, rep.energy, rep.elements_computed, rep.n_rounds,
            rep.certified)


# ---------------------------------------------------------------------------
# metrics registry + exporters
# ---------------------------------------------------------------------------
def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("solves_total", "solves")
    c.inc()
    c.inc(2, engine="pipelined")
    assert c.value() == 1 and c.value(engine="pipelined") == 2
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(5)
    g.dec(2)
    assert g.value() == 3
    h = reg.histogram("ratio", buckets=(1.0, 2.0))
    h.observe(0.5)
    h.observe(1.5)
    h.observe(99.0)
    s = h.value()
    assert s["count"] == 3 and s["buckets"] == [1, 2]


def test_registry_idempotent_and_kind_checked():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(3, mode="exact")
    reg.gauge("depth").set(2)
    reg.histogram("util", buckets=(0.5, 1.0)).observe(0.75)
    text = reg.to_text()
    assert "# HELP repro_obs_req_total requests" in text
    assert "# TYPE repro_obs_req_total counter" in text
    assert 'repro_obs_req_total{mode="exact"} 3' in text
    assert "repro_obs_depth 2" in text
    assert 'repro_obs_util_bucket{le="0.5"} 0' in text
    assert 'repro_obs_util_bucket{le="+Inf"} 1' in text
    assert "repro_obs_util_count 1" in text


def test_jsonl_export_deterministic(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a").inc()
    reg.histogram("b", buckets=(1.0,)).observe(0.5)
    t1 = reg.export_jsonl(tmp_path / "m.jsonl")
    t2 = reg.export_jsonl()
    assert t1 == t2
    assert (tmp_path / "m.jsonl").read_text() == t1
    import json
    rows = [json.loads(line) for line in t1.splitlines()]
    assert all(r["schema"] == "repro.obs.metrics/v1" for r in rows)
    assert all(r["name"].startswith("repro_obs_") for r in rows)


# ---------------------------------------------------------------------------
# the one logger namespace
# ---------------------------------------------------------------------------
def test_repro_warn_logs_and_warns(caplog):
    with caplog.at_level("WARNING", logger="repro"):
        with pytest.warns(UserWarning, match="sample message"):
            repro_warn("sample message", logger="repro.core.test")
    assert any(rec.name == "repro.core.test" and
               "sample message" in rec.message for rec in caplog.records)


def test_legacy_shim_routes_through_repro_logger(caplog):
    from repro.core.trimed import medoid
    X = _X(64)
    with caplog.at_level("WARNING", logger="repro"):
        with pytest.warns(DeprecationWarning, match="legacy entrypoint"):
            medoid(X)
    assert any(rec.name == "repro.api" for rec in caplog.records)


def test_block_clamp_warning_still_fires(caplog):
    from repro.core.distributed import _clamped_block
    with caplog.at_level("WARNING", logger="repro"):
        with pytest.warns(UserWarning, match="per-shard column"):
            _clamped_block(4096, 300, 2, "test_obs")
    assert any(rec.name == "repro.core.distributed"
               for rec in caplog.records)


# ---------------------------------------------------------------------------
# solve tracer: structure + accounting
# ---------------------------------------------------------------------------
def test_trace_basics_pipelined():
    X = _X(600, seed=0)
    rep = solve(MedoidQuery(X, trace=True), plan="pipelined")
    obs = rep.extras["obs"]
    events = obs["trace"]["events"]
    assert validate_events(events) == []
    assert events[0]["kind"] == "begin"
    assert events[0]["engine"] == "pipelined"
    assert events[-1]["kind"] == "end"
    rounds = [e for e in events if e["kind"] == "round"]
    assert rounds, "no round events from a segmented engine"
    # per-round element deltas telescope exactly to the unified cost
    assert sum(e["elements_round"] for e in rounds) == \
        rep.elements_computed
    # survivors never increase (bounds only grow, incumbent only drops)
    survs = [e["survivors"] for e in rounds]
    assert all(a >= b for a, b in zip(survs, survs[1:]))
    # the end event is the report, bit for bit
    end = events[-1]
    assert end["index"] == rep.index
    assert end["energy"] == rep.energy
    assert end["elements"] == rep.elements_computed
    assert end["rounds"] == rep.n_rounds
    assert end["certified"] == rep.certified
    # bound summaries are well-formed where present
    for e in rounds:
        if e["l_summary"] is not None:
            ls = e["l_summary"]
            assert ls["min"] <= ls["q50"] <= ls["max"]


def test_trace_no_wallclock_keys():
    """Trace events carry deterministic values only — nothing that
    smells like a timestamp, hostname or pid."""
    X = _X(300, seed=1)
    rep = solve(MedoidQuery(X, trace=True), plan="pipelined")
    for ev in rep.extras["obs"]["trace"]["events"]:
        for key in ev:
            assert not any(tok in key.lower() for tok in
                           ("time", "clock", "host", "pid", "date"))


def test_sharded_trace(tmp_path):
    X = _X(700, seed=2)
    path = tmp_path / "shard.jsonl"
    rep = solve(MedoidQuery(X, device_policy="sharded", trace=str(path)))
    assert rep.plan.engine == "sharded"
    events = load_jsonl(path)
    assert validate_events(events) == []
    rounds = [e for e in events if e["kind"] == "round"]
    assert sum(e["elements_round"] for e in rounds) == \
        rep.elements_computed
    assert events[0]["shards"] >= 1


def test_fallback_engines_get_begin_end():
    """Engines without native segment traces still produce an honest
    begin+end pair through the planner."""
    X = _X(300, seed=3)
    for engine in ("sequential", "block", "scan"):
        rep = solve(MedoidQuery(X, trace=True), plan=engine)
        events = rep.extras["obs"]["trace"]["events"]
        kinds = [e["kind"] for e in events]
        assert kinds[0] == "begin" and kinds[-1] == "end"
        assert events[-1]["index"] == rep.index
        assert events[-1]["elements"] == rep.elements_computed


def test_resolve_trace_validation():
    assert resolve_trace(None) is None
    assert resolve_trace(False) is None
    assert isinstance(resolve_trace(True), SolveTracer)
    t = SolveTracer()
    assert resolve_trace(t) is t
    assert resolve_trace("/tmp/x.jsonl").path == "/tmp/x.jsonl"
    with pytest.raises(ValueError, match="trace must be"):
        resolve_trace(42)
    with pytest.raises(ValueError, match="trace must be"):
        MedoidQuery(_X(64), trace=42)


def test_validate_events_catches_breakage():
    X = _X(300, seed=4)
    rep = solve(MedoidQuery(X, trace=True), plan="pipelined")
    good = rep.extras["obs"]["trace"]["events"]
    assert validate_events([]) == ["empty trace"]
    assert validate_events(good[1:])            # missing begin
    bad = [dict(e) for e in good]
    for e in bad:
        if e["kind"] == "round":
            e["elements_round"] += 1            # break the telescoping
            break
    assert any("sum(elements_round)" in p for p in validate_events(bad))


# ---------------------------------------------------------------------------
# bit-neutrality: tracing changes nothing about the answer
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(engine=st.sampled_from(("sequential", "block", "pipelined", "scan")),
       metric=st.sampled_from(METRICS),
       seed=st.integers(min_value=0, max_value=3))
def test_trace_on_off_bit_identical(engine, metric, seed):
    X = _X(257, seed=seed)
    with watchdog(300, "trace parity run stalled"):
        off = solve(MedoidQuery(X, metric=metric), plan=engine)
        on = solve(MedoidQuery(X, metric=metric, trace=True), plan=engine)
    assert _sig(on) == _sig(off)


def test_trace_on_off_bit_identical_sharded():
    X = _X(513, seed=1)
    off = solve(MedoidQuery(X, device_policy="sharded"))
    on = solve(MedoidQuery(X, device_policy="sharded", trace=True))
    assert _sig(on) == _sig(off)


# ---------------------------------------------------------------------------
# byte-determinism: same query + seed -> byte-identical JSONL
# ---------------------------------------------------------------------------
def test_trace_file_byte_identical_across_runs(tmp_path):
    X = _X(513, seed=2)
    blobs = []
    for run in range(2):
        path = tmp_path / f"run{run}.jsonl"
        solve(MedoidQuery(X, trace=str(path)), plan="pipelined")
        blobs.append(path.read_bytes())
    assert blobs[0] == blobs[1]
    assert blobs[0]                       # non-empty
    for line in blobs[0].decode().splitlines():
        assert "\t" not in line and line == line.strip()


def test_in_memory_events_serialise_identically(tmp_path):
    """The in-memory event list and the file are the same stream: the
    file is exactly the dumped events."""
    X = _X(300, seed=5)
    path = tmp_path / "t.jsonl"
    rep = solve(MedoidQuery(X, trace=str(path)), plan="pipelined")
    events = rep.extras["obs"]["trace"]["events"]
    dumped = "".join(dump_event(e) + "\n" for e in events)
    assert path.read_text() == dumped


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([257, 513]),
       metric=st.sampled_from(METRICS),
       kill=st.integers(min_value=1, max_value=6),
       every=st.sampled_from([1, 2]),
       seed=st.integers(min_value=0, max_value=2))
def test_kill_and_resume_trace_byte_identical(n, metric, kill, every, seed):
    """A solve killed at any segment boundary and resumed appends to the
    killed run's trace file and converges on the byte-identical trace of
    the uninterrupted run — events are written before the fault hook can
    raise, mirroring the checkpoint ordering."""
    import tempfile
    X = _X(n, seed=seed)
    with tempfile.TemporaryDirectory() as td, watchdog(
            300, "kill/resume trace parity stalled"):
        ref_path = f"{td}/ref.jsonl"
        _trimed_pipelined(X, metric=metric, checkpoint=f"{td}/ck_ref",
                          checkpoint_every=every, trace=ref_path)
        path = f"{td}/killed.jsonl"
        try:
            with faults.inject(faults.FaultSpec(fail_round=kill)):
                _trimed_pipelined(X, metric=metric, checkpoint=f"{td}/ck",
                                  checkpoint_every=every, trace=path)
        except faults.FaultError:
            pass
        _trimed_pipelined(X, metric=metric, checkpoint=f"{td}/ck",
                          checkpoint_every=every, resume="require",
                          trace=path)
        with open(ref_path, "rb") as fh:
            ref = fh.read()
        with open(path, "rb") as fh:
            got = fh.read()
        assert got == ref, f"trace diverged after kill@{kill}"


# ---------------------------------------------------------------------------
# packed solve_many lanes + heartbeats + degrade hops
# ---------------------------------------------------------------------------
def test_solve_many_lane_traces():
    qs = [MedoidQuery(_X(128, seed=s), trace=True) for s in range(3)]
    reps = solve_many(qs)
    for j, rep in enumerate(reps):
        events = rep.extras["obs"]["trace"]["events"]
        kinds = [e["kind"] for e in events]
        assert kinds == ["begin", "lane", "end"]
        lane = events[1]
        assert lane["lane"] == j
        assert lane["elements"] == rep.elements_computed
        assert events[-1]["index"] == rep.index


def test_heartbeat_events_in_trace():
    X = _X(300, seed=6)
    tracer = SolveTracer()
    before = REGISTRY.counter("watchdog_beats_total").value()
    r = _trimed_pipelined(X, heartbeat_timeout_s=100.0, trace=tracer)
    beats = [e for e in tracer.events if e["kind"] == "heartbeat"]
    assert beats, "no heartbeat events with a watchdog armed"
    assert all(set(e) == {"kind", "round"} for e in beats)
    assert REGISTRY.counter("watchdog_beats_total").value() >= \
        before + len(beats)
    assert validate_events(tracer.events) == []
    assert tracer.events[-1]["index"] == r.index


def test_degrade_hop_recorded_in_trace():
    X = _X(513, seed=7)
    ref = solve(MedoidQuery(X), plan="pipelined")
    with faults.inject(faults.FaultSpec(fail_round=1, fail_once=True)):
        rep = solve(MedoidQuery(X, on_error="degrade", trace=True),
                    plan="pipelined")
    events = rep.extras["obs"]["trace"]["events"]
    kinds = [e["kind"] for e in events]
    assert "hop" in kinds
    hop = next(e for e in events if e["kind"] == "hop")
    assert hop["engine"] == "scan"
    assert validate_events(events) == []
    assert rep.index == ref.index
    before = REGISTRY.counter("degrade_hops_total").value(engine="scan")
    assert before >= 1


# ---------------------------------------------------------------------------
# kernel profiling + roofline wiring
# ---------------------------------------------------------------------------
def test_profiler_times_eager_kernels():
    import jax.numpy as jnp
    from repro.kernels import ops
    X = jnp.asarray(_X(256, d=8), jnp.float32)
    with profile_kernels() as prof:
        ops.pairwise_distances(X[:16], X)
        ops.block_energies(X[:16], X)
    assert [r["kernel"] for r in prof.records] == \
        ["pairwise_distances", "block_energies"]
    for r in prof.records:
        assert r["flops"] > 0 and r["bytes"] > 0 and r["seconds"] > 0
    summ = prof.summary()
    assert set(summ["kernels"]) == {"pairwise_distances", "block_energies"}
    roof = summ["kernels"]["pairwise_distances"]["roofline"]
    assert set(roof) >= {"compute_s", "memory_s", "bound",
                         "achieved_flops", "achieved_bw",
                         "roofline_fraction"}
    assert roof["bound"] in ("compute", "memory")
    assert summ["totals"]["calls"] == 2


def test_profiler_results_match_unprofiled():
    import jax.numpy as jnp
    from repro.kernels import ops
    X = jnp.asarray(_X(200, d=8), jnp.float32)
    base = np.asarray(ops.pairwise_distances(X[:8], X))
    with profile_kernels():
        prof_out = np.asarray(ops.pairwise_distances(X[:8], X))
    np.testing.assert_array_equal(base, prof_out)


def test_profiler_surfaces_in_report_extras():
    X = _X(300, seed=8)
    with profile_kernels():
        rep = solve(MedoidQuery(X), plan="pipelined")
    obs = rep.extras["obs"]
    assert "kernels" in obs
    assert "totals" in obs["kernels"]
    # per-report isolation: a second profiled solve reports only its own
    # records, not the first solve's
    with profile_kernels():
        rep2 = solve(MedoidQuery(X), plan="pipelined")
    assert rep2.extras["obs"]["kernels"]["totals"]["calls"] == \
        rep.extras["obs"]["kernels"]["totals"]["calls"]


def test_kernel_roofline_math():
    from repro.roofline.analysis import kernel_roofline
    r = kernel_roofline(1e12, 1e9, 1.0, peak_flops=1e12, hbm_bw=1e12)
    assert r["bound"] == "compute"
    assert r["compute_s"] == 1.0
    assert r["achieved_flops"] == 1e12
    assert r["arithmetic_intensity"] == 1000.0
    r2 = kernel_roofline(1e6, 1e12, 0.5, peak_flops=1e12, hbm_bw=1e9)
    assert r2["bound"] == "memory"
    assert r2["achieved_bw"] == 2e12


# ---------------------------------------------------------------------------
# golden-trace structural comparison (the CI gate's comparator)
# ---------------------------------------------------------------------------
def test_compare_structure_accepts_self_and_rejects_drift():
    X = _X(300, seed=9)
    rep = solve(MedoidQuery(X, trace=True), plan="pipelined")
    events = rep.extras["obs"]["trace"]["events"]
    assert compare_structure(events, events) == []
    # value drift is fine (different BLAS), structure drift is not
    mutated = [dict(e) for e in events]
    for e in mutated:
        if e["kind"] == "round":
            e["energy"] = 123.456
    assert compare_structure(mutated, events) == []
    dropped = [dict(e) for e in events]
    for e in dropped:
        e.pop("l_summary", None)
    assert compare_structure(dropped, events)
