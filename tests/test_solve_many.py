"""solve_many: packed many-query path vs sequential solve() (DESIGN.md §12).

The parity contract under test: every report from ``solve_many`` is
**bit-identical** — index, scaled energy, elements_computed, n_rounds,
certified — to its single-query counterpart

    solve(q.with_(engine_opts=report.plan.params["equivalent"]["engine_opts"]),
          plan="pipelined")

(the pipelined engine with the compaction ladder disabled), across
random batches mixing metrics, ragged N (multiple shape buckets),
duplicate queries, warm starts and per-query budgets. On top of parity:
per-query ``elements_computed`` sum exactly to the packed program totals
in ``extras["batch"]``, ghost (padding) lanes compute nothing, and
repeat calls — including the 0- and 1-query degenerate batches — hit
the jit cache instead of recompiling.

Property tests use the ``tests/_hyp`` shim: real hypothesis when
installed, a deterministic seeded fallback driver otherwise.
"""
import numpy as np
import pytest

import repro
from repro import MedoidQuery, solve, solve_many

from _hyp import given, settings, st

METRICS = ["l2", "l1"]          # triangle-inequality metrics pack


def _X(n, d=3, seed=0):
    return np.random.default_rng(seed).standard_normal((n, d)).astype(
        np.float32)


def _counterpart(q, report):
    """The recorded bit-identical single-query equivalent."""
    eq = report.plan.params["equivalent"]
    return solve(q.with_(engine_opts=eq["engine_opts"]), plan=eq["plan"])


def _assert_bit_identical(q, report, i):
    ref = _counterpart(q, report)
    assert int(report.indices[0]) == int(ref.indices[0]), f"query {i}"
    # == not allclose: the scaled energy must match to the last bit
    assert float(report.energies[0]) == float(ref.energies[0]), f"query {i}"
    assert report.elements_computed == ref.elements_computed, f"query {i}"
    assert report.n_rounds == ref.n_rounds, f"query {i}"
    assert report.certified == ref.certified, f"query {i}"


def _assert_batch_accounting(reports):
    """Per-query elements sum to each packed program's recorded total;
    ghost lanes contribute nothing."""
    by_bucket = {}
    for r in reports:
        sm = r.plan.params.get("solve_many")
        if sm and "batch" in r.extras and sm["n_queries"] > 1:
            by_bucket.setdefault(sm["bucket"], []).append(r)
    for bucket, group in by_bucket.items():
        info = group[0].extras["batch"]
        if len(group) == info["n_queries"]:       # whole chunk visible
            total = sum(r.elements_computed for r in group)
            assert total == info["elements_total"], bucket
        assert info.get("padding_elements", 0.0) == 0.0, bucket


# ---------------------------------------------------------------------------
# the property: random ragged batches are bit-identical to sequential
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n1=st.integers(2, 300),
       n2=st.integers(2, 300), metric1=st.sampled_from(METRICS),
       metric2=st.sampled_from(METRICS), warm=st.booleans(),
       budget=st.booleans())
def test_parity_random_batches(seed, n1, n2, metric1, metric2, warm, budget):
    rng = np.random.default_rng(seed)
    X1, X2 = _X(n1, seed=seed), _X(n2, seed=seed + 1)
    queries = [
        MedoidQuery(X1, metric=metric1),
        MedoidQuery(X2, metric=metric2),
        MedoidQuery(X1, metric=metric1),          # exact duplicate
        MedoidQuery(_X(n1, seed=seed + 2), metric=metric1),
    ]
    if warm:
        # duplicates inside warm_idx must dedup to first occurrence
        w = rng.integers(0, n1, size=3)
        queries.append(MedoidQuery(X1, metric=metric1,
                                   warm_idx=[w[0], w[0], w[1], w[2]]))
    if budget:
        cap = int(rng.integers(1, n2 + 1))
        queries.append(MedoidQuery(X2, metric=metric2, mode="anytime",
                                   budget=float(cap)))
    reports = solve_many(queries)
    assert len(reports) == len(queries)
    for i, (q, r) in enumerate(zip(queries, reports)):
        _assert_bit_identical(q, r, i)
    # duplicate queries get duplicate answers
    assert float(reports[0].energies[0]) == float(reports[2].energies[0])
    assert int(reports[0].indices[0]) == int(reports[2].indices[0])
    _assert_batch_accounting(reports)


def test_parity_kernel_path():
    """The query-as-grid-dimension Pallas path (interpret mode on CPU)
    matches the kernel-path single-query engine bit for bit, including a
    budget-capped lane."""
    queries = [
        MedoidQuery(_X(256, seed=s), use_kernels=True,
                    engine_opts={"interpret": True})
        for s in range(3)
    ] + [
        MedoidQuery(_X(256, seed=7), use_kernels=True, mode="anytime",
                    budget=40.0, engine_opts={"interpret": True}),
        MedoidQuery(_X(256, seed=8), use_kernels=True,
                    warm_idx=[5, 5, 17], engine_opts={"interpret": True}),
    ]
    reports = solve_many(queries)
    for i, (q, r) in enumerate(zip(queries, reports)):
        assert r.plan.params["use_kernels"], i
        _assert_bit_identical(q, r, i)
    capped = reports[3]
    assert not capped.certified and capped.ci > 0
    _assert_batch_accounting(reports)


def test_budget_lane_reports_ci():
    """An over-budget lane keeps its incumbent, reports certified=False
    and a positive deterministic bound-gap CI; uncapped lanes in the
    same packed program stay certified with ci == 0."""
    X = _X(512, seed=3)
    reports = solve_many([
        MedoidQuery(X),
        MedoidQuery(X, mode="anytime", budget=30.0),
    ])
    exact, capped = reports
    assert exact.certified and exact.ci == 0.0
    assert not capped.certified
    assert 0.0 < capped.ci < np.inf
    assert capped.elements_computed <= 30 + 512 // 4  # one round of slack
    # the true energy sits inside [E - 2ci, E] by construction
    assert float(capped.energies[0]) - 2 * capped.ci <= \
        float(exact.energies[0]) <= float(capped.energies[0]) + 1e-12


def test_elements_sum_across_buckets():
    """Three buckets (two shapes x two metrics); every chunk's recorded
    elements_total equals the sum over its real lanes."""
    qs = ([MedoidQuery(_X(128, seed=s)) for s in range(5)]
          + [MedoidQuery(_X(200, seed=s)) for s in range(3)]
          + [MedoidQuery(_X(128, seed=s), metric="l1") for s in range(2)])
    reports = solve_many(qs)
    _assert_batch_accounting(reports)
    buckets = {r.plan.params["solve_many"]["bucket"] for r in reports}
    assert len(buckets) == 3
    for q, r in zip(qs, reports):
        _assert_bit_identical(q, r, q)


# ---------------------------------------------------------------------------
# degenerate batches and compile-cache behaviour
# ---------------------------------------------------------------------------
def test_empty_batch():
    assert solve_many([]) == []


def test_single_query_batch():
    q = MedoidQuery(_X(100, seed=4), metric="l1")
    (r,) = solve_many([q])
    _assert_bit_identical(q, r, 0)
    assert r.extras["batch"]["n_queries"] == 1


def test_n_equals_one_short_circuit():
    (r,) = solve_many([MedoidQuery(_X(1, seed=0))])
    assert int(r.indices[0]) == 0 and float(r.energies[0]) == 0.0
    assert r.certified and r.elements_computed == 1.0


def test_repeat_calls_hit_jit_cache():
    """0-/1-query batches round-trip without recompiling per call: the
    query axis is padded to powers of two, so any batch size whose pad
    width was seen before reuses the compiled program. Regression-tested
    via the jit cache size of the packed stage."""
    from repro.core.many import _many_stage_jnp
    stage = _many_stage_jnp
    # warm the (n=96, q_pad in {1, 2, 4}) programs
    for q_count in (1, 2, 3):
        solve_many([MedoidQuery(_X(96, seed=s)) for s in range(q_count)])
    size_after_warm = stage._cache_size()
    # fresh data, same shapes — every pad width must be a cache hit
    for q_count in (1, 1, 2, 3, 4, 3):
        solve_many([MedoidQuery(_X(96, seed=10 + s + q_count))
                    for s in range(q_count)])
    assert stage._cache_size() == size_after_warm, (
        "solve_many recompiled for a repeated batch shape")


# ---------------------------------------------------------------------------
# validation: what refuses to pack, refuses loudly
# ---------------------------------------------------------------------------
def test_validation_errors():
    X = _X(64)
    with pytest.raises(TypeError, match="queries\\[0\\]"):
        solve_many([X])                                  # not a query
    with pytest.raises(ValueError, match="single-medoid"):
        solve_many([MedoidQuery(X, k=4)])
    with pytest.raises(ValueError, match="single-medoid"):
        solve_many([MedoidQuery(X, topk=3)])
    with pytest.raises(ValueError, match="device_policy"):
        solve_many([MedoidQuery(X, device_policy="host")])
    with pytest.raises(ValueError, match="block_schedule"):
        solve_many([MedoidQuery(X, block_schedule=(8, 64))])
    with pytest.raises(ValueError, match="engine_opts"):
        solve_many([MedoidQuery(X, engine_opts={"ladder_min": 4})])
    with pytest.raises(ValueError, match="triangle"):
        solve_many([MedoidQuery(X, metric="cosine")])
    # a bad query anywhere in the batch fails the whole call up front
    with pytest.raises(ValueError, match="queries\\[1\\]"):
        solve_many([MedoidQuery(X), MedoidQuery(X, k=2)])
