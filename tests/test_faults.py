"""Fault injection: the harness itself, the solve policies it drives,
and the server's per-request isolation (DESIGN.md §13).

Everything here is deterministic: stalls advance a fault clock instead
of sleeping, kills are raised at named segment rounds, poison inputs
fire by object identity, and the CI fault lane widens the seed sweep
via ``REPRO_FAULTS`` (:func:`repro.runtime.faults.fault_seeds`).

The server invariants pinned at the bottom are the PR's acceptance
story: a fault-injected ``MedoidServer.step`` never raises, never drops
a request, and every quarantine/degrade decision is visible in the
request's report.
"""
import dataclasses

import numpy as np
import pytest

from _hyp import watchdog

from repro.api import MedoidQuery, solve, solve_many
from repro.runtime import faults
from repro.serve.engine import MedoidServer


def _X(n, d=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# the harness itself
# ---------------------------------------------------------------------------
def test_inject_does_not_nest():
    with faults.inject(faults.FaultSpec()):
        with pytest.raises(RuntimeError, match="nest"):
            with faults.inject(faults.FaultSpec()):
                pass


def test_clock_stall_is_simulated():
    t0 = faults.clock()
    with faults.inject(faults.FaultSpec(stall_round=0, stall_s=1e6)) as st:
        faults.on_segment(0)
        assert faults.clock() >= t0 + 1e6
        assert ("stall", 0) in st.events
    # disarmed: back to the real monotonic clock
    assert faults.clock() < t0 + 1e5


@pytest.mark.parametrize("seed", faults.fault_seeds())
def test_corrupt_plants_seeded_rows(seed):
    X = _X(64, seed=1)
    spec = faults.FaultSpec(seed=seed, nan_rows=3, inf_rows=2)
    Xc = faults.corrupt(X, spec)
    bad = ~np.isfinite(Xc).all(axis=1)
    assert bad.sum() == 5
    assert np.isnan(Xc[bad]).any() and np.isinf(Xc[bad]).any()
    # deterministic: same spec, same rows
    np.testing.assert_array_equal(bad, ~np.isfinite(
        faults.corrupt(X, spec)).all(axis=1))
    # original untouched
    assert np.isfinite(X).all()


def test_poison_requires_arming_and_clears_on_exit():
    X = _X(32)
    with pytest.raises(RuntimeError, match="inject"):
        faults.mark_poison(X)
    with faults.inject(faults.FaultSpec()):
        faults.mark_poison(X)
        with pytest.raises(faults.FaultError, match="poison"):
            faults.check_poison(X, "test site")
    faults.check_poison(X, "test site")       # disarmed: no-op


def test_fault_seeds_env(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    assert faults.fault_seeds() == (0,)
    monkeypatch.setenv("REPRO_FAULTS", "1")
    assert faults.fault_seeds() == (0, 1, 2, 3)
    monkeypatch.setenv("REPRO_FAULTS", "3, 7,11")
    assert faults.fault_seeds() == (3, 7, 11)


# ---------------------------------------------------------------------------
# solve-level policies driven by injected faults
# ---------------------------------------------------------------------------
def test_nonfinite_raise_names_rows():
    X = faults.corrupt(_X(600, seed=2),
                       faults.FaultSpec(nan_rows=1, inf_rows=1))
    with pytest.raises(ValueError, match="2 of 600"):
        solve(MedoidQuery(X))
    with pytest.raises(ValueError, match="nonfinite"):
        solve(MedoidQuery(X))
    # allow: the check is skipped and an engine runs
    rep = solve(MedoidQuery(X, nonfinite="allow"), plan="scan")
    assert rep.indices.shape == (1,)


def test_nonfinite_checked_in_solve_many():
    good = MedoidQuery(_X(257, seed=1))
    bad = MedoidQuery(faults.corrupt(
        _X(257, seed=2), faults.FaultSpec(nan_rows=2)))
    with pytest.raises(ValueError, match=r"queries\[1\]"):
        solve_many([good, bad])
    reps = solve_many([good, bad.with_(nonfinite="allow")])
    assert len(reps) == 2


def test_fault_error_propagates_by_default():
    X = _X(300, seed=3)
    with faults.inject(faults.FaultSpec(fail_round=1)):
        with pytest.raises(faults.FaultError, match="fail_round"):
            solve(MedoidQuery(X), plan="pipelined")


def test_degrade_ladder_rescues_engine_fault():
    """A pipelined kill with on_error='degrade' lands on the scan rung;
    every hop is in plan.reasons and the answer is still exact."""
    X = _X(300, seed=3)
    ref = solve(MedoidQuery(X), plan="scan")
    with faults.inject(faults.FaultSpec(fail_round=1, fail_once=False)):
        rep = solve(MedoidQuery(X, on_error="degrade"), plan="pipelined")
    assert rep.plan.engine == "scan"
    assert rep.index == ref.index and rep.energy == ref.energy
    hops = [r for r in rep.plan.reasons if "on_error=degrade" in r]
    assert any("pipelined raised FaultError" in r for r in hops)
    assert any("downgrading to 'scan'" in r for r in hops)


def test_degrade_ladder_rescues_oracle_fault():
    """The k-th oracle call dies mid-sequential-solve; the ladder falls
    back to the scan sweep, which completes."""
    from repro.core.distances import VectorOracle
    X = _X(200, seed=4)
    ref = solve(MedoidQuery(X), plan="scan")
    with faults.inject(faults.FaultSpec(fail_call=50)):
        rep = solve(MedoidQuery(VectorOracle(X), on_error="degrade"),
                    plan="sequential")
    assert rep.plan.engine == "scan"
    assert rep.index == ref.index


def test_degrade_reraises_when_every_rung_fails():
    X = _X(300, seed=5)
    with faults.inject(faults.FaultSpec()):
        faults.mark_poison(X)          # poison fires on *every* engine
        with pytest.raises(faults.FaultError, match="poison"):
            solve(MedoidQuery(X, on_error="degrade"), plan="pipelined")


def test_forced_budget_exhaustion_returns_anytime():
    X = _X(1025, seed=6)
    with faults.inject(faults.FaultSpec(force_budget=64)):
        rep = solve(MedoidQuery(X), plan="pipelined")
    assert not rep.certified
    assert rep.extras["halt_reason"] == "budget"
    assert np.isfinite(rep.ci) and rep.ci > 0.0
    assert np.isfinite(rep.extras["lower_bound"])


def test_round_watchdog_flags_stall():
    """An injected stall longer than the heartbeat timeout marks the
    solve stalled: anytime result, halt_reason='stalled'."""
    from repro.core.pipelined import _trimed_pipelined
    X = _X(1025, seed=7)
    with faults.inject(faults.FaultSpec(stall_round=1, stall_s=500.0)):
        r = _trimed_pipelined(X, heartbeat_timeout_s=100.0)
    assert not r.certified
    assert r.halt_reason == "stalled"
    assert 0 <= r.index < 1025


def test_shard_loss_degrades_to_single_device():
    from repro.compat import make_1d_mesh
    X = _X(1025, seed=8)
    ref = solve(MedoidQuery(X), plan="pipelined")
    q = MedoidQuery(X, device_policy="sharded", mesh=make_1d_mesh(1),
                    on_error="degrade")
    with faults.inject(faults.FaultSpec(lose_shard=True)):
        rep = solve(q)
    assert rep.plan.engine in ("pipelined", "scan")
    assert rep.index == ref.index and rep.energy == ref.energy
    assert any("ShardLostError" in r for r in rep.plan.reasons)


# ---------------------------------------------------------------------------
# MedoidServer isolation: bisect, retry, quarantine — never raise,
# never drop, every decision on record
# ---------------------------------------------------------------------------
def _submit_all(srv, Xs):
    return [srv.submit(MedoidQuery(X)) for X in Xs]


def test_server_bisects_and_quarantines_poison():
    Xs = [_X(257, seed=s) for s in range(6)]
    srv = MedoidServer(budget=1e9, max_retries=1, backoff_base=1)
    _submit_all(srv, Xs)
    with watchdog(300, "server stalled isolating a poison request"):
        with faults.inject(faults.FaultSpec()):
            faults.mark_poison(Xs[2])
            done = srv.run(max_steps=20)
    # never dropped: every uid accounted for
    assert sorted(r.uid for r in done) == list(range(6))
    bad = [r for r in done if r.quarantined]
    good = [r for r in done if not r.quarantined]
    assert [r.uid for r in bad] == [2]
    # healthy requests unaffected, exact, served in step 0
    assert all(r.report.certified for r in good)
    assert all(r.step == 0 for r in good)
    # tombstone: unmistakably not an answer
    tomb = bad[0].report
    assert tomb.index == -1
    assert np.isnan(tomb.energy)
    assert tomb.ci == float("inf")
    assert not tomb.certified
    assert tomb.plan.engine == "quarantined"
    assert tomb.extras["quarantined"]
    assert "poison" in tomb.extras["error"]
    # the audit trail: attempts, backoff, quarantine all on record
    decisions = tomb.extras["decisions"]
    assert any("attempt 1 failed" in d for d in decisions)
    assert any("backoff" in d for d in decisions)
    assert any("quarantined after 2 failed attempts" in d
               for d in decisions)
    # step ledger saw the failure and the quarantine
    assert srv.steps[0]["n_failed"] == 1
    assert any(s.get("n_quarantined") == 1 for s in srv.steps)


def test_server_retry_recovers_after_transient_fault():
    """A fault cleared between steps: the request is retried with
    backoff and served; the report records the retry."""
    Xs = [_X(257, seed=s) for s in range(3)]
    srv = MedoidServer(budget=1e9, max_retries=2)
    _submit_all(srv, Xs)
    with faults.inject(faults.FaultSpec()):
        faults.mark_poison(Xs[1])
        served = srv.step()
    assert sorted(r.uid for r in served) == [0, 2]      # FIFO not blocked
    srv.run(max_steps=10)
    rec = [r for r in srv.finished if r.uid == 1][0]
    assert not rec.quarantined
    assert rec.report.certified
    assert rec.report.extras["serve"]["retries"] == 1
    assert any("requeued with backoff" in d
               for d in rec.report.extras["serve"]["decisions"])


def test_server_step_deadline_defers_bisection():
    """With the step deadline already blown, the initial packed attempt
    still runs (a step always makes progress); once it fails, the
    remaining bisection halves are deferred to the next step — not
    retried, not dropped."""
    Xs = [_X(257, seed=s) for s in range(4)]
    srv = MedoidServer(budget=1e9, max_retries=2, step_deadline_s=1e-9)
    _submit_all(srv, Xs)
    with faults.inject(faults.FaultSpec()):
        faults.mark_poison(Xs[3])
        served = srv.step()
    assert served == []                     # everything deferred
    assert srv.steps[0]["n_deferred"] == 4
    assert len(srv.queue) == 4
    assert all(r.retries == 0 for r in srv.queue)       # deferral != failure
    assert all(any("deferred" in d for d in r.decisions)
               for r in srv.queue)
    # fault cleared: the deferred batch drains normally
    done = srv.run(max_steps=10)
    assert sorted(r.uid for r in done) == [0, 1, 2, 3]
    assert all(r.report.certified for r in done)


def test_server_submit_rejects_corrupt_input():
    srv = MedoidServer()
    X = faults.corrupt(_X(257), faults.FaultSpec(nan_rows=1))
    with pytest.raises(ValueError, match="non-finite"):
        srv.submit(MedoidQuery(X))
    assert not srv.queue


@pytest.mark.parametrize("seed", faults.fault_seeds())
def test_server_never_raises_never_drops(seed):
    """The acceptance sweep: random poison subset, random retry limit —
    the server always drains, every request gets a report, healthy
    answers stay exact."""
    rng = np.random.default_rng(seed)
    Xs = [_X(257, seed=100 + seed * 10 + i) for i in range(5)]
    poison = set(rng.choice(5, size=2, replace=False).tolist())
    srv = MedoidServer(budget=1e9, max_retries=int(rng.integers(0, 3)))
    _submit_all(srv, Xs)
    with watchdog(300, "server stalled draining the fault sweep"):
        with faults.inject(faults.FaultSpec(seed=seed)):
            for i in poison:
                faults.mark_poison(Xs[i])
            done = srv.run(max_steps=50)
    assert sorted(r.uid for r in done) == list(range(5))
    for r in done:
        assert r.report is not None
        if r.uid in poison:
            assert r.quarantined and r.report.extras["decisions"]
        else:
            assert r.report.certified
