"""Streaming medoid index (DESIGN.md §15).

The contract under test: after ANY sequence of ``insert`` / ``delete``
/ ``update`` churn — including duplicates, deleting the incumbent
medoid, shrinking below the tiny-N floor, restoring from disk, and a
kill/resume mid-repair — ``MedoidIndex.query()`` is **bit-for-bit**
the ``(index, energy, certificate)`` a fresh ``solve()`` returns on
the current rows. Exactness is the whole point: the repair path must
be an optimisation, never an approximation.

Economy rides along: at low turnover the repair cost (in the unified
computed-row currency) must be a small fraction of a fresh solve —
the benchmark gate lives in ``benchmarks/bench_stream.py``; here a
unit-sized version guards the same ratio.
"""
import numpy as np
import pytest

from _hyp import given, settings, st, watchdog

from repro.core.pipelined import _trimed_pipelined
from repro.core.solve_state import SolveStateMismatch
from repro.runtime import faults
from repro.stream import MedoidIndex, SlidingWindowIndex

METRICS = ("l2", "l1")


def _X(n, d=3, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def _sig(r):
    return (r.index, r.energy, r.certified)


def _fresh_sig(X, metric):
    if X.shape[0] == 1:
        return (0, 0.0, True)
    return _sig(_trimed_pipelined(X, metric=metric))


def _churn(idx, X, rng, *, n_ops=3):
    """Apply ``n_ops`` random ops to both the index and the mirror
    array; returns the updated mirror."""
    d = X.shape[1]
    for _ in range(n_ops):
        n = X.shape[0]
        choice = int(rng.integers(0, 3))
        if choice == 0 or n < 4:
            k = int(rng.integers(1, 4))
            rows = rng.normal(size=(k, d)).astype(np.float32)
            if rng.random() < 0.3 and n > 0:     # exact duplicate row
                rows[0] = X[int(rng.integers(0, n))]
            idx.insert(rows)
            X = np.concatenate([X, rows])
        elif choice == 1:
            k = min(int(rng.integers(1, 4)), n - 1)
            pos = rng.choice(n, size=k, replace=False)
            idx.delete(pos)
            X = np.delete(X, pos, axis=0)
        else:
            k = min(int(rng.integers(1, 3)), n)
            pos = rng.choice(n, size=k, replace=False)
            rows = rng.normal(size=(k, d)).astype(np.float32)
            idx.update(pos, rows)
            X = X.copy()
            X[pos] = rows
    return X


# ---------------------------------------------------------------------------
# exactness: churn then query == fresh solve, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("metric", METRICS)
def test_basic_churn_parity(metric):
    X = _X(300, seed=1)
    idx = MedoidIndex.from_data(X, metric=metric)
    assert _sig(idx.query()) == _fresh_sig(X, metric)

    rows = _X(5, seed=2) + 0.5
    idx.insert(rows)
    X = np.concatenate([X, rows])
    idx.delete([3, 7, 11])
    X = np.delete(X, [3, 7, 11], axis=0)
    upd = _X(2, seed=3) * 0.1
    idx.update([0, 50], upd)
    X = X.copy()
    X[[0, 50]] = upd
    assert _sig(idx.query()) == _fresh_sig(X, metric)
    # clean query is served from cache, no extra work
    before = idx.stats["elements_total"]
    assert _sig(idx.query()) == _fresh_sig(X, metric)
    assert idx.stats["elements_total"] == before


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=40),
       metric=st.sampled_from(METRICS))
def test_random_interleaving_parity(seed, metric):
    rng = np.random.default_rng(seed)
    n0 = int(rng.integers(5, 260))
    X = _X(n0, d=int(rng.integers(1, 5)), seed=seed + 100)
    idx = MedoidIndex.from_data(X, metric=metric)
    with watchdog(300, "stream churn parity stalled"):
        for _ in range(int(rng.integers(1, 4))):
            X = _churn(idx, X, rng, n_ops=int(rng.integers(1, 4)))
            assert _sig(idx.query()) == _fresh_sig(X, metric)


def test_delete_the_medoid():
    X = _X(257, seed=4)
    idx = MedoidIndex.from_data(X)
    m = idx.query().index
    idx.delete([m])
    X = np.delete(X, m, axis=0)
    assert _sig(idx.query()) == _fresh_sig(X, "l2")


def test_delete_down_to_tiny_then_singleton():
    X = _X(12, seed=5)
    idx = MedoidIndex.from_data(X)
    while X.shape[0] > 2:        # through the tiny-N full-resolve floor
        idx.delete([0])
        X = X[1:]
        assert _sig(idx.query()) == _fresh_sig(X, "l2")
    idx.delete([1])
    res = idx.query()            # singleton: trivially itself
    assert (res.index, res.energy) == (0, 0.0)
    with pytest.raises(ValueError, match="empty"):
        idx.delete([0])
        idx.query()


def test_duplicate_heavy_set_parity():
    """All-duplicate neighbourhoods drive the incumbent energy to ~0,
    where relative margins are vacuous — must still be exact (via the
    full-resolve fallback)."""
    base = _X(6, seed=6)
    X = np.repeat(base, 20, axis=0)          # 120 rows, 6 distinct
    idx = MedoidIndex.from_data(X)
    assert _sig(idx.query()) == _fresh_sig(X, "l2")
    idx.insert(base[:2])
    X = np.concatenate([X, base[:2]])
    assert _sig(idx.query()) == _fresh_sig(X, "l2")


def test_grown_from_empty():
    idx = MedoidIndex.from_data(np.zeros((0, 3), np.float32))
    with pytest.raises(ValueError, match="empty"):
        idx.query()
    X = _X(40, seed=7)
    idx.insert(X)
    assert _sig(idx.query()) == _fresh_sig(X, "l2")


# ---------------------------------------------------------------------------
# persistence: save/load, config refusal, schema refusal
# ---------------------------------------------------------------------------
def test_insert_after_restore_from_disk(tmp_path):
    X = _X(200, seed=8)
    idx = MedoidIndex.from_data(X, metric="l1")
    idx.query()
    idx.save(tmp_path / "ix")

    idx2 = MedoidIndex.load(tmp_path / "ix")
    assert idx2.metric == "l1"
    rows = _X(4, seed=9)
    idx2.insert(rows)
    idx2.delete([1, 2])
    X = np.delete(np.concatenate([X, rows]), [1, 2], axis=0)
    assert _sig(idx2.query()) == _fresh_sig(X, "l1")


def test_load_refuses_mismatched_snapshot(tmp_path):
    idx = MedoidIndex.from_data(_X(50, seed=10), metric="l2")
    idx.save(tmp_path / "ix")
    # a snapshot is refused under any differing config key: simulate a
    # format/config bump by tampering the persisted fingerprint
    import json
    metas = list((tmp_path / "ix").glob("step_*/meta.json"))
    assert metas
    for mp in metas:
        meta = json.loads(mp.read_text())
        meta["extra"]["stream_index"]["format"] = -1
        mp.write_text(json.dumps(meta))
    with pytest.raises(SolveStateMismatch, match="format"):
        MedoidIndex.load(tmp_path / "ix")


def test_resume_refuses_bumped_solve_state_format(tmp_path):
    """An engine checkpoint written under an older SolveState schema
    must refuse to resume (bit-identity cannot be guaranteed across a
    layout change)."""
    import json
    X = _X(300, seed=11)
    with pytest.raises(faults.FaultError):
        with faults.inject(faults.FaultSpec(fail_round=1)):
            _trimed_pipelined(X, checkpoint=tmp_path, checkpoint_every=1)
    for mp in tmp_path.glob("step_*/meta.json"):
        meta = json.loads(mp.read_text())
        meta["extra"]["fingerprint"]["format"] = 1   # pre-esum layout
        mp.write_text(json.dumps(meta))
    with pytest.raises(SolveStateMismatch, match="format"):
        _trimed_pipelined(X, checkpoint=tmp_path, resume="require")


def test_cosine_refused():
    """cosine distance violates the triangle inequality, so Trimed-style
    bounds (and therefore exact streaming repair) are unsound for it."""
    with pytest.raises(ValueError, match="triangle"):
        MedoidIndex.from_data(_X(20, seed=12), metric="cosine")


# ---------------------------------------------------------------------------
# kill/resume mid-repair
# ---------------------------------------------------------------------------
def test_kill_and_resume_mid_repair_exact(tmp_path):
    """A repair killed at a segment boundary retries the same election
    and resumes its checkpoint; the eventual answer is still exact."""
    rng = np.random.default_rng(13)
    X = _X(900, seed=13)
    idx = MedoidIndex.from_data(X, checkpoint=tmp_path)
    # inserts then deletes: the deletes lower the ledger bounds below
    # the incumbent for a mid-sized slab of eliminated rows, so the
    # repair engine (not the full-resolve fallback) does the work
    rows = rng.normal(size=(5, 3)).astype(np.float32)
    idx.insert(rows)
    X = np.concatenate([X, rows])
    pos = rng.choice(X.shape[0], size=5, replace=False)
    idx.delete(pos)
    X = np.delete(X, pos, axis=0)
    killed = False
    try:
        with faults.inject(faults.FaultSpec(fail_round=1)):
            idx.query()
    except faults.FaultError:
        killed = True
    res = idx.query()                  # retry resumes the repair
    assert _sig(res) == _fresh_sig(X, "l2")
    assert killed, "fault did not land: widen the churn"
    assert idx.stats["invalidated"] > 0, "repair path was not exercised"


# ---------------------------------------------------------------------------
# sliding window
# ---------------------------------------------------------------------------
def test_sliding_window_parity():
    rng = np.random.default_rng(14)
    stream = _X(260, seed=14)
    W = 90
    w = SlidingWindowIndex.from_data(stream[:130], window=W)
    buf = stream[130 - W:130]
    pos = 130
    with watchdog(300, "sliding window parity stalled"):
        while pos < 260:
            k = int(rng.integers(1, 8))
            chunk = stream[pos:pos + k]
            pos += k
            w.push(chunk)
            buf = np.concatenate([buf, chunk])[-W:]
            assert np.array_equal(w.X, buf)
        assert _sig(w.query()) == _fresh_sig(buf, "l2")
    # a push larger than the window keeps only its tail
    w.push(_X(W + 25, seed=15))
    assert w.n == W
    with pytest.raises(ValueError, match="window"):
        SlidingWindowIndex.from_data(stream, window=0)


# ---------------------------------------------------------------------------
# economy + accounting
# ---------------------------------------------------------------------------
def test_repair_is_fraction_of_fresh_solve():
    """The unit-sized version of the benchmark gate: amortised over a
    stream of single-point op+query cycles, repair costs well under
    15% of re-solving at every query (computed-row currency).

    The first few queries after the initial build pay a warm-up slab —
    rows compacted away by the sub-quadratic build carry only the
    incumbent-energy bound, and the first delete tips them back in;
    the engine repair then *commits their exact energies*, so the
    cache densifies and steady state settles near one row per op."""
    X = _X(1024, seed=16)
    idx = MedoidIndex.from_data(X)
    idx.query()
    fresh_cost = _trimed_pipelined(X, metric="l2").n_computed
    rng = np.random.default_rng(17)
    before = idx.stats["elements_total"]
    cycles = 20
    for _ in range(cycles):            # single-point churn, ~2% total
        idx.delete([int(rng.integers(0, idx.n))])
        idx.insert(rng.normal(size=(1, 3)).astype(np.float32))
        idx.query()
    repair_cost = idx.stats["elements_total"] - before
    assert repair_cost < 0.15 * cycles * fresh_cost, (
        repair_cost, fresh_cost)
    assert idx.stats["full_resolves"] == 1     # only the initial build
    # steady state (post warm-up) is near-free: re-run a cycle
    before = idx.stats["elements_total"]
    idx.delete([0])
    idx.insert(rng.normal(size=(1, 3)).astype(np.float32))
    idx.query()
    assert idx.stats["elements_total"] - before < 0.1 * fresh_cost


def test_plan_and_metrics_accounting():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    X = _X(300, seed=18)
    idx = MedoidIndex.from_data(X, metrics=reg)
    idx.insert(_X(2, seed=19))
    idx.delete([5])
    idx.query()
    plan = idx.last_plan
    assert plan.engine == "stream_repair"
    rep = plan.params["repair"]
    assert rep["pending_ops"] == 2
    assert rep["elements"] > 0 and rep["fresh_estimate"] > 0
    if rep["invalidated"] >= 0:        # repair path (not fallback)
        assert rep["vs_fresh"] < 1.0
    text = reg.to_text()
    assert 'repro_obs_stream_ops_total{op="insert"} 1' in text
    assert 'repro_obs_stream_ops_total{op="delete"} 1' in text
    snap = {r["name"]: r["value"] for r in reg.snapshot()
            if not r["labels"]}
    assert snap["repro_obs_stream_elements_per_op_count"] >= 1


def test_repair_trace_events_validate():
    from repro.obs.trace import SolveTracer, validate_events

    X = _X(600, seed=20)
    idx = MedoidIndex.from_data(X)
    rng = np.random.default_rng(21)
    pos = rng.choice(X.shape[0], size=20, replace=False)
    idx.delete(pos)
    tracer = SolveTracer()             # in-memory
    idx.query(trace=tracer)
    assert idx.stats["invalidated"] > 0, "repair engine never entered"
    kinds = [e["kind"] for e in tracer.events]
    assert "begin" in kinds and "repair" in kinds
    begin = next(e for e in tracer.events if e["kind"] == "begin")
    assert begin["engine"] == "stream_repair"
    rep = next(e for e in tracer.events if e["kind"] == "repair")
    assert rep["invalidated"] > 0
    assert validate_events(tracer.events) == []
