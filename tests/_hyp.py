"""hypothesis compatibility shim for mixed test modules.

Modules that are *purely* property-based guard themselves with
``pytest.importorskip("hypothesis")``. Modules that mix example-based and
property-based tests import ``given/settings/st`` from here instead: when
hypothesis is installed they get the real thing; when it is not (this
container has no network access), property tests fall back to a
deterministic fixed-sample driver — each ``@given`` test runs over a
seeded batch of drawn examples instead of being skipped, so the
example-based tests in the same file keep collecting everywhere.
"""
from __future__ import annotations

import contextlib
import signal


@contextlib.contextmanager
def watchdog(timeout_s: int = 300,
             message: str = "test stalled under the watchdog"):
    """SIGALRM watchdog: turn a livelock into a loud ``TimeoutError``
    instead of a hung CI job. Main-thread only (SIGALRM semantics);
    restores the previous handler and pending alarm on exit."""
    def _stalled(signum, frame):
        raise TimeoutError(message)

    old = signal.signal(signal.SIGALRM, _stalled)
    signal.alarm(int(timeout_s))
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _FALLBACK_EXAMPLES = 10  # cap: the shim is a smoke net, not a fuzzer

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(min_value
                                  + (max_value - min_value) * rng.random()))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    st = _Strategies()

    def settings(**kw):
        max_examples = kw.get("max_examples", _FALLBACK_EXAMPLES)

        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kw):
        def deco(fn):
            # No functools.wraps: pytest must see a zero-arg signature,
            # not the property function's drawn parameters (it would try
            # to resolve them as fixtures).
            def wrapper():
                n = getattr(wrapper, "_hyp_max_examples",
                            _FALLBACK_EXAMPLES)
                rng = np.random.default_rng(0)
                for _ in range(min(n, _FALLBACK_EXAMPLES)):
                    drawn = {k: s.draw(rng) for k, s in strategy_kw.items()}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
