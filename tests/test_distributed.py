"""Distributed tests. Multi-device cases run in subprocesses (the JAX
device count is locked at first init; the main test process keeps the
single real CPU device, per the dry-run contract)."""
import subprocess
import sys
import textwrap

from repro.configs.base import get_smoke_config
from repro.launch import shardings as sh
from repro.launch import specs as sp


def _run(script: str, devices: int = 8, timeout: int = 480):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys; sys.path.insert(0, "src")
        import repro  # installs the jax<0.5 mesh-API shims (repro.compat)
        {textwrap.indent(textwrap.dedent(script), '        ').strip()}
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, cwd=".")
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_trimed_matches_single_device():
    """The planner-reachable sharded engine (DESIGN.md §11) on 8
    subprocess devices: bit-identical to the single-device pipelined
    engine, with per-shard accounting summing to the total."""
    out = _run("""
        import numpy as np, jax
        from repro.api import MedoidQuery, solve
        from repro.core import exact_medoid
        X = np.random.default_rng(0).random((4096, 3)).astype(np.float32)
        ti, _ = exact_medoid(X)
        rep = solve(MedoidQuery(X, device_policy="sharded"))
        ref = solve(MedoidQuery(X), plan="pipelined")
        assert rep.plan.engine == "sharded"
        assert rep.plan.params["n_shards"] == 8
        assert rep.index == ref.index == ti, (rep.index, ref.index, ti)
        assert rep.energy == ref.energy
        assert rep.elements_computed == ref.elements_computed
        per = rep.plan.params["per_shard_elements"]
        assert len(per) == 8 and sum(per) == rep.elements_computed
        print("OK", rep.index, int(rep.elements_computed))
    """)
    assert "OK" in out


def test_sharded_train_step_runs_and_matches():
    """Train step under a 4x2 host mesh == single-device step (loss)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
        from repro.configs.base import get_smoke_config
        from repro.launch import shardings as sh
        from repro.models import model as M
        from repro.optim import adamw
        from repro.train.train_step import make_train_step
        cfg = get_smoke_config("qwen3_4b")
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        key = jax.random.PRNGKey(0)
        params = M.init_params(cfg, key)
        opt = adamw.init_state(params)
        batch = {"tokens": jax.random.randint(key, (8, 64), 0, cfg.vocab)}
        step = make_train_step(cfg, adamw.AdamWConfig())
        # single device reference
        _, _, _, m_ref = jax.jit(step)(params, opt, {}, batch)
        # sharded
        pspec = sh.param_specs(cfg, params, msize=2)
        ospec = sh.opt_specs(cfg, params, data_size=4, msize=2)
        bspec = sh.batch_specs(cfg, batch, mesh)
        pp = sh.shard_tree(params, pspec, mesh)
        oo = sh.shard_tree(opt, ospec, mesh)
        bb = sh.shard_tree(batch, bspec, mesh)
        _, _, _, m_sh = jax.jit(step)(pp, oo, {}, bb)
        d = abs(float(m_ref["loss"]) - float(m_sh["loss"]))
        assert d < 2e-3, d
        print("OK", float(m_ref["loss"]), float(m_sh["loss"]))
    """)
    assert "OK" in out


def test_production_mesh_shapes():
    out = _run("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert m1.shape == {"data": 16, "model": 16}
        assert m2.shape == {"pod": 2, "data": 16, "model": 16}
        print("OK", m1.size, m2.size)
    """, devices=512)
    assert "OK 256 512" in out


def test_full_config_param_specs_divisible_and_tp():
    """For every FULL config: specs build, every 'model'/'data'
    partition divides its dim (jit hard-requires), and TP is actually
    applied somewhere meaningful."""
    import jax

    from repro.configs.base import ARCHS, get_config
    from jax.sharding import PartitionSpec

    for arch in ARCHS:
        cfg = get_config(arch)
        tree = sp.params_struct(cfg)
        specs = sh.param_specs(cfg, tree, msize=16)
        flat_t = jax.tree_util.tree_leaves(tree)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
        assert len(flat_t) == len(flat_s), arch
        n_model = 0
        for leaf, spec in zip(flat_t, flat_s):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax in ("model", "data"):
                    assert dim % 16 == 0, (arch, leaf.shape, tuple(spec))
                if ax == "model":
                    n_model += 1
        assert n_model >= 4, arch


def test_moe_ep_matches_reference():
    """shard_map expert-parallel MoE == dropless reference (host mesh)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import AxisType
        from repro.configs.base import get_smoke_config
        from repro.models import moe as moe_mod
        cfg0 = get_smoke_config("qwen2_moe_a2_7b").replace(moe_ep=True)
        cfg = cfg0.replace(moe=cfg0.moe.__class__(
            **{**cfg0.moe.__dict__, "capacity_factor": 8.0}))
        key = jax.random.PRNGKey(0)
        p = moe_mod.init_moe(cfg, key)
        assert p["w_gate"].shape[0] == 16   # padded 8 -> 16
        x = jax.random.normal(key, (8, 16, cfg.d_model), jnp.float32)
        y_ref, _ = moe_mod.moe_fwd(cfg, p, x, dropless=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            assert moe_mod._ep_applicable(cfg, x) == ("data",)
            y_ep, _ = jax.jit(
                lambda p, x: moe_mod.moe_fwd(cfg, p, x, dropless=False)
            )(p, x)
        err = float(jnp.max(jnp.abs(y_ref - y_ep)))
        assert err < 5e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_moe_ep_gradients_flow():
    """EP path is differentiable (collectives transpose correctly)."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import AxisType
        from repro.configs.base import get_smoke_config
        from repro.models import moe as moe_mod
        cfg = get_smoke_config("granite_moe_3b_a800m").replace(moe_ep=True)
        key = jax.random.PRNGKey(0)
        p = moe_mod.init_moe(cfg, key)
        x = jax.random.normal(key, (8, 16, cfg.d_model), jnp.float32)
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        def loss(p, x):
            y, aux = moe_mod.moe_fwd(cfg, p, x, dropless=False)
            return (y ** 2).mean() + aux["moe_aux"]
        with jax.set_mesh(mesh):
            g = jax.jit(jax.grad(loss))(p, x)
        gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
        assert gn > 0 and jnp.isfinite(gn)
        print("OK", gn)
    """)
    assert "OK" in out


def test_seq_shard_attention_matches_unsharded():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import AxisType
        from repro.models.attention import blockwise_attention
        key = jax.random.PRNGKey(0)
        B, S, H, KV, HD = 2, 64, 4, 2, 16
        q = jax.random.normal(key, (B, S, H, HD))
        k = jax.random.normal(key, (B, S, KV, HD))
        v = jax.random.normal(key, (B, S, KV, HD))
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        ref = blockwise_attention(q, k, v, causal=True, chunk=S,
                                  q_positions=pos, kv_positions=pos)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            got = jax.jit(lambda q, k, v: blockwise_attention(
                q, k, v, causal=True, chunk=S, q_positions=pos,
                kv_positions=pos, seq_shard=True))(q, k, v)
        err = float(jnp.max(jnp.abs(ref - got)))
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out


def test_checkpoint_elastic_restore_across_meshes(tmp_path):
    """Save sharded on a (4,2) mesh, restore onto (2,2) — elastic."""
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
        from repro.checkpoint.checkpoint import Checkpointer
        mesh1 = jax.make_mesh((4, 2), ("data", "model"),
                              axis_types=(AxisType.Auto,) * 2)
        tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
        sharded = jax.tree.map(lambda a: jax.device_put(
            a, NamedSharding(mesh1, P("data", "model"))), tree)
        ck = Checkpointer(r"{tmp_path}")
        ck.save(3, sharded)
        # new, smaller mesh (simulates losing half the data axis)
        mesh2 = jax.make_mesh((2, 2), ("data", "model"),
                              axis_types=(AxisType.Auto,) * 2)
        sh2 = {{"w": NamedSharding(mesh2, P("data", "model"))}}
        step, restored = ck.restore(tree, shardings=sh2)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding.mesh.shape["data"] == 2
        print("OK")
    """)
    assert "OK" in out
