"""Substrate tests: optimizer, data pipeline, checkpoint, compression,
fault-tolerance supervisor."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer
from repro.data.pipeline import DataConfig, ShardedLoader, family_batch, lm_batch
from repro.configs.base import ShapeSpec, get_smoke_config
from repro.optim import adamw
from repro.optim.compress import (compress_with_feedback,
                                  init_error_buffers, quantize_int8,
                                  dequantize_int8, top_k_mask)
from repro.runtime.fault_tolerance import Supervisor, SupervisorConfig


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=100, schedule="constant")
    params = {"w": jnp.ones((4,)) * 5.0}
    state = adamw.init_state(params)
    for _ in range(200):
        grads = {"w": 2 * state.master["w"]}
        params, state, _ = adamw.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(adamw.schedule_lr(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(adamw.schedule_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.schedule_lr(cfg, jnp.asarray(100))) == pytest.approx(0.1)


def test_grad_clip():
    g = {"a": jnp.ones((100,)) * 10.0}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


# -------------------------------------------------------------- compression
def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-9


def test_error_feedback_accumulates():
    """EF property: compressed-sum over steps converges to true sum."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.standard_normal(256) * 1e-3, jnp.float32)
    params = {"w": g_true}
    buf = init_error_buffers(params)
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        deq, buf = compress_with_feedback({"w": g_true}, buf)
        total = total + deq["w"]
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g_true),
                               atol=2e-5)


def test_topk_mask():
    g = {"w": jnp.arange(100.0)}
    masked = top_k_mask(g, 0.1)
    assert int((masked["w"] != 0).sum()) == 10
    assert float(masked["w"].max()) == 99.0


# --------------------------------------------------------------------- data
def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(seed=3, vocab=1000, seq_len=32, global_batch=4)
    b1 = lm_batch(cfg, 7)
    b2 = lm_batch(cfg, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = lm_batch(cfg, 8)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_pipeline_host_sharding_partitions_batch():
    mc = get_smoke_config("qwen3_4b")
    shape = ShapeSpec("t", 16, 8, "train")
    full = ShardedLoader(mc, shape)(0)
    parts = [ShardedLoader(mc, shape, host_index=i, host_count=4)(0)
             for i in range(4)]
    stacked = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(stacked, np.asarray(full["tokens"]))


@pytest.mark.parametrize("arch", ["hubert_xlarge", "internvl2_26b"])
def test_pipeline_families(arch):
    mc = get_smoke_config(arch)
    shape = ShapeSpec("t", 32, 2, "train")
    b = family_batch(mc, shape, 0)
    if arch == "hubert_xlarge":
        assert b["frames"].shape == (2, 32, 512)
        assert b["targets"].shape == (2, 32)
    else:
        assert b["patches"].shape[1] == mc.n_patches


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=2)
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
    ck.save(5, tree)
    step, restored = ck.restore(tree)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_keeps_last_k_and_latest_pointer(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=2)
    tree = {"a": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert sorted(ck.all_steps()) == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_keep_last_none_is_unlimited(tmp_path):
    ck = Checkpointer(tmp_path, keep_last=None)
    tree = {"a": jnp.zeros(3)}
    for s in range(1, 8):
        ck.save(s, tree)
    assert sorted(ck.all_steps()) == list(range(1, 8))
    assert ck.latest_step() == 7


def test_checkpoint_keep_last_below_one_refused(tmp_path):
    for bad in (0, -1):
        with pytest.raises(ValueError, match="keep_last"):
            Checkpointer(tmp_path, keep_last=bad)


def test_checkpoint_prune_drops_oldest_first(tmp_path):
    """Pruning is by *step* order, not write order, and
    ``latest_step()`` always names a step that survived the prune."""
    ck = Checkpointer(tmp_path, keep_last=2)
    tree = {"a": jnp.zeros(3)}
    ck.save(7, tree)
    ck.save(2, tree)                   # out-of-order write
    assert ck.latest_step() == 2       # pointer tracks the last write
    ck.save(9, tree)                   # prunes step 2 (lowest step)
    assert sorted(ck.all_steps()) == [7, 9]
    ck.save(5, tree)                   # below the retained window:
    assert sorted(ck.all_steps()) == [7, 9]   # pruned immediately...
    assert ck.latest_step() == 9       # ...and the pointer falls back
                                       # to the highest surviving step


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"a": jnp.arange(100.0)}
    ck.save(1, tree, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_checkpoint_atomic_no_partial(tmp_path):
    """A *.tmp dir left behind by a crash must not be visible."""
    ck = Checkpointer(tmp_path)
    ck.save(1, {"a": jnp.zeros(2)})
    (tmp_path / "step_9.tmp").mkdir()
    assert ck.latest_step() == 1
    assert 9 not in ck.all_steps()


def test_checkpoint_background_write_failure_surfaces(tmp_path):
    """A failed async write must surface on the *next* interaction with
    the checkpointer — ``save``, ``wait`` or ``latest_step`` — never be
    swallowed: a fire-and-forget caller has to learn its checkpoints
    are being lost. The pending error is consumed once raised, so the
    checkpointer stays usable afterwards."""
    tree = {"a": jnp.zeros(2)}

    def _boom(step, host_tree, extra_meta=None):
        raise OSError("injected: disk gone")

    ck = Checkpointer(tmp_path / "a")
    ck._write = _boom
    ck.save(1, tree, blocking=False)
    ck._q.join()
    with pytest.raises(OSError, match="disk gone"):
        ck.save(2, tree)                    # surfaces on the next save
    del ck.__dict__["_write"]               # error consumed; disk "back"
    ck.save(3, tree)
    assert ck.latest_step() == 3

    ck2 = Checkpointer(tmp_path / "b")
    ck2._write = _boom
    ck2.save(1, tree, blocking=False)
    ck2._q.join()
    with pytest.raises(OSError, match="disk gone"):
        ck2.latest_step()                   # ...or on the next read
    del ck2.__dict__["_write"]
    assert ck2.latest_step() is None


def test_checkpoint_load_with_extra_meta(tmp_path):
    """``load`` returns the flat leaves and the stored ``extra_meta`` —
    the treeless path SolveState resume uses."""
    ck = Checkpointer(tmp_path)
    ck.save(4, {"a": jnp.arange(3.0)}, extra_meta={"fingerprint": "xyz"})
    step, leaves, meta = ck.load()
    assert step == 4
    np.testing.assert_array_equal(leaves[0], np.arange(3.0))
    assert meta["extra"] == {"fingerprint": "xyz"}


# ------------------------------------------------------------------ runtime
def test_supervisor_detects_dead_worker():
    clock = [0.0]
    sup = Supervisor(4, SupervisorConfig(heartbeat_timeout_s=10),
                     clock=lambda: clock[0])
    for w in range(4):
        sup.heartbeat(w, 1, 1.0)
    clock[0] = 5.0
    for w in range(3):  # worker 3 goes silent
        sup.heartbeat(w, 2, 1.0)
    clock[0] = 20.0
    for w in range(3):
        sup.heartbeat(w, 3, 1.0)
    evicted = sup.check()
    assert evicted == [3]
    assert sup.alive_count() == 3


def test_supervisor_evicts_straggler():
    clock = [0.0]
    sup = Supervisor(4, SupervisorConfig(straggler_factor=1.5,
                                         straggler_strikes=2),
                     clock=lambda: clock[0])
    for step in range(6):
        clock[0] += 1
        for w in range(4):
            sup.heartbeat(w, step, 5.0 if w == 2 else 1.0)
        sup.check()
    assert not sup.workers[2].alive
    assert ("straggler", 2) in sup.events


def test_supervisor_elastic_mesh_plan():
    sup = Supervisor(512, SupervisorConfig())
    # lose 17 workers -> data axis shrinks in whole-pod units of 256
    for w in range(17):
        sup.workers[w].alive = False
    plan = sup.plan_mesh(model_parallel=16, pod_size=256)
    assert plan == (16, 16)  # one pod's worth survives whole
    sup2 = Supervisor(8, SupervisorConfig(min_data_parallel=4))
    for w in range(6):
        sup2.workers[w].alive = False
    assert sup2.plan_mesh(model_parallel=1) is None


def test_supervisor_restart_budget():
    sup = Supervisor(2, SupervisorConfig(max_restarts=2))
    assert sup.should_restart()
    assert sup.should_restart()
    assert not sup.should_restart()
