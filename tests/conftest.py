import os
import sys

# Tests run on the single real CPU device (the dry-run, and only the
# dry-run, forces 512 placeholder devices in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_programs():
    """Drop jax's executable caches at module boundaries. The full
    suite compiles hundreds of distinct programs; on the CPU backend
    the accumulated JIT code eventually destabilises the process
    (native segfaults late in the run). Clearing per module bounds
    code memory by the largest module instead of the whole suite."""
    yield
    jax.clear_caches()


def make_batch(cfg, key, batch=2, seq=64):
    """Family-appropriate random batch for smoke tests."""
    import jax.numpy as jnp
    from repro.models import model as M

    if cfg.family == "encoder":
        return {
            "frames": jax.random.normal(key, (batch, seq, M.FRAME_DIM),
                                        jnp.float32),
            "mask": jax.random.bernoulli(key, 0.3, (batch, seq)),
            "targets": jax.random.randint(key, (batch, seq), 0, cfg.vocab),
        }
    if cfg.family == "vlm":
        return {
            "tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab),
            "patches": jax.random.normal(
                key, (batch, cfg.n_patches, M.VISION_DIM), jnp.float32),
        }
    return {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab)}
