"""Resumable solves and deadlines (DESIGN.md §13).

The contract under test: a pipelined solve that is killed at *any*
segment boundary and resumed from its checkpoint must be **bit
identical** — medoid index, energy, computed-element count, round
count, certificate — to the same solve run uninterrupted. That holds
because segmentation only splits the host loop around the same jitted
round program (``seg_cap`` is traced, so segmented and straight-through
runs share one compiled program), sums ride the fixed
``chunked_rowsum`` reduction grid, and resume never re-runs compaction
(re-compacting would re-order the pivot sequence).

Deadlines are the other half: a blown ``deadline_s`` returns the
incumbent as an anytime result — ``certified=False`` with a
deterministic bound-gap CI — never an exception. Kills are injected
with :mod:`repro.runtime.faults` (``fail_round``), the clock is blown
with injected stalls, so everything here is deterministic.
"""
import numpy as np
import pytest

from _hyp import given, settings, st, watchdog

from repro.api import MedoidQuery, plan_query, solve
from repro.core.pipelined import _trimed_pipelined
from repro.runtime import faults

METRICS = ("l2", "l1")


def _X(n, d=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def _sig(r):
    """The bit-identity signature of a MedoidResult."""
    return (r.index, r.energy, r.n_computed, r.n_rounds, r.certified)


def _ref(X, metric, **kw):
    return _trimed_pipelined(X, metric=metric, **kw)


# ---------------------------------------------------------------------------
# segmentation alone must not change anything
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n", [257, 4097])
def test_segmented_matches_straight_through(tmp_path, metric, n):
    X = _X(n, seed=1)
    ref = _ref(X, metric)
    seg = _trimed_pipelined(X, metric=metric, checkpoint=tmp_path,
                            checkpoint_every=1)
    assert _sig(seg) == _sig(ref)


# ---------------------------------------------------------------------------
# kill at any round, resume, bit-identical
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([257, 513, 4097]),
       metric=st.sampled_from(METRICS),
       kill=st.integers(min_value=1, max_value=10),
       every=st.sampled_from([1, 2, 3]),
       seed=st.integers(min_value=0, max_value=3))
def test_kill_and_resume_bit_identical(n, metric, kill, every, seed):
    import tempfile
    X = _X(n, seed=seed)
    ref = _ref(X, metric)
    with tempfile.TemporaryDirectory() as td, watchdog(
            300, "kill/resume parity run stalled"):
        try:
            with faults.inject(faults.FaultSpec(fail_round=kill)):
                _trimed_pipelined(X, metric=metric, checkpoint=td,
                                  checkpoint_every=every)
            killed = False          # solve finished before round `kill`
        except faults.FaultError:
            killed = True
        res = _trimed_pipelined(X, metric=metric, checkpoint=td,
                                checkpoint_every=every, resume="require")
        assert _sig(res) == _sig(ref), (
            f"resume after kill@{kill} (killed={killed}) diverged")


def test_kill_deep_in_ladder_resumes(tmp_path):
    """A kill well past the first compaction resumes mid-rung: the
    restored state re-enters `_stage_loop` without re-compacting."""
    X = _X(4097, seed=7)
    ref = _ref(X, "l2")
    assert ref.n_rounds > 6          # the grid actually reaches a ladder
    kill = int(ref.n_rounds) - 1
    with pytest.raises(faults.FaultError):
        with faults.inject(faults.FaultSpec(fail_round=kill)):
            _trimed_pipelined(X, checkpoint=tmp_path, checkpoint_every=1)
    res = _trimed_pipelined(X, checkpoint=tmp_path, checkpoint_every=1,
                            resume="require")
    assert _sig(res) == _sig(ref)


def test_double_kill_then_resume(tmp_path):
    """Two successive kills (crash during the resumed run) still land
    on the bit-identical answer."""
    X = _X(513, seed=3)
    ref = _ref(X, "l2")
    assert ref.n_rounds >= 3             # both kills actually land
    for kill in (1, 2):
        with pytest.raises(faults.FaultError):
            with faults.inject(faults.FaultSpec(fail_round=kill)):
                _trimed_pipelined(X, checkpoint=tmp_path,
                                  checkpoint_every=1, resume="auto")
    res = _trimed_pipelined(X, checkpoint=tmp_path, checkpoint_every=1,
                            resume="require")
    assert _sig(res) == _sig(ref)


def test_resume_idempotent_after_success(tmp_path):
    """Resuming from the checkpoint of a *finished* solve returns the
    same answer again (the restored state has no live candidates)."""
    X = _X(257, seed=2)
    a = _trimed_pipelined(X, checkpoint=tmp_path, checkpoint_every=1)
    b = _trimed_pipelined(X, checkpoint=tmp_path, checkpoint_every=1,
                          resume="require")
    assert _sig(a) == _sig(b)


# ---------------------------------------------------------------------------
# resume guards
# ---------------------------------------------------------------------------
def test_resume_refuses_mismatched_config(tmp_path):
    from repro.core.solve_state import SolveStateMismatch
    X = _X(257)
    with pytest.raises(faults.FaultError):
        with faults.inject(faults.FaultSpec(fail_round=1)):
            _trimed_pipelined(X, checkpoint=tmp_path, checkpoint_every=1)
    with pytest.raises(SolveStateMismatch):
        _trimed_pipelined(X, block=64, checkpoint=tmp_path,
                          resume="require")


def test_resume_require_without_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        _trimed_pipelined(_X(257), checkpoint=tmp_path / "empty",
                          resume="require")


def test_resume_never_ignores_checkpoint(tmp_path):
    X = _X(257, seed=5)
    ref = _ref(X, "l2")
    with pytest.raises(faults.FaultError):
        with faults.inject(faults.FaultSpec(fail_round=1)):
            _trimed_pipelined(X, checkpoint=tmp_path, checkpoint_every=1)
    res = _trimed_pipelined(X, checkpoint=tmp_path, checkpoint_every=1,
                            resume="never")
    assert _sig(res) == _sig(ref)        # fresh run, same answer


# ---------------------------------------------------------------------------
# checkpointing through the public API
# ---------------------------------------------------------------------------
def test_api_checkpoint_engine_opts(tmp_path):
    X = _X(300, seed=4)
    ref = solve(MedoidQuery(X), plan="pipelined")
    with pytest.raises(faults.FaultError):
        with faults.inject(faults.FaultSpec(fail_round=1)):
            solve(MedoidQuery(X, engine_opts={
                "checkpoint": str(tmp_path), "checkpoint_every": 1}),
                plan="pipelined")
    rep = solve(MedoidQuery(X, engine_opts={
        "checkpoint": str(tmp_path), "checkpoint_every": 1,
        "resume": "require"}), plan="pipelined")
    assert rep.index == ref.index
    assert rep.energy == ref.energy
    assert rep.elements_computed == ref.elements_computed
    assert rep.certified


# ---------------------------------------------------------------------------
# deadlines: anytime incumbent, never an exception
# ---------------------------------------------------------------------------
def test_generous_deadline_still_certifies():
    X = _X(257, seed=6)
    rep = solve(MedoidQuery(X, deadline_s=600.0))
    assert rep.certified
    # the planner routed to a deadline-capable engine; the answer is
    # bit-identical to the same engine run without a deadline (the
    # deadline machinery must not perturb the arithmetic)
    ref = solve(MedoidQuery(X), plan=rep.plan.engine)
    assert rep.index == ref.index and rep.energy == ref.energy


@pytest.mark.parametrize("n", [257, 4097])
def test_blown_deadline_returns_incumbent(n):
    """An injected stall blows the deadline: the solve returns the
    incumbent with ``certified=False``, a finite positive CI derived
    from the surviving lower bound, and the halt reason on record."""
    X = _X(n, seed=8)
    with faults.inject(faults.FaultSpec(stall_round=1, stall_s=1e6)):
        rep = solve(MedoidQuery(X, deadline_s=100.0), plan="pipelined")
    assert not rep.certified
    assert rep.extras["halt_reason"] == "deadline"
    assert np.isfinite(rep.ci) and rep.ci >= 0.0
    assert np.isfinite(rep.extras["lower_bound"])
    assert rep.extras["lower_bound"] <= rep.energy
    assert 0 <= rep.index < n
    # the incumbent is a real element energy, not garbage
    d = np.linalg.norm(X - X[rep.index], axis=1)
    assert rep.energy == pytest.approx(d.sum() / (n - 1), rel=1e-5)


def test_blown_deadline_sequential_oracle():
    """The host sequential engine checks the deadline between elements:
    a deadline shorter than one element's work returns the incumbent
    found so far (at least one element always completes)."""
    from repro.core.distances import VectorOracle
    X = _X(300, seed=9)
    rep = solve(MedoidQuery(VectorOracle(X), deadline_s=1e-6),
                plan="sequential")
    assert not rep.certified
    assert rep.extras["halt_reason"] == "deadline"
    assert np.isfinite(rep.energies[0])
    assert 0 <= rep.index < 300


def test_deadline_reroutes_unsupported_engines():
    """The planner reroutes block/sharded overrides to the
    deadline-capable pipelined engine and says so in the reasons; a
    planner-chosen engine is always deadline-capable."""
    X = _X(300, seed=1)
    p = plan_query(MedoidQuery(X, deadline_s=5.0))
    assert p.engine in ("sequential", "pipelined")
    p2 = solve(MedoidQuery(X, deadline_s=5.0), plan="block", explain=True)
    assert p2.engine == "pipelined"
    assert any("deadline" in r for r in p2.reasons)


def test_deadline_rejected_for_kmedoids():
    X = _X(120, seed=2)
    with pytest.raises(ValueError, match="deadline"):
        solve(MedoidQuery(X, k=3, deadline_s=5.0))


def test_deadline_validation():
    with pytest.raises(ValueError, match="deadline_s"):
        MedoidQuery(_X(64), deadline_s=-1.0)
    with pytest.raises(ValueError, match="deadline_s"):
        MedoidQuery(_X(64), deadline_s=0)
