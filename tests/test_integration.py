"""System integration tests: train loop with restart, serving engine,
paper-technique hooks (pseudo-labels, coreset, KV compression)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeSpec, get_smoke_config
from repro.models import model as M


def test_train_loop_decreases_loss_and_restarts(tmp_path):
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_smoke_config("qwen3_4b")
    shape = ShapeSpec("t", 64, 4, "train")
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    tc = TrainerConfig(steps=60, log_every=10, ckpt_every=20,
                       ckpt_dir=str(tmp_path), async_checkpoint=False)
    tr = Trainer(cfg, shape, opt, tc, seed=0)
    log1 = tr.run(steps=40)
    # crash + restore
    tr2 = Trainer(cfg, shape, opt, tc, seed=0)
    resumed = tr2.maybe_restore()
    assert resumed == 40
    log2 = tr2.run()
    assert log2[-1]["loss"] < log1[0]["loss"]


def test_train_microbatched_matches_full_batch():
    """Grad accumulation must give (nearly) the same update."""
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import make_train_step
    from repro.optim import adamw

    cfg = get_smoke_config("starcoder2_7b")
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                          schedule="constant")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab)}

    s1 = make_train_step(cfg, opt_cfg, microbatches=1)
    s2 = make_train_step(cfg, opt_cfg, microbatches=2)
    p1, _, _, m1 = s1(params, adamw.init_state(params), {}, batch)
    p2, _, _, m2 = s2(params, adamw.init_state(params), {}, batch)
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                  - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3, d


def test_train_with_compression_converges():
    from repro.optim.adamw import AdamWConfig
    from repro.optim import adamw
    from repro.optim.compress import init_error_buffers
    from repro.train.train_step import make_train_step

    cfg = get_smoke_config("granite_moe_3b_a800m")
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    step = jax.jit(make_train_step(cfg, opt_cfg, compress=True))
    opt_state = adamw.init_state(params)
    err = init_error_buffers(params)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab)}
    losses = []
    for _ in range(30):
        params, opt_state, err, metrics = step(params, opt_state, err,
                                               batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_serve_engine_continuous_batching():
    from repro.serve.engine import Request, ServeEngine

    cfg = get_smoke_config("qwen3_4b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, n_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for i in range(5):  # more requests than slots -> queueing
        eng.submit(Request(uid=i, prompt=rng.integers(0, cfg.vocab, 8),
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)


def test_serve_greedy_matches_manual_decode():
    """Engine output == hand-rolled prefill+decode loop (greedy)."""
    from repro.serve.engine import Request, ServeEngine

    cfg = get_smoke_config("minicpm3_4b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(10) % cfg.vocab
    eng = ServeEngine(cfg, params, n_slots=1, max_len=32)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    out = eng.run()[0].out_tokens

    cache = M.init_cache(cfg, 1, 32)
    last, cache = M.prefill(cfg, params,
                            {"tokens": jnp.asarray(prompt)[None]}, cache)
    toks = [int(jnp.argmax(last[0]))]
    pos = len(prompt)
    for _ in range(4):
        lg, cache = M.decode_step(cfg, params,
                                  jnp.asarray([[toks[-1]]], jnp.int32),
                                  cache, jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert out == toks


def test_pseudolabel_codebook():
    from repro.data.pseudolabel import assign_targets, build_codebook

    rng = np.random.default_rng(0)
    centers = rng.standard_normal((8, 32)) * 5
    frames = (centers[rng.integers(0, 8, 500)]
              + rng.standard_normal((500, 32)) * 0.1)
    cb, idx = build_codebook(frames, k=8, seed=0)
    assert cb.shape == (8, 32)
    t = assign_targets(frames[None], cb)[0]
    # cluster structure recovered: points from one true center share codes
    assert len(np.unique(t)) == 8


def test_coreset_dedup():
    from repro.data.coreset import dedup, select_coreset

    rng = np.random.default_rng(0)
    X = rng.standard_normal((300, 8))
    X[100:200] = X[:100] + 1e-4          # exact near-duplicates
    m_idx, assign, energy = select_coreset(X, k=10)
    assert len(np.unique(m_idx)) == 10
    keep = dedup(X, m_idx, assign, eps=1e-2)
    assert len(keep) < 300               # duplicates dropped


def test_kv_compress_decode_close():
    from repro.models.attention import decode_attention
    from repro.serve.kv_compress import (compress_cache,
                                         compressed_decode_attention)

    key = jax.random.PRNGKey(0)
    B, S, KV, HD = 1, 128, 2, 16
    # clustered keys -> compression should be near-exact
    protos = jax.random.normal(key, (8, KV, HD)) * 3
    idx = jax.random.randint(key, (S,), 0, 8)
    keys = protos[idx] + 0.01 * jax.random.normal(key, (S, KV, HD))
    keys = keys[None]
    vals = protos[idx][None] * 0.5
    q = jax.random.normal(key, (B, 1, 4, HD))
    exact = decode_attention(q, keys, vals, q_position=None,
                             kv_len=jnp.array([S]))
    mk, mv, lm = compress_cache(keys, vals, k=8, n_iter=8)
    approx = compressed_decode_attention(q, mk, mv, lm)
    rel = float(jnp.max(jnp.abs(exact - approx))
                / (jnp.max(jnp.abs(exact)) + 1e-9))
    assert rel < 0.15, rel
