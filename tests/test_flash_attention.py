"""Flash-attention Pallas kernel vs the jnp blockwise oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import blockwise_attention

CASES = [
    (2, 64, 4, 2, 16, True),
    (1, 100, 8, 8, 32, True),
    (2, 33, 4, 1, 8, False),     # MQA, bidirectional, unaligned S
    (1, 256, 4, 2, 64, True),
    (1, 17, 2, 2, 128, True),    # tiny S, wide head
]


def _ref(q, k, v, causal):
    b, sq = q.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    return blockwise_attention(q, k, v, causal=causal,
                               chunk=max(sq, k.shape[1]),
                               q_positions=pos, kv_positions=pos)


@pytest.mark.parametrize("b,s,h,kvh,hd,causal", CASES)
def test_flash_matches_reference(b, s, h, kvh, hd, causal):
    rng = np.random.default_rng(b * s + h)
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    ref = _ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(8, 8), (16, 64), (128, 32)])
def test_flash_block_shape_invariance(bq, bk):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, 96, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 96, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 96, 2, 32)), jnp.float32)
    ref = _ref(q, k, v, True)
    got = flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(4, 120), hd=st.sampled_from([8, 16, 32]),
       g=st.sampled_from([1, 2, 4]), seed=st.integers(0, 100))
def test_property_flash_matches_reference(s, hd, g, seed):
    rng = np.random.default_rng(seed)
    kvh = 2
    q = jnp.asarray(rng.standard_normal((1, s, kvh * g, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, s, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, s, kvh, hd)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, bq=16, bk=16)
    ref = _ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
