"""Fault-tolerance & elasticity runtime (launcher-level).

JAX SPMD programs are bulk-synchronous: a dead or slow chip stalls every
collective. Recovery therefore happens at the *launcher* layer, not
inside the jitted step — the supervisor pattern here is the one used by
production TPU trainers:

* **Heartbeats**: every worker bumps a counter after each step; the
  supervisor marks a worker dead after ``timeout_s`` without progress.
* **Straggler mitigation**: per-step wall-times are tracked per worker;
  workers slower than ``straggler_factor`` x the rolling median for
  ``strikes`` consecutive windows are preemptively evicted (it is
  cheaper to restart a pod than to let one slow HBM chip gate 511
  others).
* **Elastic restart**: on eviction/death the supervisor recomputes the
  largest viable mesh from surviving hosts (data axis shrinks by whole
  pods/hosts; the model axis is fixed by the sharding layout), restores
  the latest checkpoint (full-logical-array checkpoints reshard onto the
  new mesh — `repro.checkpoint`), and replays the data stream from the
  checkpointed step (the pipeline is counter-based, so replay is exact).
* **Restart budget**: crash-looping jobs stop after ``max_restarts``.

The supervisor is event-driven and fully testable without real failures:
`tests/test_runtime.py` drives it with synthetic heartbeat sequences.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class WorkerState:
    last_beat: float
    step: int = 0
    step_times: list = field(default_factory=list)
    strikes: int = 0
    alive: bool = True


@dataclass(frozen=True)
class SupervisorConfig:
    heartbeat_timeout_s: float = 300.0
    straggler_factor: float = 1.5
    straggler_strikes: int = 3
    window: int = 20
    max_restarts: int = 10
    min_data_parallel: int = 1


class Supervisor:
    def __init__(self, n_workers: int, cfg: SupervisorConfig = SupervisorConfig(),
                 clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.workers = {i: WorkerState(last_beat=clock())
                        for i in range(n_workers)}
        self.restarts = 0
        self.events: list[tuple[str, int]] = []

    # ------------------------------------------------------------ beats
    def heartbeat(self, worker: int, step: int, step_time_s: float):
        w = self.workers[worker]
        w.last_beat = self.clock()
        w.step = step
        w.step_times.append(step_time_s)
        if len(w.step_times) > self.cfg.window:
            w.step_times.pop(0)

    # ------------------------------------------------------------ checks
    def _median_step_time(self) -> float:
        times = [t for w in self.workers.values() if w.alive
                 for t in w.step_times[-self.cfg.window:]]
        if not times:
            return 0.0
        times.sort()
        return times[len(times) // 2]

    def check(self) -> list[int]:
        """Returns workers evicted this check (dead or straggling)."""
        now = self.clock()
        med = self._median_step_time()
        evicted = []
        for i, w in self.workers.items():
            if not w.alive:
                continue
            if now - w.last_beat > self.cfg.heartbeat_timeout_s:
                w.alive = False
                evicted.append(i)
                self.events.append(("dead", i))
                continue
            if med > 0 and w.step_times:
                recent = w.step_times[-1]
                if recent > self.cfg.straggler_factor * med:
                    w.strikes += 1
                    if w.strikes >= self.cfg.straggler_strikes:
                        w.alive = False
                        evicted.append(i)
                        self.events.append(("straggler", i))
                else:
                    w.strikes = 0
        return evicted

    # ----------------------------------------------------------- elastic
    def alive_count(self) -> int:
        return sum(w.alive for w in self.workers.values())

    def plan_mesh(self, model_parallel: int, pod_size: int | None = None
                  ) -> tuple[int, int] | None:
        """Largest (data, model) mesh from surviving workers. The data
        axis shrinks in whole-pod units when `pod_size` is given (ICI
        domains don't splice across pods). Returns None when below
        `min_data_parallel` (job must queue for repair)."""
        alive = self.alive_count()
        usable = alive - alive % (pod_size or 1)
        data = usable // model_parallel
        if data < self.cfg.min_data_parallel:
            return None
        return data, model_parallel

    def should_restart(self) -> bool:
        self.restarts += 1
        return self.restarts <= self.cfg.max_restarts
