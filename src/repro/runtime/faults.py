"""Deterministic fault-injection harness for the solve runtime (DESIGN.md §13).

Every failure mode the fault-tolerant runtime claims to survive is
injectable here, deterministically, with no real sleeping, no real
process kills and no real device loss — so the whole recovery story
(checkpoint/resume, deadline degradation, the planner downgrade ladder,
``MedoidServer`` bisection/quarantine) is driven by ordinary unit tests:

* **Data corruption** — :func:`corrupt` plants seeded NaN/Inf rows in a
  copy of ``X`` (the ``nonfinite="raise"`` validation and the server's
  isolation path must both catch it).
* **Poison queries** — :func:`mark_poison` registers an array so any
  engine or packed ``solve_many`` chunk touching it raises
  :class:`FaultError` at run time (not at validation time). This is the
  stand-in for "query that crashes the compiled program": deterministic,
  repeatable, and invisible to input validation — exactly the shape of
  failure the server's bisection has to isolate.
* **Oracle faults** — :func:`on_oracle_call` (hooked into
  ``VectorOracle.row``) raises at the k-th distance call.
* **Engine faults / process kills** — :func:`on_segment` (hooked into
  the pipelined engine's segment loop) raises at segment entry once the
  round counter passes ``fail_round``: combined with checkpointing this
  *is* a kill-and-resume test, without killing anything.
* **Stalls** — ``stall_round``/``stall_s`` advance the module's fault
  clock (:func:`clock`) instead of sleeping; deadline checks and the
  :class:`RoundWatchdog` heartbeat monitor read this clock, so a
  simulated stall blows deadlines and trips watchdogs in microseconds of
  real time.
* **Budget exhaustion** — ``force_budget`` clamps the engine's computed
  -row budget mid-flight (the anytime/incumbent path must fire).
* **Shard loss** — :func:`on_shard_entry` (hooked into the sharded
  executors) raises :class:`ShardLostError`, which the planner's
  downgrade ladder turns into a single-device retry.

Arm a spec with the :func:`inject` context manager; everything is a
no-op (one ``is None`` check) when nothing is armed. ``REPRO_FAULTS``
(CI's fault lane) widens the seed grid the fault tests sweep —
:func:`fault_seeds`.

The :class:`RoundWatchdog` repurposes the launcher-level
:class:`~repro.runtime.fault_tolerance.Supervisor` heartbeat pattern for
*solve rounds*: the engine beats once per segment, and a beat gap longer
than ``timeout_s`` (by the fault clock) marks the solve stalled.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Any

__all__ = [
    "FaultError", "ShardLostError", "FaultSpec", "inject", "active",
    "clock", "corrupt", "mark_poison", "check_poison", "on_segment",
    "on_oracle_call", "on_shard_entry", "effective_budget",
    "RoundWatchdog", "fault_seeds",
]


class FaultError(RuntimeError):
    """An injected fault fired (the harness's stand-in for a crash)."""


class ShardLostError(FaultError):
    """An injected loss of a device shard (multi-device engines)."""


@dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault scenario. All fields optional; a default
    spec injects nothing (useful as a base for ``dataclasses.replace``).

    ``fail_round`` / ``stall_round`` count *pipelined segments* (the
    host-visible boundaries the engine checkpoints at); ``fail_call``
    counts ``VectorOracle`` row calls, 1-based."""
    seed: int = 0
    nan_rows: int = 0            # corrupt(): rows set to NaN
    inf_rows: int = 0            # corrupt(): rows set to +Inf
    fail_call: int | None = None     # k-th oracle row call raises
    fail_round: int | None = None    # segment >= this raises (the "kill")
    fail_once: bool = True           # fire the round/shard fault only once
    stall_round: int | None = None   # segment at which the stall happens
    stall_s: float = 0.0             # simulated stall length (fault clock)
    force_budget: int | None = None  # clamp engine budget (exhaustion)
    lose_shard: bool = False         # sharded engines raise ShardLostError


class _FaultState:
    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.clock_offset = 0.0
        self.oracle_calls = 0
        self.round_fired = False
        self.stall_fired = False
        self.shard_fired = False
        self.events: list[tuple[str, Any]] = []


_ACTIVE: _FaultState | None = None
_POISON: list[int] = []      # id()s of arrays marked poisonous


def active() -> bool:
    """True when a fault spec is armed (inside :func:`inject`)."""
    return _ACTIVE is not None


class inject:
    """Context manager arming ``spec`` module-wide (not thread-safe —
    the harness is a test tool, armed around single solves)."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.state: _FaultState | None = None

    def __enter__(self) -> _FaultState:
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("faults.inject does not nest")
        self.state = _FaultState(self.spec)
        _ACTIVE = self.state
        return self.state

    def __exit__(self, *exc):
        global _ACTIVE
        _ACTIVE = None
        _POISON.clear()
        return False


def clock() -> float:
    """Monotonic host clock plus any simulated-stall offset. Deadline
    checks and watchdog heartbeats go through here, so injected stalls
    blow deadlines without real sleeping."""
    base = time.monotonic()
    return base + _ACTIVE.clock_offset if _ACTIVE is not None else base


# ---------------------------------------------------------------------------
# data faults
# ---------------------------------------------------------------------------
def corrupt(X, spec: FaultSpec):
    """A copy of ``X`` with ``spec.nan_rows`` rows of NaN and
    ``spec.inf_rows`` rows of +Inf, at seeded row positions."""
    import numpy as np
    X = np.array(X, copy=True)
    rng = np.random.default_rng(spec.seed)
    k = spec.nan_rows + spec.inf_rows
    if k == 0:
        return X
    rows = rng.choice(X.shape[0], size=min(k, X.shape[0]), replace=False)
    X[rows[:spec.nan_rows]] = np.nan
    X[rows[spec.nan_rows:]] = np.inf
    return X


def mark_poison(X) -> None:
    """Register ``X`` (by identity) as a poison input: any armed engine
    or packed chunk that touches it raises :class:`FaultError` at run
    time. Cleared when the :func:`inject` context exits."""
    if _ACTIVE is None:
        raise RuntimeError("mark_poison: arm a FaultSpec with inject() first")
    _POISON.append(id(X))


def check_poison(X, where: str) -> None:
    """Hook: raise if ``X`` was marked poisonous. No-op when disarmed."""
    if _ACTIVE is None or id(X) not in _POISON:
        return
    _ACTIVE.events.append(("poison", where))
    raise FaultError(f"injected poison input reached {where}")


# ---------------------------------------------------------------------------
# engine hooks
# ---------------------------------------------------------------------------
def on_segment(n_rounds: int) -> None:
    """Hook: called by the pipelined engine at each segment boundary
    (after any checkpoint of the previous segment). Fires the armed
    stall and/or kill for this round range. No-op when disarmed."""
    st = _ACTIVE
    if st is None:
        return
    sp = st.spec
    if (sp.stall_round is not None and not st.stall_fired
            and n_rounds >= sp.stall_round):
        st.stall_fired = True
        st.clock_offset += float(sp.stall_s)
        st.events.append(("stall", n_rounds))
    if (sp.fail_round is not None and n_rounds >= sp.fail_round
            and not (sp.fail_once and st.round_fired)):
        st.round_fired = True
        st.events.append(("fail_round", n_rounds))
        raise FaultError(
            f"injected engine failure at segment round {n_rounds} "
            f"(fail_round={sp.fail_round})")


def on_oracle_call() -> None:
    """Hook: called by ``VectorOracle.row``. Raises at the armed k-th
    distance call (1-based). No-op when disarmed."""
    st = _ACTIVE
    if st is None:
        return
    st.oracle_calls += 1
    if st.spec.fail_call is not None and st.oracle_calls == st.spec.fail_call:
        st.events.append(("fail_call", st.oracle_calls))
        raise FaultError(
            f"injected oracle failure at distance call "
            f"{st.oracle_calls}")


def on_shard_entry(n_shards: int) -> None:
    """Hook: called by the sharded executors before launching the
    multi-device program. Simulates losing a shard. No-op when
    disarmed."""
    st = _ACTIVE
    if st is None:
        return
    if st.spec.lose_shard and not (st.spec.fail_once and st.shard_fired):
        st.shard_fired = True
        st.events.append(("lose_shard", n_shards))
        raise ShardLostError(
            f"injected shard loss (1 of {n_shards} shards unreachable)")


def effective_budget(budget: int) -> int:
    """Hook: clamp an engine's computed-row budget to the armed
    ``force_budget`` (simulated surprise budget exhaustion)."""
    st = _ACTIVE
    if st is None or st.spec.force_budget is None:
        return budget
    st.events.append(("force_budget", st.spec.force_budget))
    return min(budget, int(st.spec.force_budget))


# ---------------------------------------------------------------------------
# solve-round heartbeats (the Supervisor pattern at round granularity)
# ---------------------------------------------------------------------------
class RoundWatchdog:
    """Single-worker heartbeat monitor for one solve, repurposing the
    launcher-level :class:`~repro.runtime.fault_tolerance.Supervisor`:
    the engine beats once per segment; :meth:`stalled` reports whether
    the gap since the last beat exceeds ``timeout_s`` on the fault
    clock (so injected stalls trip it deterministically).

    Heartbeats are first-class observability events (DESIGN.md §14):
    every beat increments the ``repro_obs_watchdog_beats_total``
    counter on the default metrics registry, and when a ``sink`` (a
    :class:`~repro.obs.trace.SolveTracer` or anything with an
    ``event(kind, **payload)`` method) is attached each beat lands in
    the trace as a deterministic ``heartbeat`` event — round number
    only, never wall-clock, so traced solves stay byte-identical."""

    def __init__(self, timeout_s: float, sink=None):
        from repro.runtime.fault_tolerance import (Supervisor,
                                                   SupervisorConfig)
        self.timeout_s = float(timeout_s)
        self.sink = sink
        self._sup = Supervisor(
            1, SupervisorConfig(heartbeat_timeout_s=float(timeout_s)),
            clock=clock)

    def beat(self, n_rounds: int, dt_s: float = 0.0) -> None:
        self._sup.heartbeat(0, int(n_rounds), float(dt_s))
        from repro.obs.metrics import REGISTRY
        REGISTRY.counter(
            "watchdog_beats_total",
            "RoundWatchdog heartbeats across all solves").inc()
        if self.sink is not None:
            self.sink.event("heartbeat", round=int(n_rounds))

    def stalled(self) -> bool:
        evicted = self._sup.check()
        return bool(evicted) or not self._sup.workers[0].alive

    @property
    def events(self):
        return self._sup.events


# ---------------------------------------------------------------------------
# CI seed plumbing
# ---------------------------------------------------------------------------
def fault_seeds(default=(0,)) -> tuple:
    """Seeds the fault-injection tests sweep. ``REPRO_FAULTS`` (the CI
    fault lane) widens the grid: unset/empty -> ``default``; ``"1"`` ->
    a canned 4-seed grid; a comma list (``"3,7,11"``) -> those seeds."""
    raw = os.environ.get("REPRO_FAULTS", "").strip()
    if not raw:
        return tuple(default)
    if raw == "1":
        return (0, 1, 2, 3)
    return tuple(int(s) for s in raw.split(",") if s.strip())
