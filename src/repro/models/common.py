"""Shared model building blocks: norms, RoPE, init, embedding.

All models are pure-functional: params are pytrees of jnp arrays created
by ``init_*`` functions (usable under ``jax.eval_shape`` for the dry-run)
and consumed by ``apply``-style functions. Matmul-bearing params are
created in the config dtype (bf16 in production); norm scales stay fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim, out_dim, dtype, scale: float | None = None):
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab, dim, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


def init_norm(cfg, dim=None):
    dim = dim or cfg.d_model
    if cfg.norm == "rms":
        return {"scale": jnp.ones((dim,), jnp.float32)}
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def apply_norm(cfg, p, x):
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.eps)
    return rms_norm(x, p["scale"], cfg.eps)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                    # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_xent(logits, labels, mask=None, z_coef: float = 1e-4):
    """Mean cross-entropy over mask (fp32), plus z-loss for logit drift."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    zloss = z_coef * lse**2
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = ((nll + zloss) * mask).sum() / denom
    return loss, {"nll": (nll * mask).sum() / denom}
