"""repro.models — model zoo for the assigned architectures."""
from . import attention, common, mamba2, mlp, model, moe, rwkv6
from .model import (
    FRAME_DIM,
    VISION_DIM,
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
    train_loss,
)

__all__ = [
    "attention", "common", "mamba2", "mlp", "model", "moe", "rwkv6",
    "FRAME_DIM", "VISION_DIM", "decode_step", "forward", "init_cache",
    "init_params", "prefill", "train_loss",
]
