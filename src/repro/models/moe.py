"""Mixture-of-Experts layer with capacity-based scatter dispatch.

Routing: softmax over routed experts, top-k selection, Switch-style
auxiliary load-balance loss + router z-loss. Dispatch avoids the
``O(T * E * C)`` dense one-hot tensor of the classic GShard einsum:
positions-within-expert come from a cumsum over per-choice one-hots
(``O(T * E)``), tokens are scattered into an ``(E, C, d)`` buffer
(overflowing tokens dropped — scattered to a sentinel row), experts run
as one batched einsum (EP-sharded on the ``model`` axis), and outputs
gather back with routing weights.

Shared experts (qwen2-moe) are a fused always-on SwiGLU of width
``n_shared * d_expert`` added to the routed output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import dense_init, split_keys


def _ambient_mesh_axes():
    """(batch_axes, model_axis_present) from the context mesh, if any."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = mesh.axis_names if mesh is not None else ()
    except Exception:  # noqa: BLE001
        names = ()
    bx = tuple(a for a in ("pod", "data") if a in names)
    return bx, ("model" in names)


EP_PAD = 16  # pad expert storage to a multiple of the model-axis size


def padded_experts(cfg) -> int:
    e = cfg.moe.n_experts
    if not cfg.moe_ep:
        return e
    return ((e + EP_PAD - 1) // EP_PAD) * EP_PAD


def init_moe(cfg, key):
    m = cfg.moe
    dt = cfg.param_dtype
    ks = split_keys(key, 5)
    e, d, h = padded_experts(cfg), cfg.d_model, m.d_expert
    scale = d ** -0.5
    p = {
        "router": dense_init(ks[0], d, m.n_experts, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, h), jnp.float32) * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, h), jnp.float32) * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, h, d), jnp.float32) * (h ** -0.5)).astype(dt),
    }
    if m.n_shared:
        sh = m.n_shared * h
        ks2 = split_keys(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(ks2[0], d, sh, dt),
            "w_up": dense_init(ks2[1], d, sh, dt),
            "w_down": dense_init(ks2[2], sh, d, dt),
        }
    return p


def _dispatch_local(xf, gate, idx, e_pad, cap, k):
    """Capacity-scatter dispatch over LOCAL tokens (inside shard_map).
    Returns (buf (e_pad, cap, d), flat_e, pos_c, keep)."""
    t, d = xf.shape
    flat_e = idx.reshape(t * k)
    oh = jax.nn.one_hot(flat_e, e_pad, dtype=jnp.float32)
    pos = (jnp.cumsum(oh, axis=0) - 1.0)
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    pos = pos.astype(jnp.int32)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)
    buf = jnp.zeros((e_pad, cap + 1, d), xf.dtype)
    xk = jnp.repeat(xf, k, axis=0)
    buf = buf.at[flat_e, pos_c].set(xk)
    return buf[:, :cap], flat_e, pos_c, keep


def _moe_ep_inner(cfg, k, e_pad, bx, xl, router, wg, wu, wd, shared):
    """Manual (shard_map) expert-parallel MoE over mesh axes bx+('model',).

    Tokens: sharded over bx, replicated over 'model' on entry. The token
    range is split across 'model' so each chip dispatches a distinct
    slice; dispatch buffers are exchanged with one tiled all_to_all so
    each chip runs only ITS experts over everyone's tokens; a reverse
    all_to_all + local combine, then an all_gather over 'model'
    reassembles the full token range. Per-chip expert FLOPs =
    global / (|bx| * |model|) — true expert parallelism.
    """
    m = cfg.moe
    b_loc, s, dm = xl.shape
    t = b_loc * s
    msize = jax.lax.axis_size("model")
    r = jax.lax.axis_index("model")
    xf = xl.reshape(t, dm)

    logits = (xf @ router).astype(jnp.float32)            # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)

    # aux losses (global over the data axes; replicated over model)
    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(idx, m.n_experts,
                        dtype=jnp.float32).sum(axis=(0, 1)) / (t * k)
    if bx:
        me = jax.lax.pmean(me, bx)
        ce = jax.lax.pmean(ce, bx)
    aux = m.aux_loss_coef * m.n_experts * jnp.sum(me * ce)
    zloss = m.router_z_coef * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)
    if bx:
        zloss = jax.lax.pmean(zloss, bx)

    # split the token range over the model axis
    t_m = t // msize
    xm = jax.lax.dynamic_slice_in_dim(xf, r * t_m, t_m, 0)
    gm = jax.lax.dynamic_slice_in_dim(gate, r * t_m, t_m, 0)
    im = jax.lax.dynamic_slice_in_dim(idx, r * t_m, t_m, 0)
    cap = int(max(1, round(t_m * k * m.capacity_factor / e_pad)))

    buf, flat_e, pos_c, keep = _dispatch_local(xm, gm, im, e_pad, cap, k)

    # exchange: (e_pad, cap, d) -> (e_loc, msize*cap, d)
    recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                              tiled=True)
    hgate = jax.nn.silu(jnp.einsum("ecd,edh->ech", recv, wg))
    hup = jnp.einsum("ecd,edh->ech", recv, wu)
    y = jnp.einsum("ech,ehd->ecd", (hgate * hup).astype(recv.dtype), wd)
    back = jax.lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                              tiled=True)                 # (e_pad, cap, d)

    back = jnp.pad(back, ((0, 0), (0, 1), (0, 0)))        # sentinel row
    yk = back[flat_e, pos_c]
    yk = yk * (gm.reshape(t_m * k, 1) * keep[:, None]).astype(yk.dtype)
    out_m = yk.reshape(t_m, k, dm).sum(axis=1)            # (t_m, d)

    # shared experts: full local tokens, TP over model on the hidden dim
    if shared is not None:
        sg, su, sd = shared
        part = (jax.nn.silu(xf @ sg) * (xf @ su)) @ sd    # partial (t, d)
        shared_out = jax.lax.psum(part, "model")
    else:
        shared_out = 0.0

    out = jax.lax.all_gather(out_m, "model", axis=0, tiled=True)  # (t, d)
    out = out + shared_out
    return out.reshape(b_loc, s, dm), aux, zloss


def _moe_ep_fwd(cfg, p, x, bx):
    m = cfg.moe
    k = m.top_k
    e_pad = p["w_gate"].shape[0]
    has_shared = "shared" in p
    shared_in = ((P(None, "model"), P(None, "model"), P("model", None))
                 if has_shared else None)

    def wrapped(xl, router, wg, wu, wd, *sh):
        return _moe_ep_inner(cfg, k, e_pad, bx, xl, router, wg, wu, wd,
                             sh if has_shared else None)

    in_specs = [P(bx, None, None), P(None, None),
                P("model", None, None), P("model", None, None),
                P("model", None, None)]
    args = [x, p["router"], p["w_gate"], p["w_up"], p["w_down"]]
    if has_shared:
        in_specs.extend(shared_in)
        args.extend([p["shared"]["w_gate"], p["shared"]["w_up"],
                     p["shared"]["w_down"]])
    out, aux, zloss = jax.shard_map(
        wrapped,
        in_specs=tuple(in_specs),
        out_specs=(P(bx, None, None), P(), P()),
        check_vma=False,
    )(*args)
    return out, {"moe_aux": aux, "moe_z": zloss}


def _ep_applicable(cfg, x):
    if not cfg.moe_ep:
        return None
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:  # noqa: BLE001
        return None
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return None
    bx = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    msize = dict(zip(mesh.axis_names, mesh.axis_sizes))["model"]
    bsize = 1
    for a in bx:
        bsize *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
    b, s, _ = x.shape
    t_local = (b // bsize) * s
    e_pad = padded_experts(cfg)
    if b % bsize or t_local % msize or e_pad % msize:
        return None
    if (t_local // msize) < 1:
        return None
    return bx


def moe_fwd(cfg, p, x, dropless: bool | None = None):
    """x: (B, S, d) -> (out, aux_losses dict).

    ``dropless=True`` (default for decode, S == 1) uses a sorted
    ``lax.ragged_dot`` grouped GEMM — exact, zero drops, active-expert
    FLOPs only. ``dropless=False`` (default for train/prefill) uses the
    capacity-scatter path (Switch-style dropping), which shards cleanly
    under GSPMD at scale.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    if dropless is None:
        dropless = s == 1
    if s > 1:
        # train AND sharded prefill use the EP path when a mesh is
        # ambient (GSPMD cannot partition ragged_dot/scatter dispatch;
        # capacity semantics at prefill are the standard trade) —
        # decode (s == 1, small T) keeps the exact dropless grouped GEMM.
        bx = _ep_applicable(cfg, x)
        if bx is not None:
            return _moe_ep_fwd(cfg, p, x, bx)
    xf = x.reshape(t, d)

    logits = (xf @ p["router"]).astype(jnp.float32)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                        # (T, k)

    # aux losses: Switch load-balance + router z-loss
    me = probs.mean(axis=0)                                    # (E,)
    onehot_k = jax.nn.one_hot(idx, e, dtype=jnp.float32)       # (T, k, E)
    ce = onehot_k.sum(axis=(0, 1)) / (t * k)                   # fraction per e
    aux = m.aux_loss_coef * e * jnp.sum(me * ce)
    zloss = m.router_z_coef * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2
    )

    e_buf = p["w_gate"].shape[0]        # >= e when EP-padded
    if dropless:
        # ---- dropless grouped-GEMM path (decode) ----
        flat_e = idx.reshape(t * k)
        order = jnp.argsort(flat_e)                            # stable
        xs = jnp.repeat(xf, k, axis=0)[order]                  # (T*k, d)
        group_sizes = jnp.bincount(flat_e, length=e_buf).astype(jnp.int32)
        hg = jax.nn.silu(jax.lax.ragged_dot(xs, p["w_gate"], group_sizes))
        hu = jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
        ys = jax.lax.ragged_dot((hg * hu).astype(xs.dtype), p["w_down"],
                                group_sizes)                   # (T*k, d)
        inv = jnp.argsort(order)
        yk = ys[inv] * gate.reshape(t * k, 1).astype(ys.dtype)
        out = yk.reshape(t, k, d).sum(axis=1)
        if "shared" in p:
            sp = p["shared"]
            out = out + (jax.nn.silu(xf @ sp["w_gate"])
                         * (xf @ sp["w_up"])) @ sp["w_down"]
        return out.reshape(b, s, d), {"moe_aux": aux, "moe_z": zloss}

    cap = int(max(1, round(t * k * m.capacity_factor / e)))

    # position of each (token, choice) within its expert's capacity
    flat_e = idx.reshape(t * k)                                # (T*k,)
    oh = onehot_k.reshape(t * k, e)
    pos = (jnp.cumsum(oh, axis=0) - 1.0)                       # (T*k, E)
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0].astype(jnp.int32)
    keep = pos < cap
    # overflow -> sentinel row `cap`
    pos_c = jnp.where(keep, pos, cap)

    # scatter tokens into (E_buf, cap+1, d)
    buf = jnp.zeros((e_buf, cap + 1, d), x.dtype)
    xk = jnp.repeat(xf, k, axis=0)                             # (T*k, d)
    buf = buf.at[flat_e, pos_c].set(xk.astype(x.dtype))
    buf = buf[:, :cap]                                         # (E, cap, d)

    # batched expert FFN (EP-sharded on the expert axis)
    hgate = jax.nn.silu(jnp.einsum("ecd,edh->ech", buf, p["w_gate"]))
    hup = jnp.einsum("ecd,edh->ech", buf, p["w_up"])
    y = jnp.einsum("ech,ehd->ecd", hgate * hup, p["w_down"])   # (E, cap, d)

    # gather back + combine with routing weights
    y = jnp.pad(y, ((0, 0), (0, 1), (0, 0)))                   # sentinel row 0-pad... gathered below
    yk = y[flat_e, pos_c]                                      # (T*k, d)
    yk = yk * (gate.reshape(t * k, 1) * keep[:, None]).astype(y.dtype)
    out = yk.reshape(t, k, d).sum(axis=1)

    if "shared" in p:
        sp = p["shared"]
        out = out + (jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])) @ sp["w_down"]

    return out.reshape(b, s, d), {"moe_aux": aux, "moe_z": zloss}
