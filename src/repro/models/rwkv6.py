"""RWKV6 (Finch) — attention-free time-mixing with data-dependent decay.

Faithful to arXiv:2404.05892: token-shift ddlerp (lora-modulated
interpolation with the previous token), five projections (r, k, v, g, w),
per-channel data-dependent decay ``w = exp(-exp(.))``, per-channel bonus
``u``, head-wise WKV state ``S in R^{hd x hd}``:

    o_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Training/prefill run the recurrence as an exact ``lax.scan`` over time
(per-channel vector decay admits no bounded-exponent chunked
factorisation, unlike Mamba2's scalar-per-head decay — see DESIGN.md §8
and mamba2.py, which does use the chunked form). Decode carries
``(last_x_tmix, last_x_cmix, S)`` — O(1) per step, which is what makes
the 500k-context cell admissible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, layer_norm, split_keys


def _lora(x, a, b):
    return jnp.tanh(x @ a) @ b


def init_rwkv_layer(cfg, key):
    r = cfg.rwkv
    d = cfg.d_model
    dt = cfg.param_dtype
    ks = split_keys(key, 16)
    h = cfg.n_heads
    hd = r.head_dim
    assert h * hd == d, "rwkv: n_heads * head_dim must equal d_model"
    p = {
        "ln1_scale": jnp.ones((d,), jnp.float32),
        "ln1_bias": jnp.zeros((d,), jnp.float32),
        "ln2_scale": jnp.ones((d,), jnp.float32),
        "ln2_bias": jnp.zeros((d,), jnp.float32),
        # ddlerp token-shift mixers: base mu per stream + shared lora
        "mu_base": jnp.zeros((5, d), jnp.float32),
        "mix_a": dense_init(ks[0], d, 5 * r.mix_lora, dt),
        "mix_b": (jnp.zeros((5, r.mix_lora, d))).astype(dt),
        "wr": dense_init(ks[1], d, d, dt),
        "wk": dense_init(ks[2], d, d, dt),
        "wv": dense_init(ks[3], d, d, dt),
        "wg": dense_init(ks[4], d, d, dt),
        # decay lora: w = exp(-exp(decay_base + lora))
        "decay_base": jnp.full((d,), -4.0, jnp.float32),
        "decay_a": dense_init(ks[5], d, r.decay_lora, dt),
        "decay_b": (jnp.zeros((r.decay_lora, d))).astype(dt),
        "bonus": jnp.zeros((h, hd), jnp.float32),        # u
        "ln_x_scale": jnp.ones((d,), jnp.float32),       # per-head groupnorm
        "ln_x_bias": jnp.zeros((d,), jnp.float32),
        "wo": dense_init(ks[6], d, d, dt),
        # channel mix
        "cmix_k": jnp.zeros((d,), jnp.float32),
        "cmix_r": jnp.zeros((d,), jnp.float32),
        "ck": dense_init(ks[7], d, cfg.d_ff, dt),
        "cv": dense_init(ks[8], cfg.d_ff, d, dt),
        "cr": dense_init(ks[9], d, d, dt),
    }
    return p


def _group_norm(x, scale, bias, h, eps):
    """x: (..., D) normalised per head (D = h * hd)."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], h, shp[-1] // h).astype(jnp.float32)
    mu = xh.mean(axis=-1, keepdims=True)
    var = ((xh - mu) ** 2).mean(axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    out = xh.reshape(shp) * scale + bias
    return out


def _wkv_scan(r, k, v, w, u, s0):
    """r,k,v,w: (B, T, H, hd); s0: (B, H, hd, hd). Returns (o, sT)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                        # (B, H, hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s_new = w_t[..., None] * s + kv
        return s_new, o_t

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    sT, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), sT                    # (B, T, H, hd)


def rwkv_layer_fwd(cfg, p, x, state=None):
    """x: (B, T, D). state: dict(sx_t, sx_c, wkv) or None (zeros).
    Returns (y, new_state). The layer includes BOTH time-mix and
    channel-mix sublayers (each with its own residual); outer norms are
    applied by the caller."""
    r = cfg.rwkv
    b, t, d = x.shape
    h, hd = cfg.n_heads, r.head_dim
    f32 = jnp.float32

    if state is None:
        sx_t = jnp.zeros((b, 1, d), x.dtype)
        sx_c = jnp.zeros((b, 1, d), x.dtype)
        s0 = jnp.zeros((b, h, hd, hd), f32)
    else:
        sx_t, sx_c, s0 = state["sx_t"], state["sx_c"], state["wkv"]

    # ---- time mix (pre-LN, residual) ----
    xin = x
    x = layer_norm(x, p["ln1_scale"], p["ln1_bias"], cfg.eps)
    x_prev = jnp.concatenate([sx_t, x[:, :-1]], axis=1)
    delta = x_prev - x
    mixed = x + delta * jax.nn.sigmoid(p["mu_base"].mean(0)).astype(x.dtype)[None, None]
    z = jnp.tanh((mixed @ p["mix_a"]).reshape(b, t, 5, r.mix_lora))
    lora = jnp.einsum("btsl,sld->btsd", z, p["mix_b"].astype(z.dtype))
    # (B, T, 5, D): per-stream ddlerp interpolants
    streams = x[:, :, None] + delta[:, :, None] * (
        p["mu_base"][None, None] + lora
    ).astype(x.dtype)
    x_w, x_k, x_v, x_r, x_g = [streams[:, :, i] for i in range(5)]

    rq = (x_r @ p["wr"]).reshape(b, t, h, hd).astype(f32)
    kq = (x_k @ p["wk"]).reshape(b, t, h, hd).astype(f32)
    vq = (x_v @ p["wv"]).reshape(b, t, h, hd).astype(f32)
    g = jax.nn.silu(x_g @ p["wg"])
    decay = p["decay_base"][None, None] + _lora(x_w, p["decay_a"], p["decay_b"]).astype(f32)
    w = jnp.exp(-jnp.exp(decay)).reshape(b, t, h, hd)

    o, sT = _wkv_scan(rq, kq, vq, w, p["bonus"], s0)
    o = _group_norm(o.reshape(b, t, d), p["ln_x_scale"], p["ln_x_bias"],
                    h, cfg.eps)
    y = (o.astype(x.dtype) * g) @ p["wo"]
    new_sx_t = x[:, -1:]          # shift state lives in post-LN space
    x = xin + y

    # ---- channel mix (pre-LN, residual) ----
    xin2 = x
    x = layer_norm(x, p["ln2_scale"], p["ln2_bias"], cfg.eps)
    x_prev_c = jnp.concatenate([sx_c, x[:, :-1]], axis=1)
    delta_c = x_prev_c - x
    xk = x + delta_c * jax.nn.sigmoid(p["cmix_k"]).astype(x.dtype)[None, None]
    xr = x + delta_c * jax.nn.sigmoid(p["cmix_r"]).astype(x.dtype)[None, None]
    kk = jnp.square(jax.nn.relu(xk @ p["ck"]))
    y2 = jax.nn.sigmoid(xr @ p["cr"]) * (kk @ p["cv"])
    out = xin2 + y2

    new_state = {
        "sx_t": new_sx_t,
        "sx_c": x[:, -1:],
        "wkv": sT,
    }
    return out, new_state


def init_rwkv_state(cfg, batch):
    r = cfg.rwkv
    d = cfg.d_model
    return {
        "sx_t": jnp.zeros((batch, 1, d), cfg.param_dtype),
        "sx_c": jnp.zeros((batch, 1, d), cfg.param_dtype),
        "wkv": jnp.zeros((batch, cfg.n_heads, r.head_dim, r.head_dim),
                         jnp.float32),
    }
