"""Feed-forward blocks: SwiGLU and GeLU variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys


def init_mlp(cfg, key, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    dt = cfg.param_dtype
    if cfg.act == "swiglu":
        ks = split_keys(key, 3)
        return {
            "w_gate": dense_init(ks[0], cfg.d_model, d_ff, dt),
            "w_up": dense_init(ks[1], cfg.d_model, d_ff, dt),
            "w_down": dense_init(ks[2], d_ff, cfg.d_model, dt),
        }
    ks = split_keys(key, 2)
    return {
        "w_up": dense_init(ks[0], cfg.d_model, d_ff, dt),
        "w_down": dense_init(ks[1], d_ff, cfg.d_model, dt),
    }


def mlp_fwd(cfg, p, x):
    if "w_gate" in p:
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]
