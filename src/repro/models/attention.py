"""Attention: GQA (with optional qk-norm) and MLA, train/prefill/decode.

Memory-efficient blockwise attention (online softmax over KV chunks,
sequential map over Q chunks) keeps per-device activation memory at
``O(chunk^2 * heads)`` instead of ``O(S^2 * heads)`` — required for the
32k/500k shapes. Causality is applied via position masks; KV chunks
strictly above the diagonal still occupy HLO flops (masked) — removing
that 2x score overhead is a recorded §Perf candidate (Pallas flash
kernel / triangle decomposition).

MLA (minicpm3) caches the compressed KV latent ``c_kv`` (+ shared RoPE
key) and uses the *absorbed-weight* decode path: ``W_uk`` is folded into
the query so decode attends directly over the latent cache — the cache
is ~10x smaller than full K/V and decode FLOPs drop accordingly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import apply_rope, dense_init, rms_norm, split_keys

NEG_INF = -1e30


def _mesh_axes():
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            return (), 0
        names = mesh.axis_names
        sizes = dict(zip(names, mesh.axis_sizes))
    except Exception:  # noqa: BLE001
        return (), 0
    bx = tuple(a for a in ("pod", "data") if a in names)
    return bx, sizes.get("model", 0)


def _bx_size(bx):
    try:
        mesh = jax.sharding.get_abstract_mesh()
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        n = 1
        for a in bx:
            n *= sizes.get(a, 1)
        return n
    except Exception:  # noqa: BLE001
        return 1


# ---------------------------------------------------------------------------
# blockwise attention core
# ---------------------------------------------------------------------------
def _gqa_scores(q, k, out_dtype=jnp.float32):
    """q: (B, Sq, KV, G, hd), k: (B, Sk, KV, hd) -> (B, KV, G, Sq, Sk)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                      preferred_element_type=out_dtype)


def blockwise_attention(q, k, v, *, causal: bool, chunk: int,
                        q_positions, kv_positions, kv_valid=None,
                        seq_shard: bool = False,
                        bf16_scores: bool = False):
    """Online-softmax blockwise attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); positions are absolute.
    kv_valid: optional (B, Sk) bool mask (padding / unfilled cache).
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]            # MLA: value head dim may differ from qk
    g = h // kv
    scale = hd ** -0.5

    cq = min(chunk, sq)
    ck = min(chunk, sk)
    # pad to multiples
    pq = (-sq) % cq
    pk = (-sk) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pq)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pk)))
        valid = jnp.ones((b, sk), bool) if kv_valid is None else kv_valid
        kv_valid = jnp.pad(valid, ((0, 0), (0, pk)))
    nq, nk = (sq + pq) // cq, (sk + pk) // ck

    if nq == 1 and nk == 1:
        # single-chunk dense path: no scan in the HLO (also used by the
        # dry-run cost probes, whose flop counts must not hide in loops)
        score_dt = jnp.bfloat16 if bf16_scores else jnp.float32
        qg = q.reshape(b, sq + pq, kv, g, hd) * scale
        s = _gqa_scores(qg, k, score_dt)                # (B,KV,G,Sq,Sk)
        if seq_shard:
            # §Perf P2: pin the giant score tensor to q-sequence sharding
            # over `model` — stops GSPMD resharding it over the (padded,
            # non-divisible) KV-head dim.
            bx, msize = _mesh_axes()
            if msize and (sq + pq) % msize == 0:
                s = jax.lax.with_sharding_constraint(
                    s, P(bx if b % max(
                        1, _bx_size(bx)) == 0 and b > 1 else None,
                         None, None, "model", None))
        mask = jnp.ones((b, 1, 1, sq + pq, sk + pk), bool)
        if causal:
            mask &= (q_positions[:, None, None, :, None]
                     >= kv_positions[:, None, None, None, :])
        if kv_valid is not None:
            mask &= kv_valid[:, None, None, None, :]
        s = jnp.where(mask, s, jnp.asarray(NEG_INF, s.dtype))
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
        out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        out = out.reshape(b, sq + pq, h, hd_v)[:, :sq]
        # NOTE (§Perf, refuted): pinning `out` back to batch sharding here
        # looked like it would stop the q-seq layout leaking into the
        # residual stream, but measured 16-19x WORSE collectives
        # (starcoder2 train frac 0.143 -> 0.022): GSPMD propagates the
        # seq-sharding through the residual efficiently, and the forced
        # reshard moves the full activation every layer. Left unpinned.
        return out.astype(v.dtype)

    q = q.reshape(b, nq, cq, kv, g, hd) * scale
    qp = q_positions.reshape(b, nq, cq)
    k4 = k.reshape(b, nk, ck, kv, hd)
    v4 = v.reshape(b, nk, ck, kv, hd_v)
    kp = kv_positions.reshape(b, nk, ck)
    kvld = None if kv_valid is None else kv_valid.reshape(b, nk, ck)

    def q_chunk_fn(qi):
        qc = q[:, qi]                                   # (B, cq, KV, G, hd)
        qpc = qp[:, qi]                                 # (B, cq)

        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, kpc, kvc = inp                      # (B, ck, KV, hd)...
            s = _gqa_scores(qc, kc)                     # (B,KV,G,cq,ck) fp32
            mask = jnp.ones((b, 1, 1, cq, ck), bool)
            if causal:
                mask &= (qpc[:, None, None, :, None]
                         >= kpc[:, None, None, None, :])
            if kvc is not None:
                mask &= kvc[:, None, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))      # (B,KV,G,cq)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, cq, hd_v), jnp.float32)
        xs = (
            jnp.moveaxis(k4, 1, 0), jnp.moveaxis(v4, 1, 0),
            jnp.moveaxis(kp, 1, 0),
            None if kvld is None else jnp.moveaxis(kvld, 1, 0),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), xs)
        out = acc / jnp.maximum(l[..., None], 1e-30)    # (B,KV,G,cq,hd_v)
        return jnp.moveaxis(out, 3, 1).reshape(b, cq, kv * g, hd_v)

    outs = jax.lax.map(q_chunk_fn, jnp.arange(nq))      # (nq, B, cq, H, hd_v)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * cq, h, hd_v)[:, :sq]
    return out.astype(v.dtype)


def decode_attention(q, k, v, *, q_position, kv_len):
    """Single-step attention over a (possibly huge) cache.
    q: (B, 1, H, hd); k, v: (B, S, KV, hd); kv_len: filled length (incl.
    the token written this step)."""
    b, _, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, 1, kv, g, hd) * hd ** -0.5
    s_ = _gqa_scores(qg, k)[:, :, :, 0]                 # (B, KV, G, S)
    pos = jnp.arange(s)[None, :]
    mask = pos < kv_len[:, None]                        # (B, S)
    s_ = jnp.where(mask[:, None, None, :], s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------
def init_gqa(cfg, key):
    hd = cfg.head_dim_
    ks = split_keys(key, 4)
    dt = cfg.param_dtype
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def gqa_fwd(cfg, p, x, positions, *, cache=None, cache_index=None):
    """cache: dict(k=(B,S,KV,hd), v=(B,S,KV,hd)) or None.
    In decode mode x is (B, 1, D) and cache_index the write offset.
    Returns (out, new_cache)."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.eps)
        k = rms_norm(k, p["k_norm"], cfg.eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = blockwise_attention(
            q, k, v, causal=cfg.is_causal, chunk=cfg.attn_chunk,
            q_positions=positions, kv_positions=positions,
            seq_shard=cfg.attn_seq_shard,
            bf16_scores=cfg.attn_bf16_scores,
        )
        new_cache = None
    elif s == 1:  # decode
        idx = cache_index
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
        kv_len = jnp.full((b,), idx + 1, jnp.int32)
        out = decode_attention(q, ck, cv, q_position=positions,
                               kv_len=kv_len)
        new_cache = {"k": ck, "v": cv}
    else:  # prefill into cache
        smax = cache["k"].shape[1]
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        out = blockwise_attention(
            q, k, v, causal=True, chunk=cfg.attn_chunk,
            q_positions=positions, kv_positions=positions,
            seq_shard=cfg.attn_seq_shard,
            bf16_scores=cfg.attn_bf16_scores,
        )
        new_cache = {"k": ck, "v": cv}
    out = out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]
    return out, new_cache


def init_gqa_cache(cfg, batch, seq):
    hd = cfg.head_dim_
    dt = cfg.param_dtype
    return {
        "k": jnp.zeros((batch, seq, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((batch, seq, cfg.n_kv_heads, hd), dt),
    }


# ---------------------------------------------------------------------------
# MLA module (minicpm3)
# ---------------------------------------------------------------------------
def init_mla(cfg, key):
    m = cfg.mla
    dt = cfg.param_dtype
    ks = split_keys(key, 8)
    h = cfg.n_heads
    qk = m.qk_nope_head_dim
    qr = m.qk_rope_head_dim
    vd = m.v_head_dim
    return {
        "wdq": dense_init(ks[0], cfg.d_model, m.q_lora_rank, dt),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wuq": dense_init(ks[1], m.q_lora_rank, h * (qk + qr), dt),
        "wdkv": dense_init(ks[2], cfg.d_model, m.kv_lora_rank + qr, dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wuk": dense_init(ks[3], m.kv_lora_rank, h * qk, dt),
        "wuv": dense_init(ks[4], m.kv_lora_rank, h * vd, dt),
        "wo": dense_init(ks[5], h * vd, cfg.d_model, dt),
    }


def _mla_qkv(cfg, p, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk, qr = m.qk_nope_head_dim, m.qk_rope_head_dim
    q_lat = rms_norm(x @ p["wdq"], p["q_norm"], cfg.eps)
    q = (q_lat @ p["wuq"]).reshape(b, s, h, qk + qr)
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckr = x @ p["wdkv"]                                  # (B,S,rank+qr)
    c_kv = rms_norm(ckr[..., : m.kv_lora_rank], p["kv_norm"], cfg.eps)
    k_rope = apply_rope(ckr[..., m.kv_lora_rank:][:, :, None, :],
                        positions, cfg.rope_theta)[:, :, 0]   # (B,S,qr)
    return q_nope, q_rope, c_kv, k_rope


def mla_fwd(cfg, p, x, positions, *, cache=None, cache_index=None):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk, qr, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, positions)

    if cache is not None:
        c_full = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv, (0, cache_index if s == 1 else 0, 0))
        r_full = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope, (0, cache_index if s == 1 else 0, 0))
        new_cache = {"c_kv": c_full, "k_rope": r_full}
    else:
        new_cache = None

    if cache is not None and s == 1:
        # absorbed decode: score directly against the latent cache
        wuk = p["wuk"].reshape(m.kv_lora_rank, h, qk)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wuk)   # (B,1,H,rank)
        scores = (
            jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                       c_full.astype(jnp.float32))
            + jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                         r_full.astype(jnp.float32))
        ) * (qk + qr) ** -0.5                                # (B,H,1,S)
        smax = c_full.shape[1]
        mask = jnp.arange(smax)[None, :] <= cache_index
        scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhst,btr->bshr", probs.astype(c_full.dtype),
                             c_full)                         # (B,1,H,rank)
        wuv = p["wuv"].reshape(m.kv_lora_rank, h, vd)
        out = jnp.einsum("bshr,rhv->bshv", ctx_lat, wuv)
    else:
        # train/prefill: expand K/V and run blockwise attention
        k_nope = (c_kv @ p["wuk"]).reshape(b, s, h, qk)
        v = (c_kv @ p["wuv"]).reshape(b, s, h, vd)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, qr))],
            axis=-1,
        )
        out = blockwise_attention(
            q_full, k_full, v, causal=True, chunk=cfg.attn_chunk,
            q_positions=positions, kv_positions=positions,
            seq_shard=cfg.attn_seq_shard,
            bf16_scores=cfg.attn_bf16_scores,
        )
    out = out.reshape(b, s, h * vd) @ p["wo"]
    return out, new_cache


def init_mla_cache(cfg, batch, seq):
    m = cfg.mla
    dt = cfg.param_dtype
    return {
        "c_kv": jnp.zeros((batch, seq, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, seq, m.qk_rope_head_dim), dt),
    }
