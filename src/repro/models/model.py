"""Unified model API across all assigned architecture families.

``init_params(cfg, key)`` / ``forward(cfg, params, batch, cache=None,
cache_index=None)`` / ``train_loss`` / ``prefill`` / ``decode_step`` /
``init_cache`` work for every family:

dense | moe | vlm : pre-norm transformer decoder (GQA or MLA, MLP or MoE),
                    scan-over-layers (stacked params) with per-layer remat;
encoder           : same block, bidirectional, masked-prediction head
                    (targets come from trikmeds medoid clustering);
ssm (rwkv6)       : RWKV6 blocks, recurrent state instead of KV cache;
hybrid (zamba2)   : Mamba2 backbone + ONE shared attention block applied
                    every ``ssm.attn_every`` layers (zamba weight sharing),
                    each application with its own KV-cache slot.

Modality frontends are stubs per the assignment: VLM batches carry
``patches`` (B, P, VISION_DIM) and audio batches carry ``frames``
(B, S, FRAME_DIM) — precomputed embeddings projected linearly into
``d_model``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba2, mlp, moe, rwkv6
from .common import apply_norm, embed_init, init_norm, softmax_xent, split_keys

VISION_DIM = 1024   # InternViT stub output dim
FRAME_DIM = 512     # w2v2/HuBERT conv-frontend stub output dim


# ---------------------------------------------------------------------------
# transformer layer (dense / moe / vlm / encoder)
# ---------------------------------------------------------------------------
def _init_tf_layer(cfg, key):
    k1, k2, k3, k4 = split_keys(key, 4)
    p = {
        "norm1": init_norm(cfg),
        "norm2": init_norm(cfg),
    }
    if cfg.attention == "mla":
        p["attn"] = attn.init_mla(cfg, k1)
    else:
        p["attn"] = attn.init_gqa(cfg, k1)
    if cfg.family == "moe":
        p["ffn"] = moe.init_moe(cfg, k2)
    else:
        p["ffn"] = mlp.init_mlp(cfg, k2)
    return p


def _tf_layer_fwd(cfg, p, x, positions, cache, cache_index):
    h = apply_norm(cfg, p["norm1"], x)
    if cfg.attention == "mla":
        a, new_cache = attn.mla_fwd(cfg, p["attn"], h, positions,
                                    cache=cache, cache_index=cache_index)
    else:
        a, new_cache = attn.gqa_fwd(cfg, p["attn"], h, positions,
                                    cache=cache, cache_index=cache_index)
    x = x + a
    h = apply_norm(cfg, p["norm2"], x)
    if cfg.family == "moe":
        # serving (cache present) uses the exact dropless path; training
        # uses capacity dropping (standard, shards cleanly at scale)
        f, aux = moe.moe_fwd(cfg, p["ffn"], h, dropless=cache is not None)
    else:
        f, aux = mlp.mlp_fwd(cfg, p["ffn"], h), {}
    x = x + f
    aux_vec = jnp.asarray(
        [aux.get("moe_aux", 0.0), aux.get("moe_z", 0.0)], jnp.float32)
    return x, new_cache, aux_vec


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg, key):
    keys = split_keys(key, 8)
    dt = cfg.param_dtype
    params: dict = {"final_norm": init_norm(cfg)}

    if cfg.family == "encoder":
        params["frontend_proj"] = (
            jax.random.normal(keys[0], (FRAME_DIM, cfg.d_model), jnp.float32)
            * FRAME_DIM ** -0.5).astype(dt)
        params["mask_emb"] = jnp.zeros((cfg.d_model,), jnp.float32)
    else:
        params["embed"] = embed_init(keys[0], cfg.vocab, cfg.d_model, dt)
    if cfg.family == "vlm":
        params["vision_proj"] = (
            jax.random.normal(keys[1], (VISION_DIM, cfg.d_model), jnp.float32)
            * VISION_DIM ** -0.5).astype(dt)
    params["lm_head"] = embed_init(keys[2], cfg.vocab, cfg.d_model, dt).T

    lkeys = jax.random.split(keys[3], cfg.n_layers)
    if cfg.family == "ssm":
        params["layers"] = jax.vmap(
            lambda k: rwkv6.init_rwkv_layer(cfg, k))(lkeys)
    elif cfg.family == "hybrid":
        params["layers"] = jax.vmap(
            lambda k: mamba2.init_mamba2_layer(cfg, k))(lkeys)
        acfg = cfg.replace(attention="gqa")
        params["shared_attn"] = {
            "norm": init_norm(cfg),
            "attn": attn.init_gqa(acfg, keys[4]),
        }
    else:
        params["layers"] = jax.vmap(lambda k: _init_tf_layer(cfg, k))(lkeys)
    return params


# ---------------------------------------------------------------------------
# caches / states
# ---------------------------------------------------------------------------
def init_cache(cfg, batch, seq):
    """Decode cache for `seq` total positions."""
    L = cfg.n_layers
    if cfg.family == "ssm":
        st = rwkv6.init_rwkv_state(cfg, batch)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (L, *a.shape)), st)
    if cfg.family == "hybrid":
        st = mamba2.init_mamba2_state(cfg, batch)
        states = jax.tree.map(lambda a: jnp.broadcast_to(a, (L, *a.shape)), st)
        n_groups = cfg.n_layers // cfg.ssm.attn_every
        kv = attn.init_gqa_cache(cfg, batch, seq)
        kv = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_groups, *a.shape)), kv)
        return {"ssm": states, "attn_kv": kv}
    if cfg.attention == "mla":
        c = attn.init_mla_cache(cfg, batch, seq)
    else:
        c = attn.init_gqa_cache(cfg, batch, seq)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (L, *a.shape)), c)


# ---------------------------------------------------------------------------
# embedding of model inputs (modality stubs included)
# ---------------------------------------------------------------------------
def embed_inputs(cfg, params, batch):
    """Returns (x, positions, text_offset)."""
    if cfg.family == "encoder":
        x = batch["frames"] @ params["frontend_proj"]
        if "mask" in batch:
            m = batch["mask"][..., None]
            x = jnp.where(m, params["mask_emb"].astype(x.dtype), x)
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return x, pos, 0
    tok = batch["tokens"]
    x = jnp.take(params["embed"], tok, axis=0)
    if cfg.family == "vlm" and "patches" in batch:
        vis = batch["patches"] @ params["vision_proj"]
        x = jnp.concatenate([vis.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    if "positions" in batch:
        pos = batch["positions"]
    else:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    off = cfg.n_patches if (cfg.family == "vlm" and "patches" in batch) else 0
    return x, pos, off


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _scan_layers(cfg, layer_fn, x, stacked_params, stacked_cache):
    """Scan over stacked layer params (+ per-layer cache), remat'd.
    ``cfg.scan_layers=False`` unrolls to a python loop (dry-run cost
    probes: XLA cost_analysis counts a while-loop body once, so probe
    configs unroll; production keeps the scan for compile time)."""
    def body(carry, xs):
        x, aux = carry
        lp, lc = xs
        x, new_lc, aux_vec = layer_fn(lp, x, lc)
        return (x, aux + aux_vec), new_lc

    if cfg.remat:
        if cfg.remat_policy == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            body = jax.checkpoint(body)

    if not cfg.scan_layers:
        aux = jnp.zeros((2,), jnp.float32)
        new_lcs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], stacked_params)
            lc = (None if stacked_cache is None
                  else jax.tree.map(lambda a: a[i], stacked_cache))
            (x, aux), new_lc = body((x, aux), (lp, lc))
            new_lcs.append(new_lc)
        new_cache = (None if stacked_cache is None else
                     jax.tree.map(lambda *ls: jnp.stack(ls), *new_lcs))
        return x, aux, new_cache

    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((2,), jnp.float32)),
        (stacked_params, stacked_cache))
    return x, aux, new_cache


def forward(cfg, params, batch, cache=None, cache_index=None):
    """Returns (logits, new_cache, aux). ``cache_index`` is the decode
    write position (scalar int) — required when cache is not None and
    the input is a single token."""
    x, positions, _ = embed_inputs(cfg, params, batch)

    if cfg.family == "ssm":
        def layer_fn(lp, x, lc):
            y, new_state = rwkv6.rwkv_layer_fwd(cfg, lp, x, state=lc)
            return y, new_state, jnp.zeros((2,), jnp.float32)
        st = cache if cache is not None else _null_states(cfg, x.shape[0], "ssm")
        x, aux, new_cache = _scan_layers(cfg, layer_fn, x, params["layers"], st)

    elif cfg.family == "hybrid":
        x, aux, new_cache = _hybrid_forward(cfg, params, x, positions,
                                            cache, cache_index)
    else:
        def layer_fn(lp, x, lc):
            return _tf_layer_fwd(cfg, lp, x, positions, lc, cache_index)
        x, aux, new_cache = _scan_layers(cfg, layer_fn, x, params["layers"],
                                         cache)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = x @ params["lm_head"]
    return logits, new_cache, {"moe_aux": aux[0], "moe_z": aux[1]}


def _null_states(cfg, batch, kind):
    if kind == "ssm":
        st = rwkv6.init_rwkv_state(cfg, batch)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), st)
    raise ValueError(kind)


def _hybrid_forward(cfg, params, x, positions, cache, cache_index):
    """zamba2: groups of ``attn_every`` mamba layers, each followed by the
    SHARED attention block; remainder mamba layers at the end."""
    every = cfg.ssm.attn_every
    n_groups = cfg.n_layers // every
    n_main = n_groups * every
    b = x.shape[0]

    if cache is None:
        st = mamba2.init_mamba2_state(cfg, b)
        ssm_states = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), st)
        attn_kv = [None] * n_groups
    else:
        ssm_states = cache["ssm"]
        attn_kv = [jax.tree.map(lambda a: a[g], cache["attn_kv"])
                   for g in range(n_groups)]

    main_p = jax.tree.map(lambda a: a[:n_main].reshape(n_groups, every, *a.shape[1:]),
                          params["layers"])
    rem_p = jax.tree.map(lambda a: a[n_main:], params["layers"])
    main_s = jax.tree.map(lambda a: a[:n_main].reshape(n_groups, every, *a.shape[1:]),
                          ssm_states)
    rem_s = jax.tree.map(lambda a: a[n_main:], ssm_states)

    def mamba_body(carry, xs):
        x = carry
        lp, lc = xs
        y, new_state = mamba2.mamba2_layer_fwd(cfg, lp, x, state=lc)
        return y, new_state

    if cfg.remat:
        mamba_body = jax.checkpoint(mamba_body)

    def mamba_stack(x, sp_, ss_):
        """Scan (or unrolled loop) over one stack of mamba layers."""
        n = jax.tree.leaves(sp_)[0].shape[0]
        if n == 0:
            return x, ss_
        if cfg.scan_layers:
            return jax.lax.scan(mamba_body, x, (sp_, ss_))
        new = []
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], sp_)
            lc = jax.tree.map(lambda a: a[i], ss_)
            x, ns = mamba_body(x, (lp, lc))
            new.append(ns)
        return x, jax.tree.map(lambda *ls: jnp.stack(ls), *new)

    sp = params["shared_attn"]
    acfg = cfg.replace(attention="gqa")
    new_main_s = []
    new_kv = []
    for g in range(n_groups):
        gp = jax.tree.map(lambda a: a[g], main_p)
        gs = jax.tree.map(lambda a: a[g], main_s)
        x, ns = mamba_stack(x, gp, gs)
        new_main_s.append(ns)
        h = apply_norm(cfg, sp["norm"], x)
        a, kv = attn.gqa_fwd(acfg, sp["attn"], h, positions,
                             cache=attn_kv[g], cache_index=cache_index)
        x = x + a
        new_kv.append(kv)
    x, new_rem_s = mamba_stack(x, rem_p, rem_s)

    new_states = jax.tree.map(
        lambda m, r: jnp.concatenate(
            [m.reshape(n_main, *m.shape[2:]), r], axis=0),
        jax.tree.map(lambda *gs: jnp.stack(gs), *new_main_s)
        if n_groups > 1 else jax.tree.map(lambda a: a[None], new_main_s[0]),
        new_rem_s,
    )
    new_cache = None
    if cache is not None:
        new_cache = {
            "ssm": new_states,
            "attn_kv": jax.tree.map(lambda *gs: jnp.stack(gs), *new_kv),
        }
    aux = jnp.zeros((2,), jnp.float32)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# losses / serving
# ---------------------------------------------------------------------------
def train_loss(cfg, params, batch):
    """Scalar loss + metrics for one batch (family-appropriate)."""
    if cfg.family == "encoder":
        logits, _, _ = forward(cfg, params, batch)
        loss, metrics = softmax_xent(
            logits, batch["targets"], mask=batch["mask"])
        return loss, metrics
    logits, _, aux = forward(cfg, params, batch)
    tok = batch["tokens"]
    if cfg.family == "vlm":
        # image positions are prefix: predict only text continuation
        logits = logits[:, cfg.n_patches:]
    loss, metrics = softmax_xent(
        logits[:, :-1], tok[:, 1:],
        mask=batch.get("loss_mask", None))
    loss = loss + aux["moe_aux"] + aux["moe_z"]
    metrics.update(aux)
    return loss, metrics


def prefill(cfg, params, batch, cache):
    """Run the full prompt, returning (last_logits, filled cache)."""
    logits, new_cache, _ = forward(cfg, params, batch, cache=cache,
                                   cache_index=0)
    return logits[:, -1], new_cache


def decode_step(cfg, params, token, cache, index):
    """One token: token (B, 1) int32, index scalar int32 (write pos).
    Returns (logits (B, vocab), new_cache)."""
    b = token.shape[0]
    pos = jnp.broadcast_to(index, (b, 1)).astype(jnp.int32)
    batch = {"tokens": token, "positions": pos}
    logits, new_cache, _ = forward(cfg, params, batch, cache=cache,
                                   cache_index=index)
    return logits[:, -1], new_cache
