"""Mamba2 (SSD) block — chunked-parallel training, O(1) decode state.

Mamba2's decay is *scalar per head* (``A_h < 0``), so the pairwise decay
factor ``exp(a_t - a_s)`` (``a`` = within-chunk cumsum of ``dt * A``) is
bounded in (0, 1] for ``s <= t`` — the chunked algorithm is numerically
safe in fp32 with no log-space gymnastics (contrast RWKV6's per-channel
decay, DESIGN.md §8). Per chunk of length Q:

    intra: y_t += sum_{s<=t} (C_t . B_s) exp(a_t - a_s) dt_s x_s
    inter: y_t += exp(a_t) C_t . h_in
    state: h_out = exp(a_Q) h_in + sum_s exp(a_Q - a_s) dt_s B_s x_s^T

All terms are matmul-shaped (MXU) and the scan carries only the
``(B, H, P, N)`` state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys


def _d_inner(cfg):
    return cfg.ssm.expand * cfg.d_model


def init_mamba2_layer(cfg, key):
    s = cfg.ssm
    d = cfg.d_model
    din = _d_inner(cfg)
    h = din // s.head_dim
    dt = cfg.param_dtype
    ks = split_keys(key, 4)
    conv_dim = din + 2 * s.d_state
    return {
        # in_proj -> [z, x, B, C, dt]
        "w_in": dense_init(ks[0], d, 2 * din + 2 * s.d_state + h, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32)
                   * (s.d_conv ** -0.5)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.full((h,), -3.0, jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": jnp.ones((din,), jnp.float32),
        "w_out": dense_init(ks[2], din, d, dt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, T, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i: i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + b).astype(x.dtype)


def _ssd_chunked(xh, bmat, cmat, dtv, a_head, chunk, h_init):
    """xh: (B,T,H,P); bmat/cmat: (B,T,N); dtv: (B,T,H) (softplus'd);
    a_head: (H,) negative scalars; h_init: (B,H,P,N).
    Returns (y: (B,T,H,P), h_out)."""
    b, t, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, t)
    pad = (-t) % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
    nc = (t + pad) // q

    xh = xh.reshape(b, nc, q, h, p)
    bm = bmat.reshape(b, nc, q, n)
    cm = cmat.reshape(b, nc, q, n)
    dtc = dtv.reshape(b, nc, q, h)

    def chunk_step(hstate, inp):
        xc, bc, cc, dc = inp            # (B,q,H,P) (B,q,N) (B,q,N) (B,q,H)
        loga = dc * a_head[None, None]                       # (B,q,H) <= 0
        a_cum = jnp.cumsum(loga, axis=1)                     # (B,q,H)
        # intra-chunk: G[t,s] = (C_t.B_s) exp(a_t - a_s) dt_s  (t >= s)
        gb = jnp.einsum("btn,bsn->bts", cc, bc)              # (B,q,q)
        decay = jnp.exp(a_cum[:, :, None] - a_cum[:, None])  # (B,q,s?,H)
        tri = jnp.tril(jnp.ones((q, q), bool))
        gate = jnp.where(tri[None, :, :, None], decay, 0.0)  # (B,q,q,H)
        g = gb[..., None] * gate * dc[:, None]               # (B,t,s,H)
        y = jnp.einsum("btsh,bshp->bthp", g, xh_f32(xc))     # (B,q,H,P)
        # inter-chunk: y_t += exp(a_t) C_t . h
        y = y + jnp.einsum("bth,btn,bhpn->bthp",
                           jnp.exp(a_cum), cc, hstate)
        # state update
        dec_end = jnp.exp(a_cum[:, -1:, :] - a_cum)          # (B,q,H)
        upd = jnp.einsum("bth,btn,bthp->bhpn", dec_end * dc, bc, xh_f32(xc))
        h_new = jnp.exp(a_cum[:, -1])[:, :, None, None] * hstate + upd
        return h_new, y

    def xh_f32(v):
        return v.astype(jnp.float32)

    xs = (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(bm, 1, 0),
          jnp.moveaxis(cm, 1, 0), jnp.moveaxis(dtc, 1, 0))
    h_out, ys = jax.lax.scan(chunk_step, h_init, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * q, h, p)[:, :t]
    return y, h_out


def mamba2_layer_fwd(cfg, p, x, state=None):
    """x: (B, T, D). state: dict(conv=(B,K-1,C), ssm=(B,H,P,N)) or None.
    Returns (y, new_state)."""
    s = cfg.ssm
    b, t, d = x.shape
    din = _d_inner(cfg)
    h = din // s.head_dim
    pdim = s.head_dim
    n = s.d_state

    proj = x @ p["w_in"]
    z, xs_, bmat, cmat, dtp = jnp.split(
        proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)

    conv_in = jnp.concatenate([xs_, bmat, cmat], axis=-1)
    if state is not None:
        conv_in_full = jnp.concatenate([state["conv"], conv_in], axis=1)
        conv_out = _causal_conv(conv_in_full, p["conv_w"], p["conv_b"])
        conv_out = conv_out[:, state["conv"].shape[1]:]
        new_conv = conv_in_full[:, -(s.d_conv - 1):]
    else:
        conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
        new_conv = conv_in[:, -(s.d_conv - 1):]
    xs_, bmat, cmat = jnp.split(conv_out, [din, din + n], axis=-1)

    dtv = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    a_head = -jnp.exp(p["a_log"])                                  # (H,) < 0
    xh = xs_.reshape(b, t, h, pdim)

    h0 = (jnp.zeros((b, h, pdim, n), jnp.float32) if state is None
          else state["ssm"])
    y, h_out = _ssd_chunked(xh, bmat.astype(jnp.float32),
                            cmat.astype(jnp.float32), dtv, a_head,
                            s.chunk, h0)
    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, t, din)

    # gated RMSNorm then out-projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + cfg.eps) * p["out_norm"]
    out = y.astype(x.dtype) @ p["w_out"]

    new_state = {"conv": new_conv, "ssm": h_out}
    return out, new_state


def init_mamba2_state(cfg, batch):
    s = cfg.ssm
    din = _d_inner(cfg)
    h = din // s.head_dim
    conv_dim = din + 2 * s.d_state
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), cfg.param_dtype),
        "ssm": jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32),
    }
