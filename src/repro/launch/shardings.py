"""Sharding rules: param/optimizer/batch/cache PartitionSpecs.

Parallelism layout on the production mesh (DESIGN.md §6):

* ``model`` axis — tensor parallel (attention heads, FFN hidden, vocab)
  and expert parallel (MoE expert dim);
* ``data`` (× ``pod``) — data parallel for activations; ZeRO-1 for
  optimizer state (fp32 master/m/v sharded on ``data`` over the first
  large replicated dim); optional FSDP (params sharded on ``data`` too);
* decode caches: batch on ``data`` normally; the ``long_500k`` cell
  (batch=1) shards the *sequence* axis of the KV cache on ``data``
  instead (flash-decode style).

Rules are name-based over the param tree; everything under ``layers``
gets a leading ``None`` for the stacked layer dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL = "model"

# last-path-component -> rule kind
_COL = {  # (in, out) with out sharded on `model`
    "wq", "wk", "wv", "wg", "wr", "ck", "cr", "w_gate", "w_up",
    "wuq", "wuk", "wuv", "w_in", "frontend_proj", "vision_proj", "wdq",
}
_ROW = {  # (in, out) with in sharded on `model`
    "wo", "w_down", "cv", "w_out",
}
_REPL = {  # always replicated
    "router", "mix_a", "mix_b", "decay_a", "decay_b", "wdkv",
}


def batch_axes(mesh: Mesh):
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def _leaf_spec(path, leaf, cfg, fsdp: bool, msize: int):
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    stacked = bool(names) and names[0] == "layers"
    nd = leaf.ndim - (1 if stacked else 0)
    shape = leaf.shape[1:] if stacked else leaf.shape
    fs = "data" if fsdp else None

    def ok(dim):  # jax.jit requires input dims divide the partition count
        return dim % msize == 0

    if name == "embed":
        if ok(shape[0]):
            spec = (MODEL, None)          # vocab-sharded
        elif ok(shape[1]):
            spec = (None, MODEL)          # fallback: d_model-sharded
        else:
            spec = (None, None)
    elif name == "lm_head":
        if ok(shape[1]):
            spec = (None, MODEL)
        elif ok(shape[0]):
            spec = (MODEL, None)          # row-parallel fallback
        else:
            spec = (None, None)
    elif name in _REPL or nd <= 1:
        spec = (None,) * nd
    elif name in _COL:
        if nd == 3:          # MoE expert tensors (E, d, h)
            if ok(shape[0]):
                spec = (MODEL, None, None)          # EP
            elif ok(shape[2]):
                spec = (None, None, MODEL)          # TP-within-expert
            else:
                spec = (None, None, None)
        else:
            spec = (fs, MODEL) if ok(shape[1]) else (
                (MODEL, None) if ok(shape[0]) else (None, None))
    elif name in _ROW:
        if nd == 3:          # (E, h, d)
            if ok(shape[0]):
                spec = (MODEL, None, None)
            elif ok(shape[1]):
                spec = (None, MODEL, None)
            else:
                spec = (None, None, None)
        else:
            spec = (MODEL, fs) if ok(shape[0]) else (
                (None, MODEL) if ok(shape[1]) else (None, None))
    elif name == "conv_w":   # depthwise conv (K, C): channels on model
        spec = (None, MODEL) if ok(shape[1]) else (None, None)
    else:
        spec = (None,) * nd
    if stacked:
        spec = (None, *spec)
    return P(*spec)


def param_specs(cfg, params_tree, fsdp: bool = False, msize: int = 16):
    """PartitionSpec pytree matching `params_tree` (arrays or
    ShapeDtypeStructs). ``msize`` = model-axis size (for divisibility
    fallbacks)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, cfg, fsdp, msize),
        params_tree)


def zero1_spec(spec: P, shape, data_size: int, min_size: int = 1024) -> P:
    """Add `data` (ZeRO-1) on the first unsharded dim that divides."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (ax, dim) in enumerate(zip(parts, shape)):
        if ax is None and dim % data_size == 0 and dim >= min_size:
            parts[i] = "data"
            return P(*parts)
    return P(*parts)


def opt_specs(cfg, params_tree, data_size: int, fsdp: bool = False,
              msize: int = 16):
    """Specs for AdamWState: step replicated; master/m/v ZeRO-1."""
    pspecs = param_specs(cfg, params_tree, fsdp, msize)
    z = jax.tree.map(
        lambda spec, leaf: zero1_spec(spec, leaf.shape, data_size),
        pspecs, params_tree)
    from repro.optim.adamw import AdamWState

    return AdamWState(step=P(), master=z, m=z, v=z)


def batch_specs(cfg, batch_tree, mesh: Mesh, shard_batch: bool = True):
    bx = batch_axes(mesh) if shard_batch else ()

    def leaf(path, x):
        if not shard_batch or x.shape[0] == 1:
            return P(*(None,) * x.ndim)
        return P(bx, *(None,) * (x.ndim - 1))

    return jax.tree_util.tree_map_with_path(leaf, batch_tree)


def cache_specs(cfg, cache_tree, mesh: Mesh, *, seq_sharded: bool):
    """Decode-cache specs. Leaves have a stacked leading dim (layers or
    attn groups). Heuristics by rank/name:

    * gqa kv (L, B, S, KV, hd): B on data / S on data (long ctx), KV on model
    * mla   (L, B, S, r):       B on data / S on data
    * rwkv wkv (L, B, H, hd, hd): H on model
    * conv/ssm states: feature dims on model
    """
    bx = batch_axes(mesh)

    msize = mesh.shape.get(MODEL, 1)
    bsize = 1
    for ax in bx:
        bsize *= mesh.shape.get(ax, 1)

    def leaf(path, x):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1] if names else ""
        nd = x.ndim

        def ok_b(dim):
            return dim % bsize == 0 and dim > 1

        def ok_m(dim):
            return dim % msize == 0

        if name in ("k", "v"):            # (G/L, B, S, KV, hd)
            _, b, s, kv, hd = x.shape
            bax = bx if (not seq_sharded and ok_b(b)) else None
            sax = bx if seq_sharded else None
            if ok_m(kv):                  # head-sharded cache
                return P(None, bax, sax, MODEL, None)
            if sax is None and ok_m(s):   # flash-decode: seq on model
                return P(None, bax, MODEL, None, None)
            if ok_m(hd):                  # last resort: head_dim
                return P(None, bax, sax, None, MODEL)
            return P(None, bax, sax, None, None)
        if name in ("c_kv", "k_rope"):    # (L, B, S, r) — MLA latent
            _, b, s, r = x.shape
            bax = bx if (not seq_sharded and ok_b(b)) else None
            sax = bx if seq_sharded else (MODEL if ok_m(s) else None)
            return P(None, bax, sax, None)
        bax = bx if (not seq_sharded and ok_b(x.shape[1])) else None
        if name == "wkv":                 # (L, B, H, hd, hd)
            return P(None, bax, MODEL if ok_m(x.shape[2]) else None,
                     None, None)
        if name in ("sx_t", "sx_c"):      # (L, B, 1, D)
            return P(None, bax, None, MODEL if ok_m(x.shape[3]) else None)
        if name == "conv":                # (L, B, K-1, C)
            return P(None, bax, None, MODEL if ok_m(x.shape[3]) else None)
        if name == "ssm":                 # (L, B, H, P, N)
            return P(None, bax, MODEL if ok_m(x.shape[2]) else None,
                     None, None)
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(leaf, cache_tree)


def shard_tree(tree, specs, mesh: Mesh):
    """device_put a pytree according to specs."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, specs)
