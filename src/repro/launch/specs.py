"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation: params/optimizer come from ``jax.eval_shape`` over
the real init functions, batches are constructed directly, and every
struct is tagged with its NamedSharding so ``jit(...).lower()`` sees the
production layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch import shardings as sh
from repro.models import model as M
from repro.optim import adamw

SDS = jax.ShapeDtypeStruct


def train_batch_struct(cfg: ModelConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encoder":
        return {
            "frames": SDS((b, s, M.FRAME_DIM), jnp.float32),
            "mask": SDS((b, s), jnp.bool_),
            "targets": SDS((b, s), jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "tokens": SDS((b, s - cfg.n_patches), jnp.int32),
            "patches": SDS((b, cfg.n_patches, M.VISION_DIM), jnp.float32),
        }
    return {"tokens": SDS((b, s), jnp.int32)}


def params_struct(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(M.init_params, cfg), key)


def opt_struct(params):
    return jax.eval_shape(adamw.init_state, params)


def cache_struct(cfg: ModelConfig, batch: int, seq: int):
    return jax.eval_shape(
        functools.partial(M.init_cache, cfg, batch, seq))


def with_shardings(tree, specs, mesh: Mesh):
    return jax.tree.map(
        lambda s, sp: SDS(s.shape, s.dtype,
                          sharding=NamedSharding(mesh, sp)),
        tree, specs)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                fsdp: bool = False):
    """Returns (kind, arg_structs) where arg_structs match the step fn:

    train   -> (params, opt_state, err_buf, batch)
    prefill -> (params, batch, cache)
    decode  -> (params, token, cache, index)
    """
    msize = mesh.shape["model"]
    pspec = sh.param_specs(cfg, params_struct(cfg), fsdp, msize)
    params = with_shardings(params_struct(cfg), pspec, mesh)

    if shape.kind == "train":
        opt = opt_struct(params)
        ospec = sh.opt_specs(cfg, params_struct(cfg), mesh.shape["data"],
                             fsdp, msize)
        opt = with_shardings(opt, ospec, mesh)
        batch = train_batch_struct(cfg, shape)
        bspec = sh.batch_specs(cfg, batch, mesh)
        batch = with_shardings(batch, bspec, mesh)
        return "train", (params, opt, {}, batch)

    seq_sharded = shape.global_batch == 1          # long_500k policy
    cache = cache_struct(cfg, shape.global_batch, shape.seq_len)
    cspec = sh.cache_specs(cfg, cache, mesh, seq_sharded=seq_sharded)
    cache = with_shardings(cache, cspec, mesh)

    if shape.kind == "prefill":
        if cfg.family == "encoder":
            batch = train_batch_struct(cfg, shape)
            bspec = sh.batch_specs(cfg, batch, mesh)
            return "encode", (params, with_shardings(batch, bspec, mesh))
        batch = {"tokens": SDS((shape.global_batch, shape.seq_len),
                               jnp.int32)}
        bspec = sh.batch_specs(cfg, batch, mesh)
        batch = with_shardings(batch, bspec, mesh)
        return "prefill", (params, batch, cache)

    # decode
    bx = sh.batch_axes(mesh)
    tok_spec = P(bx, None) if shape.global_batch > 1 else P(None, None)
    token = SDS((shape.global_batch, 1), jnp.int32,
                sharding=NamedSharding(mesh, tok_spec))
    index = SDS((), jnp.int32, sharding=NamedSharding(mesh, P()))
    return "decode", (params, token, cache, index)


def step_fn(cfg: ModelConfig, kind: str, opt_cfg=None, *,
            microbatches: int = 1, compress: bool = False):
    from repro.train.train_step import make_train_step

    if kind == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig()
        return make_train_step(cfg, opt_cfg, microbatches=microbatches,
                               compress=compress)
    if kind == "encode":
        def encode(params, batch):
            loss, metrics = M.train_loss(cfg, params, batch)
            return loss
        return encode
    if kind == "prefill":
        return functools.partial(M.prefill, cfg)
    if kind == "decode":
        return functools.partial(M.decode_step, cfg)
    raise ValueError(kind)
