"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this
module never touches jax device state — the dry-run process sets
XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 4, model: int = 2):
    """Small mesh over however many (host) devices exist — tests only."""
    n = len(jax.devices())
    data = min(data, max(1, n // model))
    if data * model > n:
        model = 1
        data = n
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


# TPU v5e-like hardware constants (per chip) used by the roofline model.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link
