"""Serving CLI: batched continuous decode on a smoke config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b \
        --requests 8 --slots 4 --new-tokens 16
"""
from __future__ import annotations

import argparse

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    import jax

    from repro.configs.base import get_smoke_config
    from repro.models import model as M
    from repro.serve.engine import Request, ServeEngine

    cfg = get_smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, n_slots=args.slots,
                      max_len=args.max_len, temperature=args.temperature)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        plen = int(rng.integers(4, 32))
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab, plen),
                           max_new_tokens=args.new_tokens))
    done = eng.run()
    for r in sorted(done, key=lambda r: r.uid):
        print(f"req {r.uid}: prompt[{len(r.prompt)}] -> "
              f"{len(r.out_tokens)} tokens: {r.out_tokens[:8]}...")
    print(f"served {len(done)} requests")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
