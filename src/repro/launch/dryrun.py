import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import/init: jax locks the device count on first
# initialisation, and the production dry-run needs 512 placeholder
# devices (2 pods x 16 x 16). Smoke tests / benches run in separate
# processes and see the single real CPU device.

"""Multi-pod dry-run: lower + compile every applicable
(architecture x input-shape x mesh) cell against the production mesh and
record memory/cost/collective statistics for the roofline analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out results/dryrun.json]

Each cell is lowered with ShapeDtypeStruct stand-ins (no allocation),
compiled for the 16x16 (and 2x16x16) SPMD mesh, and the compiled
artifact's ``memory_analysis()`` / ``cost_analysis()`` plus a parse of
its HLO collectives are appended to the output JSON (incremental — safe
to re-run; finished cells are skipped unless --force).
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path


def _compile_once(cfg, shape, mesh, *, fsdp, microbatches, compress,
                  save_hlo=None):
    import jax

    from repro.launch import specs as sp
    from repro.roofline.analysis import collective_bytes

    with jax.set_mesh(mesh):   # ambient mesh: GSPMD + shard_map(EP) see it
        kind, args = sp.input_specs(cfg, shape, mesh, fsdp=fsdp)
        fn = sp.step_fn(cfg, kind, microbatches=microbatches,
                        compress=compress)
        # buffer donation (§Perf): train steps update params/opt/err
        # in place; decode/prefill update the KV cache in place — without
        # donation XLA copies the whole state every step.
        donate = {"train": (0, 1, 2), "decode": (2,), "prefill": (2,),
                  "encode": ()}[kind]
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        if save_hlo:
            Path(save_hlo).write_text(hlo)
    return {
        "kind": kind,
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "collective_bytes": coll["total"],
        "collectives": coll["by_kind"],
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    }


def _probe_costs(cfg, shape, mesh, **kw):
    """Layer-extrapolated costs.

    XLA's cost_analysis counts a while-loop body once, so the production
    (scan-over-layers) compile under-reports flops/collectives. We lower
    two small UNROLLED probes (n1 and n2 layers, dense single-chunk
    attention so no inner scans hide cost) on the same mesh/sharding and
    extrapolate linearly over layers — exact for homogeneous stacks.
    Residual undercount: RWKV's WKV time-scan (<1% of its flops) and
    Mamba2's SSD chunk scan (~4%), both documented in EXPERIMENTS.md.
    """
    if cfg.family == "hybrid":
        n1, n2 = cfg.ssm.attn_every, 2 * cfg.ssm.attn_every
    else:
        n1, n2 = 1, 2
    dense_chunk = max(cfg.attn_chunk, shape.seq_len)
    probe_cfg = cfg.replace(scan_layers=False, attn_chunk=dense_chunk)
    r1 = _compile_once(probe_cfg.replace(n_layers=n1), shape, mesh, **kw)
    r2 = _compile_once(probe_cfg.replace(n_layers=n2), shape, mesh, **kw)
    scale = (cfg.n_layers - n1) / (n2 - n1)

    def extrap(a, b):
        return a + (b - a) * scale

    kinds = set(r1["collectives"]) | set(r2["collectives"])
    return {
        "flops": extrap(r1["flops"], r2["flops"]),
        "bytes_accessed": extrap(r1["bytes_accessed"],
                                 r2["bytes_accessed"]),
        "collective_bytes": extrap(r1["collective_bytes"],
                                   r2["collective_bytes"]),
        "collectives": {k: extrap(r1["collectives"].get(k, 0),
                                  r2["collectives"].get(k, 0))
                        for k in kinds},
        "probe_layers": [n1, n2],
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               *, fsdp: bool = False, microbatches: int = 1,
               compress: bool = False, save_hlo: str | None = None,
               probes: bool = True, cfg_override=None):
    from repro.configs.base import SHAPES, get_config, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import roofline_terms

    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    kw = dict(fsdp=fsdp, microbatches=microbatches, compress=compress)
    t0 = time.time()
    # 1) full production compile: proves the cell lowers + fits memory
    full = _compile_once(cfg, shape, mesh, save_hlo=save_hlo, **kw)
    t_full = time.time() - t0
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "kind": full["kind"],
        "status": "ok",
        "n_chips": mesh.size,
        "compile_s": round(t_full, 1),
        "memory": full["memory"],
        "scanned_body_flops": full["flops"],
    }
    # 2) unrolled probes for layer-true cost numbers
    if probes:
        t1 = time.time()
        costs = _probe_costs(cfg, shape, mesh, **kw)
        rec.update(costs)
        rec["probe_s"] = round(time.time() - t1, 1)
    else:
        rec.update({k: full[k] for k in
                    ("flops", "bytes_accessed", "collective_bytes",
                     "collectives")})
    rec["roofline"] = roofline_terms(cfg, shape, rec)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--baseline", action="store_true",
                    help="strip §Perf optimization flags (moe_ep, "
                         "attn_seq_shard, remat policy) to regenerate "
                         "the pre-hillclimb baseline artifact")
    args = ap.parse_args(argv)

    from repro.configs.base import ARCHS, SHAPES, get_config

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = {tuple(k.split("|")): v
                   for k, v in json.loads(out_path.read_text()).items()}

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                key = (arch, shape, "multi" if multi else "single")
                if key in results and results[key]["status"] in (
                        "ok", "skipped") and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[lower ] {key} ...", flush=True)
                try:
                    cfg_override = None
                    if args.baseline:
                        cfg_override = get_config(arch).replace(
                            moe_ep=False, attn_seq_shard=False,
                            remat_policy="full")
                    rec = lower_cell(arch, shape, multi, fsdp=args.fsdp,
                                     microbatches=args.microbatches,
                                     compress=args.compress,
                                     save_hlo=args.save_hlo,
                                     cfg_override=cfg_override)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    n_fail += 1
                results[key] = rec
                out_path.write_text(json.dumps(
                    {"|".join(k): v for k, v in results.items()}, indent=1))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" flops={rec['flops']:.3e}"
                             f" coll={rec['collective_bytes']:.3e}B"
                             f" compile={rec['compile_s']}s")
                print(f"[{status:7s}] {key}{extra}", flush=True)

    print(f"done; {n_fail} failures -> {out_path}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
