"""Training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_4b --smoke \
        --steps 50 --batch 4 --seq 128

``--smoke`` selects the reduced config (CPU-runnable); without it the
full config is built (requires a real TPU slice — on this container use
the dry-run instead). The loop wires together the deterministic data
pipeline, AdamW, async checkpointing, and the fault-tolerance
supervisor; ``--simulate-failure N`` kills the loop at step N and
restarts from the latest checkpoint to exercise the recovery path.
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--simulate-failure", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.base import ShapeSpec, get_config, get_smoke_config
    from repro.optim.adamw import AdamWConfig
    from repro.runtime.fault_tolerance import Supervisor
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    tc = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir,
                       microbatches=args.microbatches,
                       compress=args.compress)
    sup = Supervisor(n_workers=1)

    trainer = Trainer(cfg, shape, opt_cfg, tc, supervisor=sup)
    start = trainer.maybe_restore()
    if start:
        print(f"restored from checkpoint at step {start}")

    if args.simulate_failure and start < args.simulate_failure:
        print(f"[FT drill] will fail at step {args.simulate_failure}")
        trainer.run(steps=args.simulate_failure)
        print("[FT drill] simulated crash — restarting from checkpoint")
        trainer2 = Trainer(cfg, shape, opt_cfg, tc, supervisor=sup)
        restored = trainer2.maybe_restore()
        assert restored > 0, "no checkpoint written before failure"
        print(f"[FT drill] resumed at step {restored}")
        trainer2.run()
        return 0

    trainer.run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
