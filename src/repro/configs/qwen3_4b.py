"""qwen3-4b — dense decoder, GQA kv=8, per-head RMS qk-norm.
[hf:Qwen/Qwen3 family] 36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1000000.0,
    # §Perf-validated defaults (EXPERIMENTS.md):
    attn_seq_shard=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=128, head_dim=16, dtype="float32", attn_chunk=32,
    )
