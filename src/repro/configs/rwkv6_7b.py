"""rwkv6-7b (Finch) — attention-free RNN with data-dependent decay.
[arXiv:2404.05892] 32L d_model=4096 d_ff=14336 vocab=65536.
Sub-quadratic: long_500k decode RUNS for this arch."""
from .base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6_7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,              # wkv heads = d_model / head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    attention="none",
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32, gate_lora=64),
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=128, rwkv=RWKVConfig(head_dim=16, decay_lora=8, mix_lora=4,
                                   gate_lora=8),
        dtype="float32",
    )
