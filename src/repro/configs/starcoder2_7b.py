"""starcoder2-7b — dense decoder, GQA kv=4, RoPE, LN + gelu FFN.
[arXiv:2402.19173] 32L d_model=4608 36H (kv=4) d_ff=18432 vocab=49152."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2_7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    norm="ln",
    act="gelu",
    rope_theta=100000.0,
    # §Perf-validated defaults (EXPERIMENTS.md):
    attn_seq_shard=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=72, n_heads=6, n_kv_heads=2, d_ff=144,
        vocab=128, dtype="float32", attn_chunk=32,
    )
