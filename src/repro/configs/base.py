"""Model/shape configuration system.

``ModelConfig`` is a frozen dataclass covering every assigned architecture
family (dense / MLA / MoE / SSM / hybrid / encoder / VLM). One module per
architecture in this package defines ``CONFIG`` (the exact published
config) and ``smoke()`` (a reduced same-family config for CPU tests).

``SHAPES`` defines the four assigned input shapes; applicability per arch
is resolved by :func:`cells_for` (DESIGN.md §7 skip table).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0           # routed experts
    top_k: int = 0
    d_expert: int = 0            # per-expert ffn hidden
    n_shared: int = 0            # shared (always-on) experts
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_loss_coef: float = 1e-2


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64            # SSM state size per head
    d_conv: int = 4              # causal conv window
    expand: int = 2              # d_inner = expand * d_model
    head_dim: int = 64           # mamba2 head dim
    chunk: int = 64              # SSD chunk length
    attn_every: int = 0          # hybrid: shared attn block period (0 = off)


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64         # lora rank for data-dependent decay w
    mix_lora: int = 32           # lora rank for token-shift interpolation
    gate_lora: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    attention: str = "gqa"       # gqa | mla | none
    norm: str = "rms"            # rms | ln
    act: str = "swiglu"          # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    rwkv: RWKVConfig = field(default_factory=RWKVConfig)
    # vlm / audio frontends are STUBS: inputs are precomputed embeddings
    n_patches: int = 0           # vlm: image patch embeddings per example
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    moe_ep: bool = False         # shard_map expert-parallel MoE (§Perf)
    attn_seq_shard: bool = False  # shard attention scores over q-sequence
    attn_bf16_scores: bool = False  # store scores/probs in bf16 (§Perf)
    remat_policy: str = "full"   # full | dots (checkpoint_policies)
    attn_chunk: int = 1024       # blockwise-attention KV chunk
    eps: float = 1e-5

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_causal(self) -> bool:
        return self.family != "encoder"

    @property
    def supports_decode(self) -> bool:
        return self.family != "encoder"

    @property
    def sub_quadratic(self) -> bool:
        """True when long-context decode is admissible (SSM/hybrid/linear)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCHS = [
    "minicpm3_4b",
    "starcoder2_7b",
    "codeqwen15_7b",
    "qwen3_4b",
    "rwkv6_7b",
    "internvl2_26b",
    "hubert_xlarge",
    "qwen2_moe_a2_7b",
    "granite_moe_3b_a800m",
    "zamba2_1_2b",
]


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke()


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — DESIGN.md §7 cell accounting."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k dense decode excluded"
    return True, ""


def cells_for(arch: str) -> list[tuple[ShapeSpec, bool, str]]:
    cfg = get_config(arch)
    return [(s, *shape_applicable(cfg, s)) for s in SHAPES.values()]
