"""hubert-xlarge — encoder-only audio transformer (w2v2 arch).
[arXiv:2106.07447] 48L d_model=1280 16H d_ff=5120 vocab=504 (cluster codes).
Frontend (conv feature extractor) is a STUB: input_specs() supplies
precomputed frame embeddings. Targets are medoid-cluster codes produced by
trikmeds (repro.data.pseudolabel) — the paper's technique in the loop.
Encoder-only: decode shapes are skipped."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert_xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    norm="ln",
    act="gelu",
    # §Perf-validated defaults (EXPERIMENTS.md):
    remat_policy="dots",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=32, dtype="float32", attn_chunk=32,
    )
