"""internvl2-26b — VLM: InternLM2-20B text backbone, InternViT frontend STUB.
[arXiv:2404.16821] 48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92553.
The modality frontend is a stub: input_specs() supplies 256 precomputed
patch embeddings per example, linearly projected and prepended to text."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2_26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    n_patches=256,
    rope_theta=1000000.0,
    # §Perf-validated defaults (EXPERIMENTS.md):
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=128, n_patches=8, dtype="float32", attn_chunk=32,
    )
