"""qwen2-moe-a2.7b — MoE decoder: 60 routed experts top-4 + 4 shared.
[hf:Qwen/Qwen1.5-MoE-A2.7B] 24L d_model=2048 16H d_ff=1408/expert
vocab=151936."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2_moe_a2_7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    moe=MoEConfig(n_experts=60, top_k=4, d_expert=1408, n_shared=4),
    rope_theta=1000000.0,
    # §Perf-validated defaults (EXPERIMENTS.md):
    attn_seq_shard=True,
    moe_ep=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab=128, moe=MoEConfig(n_experts=8, top_k=2, d_expert=96,
                                 n_shared=1),
        dtype="float32", attn_chunk=32,
    )
