"""minicpm3-4b — dense decoder with Multi-head Latent Attention (MLA).
[hf:openbmb/MiniCPM3-4B] 62L d_model=2560 40H d_ff=6400 vocab=73448."""
from .base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3_4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    attention="mla",
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    rope_theta=10000.0,
    # §Perf-validated defaults (EXPERIMENTS.md):
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=257,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=8, qk_rope_head_dim=8, v_head_dim=8),
        dtype="float32",
        attn_chunk=32,
    )
