"""granite-moe-3b-a800m — MoE decoder: 40 routed experts top-8.
[hf:ibm-granite/granite-3.0 family] 32L d_model=1536 24H (kv=8)
d_ff=512/expert vocab=49155."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite_moe_3b_a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512, n_shared=0),
    rope_theta=10000.0,
    # §Perf-validated defaults (EXPERIMENTS.md):
    moe_ep=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=128, moe=MoEConfig(n_experts=8, top_k=2, d_expert=64,
                                 n_shared=0),
        dtype="float32", attn_chunk=32,
    )
