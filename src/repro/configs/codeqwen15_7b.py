"""codeqwen1.5-7b — qwen1.5-arch dense decoder, MHA (kv=H), SwiGLU.
[hf:Qwen/CodeQwen1.5-7B] 32L d_model=4096 32H (kv=32) d_ff=13440 vocab=92416."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen15_7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    rope_theta=1000000.0,
    # §Perf-validated defaults (EXPERIMENTS.md):
    attn_seq_shard=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab=128, dtype="float32", attn_chunk=32,
    )
