"""zamba2-1.2b — hybrid: Mamba2 backbone + shared attention block every 6
layers (zamba-style weight sharing). [arXiv:2411.15242]
38L d_model=2048 32H d_ff=8192 vocab=32000 ssm_state=64.
Sub-quadratic: long_500k decode RUNS for this arch."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2_1_2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=64,
                  attn_every=6),
    # §Perf-validated defaults (EXPERIMENTS.md):
    attn_seq_shard=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=128, ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                                 chunk=16, attn_every=2),
        dtype="float32", attn_chunk=32,
    )
