"""Training loop: data + step + checkpoint + fault-tolerance hooks.

Single-process version runs on this container (examples & tests); the
same loop body is what each host runs under a multi-pod launcher, with
the Supervisor watching heartbeats (see `repro.runtime`).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.checkpoint.checkpoint import Checkpointer
from repro.data.pipeline import ShardedLoader
from repro.optim import adamw
from repro.runtime.fault_tolerance import Supervisor
from repro.train.train_step import make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep_last: int = 2
    microbatches: int = 1
    compress: bool = False
    async_checkpoint: bool = True


class Trainer:
    def __init__(self, model_cfg, shape, opt_cfg: adamw.AdamWConfig,
                 tc: TrainerConfig, seed: int = 0, supervisor:
                 Supervisor | None = None):
        from repro.models import model as M
        from repro.optim.compress import init_error_buffers

        self.cfg = model_cfg
        self.tc = tc
        self.loader = ShardedLoader(model_cfg, shape, seed=seed)
        key = jax.random.PRNGKey(seed)
        self.params = M.init_params(model_cfg, key)
        self.opt_state = adamw.init_state(self.params)
        self.err_buf = (init_error_buffers(self.params)
                        if tc.compress else {})
        # donate params/opt/err: in-place update, no per-step state copy
        self.step_fn = jax.jit(make_train_step(
            model_cfg, opt_cfg, microbatches=tc.microbatches,
            compress=tc.compress), donate_argnums=(0, 1, 2))
        self.ckpt = Checkpointer(tc.ckpt_dir, keep_last=tc.keep_last)
        self.start_step = 0
        self.supervisor = supervisor
        self.metrics_log: list[dict] = []

    # ------------------------------------------------------------ resume
    def maybe_restore(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0
        _, tree = self.ckpt.restore(
            {"params": self.params, "opt": self.opt_state})
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.start_step = latest
        return latest

    # -------------------------------------------------------------- run
    def run(self, steps: int | None = None):
        steps = steps or self.tc.steps
        step = self.start_step
        while step < steps:
            t0 = time.time()
            batch = self.loader(step)
            self.params, self.opt_state, self.err_buf, metrics = \
                self.step_fn(self.params, self.opt_state, self.err_buf,
                             batch)
            step += 1
            dt = time.time() - t0
            if self.supervisor is not None:
                self.supervisor.heartbeat(0, step, dt)
            if step % self.tc.log_every == 0 or step == steps:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, sec_per_step=round(dt, 3))
                self.metrics_log.append(m)
                print(f"step {step:5d} loss {m.get('loss', float('nan')):.4f} "
                      f"gnorm {m.get('grad_norm', float('nan')):.3f} "
                      f"{dt*1e3:.0f} ms")
            if step % self.tc.ckpt_every == 0 or step == steps:
                self.ckpt.save(step,
                               {"params": self.params, "opt": self.opt_state},
                               blocking=not self.tc.async_checkpoint)
        self.ckpt.wait()
        return self.metrics_log
