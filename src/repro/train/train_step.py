"""Train step: value_and_grad + AdamW, microbatch accumulation, optional
inter-pod gradient compression. Designed to be `jax.jit`-ed under a mesh
with in/out shardings from `repro.launch.shardings`.

Under pjit/GSPMD the loss mean over the (data-sharded) batch already
implies the gradient all-reduce; microbatching turns one step into a
`lax.scan` of forward/backward passes whose gradient psums XLA can
overlap with the next microbatch's compute (recorded §Perf lever).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import adamw
from repro.optim.compress import compress_with_feedback


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, *,
                    microbatches: int = 1, compress: bool = False):
    """Returns train_step(params, opt_state, err_buf, batch) ->
    (params, opt_state, err_buf, metrics). ``err_buf`` may be None when
    compression is off (pass an empty dict)."""

    def loss_fn(params, batch):
        return M.train_loss(cfg, params, batch)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, err_buf, batch):
        if microbatches > 1:
            def mb_slice(x, i):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0)

            def body(carry, i):
                acc, loss_acc = carry
                mb = jax.tree.map(lambda x: mb_slice(x, i), batch)
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = loss_sum / microbatches
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if compress:
            grads, err_buf = compress_with_feedback(grads, err_buf)

        new_params, new_opt, om = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        out_metrics = {"loss": loss, **metrics, **om}
        return new_params, new_opt, err_buf, out_metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        loss, metrics = M.train_loss(cfg, params, batch)
        return {"loss": loss, **metrics}
    return eval_step
