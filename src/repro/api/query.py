"""`MedoidQuery` / `SolveReport` — the declarative query schema (DESIGN.md §10).

A :class:`MedoidQuery` describes *what* the caller wants — which data,
which metric, single medoid / top-k / per-cluster medoids / full
K-medoids, exact or anytime, under what budget — and never *how*: the
planner (:mod:`repro.api.planner`) picks the engine. The dataclass is
registered as a JAX pytree (arrays are leaves, configuration is aux
data) so queries can ride through transformations and be carried in
pytree containers.

A :class:`SolveReport` is the one result schema for every engine. It
subsumes ``MedoidResult`` / ``BatchedMedoidResult`` / ``TopKResult`` and
the bandit ``(index, estimate, CI)`` triple: ``indices``/``energies``
are always arrays (length 1 for a single-medoid query), ``certified``
says whether the answer carries the deterministic triangle-bound
certificate, ``ci`` the residual half-width (0.0 when certified, NaN
when unknown), ``elements_computed`` the unified cost
(:func:`repro.core.distances.elements_computed`), and ``plan`` the
:class:`~repro.api.planner.Plan` that produced it. The engine's native
result dataclass rides in ``extras["raw"]`` for the legacy shims.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import Any

import numpy as np

import jax

__all__ = ["MedoidQuery", "SolveReport"]

_MODES = ("exact", "anytime")
_DEVICE_POLICIES = ("auto", "host", "device", "sharded")


@dataclass
class MedoidQuery:
    """Declarative medoid query — the single public entry schema.

    Task selection (all exact unless ``mode="anytime"``/``budget``):

    * default — the single medoid of ``X``;
    * ``topk=k`` — the ``k`` lowest-energy elements, ranked;
    * ``assignments=a, k=K`` — per-cluster medoids of a fixed assignment;
    * ``k=K`` (no assignments) — full K-medoids clustering, with
      ``update`` an optional *nested* MedoidQuery template describing the
      per-iteration medoid-update search (e.g. ``mode="anytime"`` for the
      paper's §5 budgeted relaxation).

    ``budget`` is in unified computed elements; setting it (or
    ``mode="anytime"``) routes to the bandit subsystem. ``device_policy``
    steers host/device placement — ``"sharded"`` forces the multi-device
    engines (DESIGN.md §11) on ``mesh`` (or a default 1-axis mesh over
    all local devices; ``auto`` also shards when more than one device is
    available and N clears the planner threshold). ``engine_opts``
    passes power-user knobs straight to the chosen engine (e.g.
    ``policy=``, ``distance_fn=``, ``eps=``, ``samples_per_round=``,
    ``axis=`` for sharded meshes). ``X`` may be a ``(N, d)`` array or
    a host oracle (``VectorOracle`` / ``GraphOracle``).

    Robustness policies (DESIGN.md §13):

    * ``deadline_s`` — wall-clock budget in seconds. Single-medoid
      exact queries route to a deadline-capable engine; a blown
      deadline returns the incumbent as an anytime result
      (``certified=False`` with a deterministic bound-gap ``ci``),
      never an exception.
    * ``on_error`` — ``"raise"`` (default) propagates engine failures;
      ``"degrade"`` walks the planner's downgrade ladder
      (sharded→pipelined→scan, kernels→jnp), each hop recorded in
      ``plan.reasons``, re-raising only when the last rung fails.
    * ``nonfinite`` — ``"raise"`` (default) rejects NaN/Inf rows in a
      host-array ``X`` at solve time (a single NaN silently poisons
      every triangle bound); ``"allow"`` skips the check.

    Observability (DESIGN.md §14):

    * ``trace`` — ``True`` (in-memory), a JSONL path, or a
      :class:`~repro.obs.trace.SolveTracer`: record the per-round
      elimination curve at the engine's host-visible segment
      boundaries. Deterministic and bit-neutral: the traced solve
      returns the exact same answer, and ``trace=None`` leaves the
      compiled program untouched. Events (and a summary) surface in
      ``SolveReport.extras["obs"]``.
    """
    X: Any
    metric: str = "l2"
    k: int | None = None
    assignments: Any = None
    topk: int | None = None
    mode: str = "exact"
    budget: float | None = None
    delta: float = 0.01
    warm_idx: Any = None
    device_policy: str = "auto"
    mesh: Any = None
    seed: int = 0
    block: int = 128
    block_schedule: Any = None
    use_kernels: bool | None = None
    n_iter: int = 10
    update: "MedoidQuery | None" = None
    deadline_s: float | None = None
    on_error: str = "raise"
    nonfinite: str = "raise"
    trace: Any = None
    engine_opts: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(
                f"MedoidQuery: mode must be one of {_MODES}, got "
                f"{self.mode!r}")
        if self.device_policy not in _DEVICE_POLICIES:
            raise ValueError(
                "MedoidQuery: device_policy must be one of "
                f"{_DEVICE_POLICIES}, got {self.device_policy!r}")
        if self.on_error not in ("raise", "degrade"):
            raise ValueError(
                "MedoidQuery: on_error must be 'raise' or 'degrade', "
                f"got {self.on_error!r}")
        if self.nonfinite not in ("raise", "allow"):
            raise ValueError(
                "MedoidQuery: nonfinite must be 'raise' or 'allow', "
                f"got {self.nonfinite!r}")
        if self.trace is not None:
            from repro.obs.trace import resolve_trace
            resolve_trace(self.trace)   # raises on an invalid spec
        if self.deadline_s is not None and not (
                isinstance(self.deadline_s, (int, float))
                and float(self.deadline_s) > 0):
            raise ValueError(
                "MedoidQuery: deadline_s must be a positive number of "
                f"seconds, got {self.deadline_s!r}")
        if self.assignments is not None and self.k is None:
            raise ValueError(
                "MedoidQuery: assignments requires k (the cluster count)")
        if self.topk is not None and (self.k is not None
                                      or self.assignments is not None):
            raise ValueError(
                "MedoidQuery: topk is exclusive with k/assignments")

    def with_(self, **changes) -> "MedoidQuery":
        """A copy with the given fields replaced."""
        cur = {f.name: getattr(self, f.name) for f in fields(self)}
        cur.update(changes)
        return MedoidQuery(**cur)


_QUERY_LEAVES = ("X", "assignments", "warm_idx", "update")
_QUERY_AUX = tuple(f for f in (
    "metric", "k", "topk", "mode", "budget", "delta", "device_policy",
    "mesh", "seed", "block", "block_schedule", "use_kernels", "n_iter",
    "deadline_s", "on_error", "nonfinite", "trace", "engine_opts"))


def _query_flatten(q: MedoidQuery):
    return (tuple(getattr(q, f) for f in _QUERY_LEAVES),
            tuple(getattr(q, f) for f in _QUERY_AUX))


def _query_unflatten(aux, children):
    kw = dict(zip(_QUERY_LEAVES, children))
    kw.update(zip(_QUERY_AUX, aux))
    return MedoidQuery(**kw)


jax.tree_util.register_pytree_node(
    MedoidQuery, _query_flatten, _query_unflatten)


@dataclass
class SolveReport:
    """Unified result of :func:`repro.api.solve` — one schema for every
    engine. ``energies`` are on the paper's ``S/(N-1)`` convention (see
    ``repro.core.distances``); NaN marks unknown entries (empty clusters,
    estimate-only modes that report via ``extras``)."""
    indices: np.ndarray          # (1,) single; (k,) topk / per-cluster
    energies: np.ndarray         # same shape; paper normalisation
    certified: bool              # deterministic triangle-bound certificate
    elements_computed: float     # unified cost (distances.py definition)
    n_distances: int             # scalar distance evaluations
    n_rounds: int
    ci: float                    # residual half-width (0.0 certified; NaN unknown)
    plan: Any = None             # the Plan that produced this report
    assignment: np.ndarray | None = None   # K-medoids clustering only
    extras: dict = field(default_factory=dict)

    @property
    def index(self) -> int:
        """The (first) medoid index — the single-query convenience."""
        return int(self.indices[0])

    @property
    def energy(self) -> float:
        """The (first) medoid energy — the single-query convenience."""
        return float(self.energies[0])
