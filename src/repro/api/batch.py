"""``solve_many`` — pack same-shape medoid queries into shared programs.

The many-query serving front door (DESIGN.md §12). ``solve()`` amortises
nothing across calls: every query pays its own dispatch, and a thousand
small-N queries leave the device idle between tiny programs.
``solve_many`` groups compatible queries into **shape buckets** — same
``(N, d)``, dtype, metric, effective block width, kernel flag and
warm-start presence — and runs each bucket as one jitted program with
the query axis batched (``jax.vmap`` over the pipelined engine's
full-domain stage; the query axis becomes a Pallas grid dimension on the
kernel path). Per-query results are *bit-identical* to the single-query
engine: the parity contract for every report is

    solve(q, plan="pipelined",
          q.with_(engine_opts={"ladder_min": N, ...}))

i.e. the pipelined engine with the compaction ladder disabled (the
ladder is a host-loop cost optimisation that a packed program forgoes;
``report.plan.params["equivalent"]`` records the exact counterpart).

Packing layout — why buckets, not column masks: the fixed reduction
geometry (``distances.py``, DESIGN.md §11) ties energy bit-patterns to
the *exact* column count, so padding a query's N to a bucket width would
change the fp addition grouping and break bit-identity. Queries are
therefore bucketed by exact N and padded along the **query axis** only:
each bucket chunk is padded to the next power of two with zero-budget
ghost lanes (frozen from the first predicate check, computing nothing),
so the number of distinct compiled shapes per bucket stays O(log Q) and
repeat calls — including 0- and 1-query batches — hit the jit cache.

Budgets: each lane carries its own row budget through the traced budget
argument, so one program serves mixed exact/anytime traffic. A capped
lane keeps its exact-energy incumbent and reports the deterministic
bound-gap interval (``ci`` = half of ``[min live lower bound, E_cl]``,
scaled to the paper convention) with ``certified=False``.
"""
from __future__ import annotations

import numpy as np

from .metrics import require_metric
from .planner import Plan, _estimate_cost, _is_oracle, _resolve_kernels
from .query import MedoidQuery, SolveReport

__all__ = ["solve_many"]

_ALLOWED_OPTS = {"interpret"}
_HUGE = 2**31 - 1
# cap on the vmapped program's (Q, B, N) distance carries (two copies
# live across a round). This bounds working-set size, not correctness:
# keeping the carry near cache-resident beats maximal packing — on a
# single-core CPU host, sweeping the cap showed ~20% per-query wins for
# small chunks over 128-lane ones — while still amortising dispatch
# across the chunk. Ghost-lane padding makes any cap bit-neutral.
_MAX_CARRY_BYTES = 8 << 20


def _pow2_at_least(x: int) -> int:
    from repro.core.distances import pow2_at_least
    return pow2_at_least(max(int(x), 1))


def _validate(q: MedoidQuery, i: int) -> None:
    if not isinstance(q, MedoidQuery):
        raise TypeError(
            f"solve_many: queries[{i}] is {type(q).__name__}, expected "
            "MedoidQuery")
    if _is_oracle(q.X):
        raise ValueError(
            f"solve_many: queries[{i}] wraps a host oracle; the packed "
            "path needs (N, d) vector arrays (use solve() per query)")
    if q.k is not None or q.assignments is not None or q.topk is not None:
        raise ValueError(
            f"solve_many: queries[{i}] is not a single-medoid query "
            "(k/assignments/topk set); batch those via solve() per query")
    if q.device_policy not in ("auto", "device"):
        raise ValueError(
            f"solve_many: queries[{i}] has device_policy="
            f"{q.device_policy!r}; the packed path is single-device "
            "(host and sharded queries go through solve())")
    if q.mesh is not None:
        raise ValueError(
            f"solve_many: queries[{i}] carries a mesh; the packed path "
            "is single-device")
    if q.block_schedule is not None:
        raise ValueError(
            f"solve_many: queries[{i}] sets block_schedule; warm-up "
            "schedules do not pack (per-query round widths would "
            "diverge) — use warm_idx or solve() per query")
    extra = set(q.engine_opts) - _ALLOWED_OPTS
    if extra:
        raise ValueError(
            f"solve_many: queries[{i}] engine_opts {sorted(extra)} are "
            f"not packable; supported: {sorted(_ALLOWED_OPTS)}")
    require_metric(q.metric, need_triangle=True, caller="solve_many")
    if np.ndim(q.X) != 2:
        raise ValueError(
            f"solve_many: queries[{i}].X must be (N, d), got shape "
            f"{np.shape(q.X)}")
    if q.nonfinite == "raise":
        import jax.numpy as jnp
        row_ok = jnp.isfinite(jnp.asarray(q.X)).all(axis=1)
        bad = int(np.asarray((~row_ok).sum()))
        if bad:
            raise ValueError(
                f"solve_many: queries[{i}].X contains non-finite values "
                f"(NaN/Inf) in {bad} of {int(row_ok.shape[0])} rows; a "
                "single non-finite element poisons every triangle bound. "
                "Clean the input or pass nonfinite='allow'.")


def _prepare(q: MedoidQuery):
    """Resolve one query to its packing record (host-side, cheap)."""
    import jax.numpy as jnp
    X = jnp.asarray(q.X)
    n, d = X.shape
    block = int(min(int(q.block), n))
    reasons: list[str] = []
    m = require_metric(q.metric, caller="solve_many")
    use_kernels = _resolve_kernels(q, m, reasons, None)
    interpret = q.engine_opts.get("interpret")
    budget = _HUGE if q.budget is None else max(int(q.budget), 0)
    if q.warm_idx is not None:
        w = np.asarray(q.warm_idx, np.int64)
        _, first = np.unique(w, return_index=True)
        warm = w[np.sort(first)][:block].astype(np.int32)
    else:
        warm = None
    key = (n, d, str(X.dtype), q.metric, block, use_kernels, interpret,
           warm is not None)
    return {"X": X, "n": n, "d": d, "block": block, "metric": q.metric,
            "use_kernels": use_kernels, "interpret": interpret,
            "budget": budget, "warm": warm, "key": key, "query": q}


def _chunk_cap(n: int, block: int, override) -> int:
    if override is not None:
        return max(int(override), 1)
    cap = _MAX_CARRY_BYTES // max(2 * block * n * 4, 1)
    cap = 1 << max(int(cap).bit_length() - 1, 0)     # floor to a power of 2
    return int(min(max(cap, 1), 1024))


def _trivial_report(q: MedoidQuery, plan: Plan) -> SolveReport:
    """N == 1 short-circuit, matching the pipelined engine's early
    return (index 0, energy 0, one computed element)."""
    return SolveReport(
        indices=np.asarray([0], np.int64),
        energies=np.asarray([0.0], np.float64),
        certified=True, elements_computed=1.0, n_distances=1,
        n_rounds=0, ci=0.0, plan=plan,
        extras={"batch": {"n_queries": 1, "q_padded": 0,
                          "elements_total": 1.0}})


def _bucket_plan(rec, chunk_real, q_padded) -> Plan:
    q = rec["query"]
    n = rec["n"]
    capped = rec["budget"] != _HUGE
    eq_opts = {"ladder_min": n}
    if capped:
        eq_opts["max_computed"] = rec["budget"]
    if rec["interpret"] is not None:
        eq_opts["interpret"] = rec["interpret"]
    params = {
        "n": n,
        "use_kernels": rec["use_kernels"],
        "solve_many": {"bucket": rec["key"], "n_queries": chunk_real,
                       "q_padded": q_padded},
        # the bit-identical single-query counterpart (parity contract)
        "equivalent": {"plan": "pipelined", "engine_opts": eq_opts},
    }
    reasons = (
        f"solve_many: packed bucket of {chunk_real} same-shape "
        f"quer{'y' if chunk_real == 1 else 'ies'} "
        f"(N={n}, d={rec['d']}, metric={rec['metric']!r}, "
        f"block={rec['block']}), query axis "
        + ("as a Pallas grid dimension" if rec["use_kernels"]
           else "vmapped over the pipelined engine"),)
    return Plan("pipelined", reasons, params,
                cost_estimate=_estimate_cost(q, "pipelined", params))


def solve_many(queries, max_queries_per_program=None):
    """Solve a batch of single-medoid queries through shared packed
    programs; returns one :class:`SolveReport` per query, in order.

    Same-shape queries (identical ``(N, d)``, dtype, metric, block,
    kernel flag, warm presence) share one jitted program; per-query
    ``indices`` / ``energies`` / ``elements_computed`` are bit-identical
    to the single-query pipelined engine with the compaction ladder
    disabled (see ``report.plan.params["equivalent"]``), and the
    per-query ``elements_computed`` sum to the packed program totals
    recorded in ``report.extras["batch"]``.

    Per-query ``budget`` (in computed elements) caps that lane only;
    over-budget lanes come back ``certified=False`` with a
    deterministic bound-gap ``ci``. ``max_queries_per_program``
    overrides the memory-derived microbatch cap.
    """
    queries = list(queries)
    for i, q in enumerate(queries):
        _validate(q, i)

    reports: list[SolveReport | None] = [None] * len(queries)
    buckets: dict[tuple, list[tuple[int, dict]]] = {}
    for i, q in enumerate(queries):
        rec = _prepare(q)
        if rec["n"] == 1:
            reports[i] = _trivial_report(q, _bucket_plan(rec, 1, 0))
            continue
        buckets.setdefault(rec["key"], []).append((i, rec))

    for key, members in buckets.items():
        n, _d, _dt, metric, block, use_kernels, interpret, has_warm = key
        cap = _chunk_cap(n, block, max_queries_per_program)
        for lo in range(0, len(members), cap):
            chunk = members[lo:lo + cap]
            _run_chunk(chunk, n, block, metric, use_kernels, interpret,
                       has_warm, reports)
    return reports


def _run_chunk(chunk, n, block, metric, use_kernels, interpret, has_warm,
               reports):
    import jax.numpy as jnp
    from repro.core.many import solve_many_bucket
    from repro.runtime import faults

    for _i, rec in chunk:
        faults.check_poison(rec["query"].X, "solve_many packed chunk")
    q_real = len(chunk)
    q_pad = _pow2_at_least(q_real)
    Xq = jnp.stack([rec["X"] for _i, rec in chunk]
                   + [chunk[0][1]["X"]] * (q_pad - q_real))
    budgets = np.full(q_pad, 0, np.int32)        # ghost lanes: frozen
    for j, (_i, rec) in enumerate(chunk):
        budgets[j] = rec["budget"]
    if has_warm:
        bw = _pow2_at_least(max(rec["warm"].size for _i, rec in chunk))
        bw = min(bw, block)
        warm = np.zeros((q_pad, bw), np.int32)
        warm_valid = np.zeros((q_pad, bw), bool)
        for j, (_i, rec) in enumerate(chunk):
            w = rec["warm"][:bw]
            warm[j, :w.size] = w
            warm_valid[j, :w.size] = True
    else:
        warm = np.zeros((q_pad, 1), np.int32)
        warm_valid = np.zeros((q_pad, 1), bool)

    m, e_int, n_comp, n_rounds, live, lo_b = solve_many_bucket(
        Xq, warm, warm_valid, budgets, block=block, metric=metric,
        use_kernels=use_kernels, interpret=interpret, has_warm=has_warm)

    nm1 = max(n - 1, 1)
    total = float(n_comp[:q_real].sum())
    batch_info = {"n_queries": q_real, "q_padded": q_pad - q_real,
                  "elements_total": total,
                  "padding_elements": float(n_comp[q_real:].sum())}
    for j, (i, rec) in enumerate(chunk):
        certified = bool(live[j] == 0) and int(m[j]) >= 0
        ci = (0.0 if certified
              else float(e_int[j] - lo_b[j]) * n / nm1 / 2.0)
        reports[i] = SolveReport(
            indices=np.asarray([m[j]], np.int64),
            # same association as the engine's e_paper = e_cl * n / (n-1)
            # so the scaled energy is bit-identical, not just close
            energies=np.asarray([float(e_int[j]) * n / nm1], np.float64),
            certified=certified,
            elements_computed=float(n_comp[j]),
            n_distances=int(n_comp[j]) * n,
            n_rounds=int(n_rounds[j]),
            ci=ci,
            plan=_bucket_plan(rec, q_real, q_pad - q_real),
            extras={"batch": dict(batch_info),
                    "lower_bound": float(lo_b[j]) * n / nm1},
        )
        if rec["query"].trace is not None:
            _trace_lane(rec["query"].trace, j, n, metric, reports[i],
                        int(live[j]))


def _trace_lane(spec, lane, n, metric, report, survivors):
    """Per-lane trace for a packed ``solve_many`` query: the packed
    engine has no per-lane segment boundaries (all lanes advance in one
    jitted program), so the lane trace is the honest three-event
    summary — begin, one ``lane`` event, end."""
    from repro.obs.trace import resolve_trace
    tracer = resolve_trace(spec)
    tracer.start_session()
    tracer.begin(engine="batched", n=n, metric=metric)
    tracer.event("lane", lane=lane, survivors=survivors,
                 elements=int(report.elements_computed))
    tracer.end(engine="batched", index=int(report.indices[0]),
               energy=float(report.energies[0]),
               elements=int(report.elements_computed),
               rounds=int(report.n_rounds),
               certified=bool(report.certified),
               halt_reason="converged" if report.certified else "budget")
    tracer.close()
    report.extras["obs"] = {"trace": tracer.describe()}
