"""First-class Metric registry — the single capability source (DESIGN.md §10).

Every engine in the repo dispatches on a metric *name*; this module owns
what those names mean. A :class:`Metric` is a registered dataclass
carrying the dense pairwise distance function plus the capability flags
the planner and the engines consult:

* ``has_triangle`` — the triangle inequality holds, so trimed's
  elimination bound (``E(j) >= |E(i) - d(i, j)|``, paper Eq. 4/5) is a
  valid lower bound and the exact bound-driven engines are admissible.
  Non-triangle metrics (``sqeuclidean``, ``cosine``) can only be served
  exactly by the quadratic scan, or approximately by the sampling
  bandit (which needs no bounds).
* ``kernel`` — the Pallas distance tile (``kernels/pairwise._dist_tile``)
  supports the metric, so the fused-round / sampled-column kernels can
  run it on device.
* ``fused_round_fn`` / ``fused_masked_round_fn`` — optional Pallas
  kernel hooks: drop-in replacements for a whole engine round (see
  ``repro.kernels.ops.fused_round`` / ``fused_masked_round``). Resolved
  lazily so importing the registry never imports the kernel stack.

User metrics are first-class: :func:`register_metric` makes a new name
admissible everywhere its capabilities allow — the host oracle, the
dense ``pairwise`` path, and every engine built on them — without
touching any ``repro`` internals. Validation error messages come from
one place (:func:`require_metric`), so every engine reports admissible
metrics identically.

**Vector-backed vs oracle-backed metrics.** A *vector-backed* metric is
a ``pairwise_fn`` over row coordinates — the common case, and what every
dense engine consumes. An *oracle-backed* metric has no pairwise
formula: distances come from an oracle object passed as ``X`` (anything
with ``.row(i)`` and ``.n``), and the metric name exists so the planner
can route to the engine that knows how to drive that oracle. The
built-in ``"graph"`` metric is the worked example: distances are
shortest-path lengths answered by ``repro.core.graph.GraphOracle``
(device Bellman-Ford sweeps + host Dijkstra), so its registered
``pairwise_fn`` *raises* with a pointer to the oracle — calling it with
vector rows is always a routing bug, and the registry keeps that error
in one place. Register your own oracle-backed metric the same way:
``register_metric("mymetric", raising_fn, has_triangle=...)`` plus an
oracle class with ``.row``/``.n`` — the ``sequential``/``scan`` engines
drive any such oracle as-is (see README "Bring your own metric").

**`has_triangle` semantics for non-metric bounds.** ``has_triangle``
does not promise the engines use the metric axioms directly — it
promises *valid lower bounds exist* for trimed's elimination test
(``E(j) >= |E(i) - d(i, j)|``). For vector metrics that is the triangle
inequality itself. For ``"graph"`` it is the landmark (ALT) bound
``d(i, j) >= max_l |d(l, i) - d(l, j)|`` (DESIGN.md §16) — derived
*from* the triangle inequality of shortest-path length, but evaluated
without ever computing ``d(i, j)``. Either way the contract the flag
makes is the same: every bound the engines fold is a true lower bound,
so elimination is exact. Set it only when you can prove that.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

__all__ = [
    "Metric",
    "available_metrics",
    "get_metric",
    "register_metric",
    "require_metric",
    "unregister_metric",
]


@dataclass(frozen=True)
class Metric:
    """A registered distance metric and its engine capabilities."""
    name: str
    pairwise_fn: Callable               # (a: (A,d), b: (B,d)) -> (A,B) dists
    has_triangle: bool = False          # triangle-bound elimination valid
    kernel: bool = False                # Pallas distance tile exists
    fused_round_fn: Callable | None = None         # kernels.ops.fused_round-like
    fused_masked_round_fn: Callable | None = None  # fused_masked_round-like
    description: str = ""


_REGISTRY: dict[str, Metric] = {}
_BUILTIN_NAMES = ("l2", "sqeuclidean", "l1", "cosine", "graph")


def register_metric(
    name,
    pairwise_fn: Callable | None = None,
    *,
    has_triangle: bool = False,
    kernel: bool = False,
    fused_round_fn: Callable | None = None,
    fused_masked_round_fn: Callable | None = None,
    description: str = "",
    overwrite: bool = False,
) -> Metric:
    """Register a metric under ``name`` (or pass a ready :class:`Metric`).

    ``pairwise_fn(a, b)`` must return the dense ``(A, B)`` distance block
    for ``(A, d)`` / ``(B, d)`` operands (jnp-traceable; it runs inside
    jitted engine rounds). Set ``has_triangle=True`` only if the metric
    genuinely satisfies the triangle inequality — the exact engines'
    correctness rests on it. Returns the registered :class:`Metric`.
    """
    if isinstance(name, Metric):
        m = name
    else:
        if pairwise_fn is None:
            raise ValueError("register_metric: pairwise_fn is required")
        m = Metric(str(name), pairwise_fn, has_triangle=bool(has_triangle),
                   kernel=bool(kernel), fused_round_fn=fused_round_fn,
                   fused_masked_round_fn=fused_masked_round_fn,
                   description=description)
    if not overwrite and m.name in _REGISTRY:
        raise ValueError(
            f"register_metric: metric {m.name!r} is already registered "
            "(pass overwrite=True to replace it)")
    _REGISTRY[m.name] = m
    return m


def unregister_metric(name: str) -> None:
    """Remove a user-registered metric. Built-ins cannot be removed."""
    if name in _BUILTIN_NAMES:
        raise ValueError(f"unregister_metric: {name!r} is a built-in metric")
    _REGISTRY.pop(name, None)


def available_metrics(require_triangle: bool = False,
                      require_kernel: bool = False) -> tuple[str, ...]:
    """Sorted names of registered metrics matching the capability filter."""
    return tuple(sorted(
        name for name, m in _REGISTRY.items()
        if (m.has_triangle or not require_triangle)
        and (m.kernel or not require_kernel)))


def get_metric(name: str) -> Metric:
    """Look up a registered metric; canonical error for unknown names."""
    return require_metric(name)


def require_metric(name: str, need_triangle: bool = False,
                   caller: str | None = None) -> Metric:
    """The one validation gate every engine uses: resolve ``name`` and
    (optionally) demand triangle-inequality support, with the admissible
    set reported from the registry. All metric errors in the repo have
    this shape."""
    prefix = f"{caller}: " if caller else ""
    m = _REGISTRY.get(name)
    if m is None:
        raise ValueError(
            f"{prefix}unknown metric {name!r}; registered metrics: "
            f"{list(available_metrics())}")
    if need_triangle and not m.has_triangle:
        raise ValueError(
            f"{prefix}metric {name!r} does not satisfy the triangle "
            "inequality required for exact bound-driven elimination; "
            f"admissible metrics: {list(available_metrics(True))}")
    return m


# ---------------------------------------------------------------------------
# built-ins — implementations live in repro.core.distances / repro.kernels;
# resolved lazily so this module stays import-cycle-free.
# ---------------------------------------------------------------------------
def _builtin_pairwise(name):
    def pw(a, b):
        from repro.core.distances import pairwise
        return pairwise(a, b, name)
    pw.__name__ = f"pairwise_{name}"
    pw.__qualname__ = pw.__name__
    return pw


def _lazy_kernel_hook(attr):
    """One stable callable per hook (jit-static identity), resolving the
    Pallas op on first call."""
    def hook(*args, **kw):
        from repro.kernels import ops
        return getattr(ops, attr)(*args, **kw)
    hook.__name__ = attr
    hook.__qualname__ = attr
    return hook


_FUSED_ROUND = _lazy_kernel_hook("fused_round")
_FUSED_MASKED_ROUND = _lazy_kernel_hook("fused_masked_round")

register_metric(Metric(
    "l2", _builtin_pairwise("l2"), has_triangle=True, kernel=True,
    fused_round_fn=_FUSED_ROUND, fused_masked_round_fn=_FUSED_MASKED_ROUND,
    description="Euclidean distance"))
register_metric(Metric(
    "l1", _builtin_pairwise("l1"), has_triangle=True, kernel=True,
    fused_round_fn=_FUSED_ROUND, fused_masked_round_fn=_FUSED_MASKED_ROUND,
    description="Manhattan distance"))
register_metric(Metric(
    "sqeuclidean", _builtin_pairwise("sqeuclidean"), has_triangle=False,
    kernel=True, description="squared Euclidean (violates triangle)"))
register_metric(Metric(
    "cosine", _builtin_pairwise("cosine"), has_triangle=False, kernel=False,
    description="1 - cosine similarity (violates triangle)"))


def _graph_pairwise(a, b):
    """Oracle-backed: there is no coordinate formula for shortest-path
    distance, so reaching this function is a routing error by
    construction — the canonical message points at the oracle."""
    raise ValueError(
        "metric 'graph' is oracle-backed: distances are shortest-path "
        "lengths answered by a repro.core.graph.GraphOracle, not a "
        "formula over vector rows. Pass the oracle as the query input — "
        "solve(MedoidQuery(GraphOracle(adj, n), metric='graph'))")


# has_triangle=True: shortest-path length on an undirected non-negative
# graph is a true metric, and the engine's landmark (ALT) bounds
# |d(l,i) - d(l,j)| are valid elimination lower bounds (DESIGN.md §16).
register_metric(Metric(
    "graph", _graph_pairwise, has_triangle=True, kernel=False,
    description="shortest-path length on a GraphOracle (oracle-backed)"))
