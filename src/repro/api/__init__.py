"""repro.api — the single public surface (DESIGN.md §10).

One declarative entry point over every engine in the repo::

    from repro.api import MedoidQuery, solve

    report = solve(MedoidQuery(X))                      # planner picks
    plan = solve(MedoidQuery(X), explain=True)          # why it picked
    report = solve(MedoidQuery(X, budget=200.0))        # anytime hybrid
    report = solve(MedoidQuery(X, k=16))                # K-medoids
    report = solve(MedoidQuery(X), plan="pipelined")    # power override

plus the first-class :class:`Metric` registry (``register_metric``)
that owns metric capabilities for every engine. The legacy entrypoints
(``trimed_sequential`` / ``trimed_block`` / ``trimed_pipelined`` /
``batched_medoids`` / ``batched_medoids_pipelined`` / ``bandit_medoid``
/ ``trimed_topk`` / ``medoid``) are deprecated shims over this module.
"""
from __future__ import annotations

from .metrics import (Metric, available_metrics, get_metric,
                      register_metric, require_metric, unregister_metric)
from .query import MedoidQuery, SolveReport
from .planner import ENGINES, Plan, plan_query, resolve_update_plan, solve
from .batch import solve_many

__all__ = [
    "ENGINES",
    "MedoidIndex",
    "MedoidQuery",
    "Metric",
    "Plan",
    "SlidingWindowIndex",
    "SolveReport",
    "available_metrics",
    "get_metric",
    "plan_query",
    "register_metric",
    "require_metric",
    "resolve_update_plan",
    "solve",
    "solve_many",
    "unregister_metric",
]

_LAZY = {"MedoidIndex": "repro.stream.index",
         "SlidingWindowIndex": "repro.stream.window"}


def __getattr__(name: str):
    # the streaming index imports api.metrics/api.planner, so exporting
    # it here eagerly would be circular — resolve on first access
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _warn_legacy(name: str, hint: str = "") -> None:
    """Deprecation notice emitted by every legacy entrypoint shim. The
    message prefix is pinned: the tier-1 suite escalates it to an error
    when raised from ``repro.*`` internals (pytest.ini), guaranteeing no
    in-repo code still calls the shims."""
    from repro.obs.logs import repro_warn
    repro_warn(
        f"repro legacy entrypoint {name}() is deprecated; build a "
        f"repro.api.MedoidQuery and call repro.api.solve{hint}",
        DeprecationWarning, logger="repro.api", stacklevel=3)
