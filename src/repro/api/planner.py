"""Query planner: `MedoidQuery` -> `Plan` -> engine -> `SolveReport`.

``plan_query`` inspects N, the metric's registered capabilities, the
budget/mode, the input kind (array vs host oracle) and the device policy
to choose among the engines the repo has grown: the paper-faithful host
``sequential``, the device ``block`` round (DESIGN.md §2), the
survivor-compacted ``pipelined`` engine (§4), the multi-cluster
``batched``/``batched_pipelined`` engines (§3/§4), the multi-device
``sharded``/``batched_sharded`` engines (§11), the sampling
``bandit`` and the bandit+finisher ``hybrid`` (§9), the ``kmedoids``
driver (§5), host ``topk`` ranking (§6), the quadratic ``scan``
safety net for exact queries on non-triangle metrics (itself sharded
under ``device_policy="sharded"``), and the ``graph`` engine (§16) —
batched device Bellman-Ford sweeps with landmark elimination bounds for
``metric="graph"`` queries over a ``repro.core.graph.GraphOracle``
(directed oracles reroute to the host sequential sweeps: shortest-path
asymmetry breaks the landmark bounds).

``solve(query)`` executes the plan; ``solve(query, explain=True)``
returns the :class:`Plan` (engine + reasons) without computing anything;
``solve(query, plan=...)`` overrides the planner for power users (a
:class:`Plan` or an engine name from :data:`ENGINES`).

Thresholds (pinned by ``tests/test_api.py`` golden tests): at
``N <= SMALL_N`` host sequential wins (nothing to amortise a jit compile
against); up to ``BLOCK_N`` the plain block round is the simplest device
program; above it survivor compaction pays (the paper's Theorem 3.2
regime); multi-cluster searches switch to the compaction ladder above
``BATCHED_PIPELINE_N``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np

from .metrics import Metric, get_metric, require_metric
from .query import MedoidQuery, SolveReport

__all__ = ["Plan", "ENGINES", "plan_query", "solve", "resolve_update_plan"]

SMALL_N = 256               # <=: host sequential (no jit warm-up to pay off)
BLOCK_N = 2048              # <=: block round; above: survivor compaction pays
BATCHED_PIPELINE_N = 4096   # multi-cluster: ladder pays above this
SHARDED_N = 4096            # auto-shard above this when >1 device is up

ENGINES = ("sequential", "block", "pipelined", "sharded", "batched",
           "batched_pipelined", "batched_sharded", "bandit", "hybrid",
           "kmedoids", "topk", "scan", "graph")


@dataclass(frozen=True)
class Plan:
    """A chosen engine plus the planner's reasoning and derived params.

    ``cost_estimate`` is the predicted element count (computed rows, the
    unified cost axis every engine reports as ``elements_computed``) —
    what an admission scheduler budgets against *before* running
    anything. Calibrated against engine-reported accounting on uniform
    data (see ``_estimate_cost``); pinned within 2x on the planner
    golden grid by ``tests/test_api.py``."""
    engine: str
    reasons: tuple = ()
    params: dict = field(default_factory=dict)
    cost_estimate: float | None = None

    def explain(self) -> str:
        return f"engine={self.engine}: " + "; ".join(self.reasons)


def _is_oracle(X) -> bool:
    return hasattr(X, "row") and hasattr(X, "n")


def _query_n(q: MedoidQuery) -> int:
    return int(q.X.n) if _is_oracle(q.X) else int(np.shape(q.X)[0])


def _resolve_kernels(q: MedoidQuery, m: Metric, reasons: list,
                     need_hook: str | None = None) -> bool:
    """``use_kernels=None`` means auto: Pallas only where a real Mosaic
    backend exists and the metric has what the chosen engine needs — a
    distance tile, plus the fused-round hook named by ``need_hook`` for
    the engines whose kernel path is a whole-round replacement (on CPU
    the interpret path is strictly slower than jnp, so auto stays off).
    An explicit ``use_kernels=True`` is honoured as-is (the engine raises
    its canonical error if the metric lacks the hook)."""
    if q.use_kernels is not None:
        return bool(q.use_kernels)
    import jax
    auto = jax.default_backend() == "tpu" and m.kernel
    if auto and need_hook is not None and getattr(m, need_hook) is None:
        auto = False
    if auto:
        reasons.append("use_kernels auto-on: TPU backend + kernel-capable "
                       f"metric {m.name!r}")
    return auto


_KERNEL_ENGINES = ("block", "pipelined", "sharded", "batched",
                   "batched_pipelined", "batched_sharded", "kmedoids",
                   "bandit", "hybrid")

_SHARDED_ENGINES = ("sharded", "batched_sharded")


def _device_count() -> int:
    import jax
    return jax.device_count()


def _shard_params(q: MedoidQuery):
    """Resolved (shard count, mesh axis) for a sharded plan: the query's
    mesh if given, else the default 1-axis mesh the executor will build
    (largest REDUCE_CHUNKS divisor <= the local device count). An
    explicit mesh goes through the engine's own ``_resolve_mesh`` so a
    plan the engine would reject (missing axis, axis size not dividing
    the reduction grid) fails here, at planning time, with the same
    error — ``explain=True`` never reports layout params for a geometry
    that cannot execute."""
    from repro.core.distributed import AXIS, _resolve_mesh, shard_count_for
    axis = q.engine_opts.get("axis", AXIS)
    if q.mesh is not None:
        return _resolve_mesh(q.mesh, axis)[1], axis
    return shard_count_for(_device_count()), axis


def _record_block_clamp(q: MedoidQuery, params, reasons, n_shards,
                        requested):
    """Surface the sharded engines' round-width clamp (block > per-shard
    column count) in the plan, so the deviation from the single-device
    pivot sequence is visible before run time, not silent."""
    from repro.core.distributed import effective_block
    n = int(np.shape(q.X)[0])
    eff = effective_block(n, n_shards, requested)
    if eff < min(requested, n):
        params["block_effective"] = eff
        reasons.append(
            f"block={requested} exceeds the per-shard column count: "
            f"round width clamps to {eff} (exact, but pivot sequence "
            "diverges from single-device)")


def _kmedoids_update_params(q: MedoidQuery, reasons: list):
    """The K-medoids medoid-update derivation, shared by plan_query and
    the ``plan=`` override path. ``mode="anytime"`` with no nested
    update query means the paper's §5 relaxation (the budgeted bandit
    update); a top-level ``budget`` is rejected as ambiguous.
    ``device_policy="sharded"`` promotes the exact update engines to the
    sharded multi-cluster engine (DESIGN.md §11) — except on
    non-triangle metrics, where the elimination update is inadmissible
    and the driver's exact fallback is the host scan: the plan records
    ``"scan"`` honestly (with a reason) instead of claiming a sharded
    update the driver would silently downgrade."""
    if q.budget is not None:
        raise ValueError(
            "solve: a top-level budget on a K-medoids query is ambiguous "
            "(it is per medoid-update, not total); express it via a "
            "nested update query — update=MedoidQuery(None, "
            "mode='anytime', budget=...) (budget = per-cluster elements "
            "as a fraction of cluster size)")
    update = q.update
    if update is None and q.mode == "anytime":
        update = MedoidQuery(None, mode="anytime")
    mu, overrides = resolve_update_plan(update, q.metric)
    if q.device_policy == "sharded":
        if mu == "bandit":
            raise ValueError(
                "solve: device_policy='sharded' does not support the "
                "bandit medoid-update (the sampling race is single-"
                "device); drop the anytime update or the sharded policy")
        if mu in ("trimed", "pipelined"):
            mu = "sharded"
    if mu == "sharded" and not get_metric(q.metric).has_triangle:
        mu = "scan"
        from repro.obs.logs import get_logger
        get_logger("repro.api.planner").warning(
            "medoid-update: non-triangle metric %r falls back to the "
            "exact host-scan update (single-device)", q.metric)
        reasons.append(
            f"medoid-update: non-triangle metric {q.metric!r} cannot "
            "use the sharded elimination update; exact host-scan update "
            "runs single-device")
    return mu, overrides


def _derive_params(query: MedoidQuery, engine: str, reasons: list,
                   m: Metric) -> dict:
    """Engine-dependent derived params — one copy for both the planner
    and the ``plan=`` string override."""
    params: dict[str, Any] = {}
    if engine in _KERNEL_ENGINES:
        # block/batched/kmedoids kernel paths are whole-round hook
        # replacements; pipelined/sharded/bandit only need the distance
        # tile (the sharded engine reuses the masked kernels)
        need_hook = {"block": "fused_round_fn",
                     "batched": "fused_masked_round_fn",
                     "kmedoids": "fused_masked_round_fn"}.get(engine)
        params["use_kernels"] = _resolve_kernels(query, m, reasons,
                                                 need_hook)
    if engine in _SHARDED_ENGINES or (
            engine == "scan" and query.device_policy == "sharded"):
        n_shards, axis = _shard_params(query)
        params["n_shards"] = n_shards
        params["mesh_axis"] = axis
        if engine == "scan":
            params["sharded"] = True
        elif not _is_oracle(query.X):
            _record_block_clamp(query, params, reasons, n_shards,
                                int(query.block))
    if engine == "kmedoids":
        mu, overrides = _kmedoids_update_params(query, reasons)
        params["medoid_update"] = mu
        params["update_overrides"] = overrides
        if mu == "sharded":
            n_shards, axis = _shard_params(query)
            params["n_shards"] = n_shards
            params["mesh_axis"] = axis
            if not _is_oracle(query.X):
                _record_block_clamp(query, params, reasons, n_shards,
                                    int(overrides.get("block",
                                                      query.block)))
    return params


# ---------------------------------------------------------------------------
# cost model: predicted computed-row count per engine (the admission
# currency of serve.MedoidServer). The elimination engines follow the
# paper's sub-quadratic regime — O(sqrt(N)) computed rows with a
# dimension-dependent constant that saturates at N once the triangle
# bound stops eliminating (high intrinsic dimension). Constants are
# calibrated against engine-reported `elements_computed` on uniform
# data; the calibration test in tests/test_api.py pins them within 2x
# across the planner golden grid (exactly for the scan engine, whose
# count is data-independent).
# ---------------------------------------------------------------------------
_COST_SEQ = 2.4       # sequential / topk / batched multiplier (x 2^(d/2) sqrt(N))
_COST_BLOCK = 3.0     # block-round engines pay partial final blocks
_COST_ANYTIME = 5.5   # uncapped bandit race + finisher
_COST_GRAPH = 6.0     # graph sweeps: spatial networks sit in the d~2 regime
_KMED_BANDIT_FRAC = 0.125   # bandit medoid-update: default sampled fraction


def _estimate_cost(q: MedoidQuery, engine: str, params: dict) -> float:
    n = params.get("n") or _query_n(q)
    if _is_oracle(q.X) or np.ndim(q.X) < 2:
        d = 3                        # oracle rows: assume low-dim regime
    else:
        d = int(np.shape(q.X)[1])
    df = 2.0 ** (min(d, 64) / 2.0)
    block = max(1, min(int(q.block), n))
    sqn = float(np.sqrt(n))

    def elim(c, m=n):
        # c * 2^(d/2) * sqrt(m) computed rows, at least one block, at
        # most the whole domain (elimination can only save, never cost)
        return float(min(n, max(c * df * np.sqrt(m), min(block, n))))

    if engine == "scan":
        return float(n)              # exact: one row sum per element
    if engine == "graph":
        # landmark sweeps + elimination rounds; spatial networks have
        # intrinsic dimension ~2, so no 2^(d/2) blow-up term
        nl = float(q.engine_opts.get("n_landmarks", 8))
        return float(min(n, max(nl + _COST_GRAPH * sqn, nl + block)))
    if engine == "sequential":
        return float(min(n, max(_COST_SEQ * df * sqn, 1.0)))
    if engine in ("block", "pipelined", "sharded"):
        return elim(_COST_BLOCK)
    if engine == "topk":
        k = int(q.topk)
        return float(min(n, (1.0 + k / 10.0) * _COST_SEQ * df * sqn))
    if engine in ("batched", "batched_pipelined", "batched_sharded"):
        return elim(_COST_SEQ, n * int(q.k))
    if engine == "kmedoids":
        k = int(q.k)
        n_iter = int(q.n_iter)
        if params.get("medoid_update") == "bandit":
            overrides = params.get("update_overrides") or {}
            frac = float(overrides.get("bandit_budget",
                                       _KMED_BANDIT_FRAC))
            return float(n_iter * max(k, frac * n))
        return float(n_iter * max(k, elim(_COST_SEQ, n * k)))
    if engine in ("bandit", "hybrid"):
        if q.budget is not None:
            return float(min(n, max(float(q.budget), min(block, n))))
        return elim(_COST_ANYTIME)
    return float(n)


def plan_query(query: MedoidQuery) -> Plan:
    """Choose an engine for ``query`` (pure decision — nothing executes).
    Raises the registry's canonical error for unknown metrics and for
    exact bound-driven tasks on non-triangle metrics with no fallback."""
    q = query
    reasons: list[str] = []
    m = require_metric(q.metric, caller="solve")
    n = _query_n(q)
    oracle = _is_oracle(q.X)
    anytime = q.mode == "anytime" or q.budget is not None
    params: dict[str, Any] = {"n": n}

    sharded_req = q.device_policy == "sharded"
    if sharded_req:
        if oracle:
            raise ValueError(
                "solve: device_policy='sharded' needs a vector array "
                "input (host oracles cannot be device-sharded)")
        if anytime:
            raise ValueError(
                "solve: device_policy='sharded' does not combine with "
                "anytime/budgeted mode (the bandit race is single-"
                "device); drop one of the two")
        if q.topk is not None:
            raise ValueError(
                "solve: device_policy='sharded' does not support topk "
                "(the ranking engine is host-side)")
    auto_shard = (q.device_policy == "auto" and not oracle
                  and n > SHARDED_N and _device_count() > 1)

    if m.name == "graph":
        # oracle-backed metric: distances come from a GraphOracle's SSSP
        # sweeps, so the input must BE the oracle and the task must be a
        # single-medoid solve (the other kinds consume vector columns)
        if not (oracle and hasattr(q.X, "adj")):
            raise ValueError(
                "solve: metric 'graph' is oracle-backed — pass a "
                "repro.core.graph.GraphOracle as the query input: "
                "solve(MedoidQuery(GraphOracle(adj, n), metric='graph'))")
        if q.k is not None or q.assignments is not None \
                or q.topk is not None:
            raise ValueError(
                "solve: metric 'graph' supports single-medoid queries "
                "only (no k/assignments/topk — those engines consume "
                "vector columns, not sweep rows)")
        if anytime:
            raise ValueError(
                "solve: anytime/budgeted mode is not supported for "
                "metric 'graph' (the bandit samples vector columns); "
                "drop the budget/mode")
        if getattr(q.X, "directed", False):
            engine = "sequential"
            reasons.append(
                "metric 'graph' on a directed oracle: shortest-path "
                "asymmetry breaks the landmark bounds, so the device "
                "sweep engine is inadmissible; paper-faithful host "
                "sequential sweeps (the D-Sensor protocol)")
        else:
            engine = "graph"
            reasons.append(
                f"metric 'graph', N={n}: batched device Bellman-Ford "
                "sweeps with landmark (ALT) elimination bounds "
                "(DESIGN.md §16)")
    elif q.assignments is not None:
        if anytime:
            raise ValueError(
                "solve: anytime per-cluster queries are not supported "
                "standalone; use k= with an anytime nested update query")
        require_metric(q.metric, need_triangle=True, caller="solve")
        if sharded_req or auto_shard:
            reasons.append(
                "multi-cluster exact, "
                + ("device_policy='sharded'" if sharded_req else
                   f"N={n} > {SHARDED_N} with {_device_count()} devices")
                + f": column-sharded batched engine over "
                  f"{_shard_params(q)[0]} shard(s) (DESIGN.md §11)")
            engine = "batched_sharded"
        elif n > BATCHED_PIPELINE_N:
            reasons.append(f"multi-cluster exact, N={n} > "
                           f"{BATCHED_PIPELINE_N}: compaction ladder pays")
            engine = "batched_pipelined"
        else:
            reasons.append(f"multi-cluster exact, N={n} <= "
                           f"{BATCHED_PIPELINE_N}: plain batched rounds")
            engine = "batched"
    elif q.k is not None:
        engine = "kmedoids"
        # validates + names the engine for the reason line; the params
        # (and any downgrade reason) are derived once in _derive_params
        mu, _ = _kmedoids_update_params(q, [])
        reasons.append(f"K-medoids clustering (k={q.k}); medoid-update "
                       f"engine {mu!r} from the nested update query"
                       if q.update is not None or q.mode == "anytime" else
                       f"K-medoids clustering (k={q.k}); "
                       f"medoid-update engine {mu!r}")
    elif anytime:
        if oracle:
            raise ValueError(
                "solve: anytime mode needs a vector array input (the "
                "bandit samples columns); got a host oracle")
        if q.topk is not None:
            raise ValueError("solve: anytime top-k is not supported")
        if m.has_triangle:
            engine = "hybrid"
            reasons.append(
                "anytime/budgeted + triangle metric: bandit race ordering "
                "the field, exact trimed finisher settling it")
        else:
            engine = "bandit"
            reasons.append(
                f"anytime/budgeted + non-triangle metric {m.name!r}: pure "
                "sampling race (no exact finisher available)")
    elif q.topk is not None:
        if m.has_triangle:
            engine = "topk"
            reasons.append("exact top-k ranking: host bound machinery "
                           "(paper §6 extension)")
        else:
            engine = "scan"
            reasons.append(f"exact top-k on non-triangle metric "
                           f"{m.name!r}: quadratic scan is the only "
                           "exact path")
    elif not m.has_triangle:
        # the scan executor serves oracle inputs too (row sweep); under
        # device_policy='sharded' it row-shards across the mesh (§11)
        engine = "scan"
        reasons.append(
            f"exact medoid on non-triangle metric {m.name!r}: elimination "
            "bounds invalid, quadratic scan is the only exact path"
            + (" (row-sharded across the mesh)" if sharded_req else ""))
    elif sharded_req:
        engine = "sharded"
        reasons.append("device_policy='sharded': column-sharded pipelined "
                       "engine (DESIGN.md §11), bit-identical to "
                       "single-device")
    elif oracle:
        engine = "sequential"
        reasons.append("host oracle input: paper-faithful sequential "
                       "algorithm (any oracle metric)")
    elif q.device_policy == "host":
        engine = "sequential"
        reasons.append("device_policy='host': paper-faithful sequential")
    elif n <= SMALL_N and q.device_policy != "device":
        engine = "sequential"
        reasons.append(f"N={n} <= {SMALL_N}: host sequential beats jit "
                       "warm-up")
    elif n <= BLOCK_N:
        engine = "block"
        reasons.append(f"N={n} <= {BLOCK_N}: block-synchronous round")
    elif auto_shard:
        engine = "sharded"
        reasons.append(f"N={n} > {SHARDED_N} with {_device_count()} "
                       "devices up: column-sharded pipelined engine "
                       f"over {_shard_params(q)[0]} shard(s) "
                       "(DESIGN.md §11)")
    else:
        engine = "pipelined"
        reasons.append(f"N={n} > {BLOCK_N}: survivor-compacted pipelined "
                       "engine (1 X-stream/round)")

    engine = _apply_deadline_policy(q, engine, reasons)
    params.update(_derive_params(q, engine, reasons, m))
    return Plan(engine, tuple(reasons), params,
                cost_estimate=_estimate_cost(q, engine, params))


# engines whose drivers check the deadline at host-visible boundaries
# (sequential: per element; pipelined: per segment — DESIGN.md §13)
_DEADLINE_ENGINES = ("sequential", "pipelined")
# engines that reroute to a deadline-capable one with no semantic change
# (exact single-medoid either way; only cost/pivot-sequence differ)
_DEADLINE_REROUTE = {"block": "pipelined", "sharded": "pipelined"}


def _apply_deadline_policy(q: MedoidQuery, engine: str,
                           reasons: list) -> str:
    """``deadline_s`` needs an engine with host-visible progress: a
    single jitted while_loop (block) or a multi-device program (sharded)
    cannot be interrupted mid-flight, so those reroute to the segmented
    pipelined engine; task kinds with no incumbent-so-far semantics
    (clustering, top-k, batched, anytime) are rejected at plan time —
    a *blown* deadline, by contrast, never raises."""
    if q.deadline_s is None:
        return engine
    if engine in _DEADLINE_REROUTE:
        new = _DEADLINE_REROUTE[engine]
        reasons.append(
            f"deadline_s={q.deadline_s}: {engine} runs as one "
            f"uninterruptible program; rerouted to {new} (segment-"
            "granular deadline checks)")
        return new
    if engine not in _DEADLINE_ENGINES:
        raise ValueError(
            f"solve: deadline_s is not supported for engine {engine!r} "
            "(no incumbent-so-far to return at the deadline); supported: "
            f"{_DEADLINE_ENGINES} (+ {sorted(_DEADLINE_REROUTE)} via "
            "rerouting)")
    return engine


def resolve_update_plan(update, metric: str):
    """Map a K-medoids nested medoid-update query (or a legacy string)
    onto ``(medoid_update, option_overrides)`` for the kmedoids driver.

    * ``None`` -> the default exact engine (``"trimed"``; the driver
      falls back to ``"scan"`` for non-triangle metrics);
    * a string -> passed through (legacy spelling);
    * a :class:`MedoidQuery` template (its ``X``/``assignments`` are
      ignored) -> ``mode="anytime"``/``budget`` selects the budgeted
      bandit update (the paper's §5 relaxation; ``budget`` is the
      per-cluster element budget as a fraction of cluster size),
      otherwise the exact engine, honouring ``engine_opts["engine"]``
      (``"trimed" | "pipelined" | "scan"``) plus the template's
      ``block`` / ``block_schedule`` / ``use_kernels``.
    """
    if update is None:
        return "trimed", {}
    if isinstance(update, str):
        return update, {}
    if not isinstance(update, MedoidQuery):
        raise ValueError(
            "medoid_update must be a string or a MedoidQuery template, "
            f"got {type(update).__name__}")
    # fields the kmedoids driver cannot thread through must not be
    # silently dropped — reject them loudly
    unsupported = [
        name for name, ok in (
            ("k", update.k is None),
            ("assignments", update.assignments is None),
            ("topk", update.topk is None),
            ("warm_idx", update.warm_idx is None),
            ("delta", update.delta == 0.01),
            ("seed", update.seed == 0),
            ("mesh", update.mesh is None),
            ("device_policy", update.device_policy == "auto"),
            ("engine_opts",
             set(update.engine_opts) <= {"engine"}),
        ) if not ok]
    if unsupported:
        raise ValueError(
            "nested update query: the K-medoids driver does not support "
            f"overriding {unsupported} in the medoid-update template; "
            "supported fields: mode/budget, block, block_schedule, "
            "use_kernels, engine_opts={'engine': ...}")
    import dataclasses
    block_default = next(f.default for f in dataclasses.fields(MedoidQuery)
                         if f.name == "block")
    overrides: dict[str, Any] = {}
    if int(update.block) != block_default:
        overrides["block"] = int(update.block)
    if update.block_schedule is not None:
        overrides["block_schedule"] = update.block_schedule
    if update.use_kernels is not None:
        overrides["use_kernels"] = bool(update.use_kernels)
    mu = update.engine_opts.get("engine")
    if update.mode == "anytime" or update.budget is not None:
        if mu not in (None, "bandit"):
            raise ValueError(
                f"nested update query: mode='anytime' conflicts with "
                f"engine={mu!r}")
        mu = "bandit"
        if update.budget is not None:
            overrides["bandit_budget"] = float(update.budget)
    elif mu is None:
        mu = "trimed"
    elif mu not in ("trimed", "pipelined", "sharded", "scan"):
        raise ValueError(
            "nested update query: engine must be 'trimed', 'pipelined', "
            f"'sharded', 'scan' or 'bandit', got {mu!r}")
    get_metric(metric)          # canonical unknown-metric error
    return mu, overrides


# ---------------------------------------------------------------------------
# executors — engine imports are deferred so repro.api never drags the
# engine stack in at import time (and stays cycle-free with repro.core)
# ---------------------------------------------------------------------------
def _report_from_medoid(r, extras=None) -> SolveReport:
    # uncertified engines that tracked their live lower bounds report the
    # deterministic bound-gap half-width (the anytime contract, matching
    # solve_many's convention); NaN only when no bound was tracked
    lo = getattr(r, "lo_bound", float("nan"))
    if r.certified:
        ci = 0.0
    elif np.isfinite(lo) and np.isfinite(r.energy):
        ci = max(float(r.energy) - float(lo), 0.0) / 2.0
    else:
        ci = float("nan")
    ex = {"raw": r, **(extras or {})}
    halt = getattr(r, "halt_reason", "")
    if halt:
        ex["halt_reason"] = halt
    if not r.certified and np.isfinite(lo):
        ex["lower_bound"] = float(lo)
    return SolveReport(
        indices=np.asarray([r.index], np.int64),
        energies=np.asarray([r.energy], np.float64),
        certified=bool(r.certified),
        elements_computed=float(r.n_computed),
        n_distances=int(r.n_distances),
        n_rounds=int(r.n_rounds),
        ci=ci,
        extras=ex,
    )


def _run_sequential(q: MedoidQuery, plan: Plan) -> SolveReport:
    from repro.core.trimed import _trimed_sequential
    from repro.runtime import faults
    faults.check_poison(q.X, "sequential engine")
    kw = {}
    if plan.params.get("deadline_ts") is not None:
        kw["deadline_ts"] = plan.params["deadline_ts"]
    r = _trimed_sequential(q.X, seed=q.seed, metric=q.metric,
                           **kw, **q.engine_opts)
    return _report_from_medoid(r)


def _run_block(q: MedoidQuery, plan: Plan) -> SolveReport:
    from repro.core.trimed import _trimed_block
    from repro.runtime import faults
    faults.check_poison(q.X, "block engine")
    opts = dict(q.engine_opts)
    if plan.params.get("use_kernels") and "fused_round_fn" not in opts:
        hook = get_metric(q.metric).fused_round_fn
        if hook is None:
            from .metrics import available_metrics
            hooked = [n for n in available_metrics()
                      if get_metric(n).fused_round_fn is not None]
            raise ValueError(
                f"use_kernels=True: metric {q.metric!r} has no fused-round "
                f"kernel hook; metrics with hooks: {hooked}")
        opts["fused_round_fn"] = hook
    r = _trimed_block(q.X, seed=q.seed, block=q.block, metric=q.metric,
                      block_schedule=q.block_schedule, **opts)
    return _report_from_medoid(r)


def _run_pipelined(q: MedoidQuery, plan: Plan) -> SolveReport:
    from repro.core.pipelined import _trimed_pipelined
    from repro.runtime import faults
    faults.check_poison(q.X, "pipelined engine")
    kw = {}
    if plan.params.get("deadline_ts") is not None:
        kw["deadline_ts"] = plan.params["deadline_ts"]
    if plan.params.get("tracer") is not None:
        kw["trace"] = plan.params["tracer"]
    r = _trimed_pipelined(
        q.X, seed=q.seed, block=q.block, metric=q.metric,
        block_schedule=q.block_schedule,
        use_kernels=bool(plan.params.get("use_kernels")),
        warm_idx=q.warm_idx, **kw, **q.engine_opts)
    return _report_from_medoid(r)


def _sharded_engine_kw(q: MedoidQuery):
    """Split ``engine_opts`` for the sharded executors: ``axis`` names
    the mesh axis, everything else passes through to the engine."""
    opts = dict(q.engine_opts)
    kw = {}
    if "axis" in opts:
        kw["axis"] = opts.pop("axis")
    return kw, opts


def _run_sharded(q: MedoidQuery, plan: Plan) -> SolveReport:
    from repro.core.distributed import _trimed_sharded
    from repro.runtime import faults
    faults.on_shard_entry(int(plan.params.get("n_shards", 1)))
    kw, opts = _sharded_engine_kw(q)
    if plan.params.get("tracer") is not None:
        kw["trace"] = plan.params["tracer"]
    r, per_shard = _trimed_sharded(
        q.X, mesh=q.mesh, block=q.block, metric=q.metric,
        block_schedule=q.block_schedule,
        use_kernels=bool(plan.params.get("use_kernels")), **kw, **opts)
    plan.params["per_shard_elements"] = per_shard.tolist()
    return _report_from_medoid(
        r, extras={"per_shard_elements": per_shard})


def _run_topk(q: MedoidQuery, plan: Plan) -> SolveReport:
    from repro.core.trimed import _trimed_topk
    r = _trimed_topk(q.X, q.topk, seed=q.seed, metric=q.metric,
                     **q.engine_opts)
    return SolveReport(
        indices=np.asarray(r.indices, np.int64),
        energies=np.asarray(r.energies, np.float64),
        certified=True,
        elements_computed=float(r.n_computed),
        n_distances=int(r.n_computed) * _query_n(q),
        n_rounds=0, ci=0.0, extras={"raw": r})


def _run_scan(q: MedoidQuery, plan: Plan) -> SolveReport:
    """Quadratic exact scan — blockwise so the (N, N) matrix never
    materialises (host oracles take a full row sweep); the only exact
    path for non-triangle metrics. Under ``device_policy="sharded"``
    the rows shard across the mesh (DESIGN.md §11) with bit-identical
    results (both paths sum on the fixed reduction grid)."""
    from repro.core.trimed import MedoidResult, TopKResult
    from repro.runtime import faults
    faults.check_poison(q.X, "scan engine")
    if _is_oracle(q.X):
        n = int(q.X.n)
        e = np.array([q.X.row(i).sum() for i in range(n)]) / n
    elif plan.params.get("sharded"):
        from repro.core.distributed import _scan_rowsums_sharded
        kw, opts = _sharded_engine_kw(q)
        sums, per_shard = _scan_rowsums_sharded(q.X, q.metric, mesh=q.mesh,
                                                **kw, **opts)
        n = int(np.shape(q.X)[0])
        plan.params["per_shard_elements"] = per_shard.tolist()
        e = np.asarray(sums, np.float64) / n
    else:
        from repro.core.distances import scan_rowsums
        n = int(np.shape(q.X)[0])
        e = np.asarray(scan_rowsums(q.X, q.metric), np.float64) / n
    scale = n / max(n - 1, 1)
    k = int(q.topk) if q.topk is not None else 1
    order = np.argsort(e, kind="stable")[:k]
    energies = np.asarray(e[order], np.float64) * scale
    if q.topk is not None:
        raw = TopKResult(order.astype(np.int64), energies, n)
    else:
        raw = MedoidResult(int(order[0]), float(energies[0]), n, 1, n * n)
    return SolveReport(
        indices=order.astype(np.int64),
        energies=energies,
        certified=True, elements_computed=float(n),
        n_distances=n * n, n_rounds=1, ci=0.0, extras={"raw": raw})


def _cluster_energies(sums, medoids, assignments, k):
    """Paper-convention per-cluster energies S_k/(v_k - 1); NaN for empty."""
    a = np.asarray(assignments)
    valid = (a >= 0) & (a < k)
    v = np.bincount(a[valid], minlength=k)
    e = np.asarray(sums, np.float64) / np.maximum(v - 1, 1)
    return np.where(np.asarray(medoids) >= 0, e, np.nan)


def _run_batched(q: MedoidQuery, plan: Plan) -> SolveReport:
    from repro.core.batched import _batched_medoids
    opts = dict(q.engine_opts)
    if plan.params.get("use_kernels") and "fused_round_fn" not in opts:
        opts["fused_round_fn"] = get_metric(q.metric).fused_masked_round_fn
    r = _batched_medoids(q.X, q.assignments, q.k, block=q.block,
                         metric=q.metric, warm_idx=q.warm_idx,
                         block_schedule=q.block_schedule, **opts)
    return SolveReport(
        indices=np.asarray(r.medoids, np.int64),
        energies=_cluster_energies(r.sums, r.medoids, q.assignments, q.k),
        certified=True, elements_computed=float(r.n_computed),
        n_distances=int(r.n_distances), n_rounds=int(r.n_rounds),
        ci=0.0, extras={"raw": r})


def _run_batched_pipelined(q: MedoidQuery, plan: Plan) -> SolveReport:
    from repro.core.pipelined import _batched_medoids_pipelined
    r = _batched_medoids_pipelined(
        q.X, q.assignments, q.k, block=q.block, metric=q.metric,
        block_schedule=q.block_schedule,
        use_kernels=bool(plan.params.get("use_kernels")),
        warm_idx=q.warm_idx, **q.engine_opts)
    return SolveReport(
        indices=np.asarray(r.medoids, np.int64),
        energies=_cluster_energies(r.sums, r.medoids, q.assignments, q.k),
        certified=True, elements_computed=float(r.n_computed),
        n_distances=int(r.n_distances), n_rounds=int(r.n_rounds),
        ci=0.0, extras={"raw": r})


def _run_batched_sharded(q: MedoidQuery, plan: Plan) -> SolveReport:
    from repro.core.distributed import _batched_medoids_sharded
    from repro.runtime import faults
    faults.on_shard_entry(int(plan.params.get("n_shards", 1)))
    kw, opts = _sharded_engine_kw(q)
    r, per_shard = _batched_medoids_sharded(
        q.X, q.assignments, q.k, mesh=q.mesh, block=q.block,
        metric=q.metric, block_schedule=q.block_schedule,
        use_kernels=bool(plan.params.get("use_kernels")),
        warm_idx=q.warm_idx, **kw, **opts)
    plan.params["per_shard_elements"] = per_shard.tolist()
    return SolveReport(
        indices=np.asarray(r.medoids, np.int64),
        energies=_cluster_energies(r.sums, r.medoids, q.assignments, q.k),
        certified=True, elements_computed=float(r.n_computed),
        n_distances=int(r.n_distances), n_rounds=int(r.n_rounds),
        ci=0.0, extras={"raw": r, "per_shard_elements": per_shard})


def _run_bandit(q: MedoidQuery, plan: Plan, exact=None) -> SolveReport:
    from repro.bandit.api import _bandit_medoid
    r = _bandit_medoid(
        q.X, budget=q.budget, delta=q.delta, exact=exact, metric=q.metric,
        seed=q.seed, block=q.block,
        use_kernels=bool(plan.params.get("use_kernels")), **q.engine_opts)
    return SolveReport(
        indices=np.asarray([r.index], np.int64),
        energies=np.asarray([r.energy], np.float64),
        certified=bool(r.certified),
        elements_computed=float(r.n_computed),
        n_distances=int(r.n_scalars), n_rounds=int(r.n_rounds),
        ci=float(r.ci),
        extras={"raw": r, "survivors": r.survivors,
                "exact_energy": r.exact_energy, **r.extras})


def _run_hybrid(q: MedoidQuery, plan: Plan) -> SolveReport:
    return _run_bandit(q, plan, exact="trimed")


def _run_kmedoids(q: MedoidQuery, plan: Plan) -> SolveReport:
    from repro.core.distances import pairwise
    from repro.core.trikmeds import kmedoids_batched
    opts = dict(q.engine_opts)
    overrides = dict(plan.params.get("update_overrides") or {})
    mu = plan.params.get("medoid_update", "trimed")
    kw = dict(block=q.block, block_schedule=q.block_schedule,
              use_kernels=bool(plan.params.get("use_kernels")))
    if mu == "sharded" or q.device_policy == "sharded":
        # 'axis' names the mesh axis for the sharded update — consumed
        # here, or moot after the non-triangle downgrade to 'scan';
        # kmedoids_batched itself never takes it
        opts.pop("axis", None)
    if mu == "sharded":
        kw["mesh"] = q.mesh
        if "axis" in q.engine_opts:
            kw["mesh_axis"] = q.engine_opts["axis"]
    kw.update(overrides)
    res = kmedoids_batched(q.X, q.k, seed=q.seed, n_iter=q.n_iter,
                           metric=q.metric, medoid_update=mu, **kw, **opts)
    # per-cluster energies for the unified schema: one (K, N) pass
    import jax.numpy as jnp
    X = jnp.asarray(q.X)
    d = np.asarray(pairwise(jnp.take(X, jnp.asarray(res.medoids), axis=0),
                            X, q.metric), np.float64)
    same = res.assignment[None, :] == np.arange(q.k)[:, None]
    sums = np.where(same, d, 0.0).sum(axis=1)
    return SolveReport(
        indices=np.asarray(res.medoids, np.int64),
        energies=_cluster_energies(sums, res.medoids, res.assignment, q.k),
        certified=mu != "bandit",       # bandit update is approximate
        elements_computed=float(res.n_rows),
        n_distances=int(res.n_distances), n_rounds=int(res.n_iterations),
        ci=0.0 if mu != "bandit" else float("nan"),
        assignment=np.asarray(res.assignment),
        extras={"raw": res, "total_energy": float(res.energy),
                "medoid_update": mu})


def _run_graph(q: MedoidQuery, plan: Plan) -> SolveReport:
    """Batched device Bellman-Ford sweeps + landmark elimination bounds
    over a :class:`repro.core.graph.GraphOracle` (DESIGN.md §16)."""
    from repro.core.graph import graph_medoid
    from repro.runtime import faults
    faults.check_poison(q.X, "graph engine")
    opts = dict(q.engine_opts)
    block = int(opts.pop("block", q.block))
    r, info = graph_medoid(q.X, seed=q.seed, block=block, **opts)
    plan.params["sweeps"] = int(r.n_computed)
    return _report_from_medoid(r, extras={"graph": info})


_EXECUTORS = {
    "sequential": _run_sequential,
    "block": _run_block,
    "pipelined": _run_pipelined,
    "sharded": _run_sharded,
    "batched": _run_batched,
    "batched_pipelined": _run_batched_pipelined,
    "batched_sharded": _run_batched_sharded,
    "bandit": _run_bandit,
    "hybrid": _run_hybrid,
    "kmedoids": _run_kmedoids,
    "topk": _run_topk,
    "scan": _run_scan,
    "graph": _run_graph,
}
assert set(_EXECUTORS) == set(ENGINES)


def solve(query, plan=None, explain=False):
    """The front door: execute ``query`` and return a :class:`SolveReport`.

    ``plan`` overrides the planner (an engine name from :data:`ENGINES`
    or a full :class:`Plan`); ``explain=True`` returns the chosen
    :class:`Plan` — engine, reasons, derived params — without executing.
    """
    if not isinstance(query, MedoidQuery):
        raise TypeError(
            f"solve expects a MedoidQuery, got {type(query).__name__}")
    if plan is None:
        p = plan_query(query)
    elif isinstance(plan, Plan):
        p = plan
    else:
        if plan not in _EXECUTORS:
            raise ValueError(
                f"solve: unknown plan {plan!r}; engines: {list(ENGINES)}")
        reasons = [f"user override: plan={plan!r}"]
        engine = _apply_deadline_policy(query, plan, reasons)
        params = _derive_params(
            query, engine, [], require_metric(query.metric, caller="solve"))
        p = Plan(engine, tuple(reasons), params,
                 cost_estimate=_estimate_cost(query, engine, params))
    if explain:
        return p
    if p.engine not in _EXECUTORS:
        raise ValueError(
            f"solve: unknown plan engine {p.engine!r}; engines: "
            f"{list(ENGINES)}")
    _check_finite(query)
    if query.deadline_s is not None:
        from repro.runtime import faults
        if p.engine not in _DEADLINE_ENGINES:
            raise ValueError(
                f"solve: deadline_s is not supported for engine "
                f"{p.engine!r}; supported: {_DEADLINE_ENGINES}")
        # stamp the absolute deadline at execution time (fault clock, so
        # injected stalls blow it deterministically in tests)
        p.params["deadline_ts"] = faults.clock() + float(query.deadline_s)
    tracer = None
    if query.trace is not None:
        from repro.obs.trace import resolve_trace
        tracer = resolve_trace(query.trace)
        tracer.start_session()
        p.params["tracer"] = tracer
    from repro.obs import profile as _profile
    prof = _profile.active()
    prof_mark = prof.mark() if prof is not None else 0
    try:
        report = _EXECUTORS[p.engine](query, p)
        report.plan = p
    except Exception as err:
        if query.on_error != "degrade":
            raise
        report = _solve_degraded(query, p, err)
    _finish_obs(query, p, report, tracer, prof, prof_mark)
    return report


def _finish_obs(query, p: Plan, report: SolveReport, tracer, prof,
                prof_mark: int) -> None:
    """Attach ``extras["obs"]`` after a solve. Engines without native
    segment tracing (everything but pipelined/sharded) still yield a
    begin + end trace from the report — a one-event elimination curve
    is honest for a single-pass engine."""
    if tracer is None and prof is None:
        return
    obs: dict[str, Any] = {}
    if tracer is not None:
        if not tracer.engine_ran:
            tracer.begin(engine=report.plan.engine,
                         n=int(p.params.get("n") or _query_n(query)),
                         metric=query.metric)
            tracer.end(engine=report.plan.engine,
                       index=int(report.indices[0]),
                       energy=float(report.energies[0]),
                       elements=int(report.elements_computed),
                       rounds=int(report.n_rounds),
                       certified=bool(report.certified),
                       halt_reason=report.extras.get("halt_reason", ""))
        tracer.close()
        obs["trace"] = tracer.describe()
    if prof is not None:
        obs["kernels"] = prof.summary(since=prof_mark)
    report.extras["obs"] = obs


def _check_finite(query: MedoidQuery) -> None:
    """``nonfinite="raise"`` input gate: reject NaN/Inf rows in a
    host-visible array ``X`` before any engine runs (one silent NaN
    poisons every triangle bound — every ``|E - d|`` against it is NaN,
    so elimination quietly stops firing). Host path only: oracles and
    traced arrays pass through unchecked."""
    X = query.X
    if query.nonfinite != "raise" or X is None or _is_oracle(X):
        return
    try:
        from jax.core import Tracer
    except ImportError:                     # pragma: no cover
        Tracer = ()
    if isinstance(X, Tracer):
        return
    import jax.numpy as jnp
    Xa = jnp.asarray(X)
    axes = tuple(range(1, Xa.ndim))
    row_ok = jnp.isfinite(Xa).all(axis=axes) if axes else jnp.isfinite(Xa)
    bad = int(np.asarray((~row_ok).sum()))
    if bad:
        raise ValueError(
            f"solve: X contains non-finite values (NaN/Inf) in {bad} of "
            f"{int(row_ok.shape[0])} rows; a single non-finite element "
            "poisons every triangle bound. Clean the input or pass "
            "nonfinite='allow' to skip this check.")


# on_error="degrade" ladder: kernels->jnp first (same engine), then
# engine hops toward the simplest exact path for the task kind. Every
# hop is recorded in the attempted plan's reasons; the last rung's
# failure re-raises.
_DEGRADE_CHAIN = {
    "sharded": ("pipelined", "scan"),
    "block": ("pipelined", "scan"),
    "pipelined": ("scan",),
    "sequential": ("scan",),
    "batched_sharded": ("batched_pipelined", "batched"),
    "batched_pipelined": ("batched",),
    "hybrid": ("bandit",),
    # graph -> host sequential sweeps: same oracle, same exact answer
    "graph": ("sequential",),
}


def _solve_degraded(query: MedoidQuery, p: Plan, err) -> SolveReport:
    from repro.obs.logs import get_logger
    from repro.obs.metrics import REGISTRY
    log = get_logger("repro.api.planner")
    tracer = p.params.get("tracer")
    m = require_metric(query.metric, caller="solve")
    attempts = [f"on_error=degrade: {p.engine} raised "
                f"{type(err).__name__}: {err}"]
    log.warning("on_error=degrade: engine %s raised %s: %s",
                p.engine, type(err).__name__, err)
    last = err
    rungs = []
    if p.params.get("use_kernels"):
        rungs.append((p.engine, query,
                      "retrying with use_kernels=False (kernels->jnp)"))
    # cross-engine hops drop engine-specific opts (a sharded 'axis='
    # means nothing to the pipelined engine) — only 'interpret' carries.
    # The mesh goes too: hopping off a sharded engine IS the
    # single-device retry.
    safe_opts = {k: v for k, v in query.engine_opts.items()
                 if k == "interpret"}
    q2 = query.with_(engine_opts=safe_opts, use_kernels=False,
                     device_policy="auto", mesh=None)
    for eng in _DEGRADE_CHAIN.get(p.engine, ()):
        rungs.append((eng, q2, f"downgrading to {eng!r}"))
    for eng, qq, note in rungs:
        reasons = p.reasons + tuple(attempts) + (f"on_error=degrade: "
                                                 f"{note}",)
        REGISTRY.counter(
            "degrade_hops_total",
            "planner on_error=degrade ladder hops").inc(engine=eng)
        if tracer is not None:
            tracer.event("hop", engine=eng, reason=note)
        log.warning("on_error=degrade: %s", note)
        try:
            params = _derive_params(qq, eng, [], m)
            params["use_kernels"] = False
            if "n" in p.params:
                params["n"] = p.params["n"]
            if (p.params.get("deadline_ts") is not None
                    and eng in _DEADLINE_ENGINES):
                params["deadline_ts"] = p.params["deadline_ts"]
            if tracer is not None:
                params["tracer"] = tracer
            plan2 = Plan(eng, reasons, params,
                         cost_estimate=_estimate_cost(qq, eng, params))
            report = _EXECUTORS[eng](qq, plan2)
            report.plan = plan2
            return report
        except Exception as e2:
            attempts.append(f"on_error=degrade: {eng} raised "
                            f"{type(e2).__name__}: {e2}")
            log.warning("on_error=degrade: %s raised %s: %s",
                        eng, type(e2).__name__, e2)
            last = e2
    raise last


# ---------------------------------------------------------------------------
# streaming-index repair accounting (DESIGN.md §15)
# ---------------------------------------------------------------------------
def plan_repair(X, *, metric: str = "l2", block: int = 128,
                pending_ops: int = 0, invalidated: int = 0,
                elements: float = 0.0) -> Plan:
    """The :class:`~repro.stream.MedoidIndex` repair plan: not a routing
    decision (the index always repairs through the pipelined ladder) but
    the accounting record an admission scheduler budgets against —
    ``params["repair"]`` holds the churn batch size, the invalidated
    survivor count (``-1`` when the repair fell back to a full
    re-solve), the elements actually spent, and the planner's fresh
    re-solve estimate for the same set, so ``vs_fresh`` is the measured
    repair saving."""
    q = MedoidQuery(X=X, metric=metric, block=int(block))
    fresh = float(_estimate_cost(q, "pipelined", {}))
    repair = {
        "pending_ops": int(pending_ops),
        "invalidated": int(invalidated),
        "elements": float(elements),
        "fresh_estimate": fresh,
        "vs_fresh": float(elements) / fresh if fresh > 0 else None,
    }
    reason = (f"stream repair: {pending_ops} churn op(s), "
              f"{invalidated} invalidated survivor(s), "
              f"{elements:.1f} elements vs {fresh:.1f} fresh-solve "
              "estimate"
              if invalidated >= 0 else
              f"stream repair fell back to a full re-solve after "
              f"{pending_ops} churn op(s)")
    return Plan("stream_repair", (reason,), {"repair": repair},
                cost_estimate=float(elements))
