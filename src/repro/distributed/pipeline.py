"""Pipeline parallelism utility (GPipe-style, collective_permute ring).

The assigned production meshes spend their axes on (pod, data, model),
so PP is not enabled for the 40 dry-run cells — this module provides the
stage-loop for deeper meshes (e.g. ("data", "stage", "model") on 1000+
node jobs, where a 62-layer minicpm3 pipeline cuts the per-chip layer
count and with it the weight-streaming floor).

Schedule: classic GPipe — M microbatches flow through S stages inside a
`shard_map` over the `stage` axis; activations hop stages with
`collective_permute`; each chip runs only its own stage's layer slice
(selected by `axis_index`). Bubble fraction = (S-1)/(M+S-1). The
backward pass is jax-autodiff'd through the whole schedule
(collective_permute transposes to the reverse permutation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _stage_slice(stacked, stage, per_stage):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, stage * per_stage,
                                               per_stage, 0), stacked)


def pipeline_apply(layer_fn, stacked_params, x, *, n_stages: int,
                   microbatches: int, axis: str = "stage"):
    """Run ``layer_fn(params_i, h) -> h`` for every layer, pipelined.

    stacked_params: pytree with leading layer dim L (L % n_stages == 0);
    x: (B, ...) global microbatchable input (B % microbatches == 0).
    Must be called under shard_map/jit with mesh axis ``axis`` of size
    ``n_stages`` (see ``make_pipeline_fn``).
    """
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    per_stage = L // n_stages
    stage = jax.lax.axis_index(axis)
    my_params = _stage_slice(stacked_params, stage, per_stage)

    def run_stage(h):
        def body(h, lp):
            return layer_fn(lp, h), None
        h, _ = jax.lax.scan(body, h, my_params)
        return h

    b = x.shape[0]
    mb = b // microbatches
    xs = x.reshape(microbatches, mb, *x.shape[1:])
    n_ticks = microbatches + n_stages - 1
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    buf = jnp.zeros_like(xs[0])
    outs = jnp.zeros_like(xs)

    def tick(carry, t):
        buf, outs = carry
        # stage 0 ingests microbatch t (if in range)
        take = jnp.clip(t, 0, microbatches - 1)
        injected = jnp.where(
            (stage == 0) & (t < microbatches), xs[take], buf)
        h = run_stage(injected)
        # last stage emits result for microbatch t - (S-1)
        emit_idx = t - (n_stages - 1)
        emit = (stage == n_stages - 1) & (emit_idx >= 0)
        outs = jax.lax.cond(
            emit,
            lambda o: o.at[jnp.clip(emit_idx, 0, microbatches - 1)].set(h),
            lambda o: o,
            outs)
        # hop to the next stage
        buf = jax.lax.ppermute(h, axis, fwd_perm)
        return (buf, outs), None

    (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                  jnp.arange(n_ticks))
    # results live on the last stage; broadcast around the ring so every
    # stage returns the same value (replicated out_spec)
    outs = jax.lax.psum(
        jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
    return outs.reshape(b, *x.shape[1:])


def make_pipeline_fn(layer_fn, mesh, *, n_stages: int, microbatches: int,
                     axis: str = "stage"):
    """Wrap `pipeline_apply` in shard_map over the stage axis: params
    arrive replicated, activations replicated (batch sharding over other
    axes composes outside)."""
    fn = functools.partial(pipeline_apply, layer_fn,
                           n_stages=n_stages, microbatches=microbatches,
                           axis=axis)
    return jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P(), P()),
        out_specs=P(),
        check_vma=False,
    )
