"""Gradient compression for slow inter-pod links: int8 error-feedback.

Before the inter-pod gradient reduction, each gradient tensor is
quantised to int8 with a per-tensor fp32 scale; the quantisation residual
is kept in an error-feedback buffer and added to the next step's gradient
(EF-SGD / 1-bit-Adam style, here at 8 bits), which keeps convergence
unbiased over time. The reduction itself is performed on the *dequantised*
values (the wire format in a real deployment would be int8 + scale; XLA's
psum operates on the dequantised tensor here — the collective BYTES
reported by the roofline analysis for the compressed path are scaled by
`wire_bytes_fraction` = 1/4 to reflect that).

``top_k_mask`` offers magnitude sparsification (top-k per tensor) with the
same error-feedback contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

WIRE_BYTES_FRACTION = 0.25   # int8 vs fp32 on the wire


def init_error_buffers(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x):
    """Per-tensor symmetric int8 quantisation. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, error_buffers):
    """Returns (dequantised grads ready for the reduction, new buffers)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq, g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_buffers)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def top_k_mask(grads, frac: float):
    """Keep the top `frac` fraction of entries (by magnitude) per tensor."""
    def one(g):
        g32 = g.astype(jnp.float32)
        k = max(1, int(g32.size * frac))
        flat = jnp.abs(g32).reshape(-1)
        thresh = jax.lax.top_k(flat, k)[0][-1]
        return jnp.where(jnp.abs(g32) >= thresh, g32, 0.0)

    return jax.tree.map(one, grads)
