"""AdamW from scratch, mixed-precision, ZeRO-1-shardable state.

State per parameter: fp32 master copy, fp32 first/second moments. The
sharding layer (`repro.launch.shardings.opt_specs`) places these on
the ``data`` axis (ZeRO-1) on top of the parameter's own TP sharding.
Supports global-norm clipping, decoupled weight decay and cosine/linear
schedules. Gradient compression (int8 error feedback) plugs in upstream
of `apply_updates` — see `repro.optim.compress`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"     # cosine | linear | constant
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray            # scalar int32
    master: dict                 # fp32 params
    m: dict
    v: dict


def schedule_lr(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = jnp.asarray(1.0)
    return cfg.lr * warm * decay


def init_state(params) -> AdamWState:
    # copy=True: when params are already fp32, astype aliases the same
    # buffer and donating params + master together would double-donate
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def clip_by_global_norm(grads, max_norm):
    sq = sum(jnp.sum(g.astype(jnp.float32) ** 2)
             for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params_in_model_dtype, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(master, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * (g * g)
        mh = m / b1c
        vh = v / b2c
        new_master = master - lr * (
            mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master)
        return new_master, m, v

    flat_master, tdef = jax.tree.flatten(state.master)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(mm, g, m, v)
           for mm, g, m, v in zip(flat_master, flat_g, flat_m, flat_v)]
    new_master = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])

    new_params = jax.tree.map(
        lambda mm, p: mm.astype(p.dtype), new_master, params)
    new_state = AdamWState(step=step, master=new_master, m=new_m, v=new_v)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
