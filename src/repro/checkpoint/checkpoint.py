"""Sharded numpy checkpointing with atomic commit and elastic restore.

Layout:  <dir>/step_<N>/
             meta.json            (step, spec manifest, mesh shape)
             arr_<i>.npy          (one file per leaf, full logical array)
         <dir>/LATEST             (atomic pointer file)

* Writes go to ``step_<N>.tmp`` then ``os.replace`` -> crash-safe.
* ``keep_last`` old checkpoints are retained, older ones pruned
  (``keep_last=None`` keeps everything; values below 1 are refused).
* Restore is *elastic*: arrays are saved as full logical values and
  re-sharded onto whatever mesh the restoring job brings up (the mesh
  may have a different data-axis size after a failure — DESIGN.md §6).
* Async: `save(..., blocking=False)` snapshots to host memory
  immediately and writes on a background thread so the train loop
  continues (commit ordering preserved by a single worker queue).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import queue
from pathlib import Path

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str, keep_last: int | None = 3):
        if keep_last is not None and keep_last < 1:
            # keep_last=0 would slice steps[:-0] == steps[:0] and prune
            # nothing — silently acting as "unlimited"; refuse instead
            # of guessing (None is the explicit unlimited spelling)
            raise ValueError(
                f"keep_last must be >= 1 or None (unlimited), got "
                f"{keep_last}")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._q: queue.Queue = queue.Queue()
        # the writer thread starts lazily on the first async save: a
        # blocking-only checkpointer (every per-solve instance the
        # resumable engines create) must not pin a thread for its whole
        # process lifetime — a long test run accumulates enough idle
        # workers to destabilise the XLA runtime
        self._worker: threading.Thread | None = None
        self._errors: list[Exception] = []

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = True,
             extra_meta: dict | None = None):
        """Snapshot `tree` (pytree of jax/np arrays) at `step`.

        ``extra_meta`` (JSON-serialisable dict) is stored alongside the
        manifest and returned by :meth:`load` — engines use it for a
        config fingerprint so a resume can refuse a mismatched state.
        """
        self._raise_pending()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if blocking:
            self._write(step, host_tree, extra_meta)
        else:
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(target=self._drain,
                                                daemon=True)
                self._worker.start()
            self._q.put((step, host_tree, extra_meta))

    def wait(self):
        self._q.join()
        self._raise_pending()

    def close(self):
        """Drain pending async saves and stop the writer thread (no-op
        if no async save ever ran). The checkpointer stays usable — a
        later async save starts a fresh worker."""
        if self._worker is not None and self._worker.is_alive():
            self._q.join()
            self._q.put(None)
            self._worker.join(timeout=30.0)
        self._worker = None
        self._raise_pending()

    def _raise_pending(self):
        """Surface background-thread write failures eagerly: a
        fire-and-forget caller that never calls ``wait()`` must still
        learn its checkpoints are being lost, on the next interaction
        with the checkpointer rather than never."""
        if self._errors:
            err = self._errors[0]
            del self._errors[:]
            raise err

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:           # close() sentinel
                self._q.task_done()
                return
            step, tree, extra_meta = item
            try:
                self._write(step, tree, extra_meta)
            except Exception as e:  # noqa: BLE001
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, host_tree, extra_meta=None):
        leaves, treedef = jax.tree.flatten(host_tree)
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = []
        for i, leaf in enumerate(leaves):
            np.save(tmp / f"arr_{i}.npy", leaf)
            manifest.append({"shape": list(leaf.shape),
                             "dtype": str(leaf.dtype)})
        meta = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "manifest": manifest,
        }
        if extra_meta is not None:
            meta["extra"] = extra_meta
        (tmp / "meta.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        # atomic LATEST pointer
        ptr_tmp = self.dir / "LATEST.tmp"
        ptr_tmp.write_text(str(step))
        os.replace(ptr_tmp, self.dir / "LATEST")
        self._prune()

    def _prune(self):
        if self.keep_last is None:       # unlimited retention
            return
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if not p.name.endswith(".tmp")]

    def latest_step(self):
        self._raise_pending()
        ptr = self.dir / "LATEST"
        if ptr.exists():
            s = int(ptr.read_text())
            if (self.dir / f"step_{s}").exists():
                return s
        steps = self.all_steps()
        return max(steps) if steps else None

    def load(self, step: int | None = None):
        """Load a checkpoint *without* an example tree: returns
        ``(step, leaves, meta)`` where ``leaves`` is the flat list of
        numpy arrays in manifest order and ``meta`` is the stored
        metadata dict (including any ``extra`` from
        ``save(extra_meta=...)``). Callers that know their tree
        structure statically (e.g. ``SolveState``) rebuild from the
        flat leaves; ``restore()`` remains the shape-checked path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step}"
        meta = json.loads((d / "meta.json").read_text())
        leaves = [np.load(d / f"arr_{i}.npy")
                  for i in range(meta["n_leaves"])]
        return step, leaves, meta

    def restore(self, example_tree, step: int | None = None,
                shardings=None):
        """Restore into the structure of `example_tree`. If `shardings`
        (pytree of NamedSharding) is given, leaves are placed sharded —
        onto whatever mesh those shardings reference (elastic)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step}"
        meta = json.loads((d / "meta.json").read_text())
        leaves, treedef = jax.tree.flatten(example_tree)
        assert meta["n_leaves"] == len(leaves), \
            f"checkpoint has {meta['n_leaves']} leaves, model has {len(leaves)}"
        loaded = [np.load(d / f"arr_{i}.npy") for i in range(len(leaves))]
        for ld, ref in zip(loaded, leaves):
            assert tuple(ld.shape) == tuple(ref.shape), (ld.shape, ref.shape)
        tree = jax.tree.unflatten(treedef, loaded)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return step, tree
