"""Correlated sequential halving for the medoid (arXiv:1906.04356).

Fixed-budget best-arm identification: the total scalar-distance budget
``T`` is split evenly over ``ceil(log2 N)`` rounds; in round ``r`` every
surviving arm receives ``t_r = T / (|S_r| log2 N)`` pulls and the better
half (by running mean) survives. Two correlation devices make the
estimator much tighter than independent sampling:

* **Shared sample indices** — within a round, every arm is evaluated
  against the *same* freshly drawn reference columns, so the pairwise
  comparisons that drive halving are paired: for arms ``i, j`` the
  difference estimator averages ``d(x_i, x_J) - d(x_j, x_J)``, whose
  variance scales with ``d(x_i, x_j)`` (triangle inequality) rather than
  with the full distance spread.
* **Cumulative reuse** — survivors keep their running sums across
  rounds. Because every pair of survivors has seen the identical sample
  history, the pairing survives accumulation; nothing is thrown away.

Estimates are on the internal ``E = S/N`` scale (uniform sampling with
replacement, self included — ``distances.py``). Cost is counted in
unified computed elements at the padded buffer width (the device
computes the padding lanes). Per round the surviving arms are gathered
into a compacted (power-of-two padded) buffer, so late rounds touch tiny
operand shapes — the device work per round is ~constant
(``|S_r| * t_r`` is constant by construction).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.distances import (elements_computed, pairwise,
                                  pow2_at_least)
from repro.kernels import ops as _ops


@dataclass
class HalvingResult:
    """Outcome of a correlated sequential-halving run (estimates on the
    internal ``E=S/N`` scale)."""
    index: int                  # surviving arm (or best mean if capped)
    mean: float                 # its energy estimate
    survivors: np.ndarray       # final survivor set, best mean first
    means: np.ndarray           # their estimates
    n_computed: float           # unified computed elements
    n_scalars: int
    n_rounds: int
    t: int                      # pulls accumulated by the final survivors
    extras: dict = field(default_factory=dict)


@functools.partial(
    jax.jit, static_argnames=("s", "metric", "use_kernels", "interpret"))
def _halving_round(X, n_real, arm_idx, sums, key, s, metric, use_kernels,
                   interpret):
    """One round: ``s`` shared sample columns for every arm in the
    (padded) buffer; returns the updated running sums."""
    samp = jax.random.randint(key, (s,), 0, n_real)
    xs = jnp.take(X, samp, axis=0)
    Xa = jnp.take(X, arm_idx, axis=0)
    if use_kernels:
        dsum, _sq, _mx = _ops.sample_stats(Xa, xs, metric=metric,
                                           interpret=interpret)
    else:
        dsum = pairwise(Xa, xs, metric).sum(axis=1)
    return sums + dsum


def sequential_halving(
    X,
    budget: float,
    metric: str = "l2",
    seed: int = 0,
    target: int = 1,
    min_pulls: int = 1,
    use_kernels: bool = False,
    interpret=None,
) -> HalvingResult:
    """Halve the arm set down to ``target`` on a fixed ``budget`` of
    computed elements (= ``budget * N`` scalar distances). Cost is
    charged at the padded buffer width (the device computes the padding
    lanes); the kernel path auto-falls back to jnp for metrics the
    sampled-column tile does not cover."""
    from repro.api.metrics import require_metric
    m = require_metric(metric, caller='sequential_halving')
    if not m.kernel:
        use_kernels = False       # no Pallas distance tile for this metric
    X = jnp.asarray(X)
    n = X.shape[0]
    target = max(1, int(target))
    r_max = max(1, int(np.ceil(np.log2(max(n, 2) / target))))
    total_scalars = float(budget) * n
    key = jax.random.PRNGKey(seed)

    arm_idx = np.arange(n, dtype=np.int32)
    sums = np.zeros(n, np.float32)
    t = 0
    n_scalars = 0.0
    n_rounds = 0
    while len(arm_idx) > target:
        m = len(arm_idx)
        # plan each round's pulls from the PADDED width — the width the
        # device computes and the accounting charges — so the budget
        # funds the whole halving schedule
        t_r = int(total_scalars / (pow2_at_least(m) * r_max))
        t_r = max(int(min_pulls), min(t_r, 4 * n))   # cap: beyond ~N pulls
        if n_scalars + pow2_at_least(m) * t_r > total_scalars \
                and n_rounds > 0:
            break                                    # budget exhausted
        m_pad = pow2_at_least(m) - m
        # dead padding lanes recompute arm 0; sliced off below
        idx_p = np.concatenate([arm_idx, np.zeros(m_pad, np.int32)])
        sums_p = np.concatenate([sums, np.zeros(m_pad, np.float32)])
        key, sub = jax.random.split(key)
        sums_p = np.asarray(_halving_round(
            X, n, jnp.asarray(idx_p), jnp.asarray(sums_p), sub,
            t_r, metric, use_kernels, interpret))
        sums = sums_p[:m]
        t += t_r
        n_scalars += (m + m_pad) * t_r        # padding lanes are computed
        n_rounds += 1
        keep = np.argsort(sums, kind="stable")[: max(target, (m + 1) // 2)]
        keep.sort()                                  # keep index order stable
        arm_idx = arm_idx[keep]
        sums = sums[keep]

    means = sums / max(t, 1)
    order = np.argsort(means, kind="stable")
    return HalvingResult(
        index=int(arm_idx[order[0]]),
        mean=float(means[order[0]]),
        survivors=arm_idx[order].astype(np.int64),
        means=means[order].astype(np.float64),
        n_computed=elements_computed(n_scalars, n),
        n_scalars=int(n_scalars),
        n_rounds=n_rounds,
        t=t,
        extras={"r_max": r_max},
    )
