"""repro.bandit — device-side bandit medoid subsystem (DESIGN.md §9).

Sampling-based (approximate, anytime) medoid search racing on the
sampled-column Pallas kernels, plus the hybrid hand-off to the exact
trimed finisher:

* :func:`ucb_race` — Meddit-style UCB racing (arXiv:1711.00817);
* :func:`sequential_halving` — correlated sequential halving
  (arXiv:1906.04356);
* :func:`bandit_medoid` — the anytime API:
  ``bandit_medoid(X, budget=..., delta=..., exact="trimed"|None)``.
"""
from .api import BanditMedoidResult, bandit_medoid
from .halving import HalvingResult, sequential_halving
from .racing import RaceResult, ucb_race

__all__ = [
    "BanditMedoidResult", "bandit_medoid",
    "HalvingResult", "sequential_halving",
    "RaceResult", "ucb_race",
]
