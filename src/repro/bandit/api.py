"""`bandit_medoid` — the anytime / budgeted medoid query (DESIGN.md §9).

One entry point over the two sampling engines and the exact finisher:

* ``exact=None`` — pure bandit: return the best arm with an
  ``(index, energy-estimate, CI)`` triple. Metric-agnostic (sampling
  needs no triangle inequality).
* ``exact="trimed"`` — hybrid: the bandit races the field down to a
  small survivor set, then the survivor-compacted pipelined engine
  (``core.pipelined``) settles exact energies, warm-seeded with the
  survivors as its first pivot block. With no budget the finisher runs
  to completion and the result carries the engine's deterministic
  triangle-bound certificate (``certified=True``); under a budget it
  stops at the cap and returns the exact-energy incumbent with
  ``certified=False`` plus the bandit's residual CI.

Division of labour, which is what keeps the hybrid honest: the bandit's
*probabilistic* confidence intervals steer the schedule (which rows get
computed first, via ``warm_idx``) and the incumbent — choices that only
affect cost — while elimination decisions remain with the *certified*
triangle bounds. The opt-in ``seed_bounds=True`` crosses that line
deliberately: the bandit's LCBs are handed to the finisher as initial
lower bounds, which converts the deterministic certificate into a
with-probability-``>= 1 - delta`` one (Meddit's own guarantee) in
exchange for skipping the bound build-up.

Cost is reported in unified computed elements
(:func:`repro.core.distances.elements_computed`): bandit sampling counts
fractionally, finisher rows count as 1 each.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.api.metrics import get_metric, require_metric
from repro.core.pipelined import _trimed_pipelined

from .halving import sequential_halving
from .racing import ucb_race

# below this N a certified exact run is at most ~EXACT_FALLBACK_N rows;
# sampling machinery cannot beat it, so fall straight through to trimed
EXACT_FALLBACK_N = 64


@dataclass
class BanditMedoidResult:
    """Anytime medoid answer. ``energy`` is on the paper's ``S/(N-1)``
    scale (see ``distances.py``); it is an exactly computed row whenever
    ``exact_energy`` is True (always the case on the hybrid path — the
    incumbent's full row was computed), an estimate otherwise. ``ci`` is
    the half-width of the bandit estimate for the returned index — 0.0
    once the index is certified (no residual uncertainty), NaN when the
    uncertainty is unknown (halving keeps no CIs)."""
    index: int
    energy: float
    ci: float
    n_computed: float            # unified computed elements
    n_scalars: int               # scalar distance evaluations
    n_rounds: int                # bandit rounds + finisher rounds
    certified: bool              # deterministic triangle certificate
    exact_energy: bool           # energy is a full computed row
    survivors: np.ndarray | None = None
    extras: dict = field(default_factory=dict)


def _paper_scale(n: int) -> float:
    return n / max(n - 1, 1)


def _bandit_medoid(
    X,
    budget: float | None = None,
    delta: float = 0.01,
    exact: str | None = "trimed",
    engine: str = "ucb",
    metric: str = "l2",
    seed: int = 0,
    samples_per_round: int = 64,
    survivor_target: int | None = None,
    block: int = 128,
    bandit_frac: float = 0.5,
    seed_bounds: bool = False,
    use_kernels: bool = False,
    interpret=None,
) -> BanditMedoidResult:
    """Anytime / budgeted medoid. ``budget`` is in unified computed
    elements (``None`` = run to the survivor target, and to the exact
    certificate when ``exact="trimed"``); ``bandit_frac`` is the share
    of a finite budget granted to the sampling phase, the remainder
    funding the exact finisher."""
    if exact not in ("trimed", None):
        raise ValueError(f"exact must be 'trimed' or None, got {exact!r}")
    if engine not in ("ucb", "halving"):
        raise ValueError(f"engine must be 'ucb' or 'halving', got {engine!r}")
    if exact == "trimed":
        require_metric(metric, need_triangle=True,
                       caller="bandit_medoid(exact='trimed')")
    else:
        require_metric(metric, caller="bandit_medoid")
    if seed_bounds and engine != "ucb":
        raise ValueError(
            "seed_bounds=True requires engine='ucb' — halving keeps no "
            "confidence bounds to seed the finisher with")
    X = np.asarray(X)
    n = X.shape[0]
    block = int(min(block, n))
    target = int(survivor_target if survivor_target is not None
                 else (block if exact == "trimed" else 1))

    # tiny inputs: the certified engine is already cheaper than sampling
    if n <= EXACT_FALLBACK_N or (budget is not None and budget >= n):
        if get_metric(metric).has_triangle:
            r = _trimed_pipelined(X, block=block, metric=metric,
                                 use_kernels=use_kernels,
                                 interpret=interpret)
            return BanditMedoidResult(
                r.index, r.energy, 0.0, float(r.n_computed),
                r.n_distances, r.n_rounds, certified=True,
                exact_energy=True, extras={"fallback": "trimed_pipelined"})
        # non-triangle metrics: brute force the tiny case
        from repro.core.distances import exact_energies
        e = np.asarray(exact_energies(X, metric))
        i = int(np.argmin(e))
        return BanditMedoidResult(
            i, float(e[i]) * _paper_scale(n), 0.0, float(n), n * n, 1,
            certified=True, exact_energy=True, extras={"fallback": "scan"})

    if budget is not None:
        # pure bandit: the whole budget is the sampling budget; hybrid:
        # the finisher gets the complementary share
        bandit_budget = (float(budget) * bandit_frac if exact == "trimed"
                         else float(budget))
    elif exact == "trimed":
        # unbudgeted hybrid: the bandit only has to *order* the field so
        # the finisher's first block lands on the contenders — spending
        # more than a sliver of the finisher's expected cost cannot pay
        # for itself. O(sqrt(N)) elements is that sliver.
        bandit_budget = max(32.0, 2.0 * float(np.sqrt(n)))
    else:
        bandit_budget = None
    if engine == "ucb":
        race = ucb_race(
            X, budget=bandit_budget, delta=delta, metric=metric, seed=seed,
            samples_per_round=samples_per_round, target=target,
            use_kernels=use_kernels, interpret=interpret)
        lcb_full = race.lcb_full
        t = race.t
    else:
        if bandit_budget is None:
            # halving is a fixed-budget method; default to the regime
            # where it provably succeeds with high probability
            bandit_budget = max(4.0 * np.log2(max(n, 2)) ** 2, 16.0)
        race = sequential_halving(
            X, budget=bandit_budget, metric=metric, seed=seed,
            target=target, use_kernels=use_kernels, interpret=interpret)
        lcb_full = None                       # halving keeps no CIs
        t = race.t
    survivors = race.survivors
    scale = _paper_scale(n)

    if exact is None:
        ci = float(race.cis[0]) if engine == "ucb" else float("nan")
        return BanditMedoidResult(
            race.index, race.mean * scale, ci * scale,
            race.n_computed, race.n_scalars, race.n_rounds,
            certified=False, exact_energy=False, survivors=survivors,
            extras={"engine": engine, "t": t})

    # ---- exact finisher: warm-seeded survivor-compacted trimed --------
    # Warm-block width is regime-dependent (measured, EXPERIMENTS.md):
    # unbudgeted, a few forced pivots set the incumbent and the spread-out
    # lowest-bound selection does the eliminating (a wide block of
    # clustered contenders tightens bounds redundantly); budget-capped,
    # certification won't complete anyway, so every budgeted row should
    # go to the bandit's best candidates.
    warm_w = block if budget is not None else min(16, block)
    finisher_budget = None
    if budget is not None:
        finisher_budget = max(int(budget - race.n_computed), block)
    l_init = None
    if seed_bounds and lcb_full is not None:
        l_init = lcb_full                      # probabilistic certificate
    bounds_seeded = l_init is not None         # halving has no LCBs to seed
    fin = _trimed_pipelined(
        X, block=block, metric=metric, use_kernels=use_kernels,
        interpret=interpret, warm_idx=np.asarray(survivors[:warm_w]),
        l_init=l_init, max_computed=finisher_budget)

    if fin.index < 0:                          # budget below one block
        ci = (float(race.cis[0]) if engine == "ucb" else float("nan"))
        return BanditMedoidResult(
            race.index, race.mean * scale, ci * scale,
            race.n_computed, race.n_scalars, race.n_rounds,
            certified=False, exact_energy=False, survivors=survivors,
            extras={"engine": engine, "t": t})

    total_elems = race.n_computed + float(fin.n_computed)
    total_scalars = race.n_scalars + fin.n_distances
    certified = bool(fin.certified) and not bounds_seeded
    if certified:
        ci = 0.0        # deterministic certificate: no residual uncertainty
    else:
        # budget-capped, or seeded-bound (1-delta) elimination: residual
        # uncertainty is the bandit's half-width for its best arm
        # (unknown — NaN — when halving ran: it keeps no CIs)
        ci = (float(race.cis[0]) if engine == "ucb"
              else float("nan")) * scale
    return BanditMedoidResult(
        fin.index, fin.energy, ci, total_elems, total_scalars,
        race.n_rounds + fin.n_rounds,
        certified=certified, exact_energy=True, survivors=survivors,
        extras={"engine": engine, "t": t,
                "finisher_rows": int(fin.n_computed),
                "finisher_certified": bool(fin.certified),
                "seed_bounds": bounds_seeded})


# ---------------------------------------------------------------------------
# legacy entrypoint shim (deprecated — repro.api.solve is the front door)
# ---------------------------------------------------------------------------
def bandit_medoid(
    X,
    budget: float | None = None,
    delta: float = 0.01,
    exact: str | None = "trimed",
    engine: str = "ucb",
    metric: str = "l2",
    seed: int = 0,
    samples_per_round: int = 64,
    survivor_target: int | None = None,
    block: int = 128,
    bandit_frac: float = 0.5,
    seed_bounds: bool = False,
    use_kernels: bool = False,
    interpret=None,
) -> BanditMedoidResult:
    """**Deprecated** shim over ``solve(MedoidQuery(..., mode="anytime"))``
    (plan ``"hybrid"`` for ``exact="trimed"``, ``"bandit"`` otherwise)."""
    from repro.api import MedoidQuery, solve, _warn_legacy
    _warn_legacy("bandit_medoid", " (mode='anytime')")
    if exact not in ("trimed", None):
        raise ValueError(f"exact must be 'trimed' or None, got {exact!r}")
    q = MedoidQuery(
        X, metric=metric, mode="anytime", budget=budget, delta=delta,
        seed=seed, block=block, use_kernels=use_kernels,
        engine_opts={"engine": engine, "samples_per_round": samples_per_round,
                     "survivor_target": survivor_target,
                     "bandit_frac": bandit_frac, "seed_bounds": seed_bounds,
                     "interpret": interpret})
    plan = "hybrid" if exact == "trimed" else "bandit"
    return solve(q, plan=plan).extras["raw"]
