"""UCB racing: Meddit-style best-arm identification for the medoid.

Every element is an arm; pulling arm ``i`` means evaluating
``d(x_i, x_J)`` for a uniformly sampled reference column ``J`` — an
unbiased estimate of the internal energy ``E(i) = S(i)/N`` (sampling is
uniform *including self*, matching the sum-including-self convention in
``distances.py``). Per round, every surviving arm receives the same
``S`` freshly sampled reference columns (one shared gather, one
matmul-shaped ``(M, S)`` distance block — the sampled-column kernel of
``kernels/pairwise.py``), running first/second moments are updated, and
arms whose lower confidence bound exceeds the best arm's upper bound are
eliminated, all vectorised.

Confidence intervals follow Meddit's (arXiv:1711.00817) *practical*
construction — sub-Gaussian with the empirical per-arm variance:

    ci(i) = sqrt(2 v_i log(2 n / delta) / t)

with ``v_i`` the arm's unbiased empirical variance and the union bound
spread over the ``n`` arms. (Distances are bounded, hence sub-Gaussian;
Meddit's experiments drop the Maurer–Pontil range-correction term
exactly like this because it otherwise dominates the width at practical
``t`` — with it, elimination is too weak to beat the exact engines.
The guarantee is correspondingly empirical-Bernstein-flavoured rather
than worst-case.) Each arm races with the same pull count ``t`` (every
alive arm is sampled every round), so ``t`` is a scalar.

Like the pipelined engine (DESIGN.md §4), the survivor buffer lives on a
power-of-two compaction ladder: a jitted stage races at a fixed buffer
width until the live count falls below a quarter of it, then the host
re-compacts onto the next rung. Cost is counted in unified *computed
elements* (``distances.elements_computed``): ``M * S / N`` per
round — the *full resident buffer width* (padding and
already-dead lanes included — the device computes them), so the bandit's
numbers are conservative against the exact engines'.

Terminates when one arm remains, the survivor target is reached, the
element budget is spent, or ``t`` reaches ``t_cap`` (default ``N`` —
beyond that a full exact row would have been cheaper per arm; duplicate
arms are statistically indistinguishable, so a cap is required for
termination).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.distances import (elements_computed, pairwise,
                                  pow2_at_least)
from repro.kernels import ops as _ops

RACE_LADDER_MIN = 128   # survivor buffers never shrink below this size


@dataclass
class RaceResult:
    """Outcome of a UCB race (estimates on the internal ``E=S/N`` scale;
    see ``distances.py`` for both conventions)."""
    index: int                  # best arm by running mean
    mean: float                 # its energy estimate
    ci: float                   # its confidence half-width
    survivors: np.ndarray       # alive arms, best mean first
    means: np.ndarray           # their estimates
    cis: np.ndarray             # their half-widths
    lcb_full: np.ndarray        # (N,) last-known LCB per element, >= 0
    n_computed: float           # unified computed elements
    n_scalars: int              # scalar distance evaluations
    n_rounds: int
    t: int                      # samples per surviving arm
    extras: dict = field(default_factory=dict)


def _ci_of(sums, sqs, t, n_arms, delta):
    tf = jnp.maximum(t.astype(jnp.float32), 1.0)
    mean = sums / tf
    var = jnp.maximum(
        (sqs - tf * mean * mean) / jnp.maximum(tf - 1.0, 1.0), 0.0)
    log_term = jnp.log(2.0 * n_arms / delta)
    ci = jnp.sqrt(2.0 * var * log_term / tf)
    return mean, ci


@functools.partial(
    jax.jit,
    static_argnames=("s", "metric", "use_kernels", "interpret", "is_floor"),
)
def _race_stage(X, n_real, arm_idx, alive, sums, sqs, t, dmax, n_elems,
                key, budget_elems, target, t_cap, delta, n_arms0,
                s, metric, use_kernels, interpret, is_floor):
    """Race at a fixed (static) buffer width until the ladder trigger,
    the survivor target, the budget, or the pull cap fires. ``X`` may be
    row-padded; ``n_real`` bounds the sampling domain."""
    n = X.shape[0]
    m = arm_idx.shape[0]
    Xa = jnp.take(X, arm_idx, axis=0)             # stage-resident arms

    def cond(state):
        alive, t, n_elems = state[1], state[4], state[6]
        live = alive.sum()
        go = jnp.logical_and(live > 1, live > target)
        go = jnp.logical_and(go, n_elems < budget_elems)
        go = jnp.logical_and(go, t < t_cap)
        if is_floor:
            return go
        return jnp.logical_and(go, 4 * live > m)

    def body(state):
        (arm_idx, alive, sums, sqs, t, dmax, n_elems, n_rounds, key) = state
        key, sub = jax.random.split(key)
        samp = jax.random.randint(sub, (s,), 0, n_real)
        xs = jnp.take(X, samp, axis=0)
        if use_kernels:
            dsum, dsq, dmx = _ops.sample_stats(Xa, xs, metric=metric,
                                               interpret=interpret)
        else:
            d = pairwise(Xa, xs, metric)          # (M, S), VMEM-sized
            dsum = d.sum(axis=1)
            dsq = (d * d).sum(axis=1)
            dmx = d.max(axis=1)
        sums = sums + dsum
        sqs = sqs + dsq
        t = t + s
        dmax = jnp.maximum(dmax, jnp.where(alive, dmx, 0.0).max())
        # conservative accounting: the kernel computes the whole (M, S)
        # buffer block, dead/padded lanes included — charge all of it
        n_elems = n_elems + m * (s / n_real)

        mean, ci = _ci_of(sums, sqs, t, n_arms0, delta)
        mean_a = jnp.where(alive, mean, jnp.inf)
        best_ucb = (mean_a + jnp.where(alive, ci, 0.0)).min()
        # keep the best-mean arm unconditionally (ties / fp guards)
        best_arm = jnp.argmin(mean_a)
        kill = (mean - ci) > best_ucb
        kill = kill.at[best_arm].set(False)
        alive = jnp.logical_and(alive, ~kill)
        return (arm_idx, alive, sums, sqs, t, dmax, n_elems,
                n_rounds + 1, key)

    state = (arm_idx, alive, sums, sqs, t, dmax, n_elems,
             jnp.asarray(0, jnp.int32), key)
    state = jax.lax.while_loop(cond, body, state)
    (arm_idx, alive, sums, sqs, t, dmax, n_elems, n_rounds, key) = state
    mean, ci = _ci_of(sums, sqs, t, n_arms0, delta)
    return (arm_idx, alive, sums, sqs, t, dmax, n_elems, n_rounds, key,
            mean, ci)


def ucb_race(
    X,
    budget: float | None = None,
    delta: float = 0.01,
    metric: str = "l2",
    seed: int = 0,
    samples_per_round: int = 64,
    target: int = 1,
    t_cap: int | None = None,
    ladder_min: int = RACE_LADDER_MIN,
    use_kernels: bool = False,
    interpret=None,
) -> RaceResult:
    """Race all ``N`` arms down to ``target`` survivors (or until the
    ``budget`` in computed elements / the pull cap is exhausted). The
    sampled-column kernel covers the triangle/squared metrics; for the
    others the jnp path runs instead (same estimates)."""
    from repro.api.metrics import require_metric
    m = require_metric(metric, caller='ucb_race')
    if not m.kernel:
        use_kernels = False       # no Pallas distance tile for this metric
    X = jnp.asarray(X)
    n = X.shape[0]
    n_pad = pow2_at_least(n) - n
    Xp = jnp.pad(X, ((0, n_pad), (0, 0))) if n_pad else X
    s = int(min(samples_per_round, n))
    t_cap = int(n if t_cap is None else t_cap)
    budget_elems = np.float32(np.inf) if budget is None else float(budget)
    key = jax.random.PRNGKey(seed)

    m = Xp.shape[0]
    arm_idx = np.arange(m, dtype=np.int32)
    alive = arm_idx < n
    sums = np.zeros(m, np.float32)
    sqs = np.zeros(m, np.float32)
    t = jnp.asarray(0, jnp.int32)
    dmax = jnp.asarray(0.0, jnp.float32)
    n_elems = jnp.asarray(0.0, jnp.float32)
    lcb_full = np.zeros(n, np.float32)
    n_rounds = 0
    floor = max(int(ladder_min), 2 * max(int(target), 1))

    while True:
        out = _race_stage(
            Xp, n, jnp.asarray(arm_idx), jnp.asarray(alive),
            jnp.asarray(sums), jnp.asarray(sqs), t, dmax, n_elems, key,
            jnp.asarray(budget_elems, jnp.float32),
            jnp.asarray(int(target), jnp.int32),
            jnp.asarray(t_cap, jnp.int32),
            jnp.asarray(float(delta), jnp.float32),
            jnp.asarray(float(n), jnp.float32),
            s, metric, use_kernels, interpret,
            is_floor=len(arm_idx) <= floor)
        (arm_idx_d, alive_d, sums_d, sqs_d, t, dmax, n_elems, r_d, key,
         mean_d, ci_d) = out
        n_rounds += int(r_d)
        arm_idx = np.asarray(arm_idx_d)
        alive = np.asarray(alive_d)
        sums = np.asarray(sums_d)
        sqs = np.asarray(sqs_d)
        mean = np.asarray(mean_d)
        ci = np.asarray(ci_d)
        # record last-known LCBs for every arm still in the buffer (the
        # bandit hand-off's probabilistic bound seed, DESIGN.md §9)
        in_buf = arm_idx < n
        if int(t) > 0:
            lcb_full[arm_idx[in_buf]] = np.maximum(
                mean[in_buf] - ci[in_buf], 0.0)
        live = int(alive.sum())
        spent = float(n_elems)
        done = (live <= max(1, int(target)) or spent >= budget_elems
                or int(t) >= t_cap)
        next_m = max(pow2_at_least(max(live, 1)), floor)
        if done or next_m >= len(arm_idx):
            break
        keep = np.flatnonzero(alive)              # host-side compaction
        pad = next_m - len(keep)
        arm_idx = np.concatenate(
            [arm_idx[keep], np.full(pad, n, np.int32)]).astype(np.int32)
        alive = np.arange(next_m) < len(keep)
        sums = np.concatenate([sums[keep], np.zeros(pad, np.float32)])
        sqs = np.concatenate([sqs[keep], np.zeros(pad, np.float32)])

    order = np.argsort(np.where(alive, mean, np.inf), kind="stable")
    order = order[: live if live else 1]
    surv = arm_idx[order].astype(np.int64)
    means_s = mean[order].astype(np.float64)
    cis_s = ci[order].astype(np.float64)
    n_elems_f = float(n_elems)
    return RaceResult(
        index=int(surv[0]),
        mean=float(means_s[0]),
        ci=float(cis_s[0]),
        survivors=surv,
        means=means_s,
        cis=cis_s,
        lcb_full=lcb_full,
        n_computed=elements_computed(n_elems_f * n, n),
        n_scalars=int(round(n_elems_f * n)),
        n_rounds=n_rounds,
        t=int(t),
        extras={"dmax": float(dmax)},
    )
