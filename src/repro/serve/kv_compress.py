"""Medoid KV-cache compression (beyond-paper application of trikmeds).

For long prompts, per-(layer, head) keys are clustered with device-side
K-medoids; attention then runs over ``K`` medoid keys with log-cluster-
size corrected scores:

    softmax_j ( q . k_mj + log |C_j| )

i.e. each medoid stands in for its cluster with a mass prior — exact
when clusters are tight, sub-quadratic always: decode cost drops from
O(S) to O(K) per token. Medoids are *actual cached keys* (medoid
property), so no re-normalisation drift: the paired values are the
cluster-mean values (mass-weighted), computed in the same pass.

This is the serving option that makes ``long_500k`` admissible for
full-attention archs (reported separately from the baseline cells —
DESIGN.md §7)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.trikmeds import kmedoids_jax


@functools.partial(jax.jit, static_argnames=("k", "n_iter"))
def compress_head(keys, values, k: int, n_iter: int = 5, seed: int = 0):
    """keys/values: (S, hd). Returns (medoid_keys (k, hd),
    mean_values (k, hd), log_counts (k,))."""
    m_idx, assign, _ = kmedoids_jax(keys.astype(jnp.float32), k,
                                    seed=seed, n_iter=n_iter)
    med_k = jnp.take(keys, m_idx, axis=0)
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)     # (S, K)
    counts = onehot.sum(axis=0)                               # (K,)
    vsum = onehot.T @ values.astype(jnp.float32)              # (K, hd)
    mean_v = vsum / jnp.maximum(counts[:, None], 1.0)
    return med_k, mean_v.astype(values.dtype), jnp.log(
        jnp.maximum(counts, 1.0))


def compress_cache(cache_k, cache_v, k: int, n_iter: int = 5):
    """cache_k/v: (B, S, KV, hd) -> compressed (B, k, KV, hd) + log-mass
    (B, k, KV). vmapped over batch and heads."""
    def per_head(kk, vv):
        return compress_head(kk, vv, k, n_iter)

    # outer vmap strips B; per-element arrays are (S, KV, hd) -> heads
    # live on axis 1
    fn = jax.vmap(jax.vmap(per_head, in_axes=1, out_axes=(1, 1, 1)),
                  in_axes=0, out_axes=0)
    med_k, mean_v, logm = fn(cache_k, cache_v)
    # axes: (B, k, KV, hd) / (B, k, KV)
    return med_k, mean_v, logm


def compressed_decode_attention(q, med_k, mean_v, logm):
    """q: (B, 1, H, hd); med_k/mean_v: (B, K, KV, hd); logm: (B, K, KV).
    GQA-aware medoid attention with cluster-mass prior."""
    b, _, h, hd = q.shape
    kv = med_k.shape[2]
    g = h // kv
    qg = q.reshape(b, 1, kv, g, hd) * hd ** -0.5
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, med_k,
                   preferred_element_type=jnp.float32)
    s = s + logm.transpose(0, 2, 1)[:, :, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(mean_v.dtype), mean_v,
                     preferred_element_type=jnp.float32)
    return jnp.moveaxis(out, 3, 1).reshape(b, 1, h, hd).astype(mean_v.dtype)
