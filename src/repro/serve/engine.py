"""Serving engines: the medoid admission scheduler and the LM decode loop.

Two servers share this module:

* :class:`MedoidServer` — the many-query medoid scheduler (DESIGN.md
  §12). It replaces the idle slot-based pattern for medoid traffic:
  instead of fixed slots refilled one query at a time, each scheduling
  step drains the FIFO queue, packs compatible queries into shape
  buckets, and admits them against a **global element budget** using
  ``plan.cost_estimate`` (the planner's calibrated predicted row
  count). The FIFO prefix whose cumulative estimate fits the budget
  runs exact; the overflow is *never dropped* — it degrades to
  ``mode="anytime"`` with the leftover budget split evenly (down to a
  floor), coming back ``certified=False`` with a recorded deterministic
  CI. Execution is one ``solve_many`` call per step, so every bucket is
  a single jitted program.

* :class:`ServeEngine` — the LM continuous-batching decode loop: a
  slot-based engine in the vLLM style adapted to JAX static shapes
  (``n_slots`` sequences decode in lockstep; finished slots are
  refilled between steps; admission happens on host, the decode step is
  one jitted call). The medoid KV-compression hook
  (`repro.serve.kv_compress`) can be applied per-slot at admission time
  for long prompts — the per-head queries it emits are exactly the
  small same-shape traffic :class:`MedoidServer` packs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M

#: versioned schema of the MedoidServer structured event log
SERVE_EVENTS_SCHEMA = "repro.obs.serve/v1"


# ---------------------------------------------------------------------------
# medoid serving: budget-aware admission over solve_many
# ---------------------------------------------------------------------------
@dataclass
class MedoidRequest:
    """One queued medoid query plus its serving outcome."""
    uid: int
    query: object                       # MedoidQuery
    cost_estimate: float = 0.0          # plan.cost_estimate at admission
    admitted_mode: str = ""             # "exact" | "anytime"
    step: int = -1                      # scheduling step that served it
    report: object = None               # SolveReport once served
    retries: int = 0                    # failed attempts so far
    quarantined: bool = False           # tombstoned after max_retries
    error: str = ""                     # last failure (empty if none)
    not_before_step: int = 0            # backoff: earliest eligible step
    decisions: list = field(default_factory=list)   # isolation audit trail


class MedoidServer:
    """Budget-aware admission scheduler over :func:`repro.api.solve_many`.

    ``budget`` is the global element budget per scheduling step (in the
    unified computed-row currency every engine reports). Admission is
    FIFO: walking the queue in submission order, a request is admitted
    *exact* while the running sum of ``plan.cost_estimate`` stays within
    the budget; every later request in the step is admitted *anytime*
    with a per-query cap of the leftover budget split evenly (at least
    ``anytime_floor`` elements, so every request returns an answer with
    a recorded CI — over-budget traffic degrades, it is never dropped).
    One ``solve_many`` call serves the whole step, so same-shape
    requests share jitted programs regardless of admitted mode (budgets
    are traced, not compiled).

    Fault isolation (DESIGN.md §13): a failing query inside a packed
    step is bisected out — the step's ``solve_many`` call is split in
    halves until the failure is pinned to a single request, which is
    re-solved solo with ``on_error="degrade"``. A request that still
    fails is requeued with exponential backoff (``backoff_base * 2**k``
    steps) and quarantined after ``max_retries`` with a tombstone
    report (``indices=[-1]``, ``ci=inf``, the error and every isolation
    decision in ``extras``). Healthy requests in the same step are
    never blocked and nothing is ever dropped. ``step_deadline_s``
    bounds one step's wall clock: once blown, *remaining* bisection
    work is deferred to the next step (the initial packed attempt
    always runs, so a step always makes progress).
    """

    def __init__(self, budget: float = 50_000.0, anytime_floor: int = 32,
                 max_batch: int = 4096, max_queries_per_program=None,
                 max_retries: int = 2, backoff_base: int = 1,
                 step_deadline_s: float | None = None):
        if budget <= 0:
            raise ValueError("MedoidServer: budget must be positive")
        self.budget = float(budget)
        self.anytime_floor = max(int(anytime_floor), 1)
        self.max_batch = int(max_batch)
        self.max_queries_per_program = max_queries_per_program
        self.max_retries = max(int(max_retries), 0)
        self.backoff_base = max(int(backoff_base), 1)
        self.step_deadline_s = step_deadline_s
        self.queue: list[MedoidRequest] = []
        self.finished: list[MedoidRequest] = []
        self.steps: list[dict] = []
        self._uid = 0
        # observability (DESIGN.md §14): a private registry (concurrent
        # servers must not alias) + a structured event log. Every
        # isolation decision lands here as a typed event; the human-
        # readable line in ``req.decisions`` is derived from it.
        from repro.obs.metrics import MetricsRegistry
        self.metrics = MetricsRegistry()
        self.events: list[dict] = []
        # resident streaming indexes (attach_index / index_query)
        self.indexes: dict[str, object] = {}

    # -------------------------------------------------- observability
    def metrics_text(self) -> str:
        """The Prometheus-style scrape endpoint: current queue depth,
        admitted/degraded/quarantined counts, budget utilisation,
        backoff/retry counters — exposition text format."""
        self.metrics.gauge(
            "serve_queue_depth", "requests waiting in the FIFO queue"
        ).set(len(self.queue))
        return self.metrics.to_text()

    def _event(self, kind: str, req: "MedoidRequest | None" = None,
               decision: str | None = None, **fields) -> dict:
        """Append one structured event (schema ``repro.obs.serve/v1``);
        mirrors the human-readable ``decision`` line into the request's
        isolation audit trail."""
        ev = {"kind": kind, "schema": SERVE_EVENTS_SCHEMA, **fields}
        if req is not None:
            ev["uid"] = req.uid
        self.events.append(ev)
        if req is not None and decision is not None:
            req.decisions.append(decision)
        return ev

    # ------------------------------------------------------------ admin
    def submit(self, query) -> int:
        """Queue a single-medoid query; returns its uid. Eligibility is
        checked here (fail fast) with ``solve_many``'s own validator."""
        from repro.api.batch import _validate
        _validate(query, len(self.queue))
        req = MedoidRequest(self._uid, query)
        self._uid += 1
        self.queue.append(req)
        return req.uid

    # ------------------------------------------------------------- step
    def step(self) -> list[MedoidRequest]:
        """One scheduling step: admit, pack, solve, return the requests
        that got a report this step (FIFO order — served or
        quarantined). Failing requests are isolated, retried with
        backoff, and requeued; an empty/ineligible queue returns []."""
        from repro.api import solve
        from repro.runtime import faults

        step_no = len(self.steps)
        eligible = [r for r in self.queue if r.not_before_step <= step_no]
        if not eligible:
            if self.queue:
                # advance the step clock so backoff holds expire even
                # when a step finds nothing eligible
                self.steps.append({"step": step_no, "n_requests": 0,
                                   "idle": True})
            return []
        held = [r for r in self.queue if r.not_before_step > step_no]
        batch = eligible[:self.max_batch]
        self.queue = sorted(eligible[self.max_batch:] + held,
                            key=lambda r: r.uid)
        deadline_ts = (faults.clock() + float(self.step_deadline_s)
                       if self.step_deadline_s is not None else None)

        # pass 1 — FIFO exact admission against the global budget
        spent_est = 0.0
        overflow: list[MedoidRequest] = []
        for req in batch:
            plan = solve(req.query, plan="pipelined", explain=True)
            req.cost_estimate = float(plan.cost_estimate)
            if not overflow and spent_est + req.cost_estimate <= self.budget:
                req.admitted_mode = "exact"
                spent_est += req.cost_estimate
            else:
                # keep FIFO: once one request overflows, later ones do
                # not leapfrog it even if they would fit
                req.admitted_mode = "anytime"
                overflow.append(req)

        # pass 2 — split the leftover across the overflow, floor-clamped
        leftover = max(self.budget - spent_est, 0.0)
        cap = max(self.anytime_floor,
                  int(leftover // max(len(overflow), 1)))
        effective = [
            req.query if req.admitted_mode == "exact"
            else req.query.with_(mode="anytime", budget=float(cap))
            for req in batch]

        # pass 3 — solve with per-request isolation
        outcomes = self._solve_isolated(effective, deadline_ts)

        served: list[MedoidRequest] = []
        requeue: list[MedoidRequest] = []
        spent = 0.0
        n_failed = n_quarantined = n_deferred = 0
        for req, (kind, payload) in zip(batch, outcomes):
            if kind == "ok":
                rep = payload
                if req.retries or req.decisions:
                    rep.extras.setdefault("serve", {}).update(
                        retries=req.retries,
                        decisions=list(req.decisions))
                req.report = rep
                req.step = step_no
                spent += rep.elements_computed
                served.append(req)
                self.finished.append(req)
            elif kind == "deferred":
                n_deferred += 1
                self._event(
                    "deferred", req, step=step_no,
                    decision=(
                        f"step {step_no}: step deadline blown before this "
                        "request's bisection half ran; deferred to next "
                        "step"))
                self.metrics.counter(
                    "serve_deferred_total",
                    "bisection halves deferred past a step deadline").inc()
                req.not_before_step = step_no + 1
                requeue.append(req)
            else:                                   # kind == "err"
                n_failed += 1
                req.retries += 1
                req.error = payload
                self._event(
                    "failure", req, step=step_no, attempt=req.retries,
                    error=payload,
                    decision=(f"step {step_no}: attempt {req.retries} "
                              f"failed: {payload}"))
                self.metrics.counter(
                    "serve_failures_total",
                    "request attempts that raised").inc()
                if req.retries > self.max_retries:
                    n_quarantined += 1
                    req.quarantined = True
                    self._event(
                        "quarantine", req, step=step_no,
                        attempts=req.retries,
                        decision=(
                            f"step {step_no}: quarantined after "
                            f"{req.retries} failed attempts "
                            f"(max_retries={self.max_retries})"))
                    self.metrics.counter(
                        "serve_quarantined_total",
                        "requests tombstoned after max_retries").inc()
                    req.report = self._tombstone(req)
                    req.step = step_no
                    served.append(req)
                    self.finished.append(req)
                else:
                    backoff = self.backoff_base * (2 ** (req.retries - 1))
                    self._event(
                        "backoff", req, step=step_no, retries=req.retries,
                        backoff_steps=backoff,
                        decision=(f"step {step_no}: requeued with backoff "
                                  f"{backoff} step(s)"))
                    self.metrics.counter(
                        "serve_retries_total",
                        "failed requests requeued for retry").inc()
                    self.metrics.counter(
                        "serve_backoff_steps_total",
                        "cumulative backoff delay in steps").inc(backoff)
                    req.not_before_step = step_no + backoff
                    requeue.append(req)
        if requeue:
            self.queue = sorted(self.queue + requeue, key=lambda r: r.uid)

        reports = [r.report for r in served]
        # cost-model calibration: engine-reported elements vs the
        # planner's admission estimate, over the exact-admitted requests
        # actually served (anytime caps and tombstones would skew it)
        cal = [r for r in served
               if r.admitted_mode == "exact" and not r.quarantined]
        est_exact = sum(r.cost_estimate for r in cal)
        spent_exact = sum(r.report.elements_computed for r in cal)
        cost_err = (spent_exact / est_exact) if est_exact > 0 else None
        self.steps.append({
            "step": step_no,
            "n_requests": len(batch),
            "n_exact": len(batch) - len(overflow),
            "n_anytime": len(overflow),
            "n_failed": n_failed,
            "n_quarantined": n_quarantined,
            "n_deferred": n_deferred,
            "anytime_cap": cap if overflow else 0,
            "estimated_elements": spent_est,
            "spent_elements": spent,
            "cost_estimate_error": cost_err,
            "buckets": sorted({rep.plan.params["solve_many"]["bucket"]
                               for rep in reports
                               if "solve_many" in rep.plan.params}),
        })
        mx = self.metrics
        mx.counter("serve_requests_total",
                   "requests served, by admitted mode").inc(
                       len(batch) - len(overflow), mode="exact")
        if overflow:
            mx.counter("serve_requests_total",
                       "requests served, by admitted mode").inc(
                           len(overflow), mode="anytime")
        mx.histogram("serve_budget_utilisation",
                     "spent_elements / budget per step").observe(
                         spent / self.budget)
        if cost_err is not None:
            mx.histogram("serve_cost_estimate_error",
                         "spent / estimated elements over exact-admitted "
                         "requests per step").observe(cost_err)
        mx.gauge("serve_queue_depth",
                 "requests waiting in the FIFO queue").set(len(self.queue))
        self._event("step", step=step_no, n_requests=len(batch),
                    n_exact=len(batch) - len(overflow),
                    n_anytime=len(overflow), n_failed=n_failed,
                    n_quarantined=n_quarantined, n_deferred=n_deferred,
                    estimated_elements=spent_est, spent_elements=spent,
                    cost_estimate_error=cost_err)
        return served

    # ----------------------------------------------------- fault paths
    def _solve_isolated(self, queries, deadline_ts):
        """Run the step's queries through ``solve_many``, bisecting out
        failures. Returns one ``(kind, payload)`` per query in order:
        ``("ok", report)``, ``("err", message)``, or
        ``("deferred", None)`` when the step deadline cut bisection
        short."""
        from repro.api import solve_many
        from repro.runtime import faults

        out: dict[int, tuple] = {}

        def run(idx):
            qs = [queries[i] for i in idx]
            try:
                reps = solve_many(
                    qs,
                    max_queries_per_program=self.max_queries_per_program)
                for i, rep in zip(idx, reps):
                    out[i] = ("ok", rep)
            except Exception as err:
                if len(idx) == 1:
                    out[idx[0]] = self._solo(queries[idx[0]], err,
                                             deadline_ts)
                    return
                mid = len(idx) // 2
                for half in (idx[:mid], idx[mid:]):
                    if (deadline_ts is not None
                            and faults.clock() >= deadline_ts):
                        for i in half:
                            out[i] = ("deferred", None)
                    else:
                        run(half)

        run(list(range(len(queries))))
        return [out[i] for i in range(len(queries))]

    def _solo(self, q, err, deadline_ts):
        """Size-1 fallback for a bisected-out query: re-solve it alone
        through the planner with the full downgrade ladder."""
        from repro.api import solve
        from repro.runtime import faults

        changes = {"on_error": "degrade"}
        if deadline_ts is not None and q.mode == "exact":
            changes["deadline_s"] = max(deadline_ts - faults.clock(), 0.05)
        try:
            rep = solve(q.with_(**changes))
            rep.extras.setdefault("serve", {})["isolated"] = (
                f"packed batch failed ({type(err).__name__}: {err}); "
                "re-solved solo with on_error='degrade'")
            return ("ok", rep)
        except Exception as e2:
            return ("err", f"{type(e2).__name__}: {e2}")

    def _tombstone(self, req):
        """The quarantine report: a well-formed SolveReport that cannot
        be mistaken for an answer (``indices=[-1]``, ``ci=inf``)."""
        from repro.api.planner import Plan
        from repro.api.query import SolveReport

        return SolveReport(
            indices=np.asarray([-1], np.int64),
            energies=np.asarray([float("nan")], np.float64),
            certified=False,
            elements_computed=0.0,
            n_distances=0,
            n_rounds=0,
            ci=float("inf"),
            plan=Plan("quarantined", tuple(req.decisions)),
            extras={"error": req.error, "retries": req.retries,
                    "quarantined": True,
                    "decisions": list(req.decisions)},
        )

    def run(self, max_steps: int = 10_000) -> list[MedoidRequest]:
        """Drain the queue; returns all finished requests."""
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # -------------------------------------------------- stateful indexes
    # One-shot queries above are stateless: each request re-solves its
    # own X. The index mode keeps named ``repro.stream.MedoidIndex``
    # instances resident so repeat traffic over a churning dataset pays
    # incremental repair instead of a fresh solve per request. Churn and
    # queries land in the same ``repro.obs.serve/v1`` event log as the
    # scheduler's isolation decisions, and the stream instrument family
    # (``repro_obs_stream_*``) registers on the server's own registry.
    def attach_index(self, index, name: str = "default"):
        """Make ``index`` resident under ``name`` (replacing any
        previous holder) and point its metrics at the server registry."""
        index.bind_metrics(self.metrics)
        self.indexes[name] = index
        self._event("index_attach", name=name, n=index.n,
                    metric=index.metric)
        return index

    def _index(self, name: str):
        if name not in self.indexes:
            raise KeyError(
                f"no index named {name!r} is attached (have: "
                f"{sorted(self.indexes)}); call attach_index first")
        return self.indexes[name]

    def index_insert(self, rows, name: str = "default") -> None:
        ix = self._index(name)
        ix.insert(rows)
        self._event("index_churn", name=name, op="insert",
                    k=int(np.atleast_2d(rows).shape[0]), n=ix.n)

    def index_delete(self, idx, name: str = "default") -> None:
        ix = self._index(name)
        ix.delete(idx)
        self._event("index_churn", name=name, op="delete",
                    k=int(np.atleast_1d(idx).size), n=ix.n)

    def index_update(self, idx, rows, name: str = "default") -> None:
        ix = self._index(name)
        ix.update(idx, rows)
        self._event("index_churn", name=name, op="update",
                    k=int(np.atleast_1d(idx).size), n=ix.n)

    def index_query(self, name: str = "default"):
        """The exact medoid of the named index's current rows (bit-for-
        bit a fresh solve); repair cost lands in the event payload."""
        ix = self._index(name)
        before = ix.stats["elements_total"]
        res = ix.query()
        self._event("index_query", name=name, n=ix.n,
                    index=int(res.index), energy=float(res.energy),
                    certified=bool(res.certified),
                    elements=float(ix.stats["elements_total"] - before),
                    repairs=int(ix.stats["repairs"]),
                    full_resolves=int(ix.stats["full_resolves"]))
        return res


@dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, n_slots: int = 4, max_len: int = 512,
                 temperature: float = 0.0, seed: int = 0):
        if not cfg.supports_decode:
            raise ValueError(f"{cfg.name} is encoder-only")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self.cache = M.init_cache(cfg, n_slots, max_len)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.queue: list[Request] = []
        self.finished: list[Request] = []

        # donate the cache: in-place KV update, halves decode peak memory
        self._decode = jax.jit(
            lambda p, t, c, i: M.decode_step(cfg, p, t, c, i),
            donate_argnums=(2,))
        # per-slot prefill: batch of 1, padded static length buckets
        self._prefill = jax.jit(
            lambda p, toks, c: M.prefill(cfg, p, {"tokens": toks}, c),
        )

    # ------------------------------------------------------------ admin
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            # single-sequence prefill into a 1-slot cache, then splice
            tmp = M.init_cache(self.cfg, 1, self.max_len)
            last, tmp = self._prefill(self.params, toks, tmp)
            self.cache = jax.tree.map(
                lambda c, t: jax.lax.dynamic_update_slice_in_dim(
                    c, t.astype(c.dtype), s, axis=1),
                self.cache, tmp)
            tok = self._sample(last)
            req.out_tokens.append(int(tok[0]))
            self.slot_req[s] = req
            self.slot_pos[s] = len(req.prompt)

    def _sample(self, logits):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, -1)

    # ------------------------------------------------------------- step
    def step(self):
        """One lockstep decode across active slots."""
        self._admit()
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return False
        last = jnp.asarray(
            [self.slot_req[s].out_tokens[-1] if self.slot_req[s] else 0
             for s in range(self.n_slots)], jnp.int32)[:, None]
        # lockstep: all slots share one write index per step; we use the
        # max position and per-slot masking via positions array
        idx = jnp.asarray(int(self.slot_pos.max()), jnp.int32)
        logits, self.cache = self._decode(self.params, last, self.cache, idx)
        tok = self._sample(logits)
        for s in active:
            req = self.slot_req[s]
            req.out_tokens.append(int(tok[s]))
            self.slot_pos[s] += 1
            if (len(req.out_tokens) >= req.max_new_tokens
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
