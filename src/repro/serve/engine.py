"""Serving engines: the medoid admission scheduler and the LM decode loop.

Two servers share this module:

* :class:`MedoidServer` — the many-query medoid scheduler (DESIGN.md
  §12). It replaces the idle slot-based pattern for medoid traffic:
  instead of fixed slots refilled one query at a time, each scheduling
  step drains the FIFO queue, packs compatible queries into shape
  buckets, and admits them against a **global element budget** using
  ``plan.cost_estimate`` (the planner's calibrated predicted row
  count). The FIFO prefix whose cumulative estimate fits the budget
  runs exact; the overflow is *never dropped* — it degrades to
  ``mode="anytime"`` with the leftover budget split evenly (down to a
  floor), coming back ``certified=False`` with a recorded deterministic
  CI. Execution is one ``solve_many`` call per step, so every bucket is
  a single jitted program.

* :class:`ServeEngine` — the LM continuous-batching decode loop: a
  slot-based engine in the vLLM style adapted to JAX static shapes
  (``n_slots`` sequences decode in lockstep; finished slots are
  refilled between steps; admission happens on host, the decode step is
  one jitted call). The medoid KV-compression hook
  (`repro.serve.kv_compress`) can be applied per-slot at admission time
  for long prompts — the per-head queries it emits are exactly the
  small same-shape traffic :class:`MedoidServer` packs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


# ---------------------------------------------------------------------------
# medoid serving: budget-aware admission over solve_many
# ---------------------------------------------------------------------------
@dataclass
class MedoidRequest:
    """One queued medoid query plus its serving outcome."""
    uid: int
    query: object                       # MedoidQuery
    cost_estimate: float = 0.0          # plan.cost_estimate at admission
    admitted_mode: str = ""             # "exact" | "anytime"
    step: int = -1                      # scheduling step that served it
    report: object = None               # SolveReport once served


class MedoidServer:
    """Budget-aware admission scheduler over :func:`repro.api.solve_many`.

    ``budget`` is the global element budget per scheduling step (in the
    unified computed-row currency every engine reports). Admission is
    FIFO: walking the queue in submission order, a request is admitted
    *exact* while the running sum of ``plan.cost_estimate`` stays within
    the budget; every later request in the step is admitted *anytime*
    with a per-query cap of the leftover budget split evenly (at least
    ``anytime_floor`` elements, so every request returns an answer with
    a recorded CI — over-budget traffic degrades, it is never dropped).
    One ``solve_many`` call serves the whole step, so same-shape
    requests share jitted programs regardless of admitted mode (budgets
    are traced, not compiled).
    """

    def __init__(self, budget: float = 50_000.0, anytime_floor: int = 32,
                 max_batch: int = 4096, max_queries_per_program=None):
        if budget <= 0:
            raise ValueError("MedoidServer: budget must be positive")
        self.budget = float(budget)
        self.anytime_floor = max(int(anytime_floor), 1)
        self.max_batch = int(max_batch)
        self.max_queries_per_program = max_queries_per_program
        self.queue: list[MedoidRequest] = []
        self.finished: list[MedoidRequest] = []
        self.steps: list[dict] = []
        self._uid = 0

    # ------------------------------------------------------------ admin
    def submit(self, query) -> int:
        """Queue a single-medoid query; returns its uid. Eligibility is
        checked here (fail fast) with ``solve_many``'s own validator."""
        from repro.api.batch import _validate
        _validate(query, len(self.queue))
        req = MedoidRequest(self._uid, query)
        self._uid += 1
        self.queue.append(req)
        return req.uid

    # ------------------------------------------------------------- step
    def step(self) -> list[MedoidRequest]:
        """One scheduling step: admit, pack, solve, return the served
        requests (FIFO order). Empty queue returns []."""
        from repro.api import solve, solve_many

        if not self.queue:
            return []
        batch = self.queue[:self.max_batch]
        self.queue = self.queue[self.max_batch:]

        # pass 1 — FIFO exact admission against the global budget
        spent_est = 0.0
        overflow: list[MedoidRequest] = []
        for req in batch:
            plan = solve(req.query, plan="pipelined", explain=True)
            req.cost_estimate = float(plan.cost_estimate)
            if not overflow and spent_est + req.cost_estimate <= self.budget:
                req.admitted_mode = "exact"
                spent_est += req.cost_estimate
            else:
                # keep FIFO: once one request overflows, later ones do
                # not leapfrog it even if they would fit
                req.admitted_mode = "anytime"
                overflow.append(req)

        # pass 2 — split the leftover across the overflow, floor-clamped
        leftover = max(self.budget - spent_est, 0.0)
        cap = max(self.anytime_floor,
                  int(leftover // max(len(overflow), 1)))
        effective = [
            req.query if req.admitted_mode == "exact"
            else req.query.with_(mode="anytime", budget=float(cap))
            for req in batch]

        reports = solve_many(effective,
                             max_queries_per_program=self.max_queries_per_program)

        step_no = len(self.steps)
        spent = 0.0
        for req, rep in zip(batch, reports):
            req.report = rep
            req.step = step_no
            spent += rep.elements_computed
        self.finished.extend(batch)
        self.steps.append({
            "step": step_no,
            "n_requests": len(batch),
            "n_exact": len(batch) - len(overflow),
            "n_anytime": len(overflow),
            "anytime_cap": cap if overflow else 0,
            "estimated_elements": spent_est,
            "spent_elements": spent,
            "buckets": sorted({rep.plan.params["solve_many"]["bucket"]
                               for rep in reports
                               if "solve_many" in rep.plan.params}),
        })
        return batch

    def run(self, max_steps: int = 10_000) -> list[MedoidRequest]:
        """Drain the queue; returns all finished requests."""
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1
        return self.finished


@dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, n_slots: int = 4, max_len: int = 512,
                 temperature: float = 0.0, seed: int = 0):
        if not cfg.supports_decode:
            raise ValueError(f"{cfg.name} is encoder-only")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self.cache = M.init_cache(cfg, n_slots, max_len)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.queue: list[Request] = []
        self.finished: list[Request] = []

        # donate the cache: in-place KV update, halves decode peak memory
        self._decode = jax.jit(
            lambda p, t, c, i: M.decode_step(cfg, p, t, c, i),
            donate_argnums=(2,))
        # per-slot prefill: batch of 1, padded static length buckets
        self._prefill = jax.jit(
            lambda p, toks, c: M.prefill(cfg, p, {"tokens": toks}, c),
        )

    # ------------------------------------------------------------ admin
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            # single-sequence prefill into a 1-slot cache, then splice
            tmp = M.init_cache(self.cfg, 1, self.max_len)
            last, tmp = self._prefill(self.params, toks, tmp)
            self.cache = jax.tree.map(
                lambda c, t: jax.lax.dynamic_update_slice_in_dim(
                    c, t.astype(c.dtype), s, axis=1),
                self.cache, tmp)
            tok = self._sample(last)
            req.out_tokens.append(int(tok[0]))
            self.slot_req[s] = req
            self.slot_pos[s] = len(req.prompt)

    def _sample(self, logits):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, -1)

    # ------------------------------------------------------------- step
    def step(self):
        """One lockstep decode across active slots."""
        self._admit()
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return False
        last = jnp.asarray(
            [self.slot_req[s].out_tokens[-1] if self.slot_req[s] else 0
             for s in range(self.n_slots)], jnp.int32)[:, None]
        # lockstep: all slots share one write index per step; we use the
        # max position and per-slot masking via positions array
        idx = jnp.asarray(int(self.slot_pos.max()), jnp.int32)
        logits, self.cache = self._decode(self.params, last, self.cache, idx)
        tok = self._sample(logits)
        for s in active:
            req = self.slot_req[s]
            req.out_tokens.append(int(tok[s]))
            self.slot_pos[s] += 1
            if (len(req.out_tokens) >= req.max_new_tokens
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
