"""Batched serving engine: continuous-batching decode loop.

A slot-based engine in the vLLM style, adapted to JAX static shapes:
``n_slots`` sequences decode in lockstep; finished slots are refilled
from the request queue between steps (admission happens on host, the
decode step itself is one jitted call). Per-slot write positions allow
ragged sequence lengths inside one static cache.

The medoid KV-compression hook (`repro.serve.kv_compress`) can be
applied per-slot at admission time for long prompts.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, n_slots: int = 4, max_len: int = 512,
                 temperature: float = 0.0, seed: int = 0):
        if not cfg.supports_decode:
            raise ValueError(f"{cfg.name} is encoder-only")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)

        self.cache = M.init_cache(cfg, n_slots, max_len)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.queue: list[Request] = []
        self.finished: list[Request] = []

        # donate the cache: in-place KV update, halves decode peak memory
        self._decode = jax.jit(
            lambda p, t, c, i: M.decode_step(cfg, p, t, c, i),
            donate_argnums=(2,))
        # per-slot prefill: batch of 1, padded static length buckets
        self._prefill = jax.jit(
            lambda p, toks, c: M.prefill(cfg, p, {"tokens": toks}, c),
        )

    # ------------------------------------------------------------ admin
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            # single-sequence prefill into a 1-slot cache, then splice
            tmp = M.init_cache(self.cfg, 1, self.max_len)
            last, tmp = self._prefill(self.params, toks, tmp)
            self.cache = jax.tree.map(
                lambda c, t: jax.lax.dynamic_update_slice_in_dim(
                    c, t.astype(c.dtype), s, axis=1),
                self.cache, tmp)
            tok = self._sample(last)
            req.out_tokens.append(int(tok[0]))
            self.slot_req[s] = req
            self.slot_pos[s] = len(req.prompt)

    def _sample(self, logits):
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, -1)

    # ------------------------------------------------------------- step
    def step(self):
        """One lockstep decode across active slots."""
        self._admit()
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return False
        last = jnp.asarray(
            [self.slot_req[s].out_tokens[-1] if self.slot_req[s] else 0
             for s in range(self.n_slots)], jnp.int32)[:, None]
        # lockstep: all slots share one write index per step; we use the
        # max position and per-slot masking via positions array
        idx = jnp.asarray(int(self.slot_pos.max()), jnp.int32)
        logits, self.cache = self._decode(self.params, last, self.cache, idx)
        tok = self._sample(logits)
        for s in active:
            req = self.slot_req[s]
            req.out_tokens.append(int(tok[s]))
            self.slot_pos[s] += 1
            if (len(req.out_tokens) >= req.max_new_tokens
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                self.finished.append(req)
                self.slot_req[s] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
