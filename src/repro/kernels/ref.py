"""Pure-jnp oracles for the Pallas kernels (the allclose references)."""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_ref(xb: jnp.ndarray, x: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """(B, N) distance block, fp32 accumulation."""
    xb = xb.astype(jnp.float32)
    x = x.astype(jnp.float32)
    if metric in ("l2", "sqeuclidean"):
        d2 = (
            jnp.sum(xb * xb, axis=1)[:, None]
            + jnp.sum(x * x, axis=1)[None, :]
            - 2.0 * (xb @ x.T)
        )
        d2 = jnp.maximum(d2, 0.0)
        return d2 if metric == "sqeuclidean" else jnp.sqrt(d2)
    if metric == "l1":
        return jnp.abs(xb[:, None, :] - x[None, :, :]).sum(-1)
    raise ValueError(metric)


def energy_ref(xb: jnp.ndarray, x: jnp.ndarray, metric: str = "l2") -> jnp.ndarray:
    """(B,) row-sums of the distance block (un-normalised energies)."""
    return pairwise_ref(xb, x, metric).sum(axis=1)


def bound_update_ref(
    xb: jnp.ndarray,
    x: jnp.ndarray,
    e: jnp.ndarray,
    l: jnp.ndarray,
    valid: jnp.ndarray,
    metric: str = "l2",
) -> jnp.ndarray:
    """l(j) <- max(l(j), max_b |E(b) - D(b, j)|), only over valid pivots."""
    d = pairwise_ref(xb, x, metric)
    gap = jnp.abs(e.astype(jnp.float32)[:, None] - d)
    gap = jnp.where(valid[:, None], gap, -jnp.inf)
    return jnp.maximum(l.astype(jnp.float32), gap.max(axis=0))


def fused_round_ref(xb, x, l, valid, metric: str = "l2"):
    """Reference for the fused trimed round: energies + bound update,
    normalising E by N (sum-including-self convention)."""
    n = x.shape[0]
    e_sum = energy_ref(xb, x, metric)
    e = e_sum / n
    l_new = bound_update_ref(xb, x, e, l, valid, metric)
    return e, l_new


# ---------------------------------------------------------------------------
# sampled-column stats — DESIGN.md §9 (the bandit subsystem)
# ---------------------------------------------------------------------------
def sample_stats_ref(xa, xs, metric: str = "l2"):
    """Per-arm (sum, sum-of-squares, max) of distances from each arm in
    ``xa`` to every sampled column in ``xs``."""
    d = pairwise_ref(xa, xs, metric)
    return d.sum(axis=1), (d * d).sum(axis=1), d.max(axis=1)


# ---------------------------------------------------------------------------
# multi-cluster (assignment-masked) references — DESIGN.md §3
# ---------------------------------------------------------------------------
def masked_energy_ref(xb, x, a_piv, a_x, metric: str = "l2") -> jnp.ndarray:
    """(B,) in-cluster row sums: pivot b sums only columns with
    ``a_x[j] == a_piv[b]``."""
    d = pairwise_ref(xb, x, metric)
    same = a_piv[:, None] == a_x[None, :]
    return jnp.where(same, d, 0.0).sum(axis=1)


def masked_bound_update_ref(xb, x, s, v_piv, valid, a_piv, a_x, l,
                            metric: str = "l2") -> jnp.ndarray:
    """l(j) <- max(l(j), max_b |v_b * D(b,j) - S(b)|) over valid pivots
    in j's own cluster."""
    d = pairwise_ref(xb, x, metric)
    gap = jnp.abs(d * v_piv.astype(jnp.float32)[:, None]
                  - s.astype(jnp.float32)[:, None])
    ok = jnp.logical_and(a_piv[:, None] == a_x[None, :], valid[:, None])
    gap = jnp.where(ok, gap, -jnp.inf)
    return jnp.maximum(l.astype(jnp.float32), gap.max(axis=0))


def fused_masked_round_ref(xb, x, l, valid, a_piv, a_x, v_piv,
                           metric: str = "l2"):
    """Reference for the fused multi-cluster round: in-cluster sums +
    per-cluster bound tightening."""
    s = masked_energy_ref(xb, x, a_piv, a_x, metric)
    l_new = masked_bound_update_ref(xb, x, s, v_piv, valid, a_piv, a_x, l,
                                    metric)
    return s, l_new


# ---------------------------------------------------------------------------
# software-pipelined rounds — DESIGN.md §4
# ---------------------------------------------------------------------------
def pipelined_round_ref(xb_new, xb_prev, x, e_prev, valid_prev, l,
                        metric: str = "l2"):
    """Reference for the pipelined round: the current block's raw row
    sums plus the bound vector tightened by the *previous* block (whose
    energies are known). Returns ``(e_sums_new, l_new)``."""
    e_sums = energy_ref(xb_new, x, metric)
    l_new = bound_update_ref(xb_prev, x, e_prev, l, valid_prev, metric)
    return e_sums, l_new


def masked_pipelined_round_ref(xb_new, xb_prev, x, a_new, a_prev, a_x,
                               s_prev, v_prev, valid_prev, l,
                               metric: str = "l2"):
    """Reference for the multi-cluster pipelined round. Returns
    ``(s_sums_new, l_new)``."""
    s_sums = masked_energy_ref(xb_new, x, a_new, a_x, metric)
    l_new = masked_bound_update_ref(xb_prev, x, s_prev, v_prev, valid_prev,
                                    a_prev, a_x, l, metric)
    return s_sums, l_new
