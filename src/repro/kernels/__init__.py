"""repro.kernels — Pallas TPU kernels (validated in interpret mode on CPU).

* pairwise / energy / bound-update: the trimed block round (fused variant
  never materialises the (B, N) distance block in HBM);
* sample_stats: arm-tiled sampled-column moments for the bandit engines;
* flash_attention: GQA forward attention, online softmax in VMEM scratch.
ops.py holds the jit'd public wrappers; ref.py the pure-jnp oracles.
"""
from . import ops, pairwise, ref
from .flash_attention import flash_attention
from .ops import (block_energies, bound_update, fused_round,
                  make_pallas_distance_fn, pairwise_distances, sample_stats)

__all__ = [
    "ops", "pairwise", "ref", "flash_attention", "block_energies",
    "bound_update", "fused_round", "make_pallas_distance_fn",
    "pairwise_distances", "sample_stats",
]
