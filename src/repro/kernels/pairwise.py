"""Pallas TPU kernels for the trimed block round and the bandit sampler.

Eight kernels. Seven are tiled over the element axis ``N`` with
MXU-aligned blocks (the pivot block ``B`` rides the sublane axis, ``N``
tiles ride the lane axis, and the ``-2 X_B Xᵀ`` term is a
``(B, d) x (d, TN)`` MXU matmul per tile); the eighth
(``sample_stats_kernel``) flips the tiling for the bandit subsystem —
grid over the *candidate* axis, sampled columns resident:

* ``pairwise_kernel``     — materialises the ``(B, N)`` distance block.
* ``energy_kernel``       — row-sums only; the block never leaves VMEM.
* ``bound_update_kernel`` — recomputes each distance tile and folds it
  straight into ``l(j) <- max(l(j), max_b |E(b) - D(b,j)|)``.
* ``pipelined_kernel``    — the software-pipelined round (DESIGN.md §4):
  the *current* pivot block and the *previous* round's block are stacked
  into one ``(B + Bp, d)`` operand so a single tiled stream of ``X``
  feeds one MXU matmul per tile, whose top half accumulates the current
  block's row sums and whose bottom half (energies known since last
  round) folds straight into the bound vector. One X-stream per round
  instead of the two that ``energy`` + ``bound_update`` cost.
* ``masked_energy_kernel`` / ``masked_bound_kernel`` /
  ``masked_pipelined_kernel`` — the multi-cluster variants (DESIGN.md
  §3/§4): an extra int32 assignment operand rides the lane axis next to
  ``x_sq``; each pivot row only sums / tightens the columns whose
  cluster id matches the pivot's own, so K concurrent per-cluster
  searches share one ``(B, N)`` distance pass with the mask applied in
  VMEM (the masked block never reaches HBM either).
* ``many_energy_kernel`` / ``many_pipelined_kernel`` — the many-query
  variants (DESIGN.md §12): the same bodies with the query axis as a
  *leading grid dimension*, so Q same-shape queries share one kernel
  launch (``solve_many``'s packed path). Per-query tile order matches
  the single-query kernels, so per-query results are bit-identical.
* ``sample_stats_kernel`` — the sampled-column pass for the bandit
  engines (DESIGN.md §9): per candidate arm, the sum / sum-of-squares /
  max of distances to an ``S``-column sample of ``X``, with the
  ``(M, S)`` distance block living only in VMEM. Because the bandit
  races *many* arms over *few* columns, the grid runs over arm tiles
  and the gathered sample block stays resident.

``energy`` + ``bound_update`` together implement a *fused trimed round*
(DESIGN.md §2): HBM traffic is two streams of ``X`` plus the ``(N,)``
bound vector, instead of writing and re-reading a ``(B, N)`` block — the
same recompute-over-materialise trade flash-attention makes. For
``N = 1e6, B = 128`` that removes a 512 MB round-trip per round at the
cost of one extra (MXU-cheap) matmul pass. The pipelined kernels halve
that again to one stream of ``X`` per steady-state round.

VMEM budget per grid step (fp32, B=128, TN=512, d<=1024):
pivots 512 KB + X tile 2 MB + distance tile 256 KB + accumulators — well
under the ~16 MB/core budget. ``d`` is zero-padded to a multiple of 128
by the ``ops.py`` wrappers (lane alignment); zero padding is exact for
both the matmul and the squared-norm terms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128        # TPU lane width / MXU tile edge
DEFAULT_TN = 512  # N-axis tile


def _dist_tile(xb, xt, bsq, xsq, metric):
    """Distance tile (B, TN) in fp32 from VMEM-resident operands."""
    if metric in ("l2", "sqeuclidean"):
        prod = jax.lax.dot_general(
            xb, xt,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                       # (B, TN) on the MXU
        d2 = bsq[:, None] + xsq[None, :] - 2.0 * prod
        d2 = jnp.maximum(d2, 0.0)
        return d2 if metric == "sqeuclidean" else jnp.sqrt(d2)
    if metric == "l1":
        return jnp.abs(xb[:, None, :] - xt[None, :, :]).sum(-1)
    raise ValueError(metric)


# ---------------------------------------------------------------------------
# pairwise: D = dist(xb, X)  (materialised)
# ---------------------------------------------------------------------------
def _pairwise_body(n_real, tn, metric, xb_ref, x_ref, bsq_ref, xsq_ref, o_ref):
    i = pl.program_id(0)
    d = _dist_tile(xb_ref[...], x_ref[...], bsq_ref[0], xsq_ref[0], metric)
    # zero the zero-padded tail columns so downstream row-sums are exact
    col = i * tn + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    o_ref[...] = jnp.where(col < n_real, d, 0.0)


def pairwise_kernel(xb, x, bsq, xsq, *, n_real, tn=DEFAULT_TN, metric="l2",
                    interpret=False):
    b, dpad = xb.shape
    npad = x.shape[0]
    grid = (npad // tn,)
    return pl.pallas_call(
        functools.partial(_pairwise_body, n_real, tn, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, dpad), lambda i: (0, 0)),
            pl.BlockSpec((tn, dpad), lambda i: (i, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, npad), jnp.float32),
        interpret=interpret,
    )(xb, x, bsq, xsq)


# ---------------------------------------------------------------------------
# energy: E = row-sums of D (block never materialised in HBM)
# ---------------------------------------------------------------------------
def _energy_body(n_real, tn, metric, xb_ref, x_ref, bsq_ref, xsq_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = _dist_tile(xb_ref[...], x_ref[...], bsq_ref[0], xsq_ref[0], metric)
    col = i * tn + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(col < n_real, d, 0.0)
    o_ref[...] += d.sum(axis=1, keepdims=True).T     # (1, B) accumulator


def energy_kernel(xb, x, bsq, xsq, *, n_real, tn=DEFAULT_TN, metric="l2",
                  interpret=False):
    b, dpad = xb.shape
    npad = x.shape[0]
    grid = (npad // tn,)
    out = pl.pallas_call(
        functools.partial(_energy_body, n_real, tn, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, dpad), lambda i: (0, 0)),
            pl.BlockSpec((tn, dpad), lambda i: (i, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, b), jnp.float32),
        interpret=interpret,
    )(xb, x, bsq, xsq)
    return out[0]


# ---------------------------------------------------------------------------
# bound update: l <- max(l, max_b |E_b - D_bj|)   (D recomputed per tile)
# ---------------------------------------------------------------------------
def _bound_body(n_real, tn, metric,
                xb_ref, x_ref, bsq_ref, xsq_ref, e_ref, v_ref, l_ref, o_ref):
    d = _dist_tile(xb_ref[...], x_ref[...], bsq_ref[0], xsq_ref[0], metric)
    e = e_ref[0]                                     # (B,)
    valid = v_ref[0] != 0                            # (B,)
    gap = jnp.abs(e[:, None] - d)
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)
    gap = jnp.where(valid[:, None], gap, neg_inf)
    o_ref[...] = jnp.maximum(l_ref[...], gap.max(axis=0)[None, :])


def bound_update_kernel(xb, x, bsq, xsq, e, valid, l, *, n_real,
                        tn=DEFAULT_TN, metric="l2", interpret=False):
    b, dpad = xb.shape
    npad = x.shape[0]
    grid = (npad // tn,)
    out = pl.pallas_call(
        functools.partial(_bound_body, n_real, tn, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, dpad), lambda i: (0, 0)),
            pl.BlockSpec((tn, dpad), lambda i: (i, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        interpret=interpret,
    )(xb, x, bsq, xsq, e, valid, l)
    return out[0]


# ---------------------------------------------------------------------------
# pipelined round: energies of the CURRENT block + bound folds of the
# PREVIOUS block, one stream of X (DESIGN.md §4). The two pivot blocks
# arrive stacked as xb2 = concat([xb_new, xb_prev]) so each X tile feeds
# a single (B + Bp, d) x (d, TN) MXU matmul.
# ---------------------------------------------------------------------------
def _pipelined_body(n_real, b_new, tn, metric, xb_ref, x_ref, bsq_ref,
                    xsq_ref, ep_ref, vp_ref, l_ref, e_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        e_ref[...] = jnp.zeros_like(e_ref)

    d = _dist_tile(xb_ref[...], x_ref[...], bsq_ref[0], xsq_ref[0], metric)
    col = i * tn + jax.lax.broadcasted_iota(jnp.int32, (1, d.shape[1]), 1)

    # top half: row-sum accumulation for the current block's energies
    dn = jnp.where(col < n_real, d[:b_new], 0.0)
    e_ref[...] += dn.sum(axis=1, keepdims=True).T        # (1, B) accumulator

    # bottom half: fold the previous block's (now known) energies into l
    dp = d[b_new:]
    e_prev = ep_ref[0]                                   # (Bp,)
    valid_prev = vp_ref[0] != 0                          # (Bp,)
    gap = jnp.abs(e_prev[:, None] - dp)
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)
    gap = jnp.where(valid_prev[:, None], gap, neg_inf)
    o_ref[...] = jnp.maximum(l_ref[...], gap.max(axis=0)[None, :])


def pipelined_kernel(xb2, x, bsq2, xsq, e_prev, valid_prev, l, *, n_real,
                     b_new, tn=DEFAULT_TN, metric="l2", interpret=False):
    """xb2 is the stacked ``(B + Bp, d)`` pivot operand (current block
    first). Returns ``(e_sums_new, l_new)`` — un-normalised row sums for
    the current block and the bound vector tightened by the previous
    block."""
    b2, dpad = xb2.shape
    b_prev = b2 - b_new
    npad = x.shape[0]
    grid = (npad // tn,)
    e_out, l_out = pl.pallas_call(
        functools.partial(_pipelined_body, n_real, b_new, tn, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b2, dpad), lambda i: (0, 0)),
            pl.BlockSpec((tn, dpad), lambda i: (i, 0)),
            pl.BlockSpec((1, b2), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
            pl.BlockSpec((1, b_prev), lambda i: (0, 0)),
            pl.BlockSpec((1, b_prev), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, b_new), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, b_new), jnp.float32),
            jax.ShapeDtypeStruct((1, npad), jnp.float32),
        ],
        interpret=interpret,
    )(xb2, x, bsq2, xsq, e_prev, valid_prev, l)
    return e_out[0], l_out[0]


# ---------------------------------------------------------------------------
# many-query family: the same energy / pipelined bodies with the query
# axis as a LEADING GRID DIMENSION (DESIGN.md §12). Each (q, i) grid step
# works on query q's tile i; all per-query operands gain a leading
# length-1 block axis indexed by q. No new kernel math — the masked
# family already proved per-column validity composes with the tile
# bodies, and a query axis is just one more level of the same grid.
# The grid iterates i fastest (row-major), so each query's accumulator
# runs its tiles in the same order as the single-query kernel —
# per-query results are bit-identical to the single-query calls.
# ---------------------------------------------------------------------------
def _many_energy_body(n_real, tn, metric, xb_ref, x_ref, bsq_ref, xsq_ref,
                      o_ref):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = _dist_tile(xb_ref[0], x_ref[0], bsq_ref[0, 0], xsq_ref[0, 0], metric)
    col = i * tn + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(col < n_real, d, 0.0)
    o_ref[...] += d.sum(axis=1, keepdims=True).T[None]   # (1, 1, B)


def many_energy_kernel(xb, x, bsq, xsq, *, n_real, tn=DEFAULT_TN,
                       metric="l2", interpret=False):
    """Query-batched ``energy_kernel``: ``xb`` is ``(Q, B, d)``, ``x`` is
    ``(Q, Npad, d)``; returns per-query row sums ``(Q, 1, B)``."""
    q, b, dpad = xb.shape
    npad = x.shape[1]
    grid = (q, npad // tn)
    return pl.pallas_call(
        functools.partial(_many_energy_body, n_real, tn, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b, dpad), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, tn, dpad), lambda q, i: (q, i, 0)),
            pl.BlockSpec((1, 1, b), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, 1, tn), lambda q, i: (q, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, b), lambda q, i: (q, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, 1, b), jnp.float32),
        interpret=interpret,
    )(xb, x, bsq, xsq)


def _many_pipelined_body(n_real, b_new, tn, metric, xb_ref, x_ref, bsq_ref,
                         xsq_ref, ep_ref, vp_ref, l_ref, e_ref, o_ref):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        e_ref[...] = jnp.zeros_like(e_ref)

    d = _dist_tile(xb_ref[0], x_ref[0], bsq_ref[0, 0], xsq_ref[0, 0], metric)
    col = i * tn + jax.lax.broadcasted_iota(jnp.int32, (1, d.shape[1]), 1)

    # top half: row-sum accumulation for the current block's energies
    dn = jnp.where(col < n_real, d[:b_new], 0.0)
    e_ref[...] += dn.sum(axis=1, keepdims=True).T[None]  # (1, 1, B)

    # bottom half: fold the previous block's energies into this query's l
    dp = d[b_new:]
    e_prev = ep_ref[0, 0]                                # (Bp,)
    valid_prev = vp_ref[0, 0] != 0                       # (Bp,)
    gap = jnp.abs(e_prev[:, None] - dp)
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)
    gap = jnp.where(valid_prev[:, None], gap, neg_inf)
    o_ref[...] = jnp.maximum(l_ref[...], gap.max(axis=0)[None, None, :])


def many_pipelined_kernel(xb2, x, bsq2, xsq, e_prev, valid_prev, l, *,
                          n_real, b_new, tn=DEFAULT_TN, metric="l2",
                          interpret=False):
    """Query-batched ``pipelined_kernel``: per-query stacked pivot
    operand ``(Q, B + Bp, d)`` against per-query domains ``(Q, Npad, d)``.
    Returns ``(e_sums_new (Q, 1, B), l_new (Q, 1, Npad))``."""
    q, b2, dpad = xb2.shape
    b_prev = b2 - b_new
    npad = x.shape[1]
    grid = (q, npad // tn)
    e_out, l_out = pl.pallas_call(
        functools.partial(_many_pipelined_body, n_real, b_new, tn, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, b2, dpad), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, tn, dpad), lambda q, i: (q, i, 0)),
            pl.BlockSpec((1, 1, b2), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, 1, tn), lambda q, i: (q, 0, i)),
            pl.BlockSpec((1, 1, b_prev), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, 1, b_prev), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, 1, tn), lambda q, i: (q, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, b_new), lambda q, i: (q, 0, 0)),
            pl.BlockSpec((1, 1, tn), lambda q, i: (q, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, 1, b_new), jnp.float32),
            jax.ShapeDtypeStruct((q, 1, npad), jnp.float32),
        ],
        interpret=interpret,
    )(xb2, x, bsq2, xsq, e_prev, valid_prev, l)
    return e_out, l_out


# ---------------------------------------------------------------------------
# sampled-column stats: per-arm sum / sum-of-squares / max of distances to
# an S-column sample of X (DESIGN.md §9, the bandit subsystem). The roles
# flip relative to the kernels above: the bandit has MANY candidate arms
# and FEW sampled columns, so the grid tiles the *arm* axis and the whole
# gathered sample block (S, d) stays VMEM-resident across grid steps.
# ---------------------------------------------------------------------------
def _sample_stats_body(s_real, metric, xa_ref, xs_ref, asq_ref, ssq_ref,
                       sum_ref, sq_ref, mx_ref):
    d = _dist_tile(xa_ref[...], xs_ref[...], asq_ref[0], ssq_ref[0], metric)
    # zero the zero-padded sample columns so sums/sumsq/max are exact
    # (distances are >= 0, so 0 is the identity for the running max too)
    col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(col < s_real, d, 0.0)
    sum_ref[...] = d.sum(axis=1, keepdims=True).T        # (1, TB)
    sq_ref[...] = (d * d).sum(axis=1, keepdims=True).T
    mx_ref[...] = d.max(axis=1, keepdims=True).T


def sample_stats_kernel(xa, xs, asq, ssq, *, s_real, tb, metric="l2",
                        interpret=False):
    """Per-arm first/second moments and max over the sampled columns.

    ``xa`` is the (padded) ``(M, d)`` arm block, ``xs`` the gathered
    ``(S, d)`` sample block. Returns ``(sums, sumsq, maxs)``, each
    ``(1, M)``. One MXU matmul per ``(TB, d) x (d, S)`` arm tile."""
    m, dpad = xa.shape
    spad = xs.shape[0]
    grid = (m // tb,)
    return pl.pallas_call(
        functools.partial(_sample_stats_body, s_real, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, dpad), lambda i: (i, 0)),
            pl.BlockSpec((spad, dpad), lambda i: (0, 0)),
            pl.BlockSpec((1, tb), lambda i: (0, i)),
            pl.BlockSpec((1, spad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tb), lambda i: (0, i)),
            pl.BlockSpec((1, tb), lambda i: (0, i)),
            pl.BlockSpec((1, tb), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((1, m), jnp.float32),
        ],
        interpret=interpret,
    )(xa, xs, asq, ssq)


# ---------------------------------------------------------------------------
# masked energy: S(b) = sum_j [a(j) == a_piv(b)] D(b, j)   (DESIGN.md §3)
# ---------------------------------------------------------------------------
def _masked_energy_body(n_real, tn, metric, xb_ref, x_ref, bsq_ref, xsq_ref,
                        ap_ref, ax_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = _dist_tile(xb_ref[...], x_ref[...], bsq_ref[0], xsq_ref[0], metric)
    col = i * tn + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    same = ap_ref[0][:, None] == ax_ref[0][None, :]       # (B, TN) cluster mask
    d = jnp.where(jnp.logical_and(same, col < n_real), d, 0.0)
    o_ref[...] += d.sum(axis=1, keepdims=True).T          # (1, B) accumulator


def masked_energy_kernel(xb, x, bsq, xsq, a_piv, a_x, *, n_real,
                         tn=DEFAULT_TN, metric="l2", interpret=False):
    b, dpad = xb.shape
    npad = x.shape[0]
    grid = (npad // tn,)
    out = pl.pallas_call(
        functools.partial(_masked_energy_body, n_real, tn, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, dpad), lambda i: (0, 0)),
            pl.BlockSpec((tn, dpad), lambda i: (i, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, b), jnp.float32),
        interpret=interpret,
    )(xb, x, bsq, xsq, a_piv, a_x)
    return out[0]


# ---------------------------------------------------------------------------
# masked bound update: l(j) <- max(l(j), max_b [same cluster] |v_b D - S_b|)
# ---------------------------------------------------------------------------
def _masked_bound_body(n_real, tn, metric, xb_ref, x_ref, bsq_ref, xsq_ref,
                       s_ref, vsz_ref, v_ref, ap_ref, ax_ref, l_ref, o_ref):
    d = _dist_tile(xb_ref[...], x_ref[...], bsq_ref[0], xsq_ref[0], metric)
    s = s_ref[0]                                          # (B,) in-cluster sums
    vsz = vsz_ref[0]                                      # (B,) cluster sizes
    valid = v_ref[0] != 0                                 # (B,)
    same = ap_ref[0][:, None] == ax_ref[0][None, :]       # (B, TN)
    gap = jnp.abs(d * vsz[:, None] - s[:, None])
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)
    gap = jnp.where(jnp.logical_and(same, valid[:, None]), gap, neg_inf)
    o_ref[...] = jnp.maximum(l_ref[...], gap.max(axis=0)[None, :])


def masked_bound_kernel(xb, x, bsq, xsq, s, vsz, valid, a_piv, a_x, l, *,
                        n_real, tn=DEFAULT_TN, metric="l2", interpret=False):
    b, dpad = xb.shape
    npad = x.shape[0]
    grid = (npad // tn,)
    out = pl.pallas_call(
        functools.partial(_masked_bound_body, n_real, tn, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, dpad), lambda i: (0, 0)),
            pl.BlockSpec((tn, dpad), lambda i: (i, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, b), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, npad), jnp.float32),
        interpret=interpret,
    )(xb, x, bsq, xsq, s, vsz, valid, a_piv, a_x, l)
    return out[0]


# ---------------------------------------------------------------------------
# masked pipelined round: in-cluster sums of the CURRENT block + scaled
# bound folds of the PREVIOUS block, one stream of X (DESIGN.md §4)
# ---------------------------------------------------------------------------
def _masked_pipelined_body(n_real, b_new, tn, metric, xb_ref, x_ref, bsq_ref,
                           xsq_ref, ap_ref, ax_ref, sp_ref, vszp_ref, vp_ref,
                           l_ref, s_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    d = _dist_tile(xb_ref[...], x_ref[...], bsq_ref[0], xsq_ref[0], metric)
    col = i * tn + jax.lax.broadcasted_iota(jnp.int32, (1, d.shape[1]), 1)
    same = ap_ref[0][:, None] == ax_ref[0][None, :]       # (B+Bp, TN)

    # top half: masked row-sum accumulation (current block)
    dn = jnp.where(jnp.logical_and(same[:b_new], col < n_real),
                   d[:b_new], 0.0)
    s_ref[...] += dn.sum(axis=1, keepdims=True).T         # (1, B)

    # bottom half: fold previous block's size-scaled gaps into l
    dp = d[b_new:]
    s_prev = sp_ref[0]                                    # (Bp,)
    vsz_prev = vszp_ref[0]                                # (Bp,)
    valid_prev = vp_ref[0] != 0                           # (Bp,)
    gap = jnp.abs(dp * vsz_prev[:, None] - s_prev[:, None])
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)
    ok = jnp.logical_and(same[b_new:], valid_prev[:, None])
    gap = jnp.where(ok, gap, neg_inf)
    o_ref[...] = jnp.maximum(l_ref[...], gap.max(axis=0)[None, :])


def masked_pipelined_kernel(xb2, x, bsq2, xsq, a_piv2, a_x, s_prev, vsz_prev,
                            valid_prev, l, *, n_real, b_new, tn=DEFAULT_TN,
                            metric="l2", interpret=False):
    """Multi-cluster pipelined round. ``xb2``/``a_piv2`` are the stacked
    ``(B + Bp,)``-leading current+previous pivot operands; returns
    ``(s_sums_new, l_new)``."""
    b2, dpad = xb2.shape
    b_prev = b2 - b_new
    npad = x.shape[0]
    grid = (npad // tn,)
    s_out, l_out = pl.pallas_call(
        functools.partial(_masked_pipelined_body, n_real, b_new, tn, metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b2, dpad), lambda i: (0, 0)),
            pl.BlockSpec((tn, dpad), lambda i: (i, 0)),
            pl.BlockSpec((1, b2), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
            pl.BlockSpec((1, b2), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
            pl.BlockSpec((1, b_prev), lambda i: (0, 0)),
            pl.BlockSpec((1, b_prev), lambda i: (0, 0)),
            pl.BlockSpec((1, b_prev), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, b_new), lambda i: (0, 0)),
            pl.BlockSpec((1, tn), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, b_new), jnp.float32),
            jax.ShapeDtypeStruct((1, npad), jnp.float32),
        ],
        interpret=interpret,
    )(xb2, x, bsq2, xsq, a_piv2, a_x, s_prev, vsz_prev, valid_prev, l)
    return s_out[0], l_out[0]
