"""jit'd public wrappers around the Pallas kernels.

Handles padding (``d`` to a multiple of 128 lanes, ``N`` to a multiple of
the tile), fp32 norm precomputation, and CPU fallback via
``interpret=True`` (the kernel body runs in Python on CPU — numerically
identical, used by tests and this container). On TPU the same code path
compiles to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import pairwise as _pk
from .pairwise import DEFAULT_TN, LANE


def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def _prep(xb, x, tn):
    """Pad operands: d -> multiple of LANE, N -> multiple of tn."""
    xb = xb.astype(jnp.float32)
    x = x.astype(jnp.float32)
    b, d = xb.shape
    n = x.shape[0]
    d_pad = (-d) % LANE
    n_pad = (-n) % tn
    if d_pad:
        xb = jnp.pad(xb, ((0, 0), (0, d_pad)))
        x = jnp.pad(x, ((0, 0), (0, d_pad)))
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    bsq = jnp.sum(xb * xb, axis=1)[None, :]          # (1, B)
    xsq = jnp.sum(x * x, axis=1)[None, :]            # (1, Npad)
    return xb, x, bsq, xsq, n


@functools.partial(jax.jit, static_argnames=("metric", "tn", "interpret"))
def pairwise_distances(xb, x, metric="l2", tn=DEFAULT_TN, interpret=None):
    """(B, N) distance block via the Pallas kernel."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[0]
    tn = min(tn, max(LANE, n))
    xb_p, x_p, bsq, xsq, n_real = _prep(xb, x, tn)
    out = _pk.pairwise_kernel(
        xb_p, x_p, bsq, xsq, n_real=n_real, tn=tn, metric=metric,
        interpret=interpret,
    )
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("metric", "tn", "interpret"))
def block_energies(xb, x, metric="l2", tn=DEFAULT_TN, interpret=None):
    """(B,) un-normalised energies (row sums) without materialising D."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[0]
    tn = min(tn, max(LANE, n))
    xb_p, x_p, bsq, xsq, n_real = _prep(xb, x, tn)
    return _pk.energy_kernel(
        xb_p, x_p, bsq, xsq, n_real=n_real, tn=tn, metric=metric,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("metric", "tn", "interpret"))
def bound_update(xb, x, e, valid, l, metric="l2", tn=DEFAULT_TN,
                 interpret=None):
    """Fused l(j) <- max(l(j), max_b |E(b) - D(b, j)|) without
    materialising D. ``valid`` masks padded/dead pivots."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[0]
    tn = min(tn, max(LANE, n))
    xb_p, x_p, bsq, xsq, n_real = _prep(xb, x, tn)
    n_pad = x_p.shape[0] - n
    l_p = jnp.pad(l.astype(jnp.float32), (0, n_pad))[None, :]
    e_p = e.astype(jnp.float32)[None, :]
    v_p = valid.astype(jnp.int32)[None, :]
    out = _pk.bound_update_kernel(
        xb_p, x_p, bsq, xsq, e_p, v_p, l_p, n_real=n_real, tn=tn,
        metric=metric, interpret=interpret,
    )
    return out[:n]


def fused_round(xb, x, l, valid, metric="l2", tn=DEFAULT_TN, interpret=None):
    """One trimed block round: exact pivot energies (normalised by N) and
    the tightened bound vector — the ``(B, N)`` distance block never
    touches HBM. Drop-in ``distance-free`` replacement for the jnp round
    in ``core.trimed`` (wired up via ``trimed_block_pallas``)."""
    n = x.shape[0]
    e_sum = block_energies(xb, x, metric=metric, tn=tn, interpret=interpret)
    e = e_sum / n
    l_new = bound_update(xb, x, e, valid, l, metric=metric, tn=tn,
                         interpret=interpret)
    return e, l_new


@functools.partial(jax.jit, static_argnames=("metric", "tn", "interpret"))
def masked_energies(xb, x, a_piv, a_x, metric="l2", tn=DEFAULT_TN,
                    interpret=None):
    """(B,) *in-cluster* row sums: pivot ``b`` only sums columns ``j``
    with ``a_x[j] == a_piv[b]`` (DESIGN.md §3). Raw sums — not divided by
    the cluster size; callers compare sums within one cluster only."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[0]
    tn = min(tn, max(LANE, n))
    xb_p, x_p, bsq, xsq, n_real = _prep(xb, x, tn)
    n_pad = x_p.shape[0] - n
    # padded columns get cluster id -1: no pivot matches, so they add 0
    ax_p = jnp.pad(a_x.astype(jnp.int32), (0, n_pad),
                   constant_values=-1)[None, :]
    ap = a_piv.astype(jnp.int32)[None, :]
    return _pk.masked_energy_kernel(
        xb_p, x_p, bsq, xsq, ap, ax_p, n_real=n_real, tn=tn, metric=metric,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("metric", "tn", "interpret"))
def masked_bound_update(xb, x, s, v_piv, valid, a_piv, a_x, l, metric="l2",
                        tn=DEFAULT_TN, interpret=None):
    """Fused multi-cluster tightening: for every element ``j``,
    ``l(j) <- max(l(j), max_b |v_b * D(b, j) - S(b)|)`` over the valid
    pivots ``b`` in ``j``'s own cluster — each pivot's information is
    scattered only into its cluster's row of the logical ``l[K, N]``."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[0]
    tn = min(tn, max(LANE, n))
    xb_p, x_p, bsq, xsq, n_real = _prep(xb, x, tn)
    n_pad = x_p.shape[0] - n
    l_p = jnp.pad(l.astype(jnp.float32), (0, n_pad))[None, :]
    ax_p = jnp.pad(a_x.astype(jnp.int32), (0, n_pad),
                   constant_values=-1)[None, :]
    s_p = s.astype(jnp.float32)[None, :]
    vsz_p = v_piv.astype(jnp.float32)[None, :]
    v_p = valid.astype(jnp.int32)[None, :]
    ap = a_piv.astype(jnp.int32)[None, :]
    out = _pk.masked_bound_kernel(
        xb_p, x_p, bsq, xsq, s_p, vsz_p, v_p, ap, ax_p, l_p, n_real=n_real,
        tn=tn, metric=metric, interpret=interpret,
    )
    return out[:n]


@functools.partial(jax.jit, static_argnames=("metric", "tn", "interpret"))
def partial_energies(xb, x, col_valid, metric="l2", tn=DEFAULT_TN,
                     interpret=None):
    """(B,) row sums over only the columns with ``col_valid`` True.

    The sharded engine's per-shard energy pass (DESIGN.md §11): a shard
    holds a contiguous column slice of the padded element set, and the
    trailing layout-padding columns must contribute exactly zero. The
    column mask is encoded as cluster membership (valid -> 0, invalid ->
    -1) so the existing assignment-masked energy kernel serves as the
    masked partial-sum kernel with a single cluster — no new Pallas
    code, one stream of the local block."""
    a_x = jnp.where(col_valid, 0, -1).astype(jnp.int32)
    a_piv = jnp.zeros(xb.shape[0], jnp.int32)
    return masked_energies(xb, x, a_piv, a_x, metric=metric, tn=tn,
                           interpret=interpret)


def fused_masked_round(xb, x, l, valid, a_piv, a_x, v_piv, metric="l2",
                       tn=DEFAULT_TN, interpret=None):
    """One batched multi-cluster round (DESIGN.md §3): exact in-cluster
    sums for the packed pivot block plus the per-cluster bound tightening,
    with the masked ``(B, N)`` distance block never touching HBM. Drop-in
    for the jnp round in ``core.batched`` (wired up via
    ``batched_medoids(fused_round_fn=...)``)."""
    s = masked_energies(xb, x, a_piv, a_x, metric=metric, tn=tn,
                        interpret=interpret)
    l_new = masked_bound_update(xb, x, s, v_piv, valid, a_piv, a_x, l,
                                metric=metric, tn=tn, interpret=interpret)
    return s, l_new


@functools.partial(jax.jit, static_argnames=("metric", "tn", "interpret"))
def pipelined_round(xb_new, xb_prev, x, e_prev, valid_prev, l, metric="l2",
                    tn=DEFAULT_TN, interpret=None):
    """One software-pipelined trimed round (DESIGN.md §4): the current
    block's exact raw row sums *and* the fold of the previous block's
    (now known) energies into the bound vector, in a single tiled stream
    of ``X``. ``e_prev`` is on the normalised ``S/N`` scale. Returns
    ``(e_sums_new, l_new)`` — callers normalise ``e_sums_new`` by N."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[0]
    tn = min(tn, max(LANE, n))
    b_new = xb_new.shape[0]
    xb2 = jnp.concatenate(
        [xb_new.astype(jnp.float32), xb_prev.astype(jnp.float32)], axis=0)
    xb2_p, x_p, bsq2, xsq, n_real = _prep(xb2, x, tn)
    n_pad = x_p.shape[0] - n
    l_p = jnp.pad(l.astype(jnp.float32), (0, n_pad))[None, :]
    ep = e_prev.astype(jnp.float32)[None, :]
    vp = valid_prev.astype(jnp.int32)[None, :]
    e_sums, l_new = _pk.pipelined_kernel(
        xb2_p, x_p, bsq2, xsq, ep, vp, l_p, n_real=n_real, b_new=b_new,
        tn=tn, metric=metric, interpret=interpret,
    )
    return e_sums, l_new[:n]


@functools.partial(jax.jit, static_argnames=("metric", "tn", "interpret"))
def masked_pipelined_round(xb_new, xb_prev, x, a_new, a_prev, a_x, s_prev,
                           v_prev, valid_prev, l, metric="l2", tn=DEFAULT_TN,
                           interpret=None):
    """Multi-cluster pipelined round (DESIGN.md §4): current block's
    exact in-cluster sums + previous block's size-scaled bound folds, one
    stream of ``X``. Returns ``(s_sums_new, l_new)``."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[0]
    tn = min(tn, max(LANE, n))
    b_new = xb_new.shape[0]
    xb2 = jnp.concatenate(
        [xb_new.astype(jnp.float32), xb_prev.astype(jnp.float32)], axis=0)
    xb2_p, x_p, bsq2, xsq, n_real = _prep(xb2, x, tn)
    n_pad = x_p.shape[0] - n
    l_p = jnp.pad(l.astype(jnp.float32), (0, n_pad))[None, :]
    ax_p = jnp.pad(a_x.astype(jnp.int32), (0, n_pad),
                   constant_values=-1)[None, :]
    ap2 = jnp.concatenate(
        [a_new.astype(jnp.int32), a_prev.astype(jnp.int32)])[None, :]
    sp = s_prev.astype(jnp.float32)[None, :]
    vszp = v_prev.astype(jnp.float32)[None, :]
    vp = valid_prev.astype(jnp.int32)[None, :]
    s_sums, l_new = _pk.masked_pipelined_kernel(
        xb2_p, x_p, bsq2, xsq, ap2, ax_p, sp, vszp, vp, l_p, n_real=n_real,
        b_new=b_new, tn=tn, metric=metric, interpret=interpret,
    )
    return s_sums, l_new[:n]


def _prep_many(xb, x, tn):
    """Query-batched ``_prep``: pad d -> LANE multiple, N -> tn multiple
    over the leading query axis; per-query fp32 norms."""
    xb = xb.astype(jnp.float32)
    x = x.astype(jnp.float32)
    d = xb.shape[2]
    n = x.shape[1]
    d_pad = (-d) % LANE
    n_pad = (-n) % tn
    if d_pad:
        xb = jnp.pad(xb, ((0, 0), (0, 0), (0, d_pad)))
        x = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad)))
    if n_pad:
        x = jnp.pad(x, ((0, 0), (0, n_pad), (0, 0)))
    bsq = jnp.sum(xb * xb, axis=2)[:, None, :]       # (Q, 1, B)
    xsq = jnp.sum(x * x, axis=2)[:, None, :]         # (Q, 1, Npad)
    return xb, x, bsq, xsq, n


@functools.partial(jax.jit, static_argnames=("metric", "tn", "interpret"))
def many_block_energies(xb, x, metric="l2", tn=DEFAULT_TN, interpret=None):
    """(Q, B) un-normalised per-query energies: ``block_energies`` with
    the query axis as a leading grid dimension (DESIGN.md §12)."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[1]
    tn = min(tn, max(LANE, n))
    xb_p, x_p, bsq, xsq, n_real = _prep_many(xb, x, tn)
    out = _pk.many_energy_kernel(
        xb_p, x_p, bsq, xsq, n_real=n_real, tn=tn, metric=metric,
        interpret=interpret,
    )
    return out[:, 0]


@functools.partial(jax.jit, static_argnames=("metric", "tn", "interpret"))
def many_pipelined_round(xb_new, xb_prev, x, e_prev, valid_prev, l,
                         metric="l2", tn=DEFAULT_TN, interpret=None):
    """Query-batched ``pipelined_round``: Q same-shape pipelined rounds
    in one kernel launch. ``xb_new``/``xb_prev`` are ``(Q, B, d)`` /
    ``(Q, Bp, d)``, ``x`` is ``(Q, N, d)``; returns
    ``(e_sums_new (Q, B), l_new (Q, N))``."""
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[1]
    tn = min(tn, max(LANE, n))
    b_new = xb_new.shape[1]
    xb2 = jnp.concatenate(
        [xb_new.astype(jnp.float32), xb_prev.astype(jnp.float32)], axis=1)
    xb2_p, x_p, bsq2, xsq, n_real = _prep_many(xb2, x, tn)
    n_pad = x_p.shape[1] - n
    l_p = jnp.pad(l.astype(jnp.float32), ((0, 0), (0, n_pad)))[:, None, :]
    ep = e_prev.astype(jnp.float32)[:, None, :]
    vp = valid_prev.astype(jnp.int32)[:, None, :]
    e_sums, l_new = _pk.many_pipelined_kernel(
        xb2_p, x_p, bsq2, xsq, ep, vp, l_p, n_real=n_real, b_new=b_new,
        tn=tn, metric=metric, interpret=interpret,
    )
    return e_sums[:, 0], l_new[:, 0, :n]


DEFAULT_TB = 256  # arm-axis tile for the sampled-column kernel


@functools.partial(jax.jit, static_argnames=("metric", "tb", "interpret"))
def sample_stats(xa, xs, metric="l2", tb=DEFAULT_TB, interpret=None):
    """Per-arm ``(sums, sumsq, maxs)`` of distances to the sampled
    columns ``xs`` (already gathered: ``xs = X[sample_idx]``), via the
    arm-tiled Pallas kernel (DESIGN.md §9). Feeds the bandit engines'
    running means and empirical-Bernstein confidence intervals; the
    ``(M, S)`` distance block never reaches HBM."""
    if interpret is None:
        interpret = _interpret_default()
    m = xa.shape[0]
    s = xs.shape[0]
    tb = min(tb, max(LANE, m))
    xa = xa.astype(jnp.float32)
    xs = xs.astype(jnp.float32)
    d = xa.shape[1]
    d_pad = (-d) % LANE
    if d_pad:
        xa = jnp.pad(xa, ((0, 0), (0, d_pad)))
        xs = jnp.pad(xs, ((0, 0), (0, d_pad)))
    m_pad = (-m) % tb
    s_pad = (-s) % LANE
    if m_pad:
        xa = jnp.pad(xa, ((0, m_pad), (0, 0)))
    if s_pad:
        xs = jnp.pad(xs, ((0, s_pad), (0, 0)))
    asq = jnp.sum(xa * xa, axis=1)[None, :]          # (1, Mpad)
    ssq = jnp.sum(xs * xs, axis=1)[None, :]          # (1, Spad)
    sums, sumsq, maxs = _pk.sample_stats_kernel(
        xa, xs, asq, ssq, s_real=s, tb=tb, metric=metric,
        interpret=interpret,
    )
    return sums[0, :m], sumsq[0, :m], maxs[0, :m]


def make_pallas_distance_fn(metric="l2", tn=DEFAULT_TN, interpret=None):
    """Adapter for ``core.trimed.trimed_block(distance_fn=...)``: computes
    the materialised (B, N) block with the Pallas kernel."""
    def fn(xb, x):
        return pairwise_distances(xb, x, metric=metric, tn=tn,
                                  interpret=interpret)
    return fn


# ---------------------------------------------------------------------------
# observability (DESIGN.md §14): route every public kernel wrapper
# through repro.obs.profile.observed. Disabled (the default) this is one
# `is None` check in front of the *same* jitted callable — the compiled
# program is untouched; inside `with profile_kernels()` eager calls are
# timed and placed on the roofline. The raw jitted callables stay
# importable as `_<name>_jit`.
# ---------------------------------------------------------------------------
from repro.obs import profile as _prof  # noqa: E402


def _observe_wrap(name, fn):
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        return _prof.observed(name, fn, *args, **kwargs)
    return wrapper


for _name in ("pairwise_distances", "block_energies", "bound_update",
              "masked_energies", "masked_bound_update", "pipelined_round",
              "masked_pipelined_round", "many_block_energies",
              "many_pipelined_round", "sample_stats"):
    _fn = globals()[_name]
    globals()["_" + _name + "_jit"] = _fn
    globals()[_name] = _observe_wrap(_name, _fn)
del _name, _fn
