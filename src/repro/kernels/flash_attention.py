"""Pallas flash-attention forward kernel (GQA, causal/bidirectional).

The §Perf analysis shows every dense LM cell is memory-term dominated by
materialised attention scores; this kernel is the documented next lever:
scores/probs live only in VMEM (same recompute-over-materialise trade as
the trimed fused round). HBM traffic per (batch, head): Q + K + V + O
— no S^2 tensor.

Layout: q is reshaped to (B*KV*G, Sq, hd) and k/v to (B*KV, Sk, hd);
grid = (B*KV*G, nq, nk) with the KV-block axis innermost so the online-
softmax accumulators (m, l, acc) persist in VMEM scratch across the kv
sweep of each (head, q-block). Causal masking is applied per element;
fully-masked blocks short-circuit via `pl.when` (on TPU Mosaic this
skips the MXU work; interpret mode just branches).

Forward only: the training path keeps the jnp blockwise formulation
(autodiff), serving/prefill can adopt this kernel on TPU. Validated
against `models.attention.blockwise_attention` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_body(causal, sq_real, sk_real, bq, bk, scale,
                q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    live = jnp.logical_and(q_pos < sq_real, k_pos < sk_real)
    if causal:
        live = jnp.logical_and(live, q_pos >= k_pos)

    # block is relevant unless completely masked (causal upper triangle)
    relevant = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)         # (bq, bk)
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool | None = None):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd); H % KV == 0.
    Returns (B, Sq, H, hd) attention output, fp32 accumulation."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5

    bq = min(bq, max(8, sq))
    bk = min(bk, max(8, sk))
    pq, pk = (-sq) % bq, (-sk) % bk
    sq_p, sk_p = sq + pq, sk + pk

    # heads-major layout: (B*KV*G, S, hd) for q, (B*KV, S, hd) for k/v
    qh = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(b * kv, sk, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(b * kv, sk, hd)
    if pq:
        qh = jnp.pad(qh, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kh = jnp.pad(kh, ((0, 0), (0, pk), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pk), (0, 0)))

    grid = (b * h, sq_p // bq, sk_p // bk)

    out = pl.pallas_call(
        functools.partial(_flash_body, causal, sq, sk, bq, bk, scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda i, qi, ki: (i, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda i, qi, ki: (i // g, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda i, qi, ki: (i // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda i, qi, ki: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out[:, :sq].reshape(b, h, sq, hd)
    return jnp.moveaxis(out, 1, 2)
