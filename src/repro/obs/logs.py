"""One ``repro`` logger namespace for every engine/planner diagnostic.

Before this module the repo's user-facing diagnostics were split across
two channels with different ergonomics: ``warnings.warn`` (block
clamping, legacy-shim deprecations) and silent plan ``reasons`` (the
kmedoids non-triangle fallback). Operators of a long-running service
configure ``logging``, not ``warnings`` — so every diagnostic now
*also* flows through a logger under the single ``repro`` namespace
(``repro.api``, ``repro.core.distributed``, ...), where standard
``logging`` config can silence, capture or ship it.

:func:`repro_warn` keeps the ``warnings`` channel intact — the
pytest warnings-as-errors contract (``pytest.ini``) keys on the
warning's *origin module* via ``stacklevel``, so the helper bumps
``stacklevel`` by exactly one to stay transparent to that resolution.
"""
from __future__ import annotations

import logging
import warnings

ROOT = "repro"


def get_logger(name: str = ROOT) -> logging.Logger:
    """A logger under the ``repro`` namespace. ``name`` may be a full
    dotted path (``"repro.api"``) or a suffix (``"api"``)."""
    if name != ROOT and not name.startswith(ROOT + "."):
        name = f"{ROOT}.{name}"
    return logging.getLogger(name)


def repro_warn(message: str, category=UserWarning, *,
               logger: str = ROOT, stacklevel: int = 2) -> None:
    """Emit ``message`` on both channels: a ``repro.*`` log record (for
    ``logging`` config) and a real warning (for ``warnings`` filters and
    the pytest contract).

    ``stacklevel`` has the same meaning as in :func:`warnings.warn` as
    seen by *our caller*: the helper adds its own frame transparently.
    """
    get_logger(logger).warning("%s", message)
    warnings.warn(message, category, stacklevel=stacklevel + 1)
