"""Observability subsystem (DESIGN.md §14): traces, metrics, profiling.

Three layers, all inert unless asked for:

* :mod:`repro.obs.trace` — per-round solve telemetry (the paper's
  elimination curve) captured at the fault-runtime's host-visible
  segment boundaries; deterministic, byte-identical JSONL.
* :mod:`repro.obs.metrics` — counters/gauges/histograms under the
  ``repro_obs_`` namespace with Prometheus-text and JSONL exporters;
  ``MedoidServer`` serves a registry at ``metrics_text()``.
* :mod:`repro.obs.profile` — per-invocation Pallas kernel timing with
  analytic FLOP/byte models placed on the machine roofline.

:mod:`repro.obs.logs` routes every engine/planner diagnostic through
the single ``repro`` logger namespace.
"""
from .logs import get_logger, repro_warn
from .metrics import REGISTRY, METRICS_SCHEMA, MetricsRegistry
from .profile import KernelProfiler, profile_kernels
from .trace import (TRACE_SCHEMA, SolveTracer, compare_structure,
                    load_jsonl, resolve_trace, validate_events)

__all__ = [
    "TRACE_SCHEMA", "METRICS_SCHEMA", "SolveTracer", "resolve_trace",
    "validate_events", "compare_structure", "load_jsonl",
    "MetricsRegistry", "REGISTRY", "KernelProfiler", "profile_kernels",
    "get_logger", "repro_warn",
]
