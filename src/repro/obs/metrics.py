"""Metrics registry: counters/gauges/histograms under ``repro.obs``.

A deliberately small, dependency-free re-implementation of the usual
client-library surface (DESIGN.md §14): metrics live in a
:class:`MetricsRegistry`, carry optional label sets, and export through
two channels —

* :meth:`MetricsRegistry.to_text` — Prometheus exposition format
  (``# HELP`` / ``# TYPE`` / samples), served by
  ``MedoidServer.metrics_text()`` as the scrape endpoint;
* :meth:`MetricsRegistry.export_jsonl` — a JSONL event log under the
  versioned schema ``repro.obs.metrics/v1``, one sample per line, with
  deterministic key order and float formatting (the same dump rules as
  the solve tracer, so snapshots diff cleanly).

All metric names are prefixed ``repro_obs_`` so every exported sample
sits in one namespace. A process-wide default registry ``REGISTRY``
collects library-level counters (packed-solve lanes, watchdog beats);
servers own private registries so concurrent servers don't alias.
"""
from __future__ import annotations

import json
import math

METRICS_SCHEMA = "repro.obs.metrics/v1"
PREFIX = "repro_obs_"

_RATIO_BUCKETS = (0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 4.0, 8.0)


def dump_json(obj) -> str:
    """Deterministic JSON: sorted keys, no whitespace, shortest-repr
    floats (Python's ``repr`` round-trips bit-exactly)."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def _labels_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _fmt_value(v) -> str:
    if isinstance(v, float):
        if math.isinf(v):
            return "+Inf" if v > 0 else "-Inf"
        return repr(v)
    return str(v)


def _fmt_labels(items) -> str:
    if not items:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + inner + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help_: str = ""):
        self.name = name
        self.help = help_
        self._series: dict = {}

    def _slot(self, labels: dict):
        key = _labels_key(labels)
        if key not in self._series:
            self._series[key] = self._new_series()
        return self._series[key]

    def samples(self):
        """Yields ``(suffix, label_items, value)`` exposition samples."""
        raise NotImplementedError


class Counter(_Metric):
    kind = "counter"

    def _new_series(self):
        return [0.0]

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        self._slot(labels)[0] += amount

    def value(self, **labels) -> float:
        return self._slot(labels)[0]

    def samples(self):
        for key, slot in sorted(self._series.items()):
            yield "", key, slot[0]


class Gauge(_Metric):
    kind = "gauge"

    def _new_series(self):
        return [0.0]

    def set(self, value: float, **labels) -> None:
        self._slot(labels)[0] = value

    def inc(self, amount: float = 1, **labels) -> None:
        self._slot(labels)[0] += amount

    def dec(self, amount: float = 1, **labels) -> None:
        self._slot(labels)[0] -= amount

    def value(self, **labels) -> float:
        return self._slot(labels)[0]

    def samples(self):
        for key, slot in sorted(self._series.items()):
            yield "", key, slot[0]


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help_="", buckets=_RATIO_BUCKETS):
        super().__init__(name, help_)
        self.buckets = tuple(sorted(float(b) for b in buckets))

    def _new_series(self):
        # per-bucket cumulative counts + sum + count
        return {"buckets": [0] * len(self.buckets), "sum": 0.0, "count": 0}

    def observe(self, value: float, **labels) -> None:
        slot = self._slot(labels)
        for i, b in enumerate(self.buckets):
            if value <= b:
                slot["buckets"][i] += 1
        slot["sum"] += value
        slot["count"] += 1

    def value(self, **labels) -> dict:
        return dict(self._slot(labels))

    def samples(self):
        for key, slot in sorted(self._series.items()):
            for b, c in zip(self.buckets, slot["buckets"]):
                yield "_bucket", key + (("le", _fmt_value(float(b))),), c
            yield "_bucket", key + (("le", "+Inf"),), slot["count"]
            yield "_sum", key, slot["sum"]
            yield "_count", key, slot["count"]


class MetricsRegistry:
    """A named collection of metrics with idempotent constructors: asking
    twice for the same name returns the same instrument (mismatched
    kinds raise)."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _register(self, cls, name, help_, **kw):
        if not name.startswith(PREFIX):
            name = PREFIX + name
        existing = self._metrics.get(name)
        if existing is not None:
            if not isinstance(existing, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{existing.kind}, not {cls.kind}")
            return existing
        m = cls(name, help_, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._register(Counter, name, help_)

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._register(Gauge, name, help_)

    def histogram(self, name: str, help_: str = "",
                  buckets=_RATIO_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help_, buckets=buckets)

    # -- exporters ----------------------------------------------------
    def to_text(self) -> str:
        """Prometheus exposition format."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for suffix, label_items, value in m.samples():
                lines.append(f"{name}{suffix}{_fmt_labels(label_items)} "
                             f"{_fmt_value(value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> list[dict]:
        """All current samples as plain dicts (the JSONL export rows)."""
        rows = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            for suffix, label_items, value in m.samples():
                rows.append({
                    "schema": METRICS_SCHEMA,
                    "name": name + suffix,
                    "kind": m.kind,
                    "labels": dict(label_items),
                    "value": value,
                })
        return rows

    def export_jsonl(self, path=None) -> str:
        """The JSONL event-log exporter: one deterministic line per
        sample. Returns the text; also writes it when ``path`` given."""
        text = "".join(dump_json(row) + "\n" for row in self.snapshot())
        if path is not None:
            with open(path, "w", encoding="utf-8") as fh:
                fh.write(text)
        return text


#: histogram buckets for per-op repair cost (distance-matrix elements):
#: a healthy streaming index amortises to a handful of rows per op
_ELEMENTS_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 64.0, 256.0, 1024.0,
                     4096.0, 16384.0)


def stream_metrics(registry: "MetricsRegistry") -> dict:
    """The streaming-index instrument family (``repro_obs_stream_*``),
    registered idempotently on ``registry``. ``repro.stream.index``
    feeds these; the keys are its contract:

    - ``ops``            counter, labeled ``op=insert|delete|update``
    - ``repairs``        counter: incremental repairs served
    - ``invalidated``    counter: survivors re-admitted to the engine
    - ``resolves``       counter: full re-solve fallbacks
    - ``elements``       counter, labeled ``path=repair|resolve``
    - ``elements_per_op`` histogram: amortised repair elements per
      churn op — the headline economy of the index
    """
    return {
        "ops": registry.counter(
            "stream_ops_total", "churn operations applied to the index"),
        "repairs": registry.counter(
            "stream_repairs_total", "incremental repairs served"),
        "invalidated": registry.counter(
            "stream_invalidated_total",
            "eliminated rows re-admitted to the engine by repair"),
        "resolves": registry.counter(
            "stream_full_resolves_total", "full re-solve fallbacks"),
        "elements": registry.counter(
            "stream_elements_total",
            "repair cost in n-length distance row passes, by path"),
        "elements_per_op": registry.histogram(
            "stream_elements_per_op",
            "amortised repair row passes per churn op",
            buckets=_ELEMENTS_BUCKETS),
    }


def graph_metrics(registry: "MetricsRegistry") -> dict:
    """The graph-engine instrument family (``repro_obs_graph_*``),
    registered idempotently on ``registry``. ``repro.core.graph`` feeds
    these; the keys are its contract:

    - ``sweeps``       counter, labeled ``kind=landmark|pivot|certify``:
      SSSP sweeps — the graph workload's computed-element currency
      (landmark = ALT bound seeding, pivot = elimination rounds,
      certify = f64 host finalist rows)
    - ``relax_iters``  counter: Bellman-Ford relaxation iterations the
      device while_loop ran (the sweep-depth cost axis — one iteration
      streams the whole edge list once)
    - ``solves``       counter: graph-engine solves completed
    """
    return {
        "sweeps": registry.counter(
            "graph_sweeps_total",
            "SSSP sweeps run by the graph engine, by kind"),
        "relax_iters": registry.counter(
            "graph_relax_iters_total",
            "Bellman-Ford edge-list relaxation iterations"),
        "solves": registry.counter(
            "graph_solves_total", "graph-engine solves completed"),
    }


#: process-wide default registry for library-level counters
REGISTRY = MetricsRegistry()
