"""Kernel profiling hooks: per-invocation timing + roofline placement.

Every public wrapper in :mod:`repro.kernels.ops` routes through
:func:`observed`. When no profiler is active (the default) the hook is a
single ``is None`` check and the call proceeds to the *same* jitted
callable as before — the disabled path runs the exact compiled program
it always did. Inside ``with profile_kernels() as prof:`` each *eager*
invocation is timed wall-clock (``block_until_ready``) and recorded with
an analytic FLOP/byte model, then placed on the machine roofline via
:func:`repro.roofline.analysis.kernel_roofline` (wiring the previously
idle seed module).

Two honest caveats, by design:

* calls whose operands are tracers (a kernel invoked *inside* an
  engine's jitted stage) are passed through untimed — they fuse into
  the enclosing program and have no per-invocation wall-clock. The
  engines' end-to-end cost lives in the solve trace; per-kernel
  rooflines come from eager invocations (``benchmarks/bench_obs.py``
  drives exactly those).
* timings include dispatch overhead — on the CPU/interpret path that
  dominates, and the reported ``roofline_fraction`` is correspondingly
  tiny. The numbers become meaningful on an accelerator backend; the
  *model* FLOPs/bytes are backend-independent.
"""
from __future__ import annotations

import contextlib
import time

F32 = 4                     # the kernel family computes in fp32


def _pass_cost(b, n, d, out_elems, flops_per_cell=2.0):
    """One tiled stream of ``x`` against a ``(b, d)`` pivot block: the
    ``b*n*d`` multiply-adds of the distance dot products dominate;
    ``flops_per_cell`` covers the per-cell epilogue (norm combine,
    sqrt/abs, mask, accumulate). Bytes: both operands + the output —
    the fused kernels never materialise the ``(b, n)`` block in HBM."""
    flops = 2.0 * b * n * d + flops_per_cell * b * n
    nbytes = F32 * (b * d + n * d + out_elems)
    return flops, nbytes


def _cost_pairwise(xb, x, **kw):
    b, d = xb.shape[-2], xb.shape[-1]
    n = x.shape[-2]
    # materialised (B, N) output is the point of this kernel
    return _pass_cost(b, n, d, out_elems=b * n)


def _cost_energies(xb, x, *rest, **kw):
    b, d = xb.shape[-2], xb.shape[-1]
    n = x.shape[-2]
    q = xb.shape[0] if xb.ndim == 3 else 1
    f, by = _pass_cost(b, n, d, out_elems=b)
    return q * f, q * by


def _cost_bound_update(xb, x, *rest, **kw):
    b, d = xb.shape[-2], xb.shape[-1]
    n = x.shape[-2]
    # reads + writes the length-n bound vector on top of the pass
    f, by = _pass_cost(b, n, d, out_elems=n, flops_per_cell=4.0)
    return f, by + F32 * n


def _cost_pipelined(xb_new, xb_prev, x, *rest, **kw):
    b = xb_new.shape[-2] + xb_prev.shape[-2]
    d = x.shape[-1]
    n = x.shape[-2]
    q = x.shape[0] if x.ndim == 3 else 1
    f, by = _pass_cost(b, n, d, out_elems=xb_new.shape[-2] + n,
                       flops_per_cell=4.0)
    return q * f, q * (by + F32 * n)


def _cost_sample_stats(xa, xs, **kw):
    m, d = xa.shape
    s = xs.shape[0]
    # three (M,) outputs: sums, sumsq, maxs
    return _pass_cost(m, s, d, out_elems=3 * m, flops_per_cell=5.0)


#: analytic FLOP/byte models keyed by the ops.py wrapper name
KERNEL_COSTS = {
    "pairwise_distances": _cost_pairwise,
    "block_energies": _cost_energies,
    "bound_update": _cost_bound_update,
    "masked_energies": _cost_energies,
    "masked_bound_update": _cost_bound_update,
    "pipelined_round": _cost_pipelined,
    "masked_pipelined_round": _cost_pipelined,
    "many_block_energies": _cost_energies,
    "many_pipelined_round": _cost_pipelined,
    "sample_stats": _cost_sample_stats,
}

try:
    from jax.core import Tracer as _Tracer
except ImportError:                                  # pragma: no cover
    try:
        from jax import core as _jax_core
        _Tracer = _jax_core.Tracer
    except Exception:
        _Tracer = ()


def _eager(args) -> bool:
    return not any(isinstance(a, _Tracer) for a in args)


class KernelProfiler:
    """Per-invocation records of the Pallas kernel family."""

    def __init__(self):
        self.records: list[dict] = []

    def record(self, name: str, flops: float, nbytes: float,
               seconds: float) -> None:
        self.records.append({"kernel": name, "flops": flops,
                             "bytes": nbytes, "seconds": seconds})

    def mark(self) -> int:
        return len(self.records)

    def summary(self, since: int = 0) -> dict:
        """Aggregate per-kernel totals + roofline placement for the
        records from index ``since`` on."""
        return summarise(self.records[since:])


def summarise(records) -> dict:
    from repro.roofline.analysis import kernel_roofline

    per = {}
    for r in records:
        s = per.setdefault(r["kernel"], {"calls": 0, "flops": 0.0,
                                         "bytes": 0.0, "seconds": 0.0})
        s["calls"] += 1
        s["flops"] += r["flops"]
        s["bytes"] += r["bytes"]
        s["seconds"] += r["seconds"]
    for s in per.values():
        s["roofline"] = kernel_roofline(s["flops"], s["bytes"],
                                        s["seconds"])
    totals = {
        "calls": sum(s["calls"] for s in per.values()),
        "flops": sum(s["flops"] for s in per.values()),
        "bytes": sum(s["bytes"] for s in per.values()),
        "seconds": sum(s["seconds"] for s in per.values()),
    }
    return {"kernels": per, "totals": totals}


_ACTIVE: KernelProfiler | None = None


def active() -> KernelProfiler | None:
    return _ACTIVE


@contextlib.contextmanager
def profile_kernels():
    """Activate kernel timing for the dynamic extent (not thread-safe —
    one profiler per process, like jax's own profiler)."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, KernelProfiler()
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def observed(name: str, fn, *args, **kwargs):
    """The ops.py hook: time the call iff a profiler is active and the
    operands are concrete (an eager invocation). Otherwise — always,
    when disabled — fall straight through to the same jitted callable."""
    prof = _ACTIVE
    if prof is None or not _eager(args):
        return fn(*args, **kwargs)
    import jax
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    seconds = time.perf_counter() - t0
    flops, nbytes = KERNEL_COSTS[name](*args, **kwargs)
    prof.record(name, flops, nbytes, seconds)
    return out
