"""Per-round solve telemetry: the elimination-curve tracer (DESIGN.md §14).

The paper's central empirical object is the elimination curve — how many
candidates survive each round and how many distance computations that
cost. :class:`SolveTracer` captures it by riding the host-visible
segment boundaries the fault-tolerant runtime already creates
(``core/pipelined.py``, DESIGN.md §13): at every boundary the engine is
*already* synchronising with the host, so the tracer reads the same
host-resident values and adds **zero extra device→host syncs**.

Determinism contract (property-tested in ``tests/test_obs.py``):

* events carry deterministic values only — round counts, survivor
  counts, incumbent index/energy, element counts, bound quantiles.
  **No wall-clock, no hostnames, no pids.** Wall-clock profiling lives
  in :mod:`repro.obs.profile`, outside the trace;
* events serialise with sorted keys, no whitespace, shortest-repr
  floats — the same query + seed yields a **byte-identical** JSONL
  file across runs, and a kill-and-resume run *appends* to the killed
  run's file and converges on the byte-identical uninterrupted trace
  (events are written before the fault hook can raise, mirroring the
  checkpoint-before-kill ordering);
* tracing never changes the solve: with ``trace=None`` the engine's
  segmentation condition is untouched (the disabled path compiles to
  the exact same program), and with tracing on the values are read at
  boundaries whose round sequence is bit-identical anyway (PR 7's
  segmentation-neutrality contract).

Schema ``repro.obs.trace/v1`` — one JSON object per line:

* ``begin``  — solve header: engine, n, d, metric, block;
* ``round``  — one segment boundary: cumulative ``round``, ``phase``
  (``full``/``ladder``), ladder ``rung`` size and ``stage`` ordinal,
  ``survivors``, incumbent index + paper-scale ``energy``, cumulative
  ``elements`` + ``elements_round`` delta, and ``l_summary`` bound
  quantiles (the bound-tightness histogram summary);
* ``heartbeat`` — a RoundWatchdog beat (only when a heartbeat is armed);
* ``hop``    — a planner degrade/retry hop (``on_error="degrade"``);
* ``lane``   — a packed ``solve_many`` per-lane summary;
* ``repair`` — a streaming-index churn repair summary (DESIGN.md §15):
  the op batch absorbed, rows delta-repaired, survivors invalidated —
  emitted right after a ``begin`` with ``engine="stream_repair"``, whose
  ``round`` events then use ``phase="repair"``;
* ``end``    — final index/energy/elements/rounds/certified/halt_reason.

``sum(elements_round) == SolveReport.elements_computed`` exactly: the
engine always emits the final boundary, and deltas telescope.
"""
from __future__ import annotations

import json
import os

import numpy as np

TRACE_SCHEMA = "repro.obs.trace/v1"

#: event kinds and the keys every event of that kind must carry
EVENT_KEYS = {
    "begin": {"kind", "schema", "engine", "n", "metric"},
    "round": {"kind", "round", "phase", "stage", "rung", "survivors",
              "incumbent", "energy", "elements", "elements_round",
              "l_summary"},
    "heartbeat": {"kind", "round"},
    "hop": {"kind", "engine", "reason"},
    "lane": {"kind", "lane", "survivors", "elements"},
    "repair": {"kind", "op", "repaired", "invalidated"},
    "end": {"kind", "engine", "index", "energy", "elements", "rounds",
            "certified", "halt_reason"},
}


def dump_event(event: dict) -> str:
    """Deterministic single-line JSON (sorted keys, no whitespace)."""
    return json.dumps(event, sort_keys=True, separators=(",", ":"))


def _finite(x) -> float | None:
    """JSON-safe float: non-finite becomes ``None`` (strict-JSON lines)."""
    x = float(x)
    return x if np.isfinite(x) else None


def l_summary(l, mask) -> dict | None:
    """Bound-tightness summary over the live entries: quantiles + mean of
    the lower-bound vector. float64 quantiles of identical inputs are
    bit-deterministic, so this stays inside the byte-identity contract."""
    vals = np.asarray(l, np.float64)[np.asarray(mask, bool)]
    if vals.size == 0:
        return None
    qs = np.quantile(vals, (0.0, 0.25, 0.5, 0.75, 1.0))
    return {"min": _finite(qs[0]), "q25": _finite(qs[1]),
            "q50": _finite(qs[2]), "q75": _finite(qs[3]),
            "max": _finite(qs[4]), "mean": _finite(vals.mean())}


class SolveTracer:
    """Collects trace events in memory and (optionally) streams them to a
    JSONL file. Events are **per round** regardless of ``every`` — the
    engine records round telemetry inside its jitted loop and drains it
    at segment boundaries. ``every`` only requests a specific drain
    (segment) cadence in rounds when tracing is the sole reason to
    segment; ``None`` (default) lets the engine amortise the host sync
    over its usual segment length, and an explicit ``checkpoint_every``
    always wins.
    """

    schema = TRACE_SCHEMA

    def __init__(self, path=None, every: int | None = None):
        self.path = os.fspath(path) if path is not None else None
        self.every = max(int(every), 1) if every is not None else None
        self.events: list[dict] = []
        self._fh = None
        self._begun = False
        self.engine_ran = False
        self._elements_prev = 0
        self._last_round = -1
        self._complete = False

    # -- lifecycle ----------------------------------------------------
    def start_session(self) -> None:
        """Called by ``solve()`` at entry: a fresh in-memory event list
        for this solve. Never touches the file — whether the file is
        truncated or appended is decided by ``begin(resumed=...)``, so
        a resumed solve keeps the killed run's prefix."""
        self.close()
        self.events = []
        self._begun = False
        self.engine_ran = False
        self._elements_prev = 0
        self._last_round = -1
        self._complete = False

    def begin(self, *, engine: str, resumed: bool = False,
              elements: int = 0, round_base: int = -1, **meta) -> None:
        """Engine entry. Fresh solves truncate the sink and write the
        ``begin`` header; resumed solves append (the killed run already
        wrote the header) and re-base the element-delta accounting at
        the restored cumulative count. ``round_base`` is the restored
        round counter: a resumed engine may replay a zero-round segment
        at the restored boundary (the killed run already logged it), so
        :meth:`segment` drops events at rounds <= this base."""
        self.engine_ran = True
        if self._begun:
            # a degrade/retry hop re-entered with a new engine: keep the
            # trace rolling in the same session, re-basing the element
            # deltas at the new engine's starting count
            self._elements_prev = int(elements)
            self._last_round = -1
            self._emit({"kind": "begin", "schema": TRACE_SCHEMA,
                        "engine": engine, "resumed": False, **meta})
            return
        self._begun = True
        self._elements_prev = int(elements)
        self._last_round = int(round_base)
        if resumed and self.path is not None and os.path.exists(self.path):
            # resuming from the checkpoint of a *finished* solve (the
            # kill never landed): the trace is already complete, and a
            # replayed run must not append a second ``end``
            try:
                lines = [ln for ln in
                         open(self.path, encoding="utf-8").read()
                         .splitlines() if ln.strip()]
                if lines and json.loads(lines[-1]).get("kind") == "end":
                    self._complete = True
            except (OSError, ValueError):    # pragma: no cover
                pass
        if self.path is not None:
            self._fh = open(self.path, "a" if resumed else "w",
                            encoding="utf-8")
        if not resumed:
            self._emit({"kind": "begin", "schema": TRACE_SCHEMA,
                        "engine": engine, "resumed": False, **meta})
        self.flush()

    def segment(self, *, round: int, phase: str, stage: int, rung: int,
                survivors: int, incumbent: int, energy, elements: int,
                l_summary=None) -> None:
        """One host-visible segment boundary (>= 1 elimination rounds).
        A boundary at an already-logged round (a resumed engine's
        zero-round replay segment) is dropped — the killed run wrote
        it, and byte-identity with the uninterrupted trace depends on
        not writing it twice."""
        elements = int(elements)
        if int(round) <= self._last_round:
            self._elements_prev = elements
            return
        self._last_round = int(round)
        self._emit({
            "kind": "round", "round": int(round), "phase": phase,
            "stage": int(stage), "rung": int(rung),
            "survivors": int(survivors), "incumbent": int(incumbent),
            "energy": _finite(energy) if energy is not None else None,
            "elements": elements,
            "elements_round": elements - self._elements_prev,
            "l_summary": l_summary,
        })
        self._elements_prev = elements

    def event(self, kind: str, **payload) -> None:
        """A free-form deterministic event (``heartbeat``, ``hop``,
        ``lane``). These are rare, so each is flushed immediately —
        the dense per-round stream batches via :meth:`flush` instead."""
        self._emit({"kind": kind, **payload})
        self.flush()

    def flush(self) -> None:
        """Push buffered events to disk. Engines call this at segment
        boundaries *before* their fault hooks run, so a kill at a
        boundary leaves every earlier event durable (the kill/resume
        byte-identity contract) without paying one flush per round."""
        if self._fh is not None:
            self._fh.flush()

    def end(self, *, engine: str, index: int, energy, elements: int,
            rounds: int, certified: bool, halt_reason: str = "",
            **extra) -> None:
        self._emit({
            "kind": "end", "engine": engine, "index": int(index),
            "energy": _finite(energy) if energy is not None else None,
            "elements": int(elements), "rounds": int(rounds),
            "certified": bool(certified), "halt_reason": halt_reason,
            **extra,
        })
        self.close()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- accounting helpers -------------------------------------------
    def _emit(self, event: dict) -> None:
        if self._complete:
            return
        self.events.append(event)
        if self._fh is not None:
            self._fh.write(dump_event(event) + "\n")

    def describe(self) -> dict:
        """The ``SolveReport.extras["obs"]["trace"]`` summary."""
        return {"schema": TRACE_SCHEMA, "path": self.path,
                "n_events": len(self.events), "events": list(self.events)}


def resolve_trace(spec) -> SolveTracer | None:
    """Normalise the ``MedoidQuery.trace`` knob: ``None``/``False`` off,
    ``True`` an in-memory tracer, a path a JSONL-backed tracer, a
    :class:`SolveTracer` taken as-is."""
    if spec is None or spec is False:
        return None
    if isinstance(spec, SolveTracer):
        return spec
    if spec is True:
        return SolveTracer()
    if isinstance(spec, (str, os.PathLike)):
        return SolveTracer(path=spec)
    raise ValueError(
        f"trace must be None, True, a path, or a SolveTracer; "
        f"got {type(spec).__name__}")


# ---------------------------------------------------------------------------
# validation (the CI golden-trace gate)
# ---------------------------------------------------------------------------
def validate_events(events) -> list[str]:
    """Structural validation of a trace event stream. Returns a list of
    problems (empty == valid). Checks the schema header, per-kind
    required keys, and the paper-grounded monotonicity invariants:
    rounds increase, survivors never increase (bounds only grow and the
    incumbent only tightens), cumulative elements never decrease, and
    the per-round deltas telescope to the final element count."""
    errs = []
    events = list(events)
    if not events:
        return ["empty trace"]
    if events[0].get("kind") != "begin":
        errs.append("first event is not 'begin'")
    elif events[0].get("schema") != TRACE_SCHEMA:
        errs.append(f"schema {events[0].get('schema')!r} != {TRACE_SCHEMA}")
    last_round, last_surv, last_elem = -1, None, None
    delta_sum = 0
    for i, ev in enumerate(events):
        kind = ev.get("kind")
        need = EVENT_KEYS.get(kind)
        if need is None:
            errs.append(f"event {i}: unknown kind {kind!r}")
            continue
        missing = need - set(ev)
        if missing:
            errs.append(f"event {i} ({kind}): missing {sorted(missing)}")
            continue
        if kind == "begin" and i > 0:
            # a degrade hop restarts the engine: rounds/elements re-base
            last_round, last_surv, last_elem = -1, None, None
            delta_sum = 0
        if kind != "round":
            continue
        if ev["round"] <= last_round:
            errs.append(f"event {i}: round {ev['round']} not increasing")
        last_round = ev["round"]
        if last_surv is not None and ev["survivors"] > last_surv:
            errs.append(f"event {i}: survivors grew "
                        f"{last_surv} -> {ev['survivors']}")
        last_surv = ev["survivors"]
        if last_elem is not None and ev["elements"] < last_elem:
            errs.append(f"event {i}: elements decreased")
        last_elem = ev["elements"]
        delta_sum += ev["elements_round"]
    ends = [ev for ev in events if ev.get("kind") == "end"]
    rounds = [ev for ev in events if ev.get("kind") == "round"]
    if ends and rounds:
        if ends[-1]["elements"] != rounds[-1]["elements"]:
            errs.append("end.elements != last round.elements")
        if delta_sum != ends[-1]["elements"]:
            errs.append(f"sum(elements_round)={delta_sum} != "
                        f"end.elements={ends[-1]['elements']}")
    return errs


def load_jsonl(path) -> list[dict]:
    events = []
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def compare_structure(events, golden) -> list[str]:
    """Golden-trace comparison for CI: the live trace must exhibit every
    event kind the golden trace has, with byte-identical key sets per
    kind, and the first/last kinds must agree. Numeric values and round
    *counts* are deliberately not compared — float bits (and hence the
    exact pivot sequence) drift across BLAS/jax builds; structure is the
    cross-platform contract, byte-identity is the same-host contract
    tested in tests/test_obs.py."""
    errs = []
    if not events or not golden:
        return ["empty trace or golden"]

    def _keysets(evs):
        out = {}
        for ev in evs:
            out.setdefault(ev.get("kind"), set()).update(ev)
        return out

    live_k, gold_k = _keysets(events), _keysets(golden)
    for kind, gkeys in sorted(gold_k.items()):
        if kind not in live_k:
            errs.append(f"kind {kind!r} present in golden, absent live")
        elif live_k[kind] != gkeys:
            errs.append(f"kind {kind!r}: keys "
                        f"{sorted(live_k[kind] ^ gkeys)} drifted")
    for kind in sorted(set(live_k) - set(gold_k)):
        errs.append(f"kind {kind!r} absent from golden")
    if events[0].get("kind") != golden[0].get("kind"):
        errs.append("first event kind drifted")
    if events[-1].get("kind") != golden[-1].get("kind"):
        errs.append("last event kind drifted")
    return errs
