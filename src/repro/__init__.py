"""repro — multi-pod JAX framework around the trimed exact-medoid algorithm.

The public surface is :mod:`repro.api` — one declarative front door
(``MedoidQuery`` -> planner -> ``SolveReport``) over every engine, plus
the first-class ``Metric`` registry. Layers underneath: core (the
paper's engines), bandit (anytime / budgeted queries: UCB racing +
sequential halving + the exact-finisher hybrid), kernels (Pallas),
models (arch zoo), stream (exact churn maintenance), train/serve
(drivers), data/optim/checkpoint/runtime (substrate), launch (mesh +
shardings + dry-run), roofline (perf analysis).
"""
from . import compat  # noqa: F401  (installs jax<0.5 mesh-API shims)
from .api import (  # noqa: F401
    ENGINES,
    MedoidQuery,
    Metric,
    Plan,
    SolveReport,
    available_metrics,
    get_metric,
    plan_query,
    register_metric,
    solve,
    solve_many,
    unregister_metric,
)

__all__ = [
    "ENGINES",
    "MedoidQuery",
    "Metric",
    "Plan",
    "SolveReport",
    "available_metrics",
    "get_metric",
    "plan_query",
    "register_metric",
    "solve",
    "solve_many",
    "unregister_metric",
]

__version__ = "2.0.0"
