"""repro — multi-pod JAX framework around the trimed exact-medoid algorithm.

Layers: core (the paper), bandit (anytime / budgeted medoid queries:
UCB racing + sequential halving + the exact-finisher hybrid), kernels
(Pallas), models (arch zoo), distributed (sharding), train/serve
(drivers), data/optim/checkpoint/runtime (substrate), launch (mesh +
dry-run), roofline (perf analysis).
"""
from . import compat  # noqa: F401  (installs jax<0.5 mesh-API shims)

__version__ = "1.0.0"
