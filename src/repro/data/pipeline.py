"""Deterministic, sharding-aware, resumable synthetic data pipeline.

Production shape without external corpora: batches are generated from a
counter-based PRNG (stateless — batch ``i`` is a pure function of
``(seed, i)``), so

* any worker can regenerate any batch (fault tolerance / elastic
  restarts need no data-loader state beyond the step counter),
* per-host sharding falls out of slicing the global batch by host index,
* resuming from a checkpoint at step ``s`` is exact: the loader is just
  ``batch(s)``.

A mixture of synthetic "domains" (different zipf exponents / sequence
statistics) stands in for a real corpus; the medoid **coreset** hook
(`repro.data.coreset`) subsamples representative sequences per batch
with the paper's trikmeds.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import FRAME_DIM, VISION_DIM


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    n_domains: int = 4


def _keys(cfg: DataConfig, step: int):
    root = jax.random.PRNGKey(cfg.seed)
    return jax.random.fold_in(root, step)


def lm_batch(cfg: DataConfig, step: int, model_cfg=None):
    """Global LM batch for `step`, deterministic. Markov-ish synthetic
    tokens: domain-dependent zipf over vocab with local repetition."""
    key = _keys(cfg, step)
    k1, k2, k3 = jax.random.split(key, 3)
    b, s = cfg.global_batch, cfg.seq_len
    dom = jax.random.randint(k1, (b, 1), 0, cfg.n_domains)
    alpha = 1.0 + 0.3 * dom.astype(jnp.float32)            # zipf exponent
    u = jax.random.uniform(k2, (b, s), minval=1e-6, maxval=1.0)
    ranks = jnp.exp(jnp.log(u) / -alpha)                   # heavy tail
    toks = jnp.clip((ranks * 97.0).astype(jnp.int32) % cfg.vocab, 0,
                    cfg.vocab - 1)
    # local repetition: with p=0.2 copy previous token
    rep = jax.random.bernoulli(k3, 0.2, (b, s))
    toks = jnp.where(rep, jnp.roll(toks, 1, axis=1), toks)
    return {"tokens": toks}


def family_batch(model_cfg, shape, step: int, seed: int = 0):
    """Batch matching `launch.specs.train_batch_struct` for any family."""
    cfg = DataConfig(seed=seed, vocab=model_cfg.vocab,
                     seq_len=shape.seq_len, global_batch=shape.global_batch)
    key = _keys(cfg, step)
    b, s = shape.global_batch, shape.seq_len
    if model_cfg.family == "encoder":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "frames": jax.random.normal(k1, (b, s, FRAME_DIM), jnp.float32),
            "mask": jax.random.bernoulli(k2, 0.08, (b, s)),
            "targets": jax.random.randint(k3, (b, s), 0, model_cfg.vocab),
        }
    if model_cfg.family == "vlm":
        k1, k2 = jax.random.split(key)
        base = lm_batch(DataConfig(seed=seed, vocab=model_cfg.vocab,
                                   seq_len=s - model_cfg.n_patches,
                                   global_batch=b), step)
        return {
            "tokens": base["tokens"],
            "patches": jax.random.normal(
                k2, (b, model_cfg.n_patches, VISION_DIM), jnp.float32),
        }
    return lm_batch(cfg, step)


class ShardedLoader:
    """Per-host view of the global batch (slice by host index). With one
    process it degenerates to the global batch; under multi-host it
    feeds `jax.make_array_from_process_local_data`."""

    def __init__(self, model_cfg, shape, seed=0,
                 host_index=0, host_count=1):
        self.model_cfg = model_cfg
        self.shape = shape
        self.seed = seed
        self.host_index = host_index
        self.host_count = host_count

    def __call__(self, step: int):
        batch = family_batch(self.model_cfg, self.shape, step, self.seed)
        if self.host_count == 1:
            return batch
        per = self.shape.global_batch // self.host_count
        lo = self.host_index * per
        return jax.tree.map(lambda x: x[lo:lo + per], batch)
