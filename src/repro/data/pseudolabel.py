"""HuBERT-style pseudo-labels from trikmeds medoid clustering.

HuBERT's training targets are cluster codes of (masked) audio frames.
Upstream uses k-means; here the codebook is the set of K *medoids*
(paper technique — valid in any metric, robust to outliers):

1. pool a calibration set of frame embeddings,
2. run device-side K-medoids (K = codebook size, e.g. the 504-tier),
3. targets = nearest-medoid index per frame.

The returned codebook is reusable across the corpus (targets for new
frames are a single (T, K) distance argmin)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.distances import pairwise
from repro.core.trikmeds import kmedoids_batched, kmedoids_jax


def build_codebook(frames: np.ndarray, k: int, seed: int = 0,
                   n_iter: int = 8, medoid_update: str = "trimed"):
    """frames: (N, F) pooled calibration frames. Returns (codebook
    (K, F) medoid vectors, medoid indices). The medoid update runs the
    batched multi-cluster trimed engine (DESIGN.md §3) — at 504-code
    scale the quadratic scan dominates codebook build time, so this is
    the difference between minutes and hours on large calibration sets;
    pass ``medoid_update="scan"`` to force the quadratic path."""
    X = jnp.asarray(frames, jnp.float32)
    m_idx, _, _ = kmedoids_jax(X, k, seed=seed, n_iter=n_iter,
                               medoid_update=medoid_update)
    return np.asarray(jnp.take(X, m_idx, axis=0)), np.asarray(m_idx)


def build_codebook_instrumented(frames: np.ndarray, k: int, seed: int = 0,
                                n_iter: int = 8,
                                medoid_update: str = "trimed"):
    """As :func:`build_codebook`, also returning the
    :class:`repro.core.trikmeds.KMedoidsJaxResult` with distance-
    computation counts (EXPERIMENTS.md §Batched reports these)."""
    X = jnp.asarray(frames, jnp.float32)
    res = kmedoids_batched(X, k, seed=seed, n_iter=n_iter,
                           medoid_update=medoid_update)
    return np.asarray(X[res.medoids]), res.medoids, res


def assign_targets(frames: np.ndarray, codebook: np.ndarray):
    """frames: (B, T, F) -> targets (B, T) int32 nearest-medoid codes."""
    b, t, f = frames.shape
    d = pairwise(jnp.asarray(frames.reshape(b * t, f), jnp.float32),
                 jnp.asarray(codebook, jnp.float32))
    return np.asarray(jnp.argmin(d, axis=1).reshape(b, t).astype(jnp.int32))
