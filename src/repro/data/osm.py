"""OSM-style road-network loader (offline stub).

The paper's network experiments run on OpenStreetMap road graphs
(Table 1: Europe / road networks). This container has **no network
access and no OSM extracts**, so this module cannot reproduce those
rows — EXPERIMENTS.md §Networks states the gap, and the synthetic
generators (``grid_network``, ``sensor_network``) stand in as
structurally matched proxies.

What this module *does* provide is the ingestion seam: a parser for a
minimal node/edge text format (the shape an OSM ``.osm.pbf`` →
edge-list extraction produces) into a :class:`~repro.core.graph.
GraphOracle`, so a real extract dropped into the container plugs
straight into ``solve(MedoidQuery(oracle, metric="graph"))`` with no
code changes. The format, one record per line, ``#`` comments:

    node <id> <x> <y>
    edge <u> <v> [<weight>]

Node ids are arbitrary integers (remapped densely); an omitted edge
weight defaults to the Euclidean length between the endpoint
coordinates — the road-length proxy the paper's protocol uses. Edges
are undirected (shortest-path length on an undirected non-negative
graph is a true metric, which the graph engine's landmark bounds
require — DESIGN.md §16); pass ``directed=True`` only if you accept
the planner rerouting to the host sequential engine.
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

__all__ = ["load_osm_graph", "parse_osm_text"]


def parse_osm_text(text: str, directed: bool = False):
    """Parse the node/edge format into ``(GraphOracle, coords)``.

    ``coords`` is the ``(n, 2)`` float array of node positions in file
    order after dense id remapping. Raises ``ValueError`` on malformed
    records or edges naming unknown nodes — a silently dropped edge
    would change every shortest path downstream of it.
    """
    from repro.core.graph import GraphOracle

    ids: dict[int, int] = {}
    xs: list[tuple[float, float]] = []
    edges: list[tuple[int, int, float | None]] = []
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        parts = line.split()
        kind = parts[0]
        if kind == "node" and len(parts) == 4:
            nid = int(parts[1])
            if nid in ids:
                raise ValueError(f"line {lineno}: duplicate node {nid}")
            ids[nid] = len(xs)
            xs.append((float(parts[2]), float(parts[3])))
        elif kind == "edge" and len(parts) in (3, 4):
            w = float(parts[3]) if len(parts) == 4 else None
            if w is not None and (w < 0 or not np.isfinite(w)):
                raise ValueError(
                    f"line {lineno}: edge weight {w} must be finite "
                    "and non-negative (shortest-path metric)")
            edges.append((int(parts[1]), int(parts[2]), w))
        else:
            raise ValueError(
                f"line {lineno}: expected 'node <id> <x> <y>' or "
                f"'edge <u> <v> [<w>]', got {raw!r}")

    coords = np.asarray(xs, dtype=np.float64).reshape(len(xs), 2)
    adj: dict[int, list[tuple[int, float]]] = {
        i: [] for i in range(len(xs))}
    for u, v, w in edges:
        if u not in ids or v not in ids:
            raise ValueError(f"edge ({u}, {v}) names an undeclared node")
        ui, vi = ids[u], ids[v]
        if w is None:
            w = float(np.linalg.norm(coords[ui] - coords[vi]))
        adj[ui].append((vi, w))
        if not directed:
            adj[vi].append((ui, w))
    return GraphOracle(adj, len(xs), directed=directed), coords


def load_osm_graph(path: str | Path, directed: bool = False):
    """Load a node/edge file into ``(GraphOracle, coords)``.

    The canonical error for the missing-data case names the gap
    honestly instead of failing deep in the parser: no OSM extract
    ships with this repo, and none can be fetched from inside the
    container.
    """
    p = Path(path)
    if not p.exists():
        raise FileNotFoundError(
            f"{p}: no OSM extract found. This environment has no "
            "network access and ships no real road-network data — the "
            "paper's OSM rows are reproduced in protocol only, on the "
            "synthetic grid/sensor generators (EXPERIMENTS.md "
            "§Networks). To run on real data, export an edge list to "
            "the 'node <id> <x> <y>' / 'edge <u> <v> [<w>]' format "
            "and pass its path here.")
    return parse_osm_text(p.read_text(), directed=directed)
