"""Medoid coreset selection / dedup for the data pipeline (paper hook).

Given a stream of sequence embeddings, pick K representative sequences
(medoids) per pool and optionally drop near-duplicates (elements within
``dedup_eps`` of a medoid other than itself). Runs the device-side
K-medoids (`core.trikmeds.kmedoids_jax`) per pool; for multi-device
pools the sharded trimed (`core.distributed`) finds the global medoid of
each pool shard-locally with only O(B d) communication per round."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distances import pairwise
from repro.core.trikmeds import kmedoids_batched, kmedoids_jax


def mean_pool_embed(params_embed: jnp.ndarray, tokens: jnp.ndarray):
    """Cheap sequence embedding: mean-pooled token embeddings."""
    emb = jnp.take(params_embed, tokens, axis=0)   # (B, S, D)
    return emb.mean(axis=1)


def select_coreset(embeddings, k: int, seed: int = 0,
                   medoid_update: str = "trimed"):
    """Returns indices of K medoid sequences in the pool. The medoid
    update runs the batched multi-cluster trimed engine (DESIGN.md §3);
    pool sizes here routinely exceed 10^5 sequences, where the quadratic
    scan would dominate the pipeline."""
    m_idx, assign, energy = kmedoids_jax(
        jnp.asarray(embeddings, jnp.float32), k, seed=seed,
        medoid_update=medoid_update)
    return np.asarray(m_idx), np.asarray(assign), float(energy)


def select_coreset_instrumented(embeddings, k: int, seed: int = 0,
                                medoid_update: str = "trimed"):
    """As :func:`select_coreset`, returning the full instrumented
    :class:`repro.core.trikmeds.KMedoidsJaxResult` (distance counts
    included) for pipeline cost accounting."""
    return kmedoids_batched(jnp.asarray(embeddings, jnp.float32), k,
                            seed=seed, medoid_update=medoid_update)


def dedup(embeddings, medoid_idx, assign, eps: float):
    """Keep medoids + all elements farther than eps from their medoid."""
    X = jnp.asarray(embeddings, jnp.float32)
    med = jnp.take(X, jnp.asarray(medoid_idx), axis=0)
    d = pairwise(X, med)                            # (N, K)
    dmed = jnp.take_along_axis(d, jnp.asarray(assign)[:, None], 1)[:, 0]
    keep = np.asarray(dmed) > eps
    keep[np.asarray(medoid_idx)] = True
    return np.flatnonzero(keep)
