"""Version-compat shims for jax < 0.5 mesh/sharding APIs.

The distributed layer (``core/distributed.py``, ``models/moe.py``,
``launch/mesh.py``) is written against the
modern mesh API: ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)``, ``jax.set_mesh`` (ambient mesh), ``jax.shard_map``
(with ``check_vma``) and ``jax.sharding.get_abstract_mesh``. jax 0.4.x
(this container ships 0.4.37) predates all five. Importing this module
— it is imported from ``repro/__init__.py``, so any ``import repro``
suffices — installs equivalents into the ``jax`` namespace when they are
missing:

* ``AxisType`` — a stand-in enum (axis types only affect the sharding
  *dialect*, not numerics; every in-repo use is ``Auto``);
* ``make_mesh`` — wrapper accepting and dropping ``axis_types``;
* ``set_mesh`` — context manager recording the ambient mesh in a module
  global;
* ``get_abstract_mesh`` — returns that ambient mesh (a concrete ``Mesh``
  carries the ``axis_names`` / ``axis_sizes`` / ``empty`` surface the
  callers use) or ``None``;
* ``shard_map`` — adapter over ``jax.experimental.shard_map.shard_map``
  translating ``check_vma`` -> ``check_rep`` and resolving a missing
  ``mesh`` from the ambient one.

On jax versions that already provide an API, the shim for it is a no-op,
so this module is safe to import unconditionally.
"""
from __future__ import annotations

import contextlib
import enum
import functools
import inspect

import jax
import jax.sharding as _sharding

_ambient_mesh = None     # set by the set_mesh shim


if not hasattr(_sharding, "AxisType"):
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _sharding.AxisType = AxisType
else:                                             # pragma: no cover
    AxisType = _sharding.AxisType


if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
    _orig_make_mesh = jax.make_mesh

    @functools.wraps(_orig_make_mesh)
    def _make_mesh(axis_shapes, axis_names, *, devices=None,
                   axis_types=None):
        del axis_types               # pre-AxisType jax: Auto is implicit
        return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = _make_mesh


if not hasattr(jax, "set_mesh"):
    @contextlib.contextmanager
    def _set_mesh(mesh):
        global _ambient_mesh
        prev = _ambient_mesh
        _ambient_mesh = mesh
        try:
            # the legacy resource-env context is what pre-0.5
            # with_sharding_constraint/GSPMD consult for PartitionSpecs
            with mesh:
                yield mesh
        finally:
            _ambient_mesh = prev

    jax.set_mesh = _set_mesh

    def _get_abstract_mesh():
        return _ambient_mesh

    _sharding.get_abstract_mesh = _get_abstract_mesh


def _install_optimization_barrier_batching():
    """jax 0.4.x ships ``lax.optimization_barrier`` without a vmap
    batching rule (added upstream later), which breaks ``vmap`` over
    anything using the fixed reduction geometry of
    ``core/distances.py`` (e.g. the per-head K-medoids in
    ``serve/kv_compress.py``). The barrier is an elementwise identity,
    so the rule is pass-through. No-op where the rule already exists."""
    try:
        from jax._src.interpreters import batching
        from jax._src.lax import lax as _lax_impl
        prim = _lax_impl.optimization_barrier_p
    except (ImportError, AttributeError):      # pragma: no cover
        return

    if prim in batching.primitive_batchers:    # pragma: no cover
        return

    def _batcher(batched_args, batch_dims, **params):
        return prim.bind(*batched_args, **params), batch_dims

    batching.primitive_batchers[prim] = _batcher


_install_optimization_barrier_batching()


if not hasattr(jax.lax, "axis_size"):
    def _axis_size(axis_name):
        # psum of a literal 1 constant-folds to the static axis size
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = _axis_size


def make_1d_mesh(n_shards: int | None = None, axis: str = "shard"):
    """A one-axis mesh over the first ``n_shards`` local devices.

    Version-portable mesh construction for the sharded medoid engine
    (``core/distributed.py``): ``jax.make_mesh`` only learned to take a
    device subset and ``axis_types`` in newer releases, while the plain
    :class:`jax.sharding.Mesh` constructor has been stable across every
    version the repo supports — so build on that."""
    import numpy as np

    devs = jax.devices()
    p = len(devs) if n_shards is None else int(n_shards)
    if not 1 <= p <= len(devs):
        raise ValueError(
            f"make_1d_mesh: n_shards={p} outside [1, {len(devs)}] "
            "available devices")
    return _sharding.Mesh(np.asarray(devs[:p]), (axis,))


if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, mesh=None, in_specs=None, out_specs=None,
                   check_vma=None, check_rep=None, **kwargs):
        if mesh is None:
            mesh = _ambient_mesh
        if mesh is None:
            raise ValueError(
                "shard_map needs a mesh: pass mesh=... or enter a "
                "jax.set_mesh(...) context")
        if check_vma is None:
            check_vma = True if check_rep is None else check_rep
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs,
                              check_rep=bool(check_vma), **kwargs)

    jax.shard_map = _shard_map
