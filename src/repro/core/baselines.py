"""Baselines the paper compares against: RAND, TOPRANK, TOPRANK2, KMEDS.

All host-side (numpy) and instrumented with the unified cost unit the
paper reports — *computed elements* (full distance rows; partial work
counts fractionally via :func:`repro.core.distances.elements_computed`,
so these numbers sit on the same axis as the device engines' and the
bandit subsystem's). TOPRANK/TOPRANK2 follow
the pseudocode in SM-C (Alg. 3-5), including the parameter choices the
paper uses in its experiments: ``q = 1`` anchor-count constant and
``alpha' = 1`` for the threshold, ``l0 = sqrt(N)`` / increment ``log N``
for TOPRANK2.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .distances import VectorOracle


@dataclass
class BaselineResult:
    index: int
    energy: float
    n_computed: float            # unified computed elements (distances.py)
    extras: dict = field(default_factory=dict)


def _as_oracle(oracle_or_X, metric):
    if isinstance(oracle_or_X, np.ndarray):
        return VectorOracle(oracle_or_X, metric)
    return oracle_or_X


# ---------------------------------------------------------------------------
# RAND (Eppstein & Wang 2004) — Alg. 3
# ---------------------------------------------------------------------------
def rand_energies(oracle, n_anchors: int, rng) -> tuple[np.ndarray, np.ndarray]:
    """Estimate all energies from ``n_anchors`` uniformly sampled anchors.
    Returns (E_hat, anchor_indices). Distance rows are computed *from* the
    anchors (Dijkstra-friendly on graphs), giving dist(anchor, j) for all j."""
    n = oracle.n
    anchors = rng.choice(n, size=min(n_anchors, n), replace=False)
    rows = np.stack([oracle.row(i) for i in anchors])      # (A, N)
    # E_hat(j) = N / (|I| (N-1)) * sum_i dist(x(j), x(i))
    e_hat = rows.sum(axis=0) * n / (len(anchors) * (n - 1))
    return e_hat, anchors, rows


def rand_medoid(
    oracle_or_X, epsilon: float = 0.05, seed: int = 0, metric: str = "l2"
) -> BaselineResult:
    """RAND used as an approximate medoid finder: log(N)/eps^2 anchors."""
    oracle = _as_oracle(oracle_or_X, metric)
    rng = np.random.default_rng(seed)
    n_anchors = int(np.ceil(np.log(oracle.n) / epsilon**2))
    e_hat, anchors, _ = rand_energies(oracle, n_anchors, rng)
    idx = int(np.argmin(e_hat))
    return BaselineResult(idx, float(e_hat[idx]), oracle.elements)


# ---------------------------------------------------------------------------
# TOPRANK (Okamoto et al. 2008) — Alg. 4
# ---------------------------------------------------------------------------
def toprank(
    oracle_or_X,
    k: int = 1,
    alpha: float = 1.0,
    q: float = 1.0,
    seed: int = 0,
    metric: str = "l2",
) -> BaselineResult:
    oracle = _as_oracle(oracle_or_X, metric)
    n = oracle.n
    rng = np.random.default_rng(seed)

    n_anchors = int(np.ceil(q * n ** (2.0 / 3.0) * np.log(n) ** (1.0 / 3.0)))
    n_anchors = min(n_anchors, n)
    e_hat, anchors, rows = rand_energies(oracle, n_anchors, rng)

    # Delta_hat = 2 min_i max_j d(i, j) over anchor rows
    delta_hat = 2.0 * rows.max(axis=1).min()
    kth = np.partition(e_hat, k - 1)[k - 1]
    tau = kth + 2.0 * alpha * delta_hat * np.sqrt(np.log(n) / n_anchors)

    candidates = np.flatnonzero(e_hat <= tau)
    anchor_set = set(int(a) for a in anchors)
    best_i, best_e = -1, np.inf
    for i in candidates:
        d = oracle.row(int(i))
        e = d.sum() / (n - 1)
        if e < best_e:
            best_i, best_e = int(i), float(e)
    return BaselineResult(
        best_i,
        best_e,
        oracle.elements,
        {"n_anchors": n_anchors, "n_candidates": len(candidates), "tau": tau},
    )


# ---------------------------------------------------------------------------
# TOPRANK2 (Okamoto et al. 2008) — Alg. 5
# ---------------------------------------------------------------------------
def toprank2(
    oracle_or_X,
    k: int = 1,
    alpha: float = 1.0,
    seed: int = 0,
    metric: str = "l2",
) -> BaselineResult:
    oracle = _as_oracle(oracle_or_X, metric)
    n = oracle.n
    rng = np.random.default_rng(seed)

    l0 = max(int(np.sqrt(n)), 1)          # SM-C.3: l0 = sqrt(N)
    q = max(int(np.log(n)), 1)            # increment log(N)

    remaining = rng.permutation(n).tolist()
    anchors: list[int] = []
    rows_sum = np.zeros(n)
    row_max_min = np.inf

    def add_anchors(count):
        nonlocal row_max_min
        for _ in range(count):
            if not remaining:
                return
            a = remaining.pop()
            anchors.append(a)
            r = oracle.row(a)
            rows_sum[:] += r
            row_max_min = min(row_max_min, r.max())

    add_anchors(l0)

    def candidate_set():
        e_hat = rows_sum * n / (len(anchors) * (n - 1))
        delta_hat = 2.0 * row_max_min
        kth = np.partition(e_hat, k - 1)[k - 1]
        tau = kth + 2.0 * alpha * delta_hat * np.sqrt(np.log(n) / len(anchors))
        return np.flatnonzero(e_hat <= tau)

    cand = candidate_set()
    while len(anchors) < n:
        prev = len(cand)
        add_anchors(q)
        cand = candidate_set()
        if prev - len(cand) < np.log(n):   # break-out criterion (Alg. 5)
            break

    best_i, best_e = -1, np.inf
    for i in cand:
        d = oracle.row(int(i))
        e = d.sum() / (n - 1)
        if e < best_e:
            best_i, best_e = int(i), float(e)
    return BaselineResult(
        best_i,
        best_e,
        oracle.elements,
        {"n_anchors": len(anchors), "n_candidates": len(cand)},
    )


# ---------------------------------------------------------------------------
# KMEDS (Park & Jun 2009) — Alg. 2, with both init schemes
# ---------------------------------------------------------------------------
@dataclass
class KMedoidsResult:
    medoids: np.ndarray           # (K,) element indices
    assignment: np.ndarray        # (N,)
    energy: float                 # sum over elements of dist to its medoid
    n_distances: int              # scalar distance computations
    n_iterations: int


def parkjun_init(D: np.ndarray, k: int) -> np.ndarray:
    """Park–Jun initialisation: pick K minimisers of
    f(i) = sum_j D(i,j) / S(j)."""
    s = D.sum(axis=0)
    f = (D / s[None, :]).sum(axis=1)
    return np.argsort(f)[:k]


def kmeds(
    X: np.ndarray,
    k: int,
    init: str = "parkjun",
    max_iter: int = 100,
    seed: int = 0,
    metric: str = "l2",
    init_medoids: np.ndarray | None = None,
) -> KMedoidsResult:
    """The quadratic Voronoi-iteration baseline: all N^2 distances upfront."""
    oracle = VectorOracle(X, metric)
    n = oracle.n
    rng = np.random.default_rng(seed)
    D = np.stack([oracle.row(i) for i in range(n)])   # Theta(N^2)

    if init_medoids is not None:
        medoids = np.array(init_medoids, dtype=int).copy()
    elif init == "parkjun":
        medoids = parkjun_init(D, k)
    elif init == "uniform":
        medoids = rng.choice(n, size=k, replace=False)
    else:
        raise ValueError(f"unknown init {init!r}")

    assignment = np.argmin(D[medoids], axis=0)
    for it in range(max_iter):
        new_medoids = medoids.copy()
        for c in range(k):
            members = np.flatnonzero(assignment == c)
            if len(members) == 0:
                continue
            sub = D[np.ix_(members, members)]
            new_medoids[c] = members[np.argmin(sub.sum(axis=1))]
        new_assignment = np.argmin(D[new_medoids], axis=0)
        converged = np.array_equal(new_medoids, medoids) and np.array_equal(
            new_assignment, assignment
        )
        medoids, assignment = new_medoids, new_assignment
        if converged:
            break
    energy = float(D[medoids][assignment, np.arange(n)].sum())
    return KMedoidsResult(
        medoids, assignment, energy, oracle.scalar_distances, it + 1
    )
