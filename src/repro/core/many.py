"""Many-query engine: Q same-shape medoid searches in one jitted program.

The serving path (DESIGN.md §12). A bucket of same-shape queries —
identical ``(N, d)``, dtype, metric and block width — runs as ONE jitted
program with the query axis batched two ways:

* **jnp path** (default): ``jax.vmap`` over the full-domain stage of the
  pipelined engine (:func:`repro.core.pipelined._pipe_round0` reused
  verbatim). ``lax.while_loop`` under vmap freezes each lane's state the
  moment its own predicate goes false, so per-query ``n_computed`` /
  ``n_rounds`` are *bit-identical* to the single-query engine run with
  the compaction ladder disabled (``ladder_min >= N`` — compaction is a
  host-loop cost optimisation; a serving bucket of small-N queries never
  reaches the ladder regime, and disabling it keeps the whole search one
  device program).

* **kernel path** (``use_kernels=True``): the query axis becomes a
  leading *grid dimension* of the pipelined Pallas kernel family
  (``kernels.ops.many_pipelined_round``); the batched round is explicit
  and every lane's state update is gated by its own live predicate
  (select-based freeze), replicating the vmap semantics exactly.

Per-query budgets ride the already-traced ``budget`` argument, so one
program serves mixed exact/anytime lanes: a budget-capped lane stops
eliminating, keeps its exact-energy incumbent, and reports the
deterministic bound-gap interval ``[min live l, E_cl]`` (every live
``l`` is a valid lower bound on the winner's energy — no probabilistic
machinery needed, unlike the bandit CI).

Warm starts ride a forced first pivot block with an explicit per-query
validity mask (queries in one bucket may warm-seed different counts;
invalid slots are bit-inert pads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as _ops

from .distances import sq_norms
from .pipelined import NEG_INF, _budget_cap, _pad_prev, _pipe_round0

HUGE_BUDGET = 2**31 - 1


# ---------------------------------------------------------------------------
# jnp path: vmap over the single-query full-domain stage
# ---------------------------------------------------------------------------
def _lane_stage(X, l0, warm_arr, warm_valid, budget, block, metric,
                has_warm):
    """One query's full-domain stage — line-for-line the body of
    ``pipelined._stage0`` with ``can_compact=False``, plus an explicit
    ``warm_valid`` mask (``_stage0`` hardcodes all-valid warm pivots; a
    bucket packs warm arrays of different lengths, padded invalid)."""
    n = X.shape[0]
    x_sq = (sq_norms(X) if metric in ("l2", "sqeuclidean")
            else jnp.zeros(n, X.dtype))
    state = (
        l0.astype(X.dtype),                       # l
        jnp.ones(n, bool),                        # alive (= not computed)
        jnp.asarray(jnp.inf, X.dtype),            # e_cl
        jnp.asarray(-1, jnp.int32),               # m_cl
        jnp.zeros(0, jnp.int32),                  # prev idx (empty: round 0)
        jnp.zeros(0, X.dtype),                    # prev energies
        jnp.zeros(0, bool),                       # prev valid
        jnp.zeros((0, n), X.dtype),               # prev rows (jnp carry)
        jnp.asarray(0, jnp.int32),                # n_computed
        jnp.asarray(0, jnp.int32),                # n_rounds
        jnp.zeros(n, X.dtype),                    # esum energy cache
    )
    round_fn = functools.partial(_pipe_round0, X, x_sq, n, metric,
                                 False, None, budget)
    if has_warm:
        bw = warm_arr.shape[0]
        state = round_fn(state, bw, forced_idx=warm_arr,
                         forced_valid=warm_valid)
    state = _pad_prev(state, block, has_carry=True)

    def cond(state):
        l, alive, e_cl = state[0], state[1], state[2]
        live = jnp.logical_and(alive, l < e_cl).sum()
        return jnp.logical_and(live > 0, state[8] < budget)

    state = jax.lax.while_loop(cond, lambda s: round_fn(s, block), state)
    return _summarise(state)


def _summarise(state):
    """(m_cl, e_cl, n_comp, n_rounds, live, lo) from a final lane state.
    ``lo`` is the certificate floor: min live lower bound (or the
    incumbent itself when none survive) — the true optimum lies in
    ``[lo, e_cl]``, deterministically."""
    (l, alive, e_cl, m_cl, _pi, _pe, _pv, _d, n_comp, n_rounds,
     _es) = state
    live_mask = jnp.logical_and(alive, l < e_cl)
    live = live_mask.sum()
    lo = jnp.where(live_mask, l, jnp.inf).min(axis=-1)
    lo = jnp.minimum(lo, e_cl)
    return m_cl, e_cl, n_comp, n_rounds, live, lo


@functools.partial(
    jax.jit,
    static_argnames=("block", "metric", "has_warm"),
)
def _many_stage_jnp(Xq, l0q, warm_q, warm_valid_q, budget_q, block, metric,
                    has_warm):
    fn = functools.partial(_lane_stage, block=block, metric=metric,
                           has_warm=has_warm)
    return jax.vmap(fn)(Xq, l0q, warm_q, warm_valid_q, budget_q)


# ---------------------------------------------------------------------------
# kernel path: explicit batched rounds, query axis as a Pallas grid dim
# ---------------------------------------------------------------------------
def _kround(Xq, n, metric, interpret, budget_q, state, b, first,
            forced_idx=None, forced_valid=None):
    """One batched kernel round — ``_pipe_round0``'s kernel branch with a
    leading query axis on every operand."""
    (l, alive, e_cl, m_cl, pidx, pe, pv, n_comp, n_rounds) = state
    qn = Xq.shape[0]

    if forced_idx is None:
        score = jnp.where(jnp.logical_and(alive, l < e_cl[:, None]),
                          -l, NEG_INF)
        top, idx = jax.lax.top_k(score, b)
        valid = top > NEG_INF
    else:
        idx, valid = forced_idx, forced_valid
    rank = jnp.cumsum(valid.astype(jnp.int32), axis=1)
    valid = jnp.logical_and(valid,
                            n_comp[:, None] + rank <= budget_q[:, None])
    xb = jnp.take_along_axis(Xq, idx[..., None], axis=1)

    if first:
        e_sums = _ops.many_block_energies(xb, Xq, metric=metric,
                                          interpret=interpret)
    else:
        xbp = jnp.take_along_axis(Xq, pidx[..., None], axis=1)
        e_sums, l = _ops.many_pipelined_round(xb, xbp, Xq, pe, pv, l,
                                              metric=metric,
                                              interpret=interpret)

    e_blk = jnp.where(valid, e_sums / n, jnp.inf)
    b_best = jnp.argmin(e_blk, axis=1)
    e_best = jnp.take_along_axis(e_blk, b_best[:, None], 1)[:, 0]
    i_best = jnp.take_along_axis(idx, b_best[:, None], 1)[:, 0]
    better = e_best < e_cl
    e_cl = jnp.where(better, e_best, e_cl)
    m_cl = jnp.where(better, i_best, m_cl)
    qi = jnp.arange(qn)[:, None]
    alive = alive.at[qi, idx].set(
        jnp.where(valid, False, jnp.take_along_axis(alive, idx, axis=1)))
    n_comp = n_comp + valid.sum(axis=1)
    pe = jnp.where(valid, e_blk, 0.0)
    return (l, alive, e_cl, m_cl, idx, pe, valid, n_comp, n_rounds + 1)


def _lane_active(state, budget_q):
    (l, alive, e_cl, _m, _pi, _pe, _pv, n_comp, _r) = state
    live = jnp.logical_and(alive, l < e_cl[:, None]).sum(axis=1)
    return jnp.logical_and(live > 0, n_comp < budget_q)


def _select(active, new, old):
    """Per-lane freeze: a lane whose predicate went false keeps its old
    state — exactly what ``while_loop`` under vmap does."""
    def pick(a, b):
        mask = active.reshape(active.shape + (1,) * (a.ndim - 1))
        return jnp.where(mask, a, b)
    return jax.tree.map(pick, new, old)


@functools.partial(
    jax.jit,
    static_argnames=("block", "metric", "interpret", "has_warm"),
)
def _many_stage_kernels(Xq, l0q, warm_q, warm_valid_q, budget_q, block,
                        metric, interpret, has_warm):
    qn, n, _d = Xq.shape
    state = (
        l0q.astype(Xq.dtype),                     # l           (Q, N)
        jnp.ones((qn, n), bool),                  # alive
        jnp.full((qn,), jnp.inf, Xq.dtype),       # e_cl
        jnp.full((qn,), -1, jnp.int32),           # m_cl
        jnp.zeros((qn, block), jnp.int32),        # prev idx
        jnp.zeros((qn, block), Xq.dtype),         # prev energies
        jnp.zeros((qn, block), bool),             # prev valid
        jnp.zeros((qn,), jnp.int32),              # n_computed
        jnp.zeros((qn,), jnp.int32),              # n_rounds
    )
    round_fn = functools.partial(_kround, Xq, n, metric, interpret,
                                 budget_q)
    if has_warm:
        # warm forced round: like _stage0's, it runs before the loop and
        # every lane takes it (a bucket splits on warm presence)
        bw = warm_q.shape[1]
        new = round_fn(state, bw, first=True, forced_idx=warm_q,
                       forced_valid=warm_valid_q)
        pad = block - bw
        if pad:
            (l, alive, e_cl, m_cl, pidx, pe, pv, n_comp, n_rounds) = new
            pidx = jnp.pad(pidx, ((0, 0), (0, pad)))
            pe = jnp.pad(pe, ((0, 0), (0, pad)))
            pv = jnp.pad(pv, ((0, 0), (0, pad)))
            new = (l, alive, e_cl, m_cl, pidx, pe, pv, n_comp, n_rounds)
        state = new

    def cond(state):
        return _lane_active(state, budget_q).any()

    def body(state):
        active = _lane_active(state, budget_q)
        return _select(active, round_fn(state, block, first=False), state)

    state = jax.lax.while_loop(cond, body, state)

    (l, alive, e_cl, m_cl, _pi, _pe, _pv, n_comp, n_rounds) = state
    live_mask = jnp.logical_and(alive, l < e_cl[:, None])
    live = live_mask.sum(axis=1)
    lo = jnp.minimum(jnp.where(live_mask, l, jnp.inf).min(axis=1), e_cl)
    return m_cl, e_cl, n_comp, n_rounds, live, lo


# ---------------------------------------------------------------------------
# host-level bucket driver
# ---------------------------------------------------------------------------
def solve_many_bucket(Xq, warm_q, warm_valid_q, budget_q, *, block: int,
                      metric: str, use_kernels: bool = False,
                      interpret=None, has_warm: bool = False):
    """Run one packed bucket of Q same-shape queries; returns numpy
    arrays ``(m, e_internal, n_comp, n_rounds, live, lo)`` of length Q.

    ``Xq`` is ``(Q, N, d)``; ``budget_q`` int32 ``(Q,)`` row budgets
    (``HUGE_BUDGET`` for exact lanes); ``warm_q``/``warm_valid_q`` are
    ``(Q, BW)`` forced first pivots + validity (ignored unless
    ``has_warm``). Energies come back on the internal ``S/N`` scale
    (distances.py note) — callers apply the paper's ``n/(n-1)``."""
    Xq = jnp.asarray(Xq)
    qn, n, _d = Xq.shape
    block = int(min(block, n))
    l0q = jnp.zeros((qn, n), Xq.dtype)
    warm_q = jnp.asarray(warm_q, jnp.int32)
    warm_valid_q = jnp.asarray(warm_valid_q, bool)
    budget_q = jnp.asarray(budget_q, jnp.int32)
    if use_kernels:
        out = _many_stage_kernels(Xq, l0q, warm_q, warm_valid_q, budget_q,
                                  block, metric, interpret, has_warm)
    else:
        out = _many_stage_jnp(Xq, l0q, warm_q, warm_valid_q, budget_q,
                              block, metric, has_warm)
    out = tuple(np.asarray(o) for o in out)
    # library-level observability counters (DESIGN.md §14): packed-solve
    # volume on the process-wide registry — host-side, after the solve
    from repro.obs.metrics import REGISTRY
    REGISTRY.counter("many_buckets_total",
                     "packed solve_many bucket launches").inc()
    REGISTRY.counter("many_lanes_total",
                     "lanes across all packed buckets").inc(qn)
    REGISTRY.counter("many_elements_total",
                     "computed elements across all packed buckets").inc(
                         float(out[2].sum()))
    return out
