"""repro.core — the paper's contribution: trimed / trikmeds and baselines."""
from .distances import (
    VectorOracle,
    elements_computed,
    exact_energies,
    exact_medoid,
    pairwise,
    sq_norms,
)
from .trimed import (MedoidResult, TopKResult, medoid, trimed_block,
                     trimed_sequential, trimed_topk)
from .batched import BatchedMedoidResult, batched_medoids
from .pipelined import (batched_medoids_pipelined, trimed_pipelined,
                        warmup_schedule)
from .trikmeds import (KMedoidsJaxResult, TrikmedsResult, kmedoids_batched,
                       kmedoids_jax, trikmeds)
from .baselines import (
    BaselineResult,
    KMedoidsResult,
    kmeds,
    parkjun_init,
    rand_medoid,
    toprank,
    toprank2,
)
from .graph import (GraphOracle, graph_medoid, grid_network,
                    landmark_energy_bounds, largest_component,
                    sensor_network, sweep_distances)

__all__ = [
    "VectorOracle",
    "GraphOracle",
    "MedoidResult",
    "BaselineResult",
    "KMedoidsResult",
    "TrikmedsResult",
    "medoid",
    "trimed_block",
    "trimed_sequential",
    "trimed_topk",
    "TopKResult",
    "trikmeds",
    "BatchedMedoidResult",
    "batched_medoids",
    "batched_medoids_pipelined",
    "trimed_pipelined",
    "warmup_schedule",
    "KMedoidsJaxResult",
    "kmedoids_batched",
    "kmedoids_jax",
    "kmeds",
    "parkjun_init",
    "rand_medoid",
    "toprank",
    "toprank2",
    "elements_computed",
    "exact_energies",
    "exact_medoid",
    "pairwise",
    "sq_norms",
    "sensor_network",
    "graph_medoid",
    "grid_network",
    "landmark_energy_bounds",
    "largest_component",
    "sweep_distances",
]
