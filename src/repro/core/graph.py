"""Graph distance oracle — shortest-path metric for spatial-network data.

The paper's Table 1 runs trimed on road/rail/sensor networks where
``dist`` is shortest-path length and "computing an element" means one
Dijkstra sweep. Shortest-path is pointer-chasing work with no TPU
analogue (DESIGN.md §8), so this oracle is host-side; the *algorithmic*
layer (trimed's bound logic) is shared with the vector path.
"""
from __future__ import annotations

import heapq

import numpy as np


class GraphOracle:
    """Instrumented Dijkstra oracle over an adjacency list.

    ``adj`` maps node -> list of (neighbor, weight). Unreachable nodes get
    distance ``inf``; trimed handles this correctly (their bound only ever
    grows, and an element with infinite energy is never a medoid candidate
    in a connected component).
    """

    def __init__(self, adj: dict[int, list[tuple[int, float]]], n: int):
        self.adj = adj
        self.n = n
        self.rows_computed = 0
        self.scalar_distances = 0

    def row(self, i: int) -> np.ndarray:
        self.rows_computed += 1
        self.scalar_distances += self.n
        dist = np.full(self.n, np.inf)
        dist[i] = 0.0
        heap = [(0.0, i)]
        while heap:
            d, u = heapq.heappop(heap)
            if d > dist[u]:
                continue
            for v, w in self.adj.get(u, ()):
                nd = d + w
                if nd < dist[v]:
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
        return dist

    def pair(self, i: int, j: int) -> float:
        # single-pair shortest path: run Dijkstra with early exit
        self.scalar_distances += 1
        dist = {i: 0.0}
        heap = [(0.0, i)]
        while heap:
            d, u = heapq.heappop(heap)
            if u == j:
                return d
            if d > dist.get(u, np.inf):
                continue
            for v, w in self.adj.get(u, ()):
                nd = d + w
                if nd < dist.get(v, np.inf):
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
        return float("inf")

    def subrow(self, i: int, idx: np.ndarray) -> np.ndarray:
        self.scalar_distances += len(idx) - self.n  # row() adds n below
        return self.row(i)[idx]


def largest_component(
    adj: dict[int, list[tuple[int, float]]], n: int, directed: bool = False
) -> tuple[dict[int, list[tuple[int, float]]], np.ndarray]:
    """Restrict a graph to its largest (strongly) connected component and
    relabel nodes 0..m-1. The paper's network datasets are connected; random
    sensor nets near the connectivity threshold are not, and the medoid is
    undefined on a disconnected graph (all energies infinite)."""
    if not directed:
        # union-find
        parent = list(range(n))

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for u, edges in adj.items():
            for v, _ in edges:
                ru, rv = find(u), find(v)
                if ru != rv:
                    parent[ru] = rv
        comp: dict[int, list[int]] = {}
        for i in range(n):
            comp.setdefault(find(i), []).append(i)
        keep = max(comp.values(), key=len)
    else:
        # Kosaraju (iterative) for largest SCC
        order: list[int] = []
        seen = [False] * n
        for s in range(n):
            if seen[s]:
                continue
            stack = [(s, iter(adj.get(s, ())))]
            seen[s] = True
            while stack:
                u, it = stack[-1]
                advanced = False
                for v, _ in it:
                    if not seen[v]:
                        seen[v] = True
                        stack.append((v, iter(adj.get(v, ()))))
                        advanced = True
                        break
                if not advanced:
                    order.append(u)
                    stack.pop()
        radj: dict[int, list[int]] = {i: [] for i in range(n)}
        for u, edges in adj.items():
            for v, _ in edges:
                radj[v].append(u)
        comp_id = [-1] * n
        comps: list[list[int]] = []
        for s in reversed(order):
            if comp_id[s] != -1:
                continue
            cid = len(comps)
            comps.append([])
            stack2 = [s]
            comp_id[s] = cid
            while stack2:
                u = stack2.pop()
                comps[cid].append(u)
                for v in radj[u]:
                    if comp_id[v] == -1:
                        comp_id[v] = cid
                        stack2.append(v)
        keep = max(comps, key=len)

    keep_sorted = sorted(keep)
    remap = {old: new for new, old in enumerate(keep_sorted)}
    new_adj: dict[int, list[tuple[int, float]]] = {i: [] for i in range(len(keep_sorted))}
    for old in keep_sorted:
        for v, w in adj.get(old, ()):
            if v in remap:
                new_adj[remap[old]].append((remap[v], w))
    return new_adj, np.array(keep_sorted)


def sensor_network(
    n: int, seed: int = 0, directed: bool = False, radius_scale: float = 1.25
) -> tuple[GraphOracle, np.ndarray]:
    """The paper's U-/D-Sensor Net generator (SM-I): n points uniform in the
    unit square, edge when distance < radius_scale / sqrt(n) (the paper
    writes ``1.25 sqrt(N)`` — with unit-square density this is the
    connectivity-threshold scaling ``c / sqrt(N)``). Euclidean edge weights;
    directed edges get a random direction."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    r = radius_scale / np.sqrt(n)
    # grid binning for near-neighbour search
    cell = r
    grid: dict[tuple[int, int], list[int]] = {}
    for i, p in enumerate(pts):
        grid.setdefault((int(p[0] / cell), int(p[1] / cell)), []).append(i)
    adj: dict[int, list[tuple[int, float]]] = {i: [] for i in range(n)}
    for (cx, cy), members in grid.items():
        neigh = []
        for dx in (-1, 0, 1):
            for dy in (-1, 0, 1):
                neigh.extend(grid.get((cx + dx, cy + dy), ()))
        for i in members:
            for j in neigh:
                if j <= i:
                    continue
                w = float(np.linalg.norm(pts[i] - pts[j]))
                if w < r:
                    if directed:
                        if rng.random() < 0.5:
                            adj[i].append((j, w))
                        else:
                            adj[j].append((i, w))
                    else:
                        adj[i].append((j, w))
                        adj[j].append((i, w))
    adj, keep = largest_component(adj, n, directed=directed)
    return GraphOracle(adj, len(keep)), pts[keep]
