"""Graph-distance subsystem — shortest-path metric for spatial networks.

The paper's headline results (Table 1, Fig. 3) are on road/rail/sensor
networks where ``dist`` is shortest-path length and "computing an
element" means one single-source shortest-path (SSSP) sweep. This module
supplies both halves of that workload:

* :class:`GraphOracle` — CSR adjacency held on device plus an
  instrumented host Dijkstra (the parity reference). ``row(i)`` is one
  full sweep (one computed element — ``distances.elements_computed``);
  ``pair``/``subrow`` run early-exit Dijkstra and charge the settled
  fraction of a sweep.

* :func:`sweep_distances` — the device "column" primitive: a batched
  multi-source Bellman-Ford relaxation (one ``jax.lax.while_loop`` over
  a ``(B, N)`` distance block, scatter-min over the edge list per
  iteration) playing the role one pairwise block plays for the vector
  engines. Unreachable nodes keep distance ``inf``, exactly like the
  host Dijkstra.

* :func:`graph_medoid` — trimed's elimination over sweeps. ``n_landmarks``
  farthest-point sweeps seed ALT-style lower bounds (DESIGN.md §16):
  shortest-path length on an undirected non-negatively-weighted graph is
  a true metric, so ``d(i, j) >= |d(l, i) - d(l, j)|`` for every
  landmark ``l``, and per-landmark energy lower bounds
  ``E(j) >= (1/N) sum_i |L[l, j] - L[l, i]|`` follow by summing —
  computed for all ``j`` at once in O(N log N) per landmark via sorted
  prefix sums (:func:`landmark_energy_bounds`). Elimination then runs
  the standard trimed round on exact sweep rows. Device sweeps are f32;
  exactness against the f64 host reference is restored by an explicit
  ``rel_margin`` slack on every elimination decision plus an f64 host
  recompute of the finalist set (the §15 margin-election pattern), so
  the returned index is bit-equal to the full-scan argmin.

* Generators: :func:`grid_network` (road-like jittered lattice) and
  :func:`sensor_network` (the paper's U-/D-Sensor Net, SM-I), both
  restricted to their largest component via :func:`largest_component`.

Directed graphs (the paper's D-Sensor) are quasi-metrics — landmark
bounds need symmetry — so the planner routes them to the host
sequential engine; :func:`graph_medoid` refuses them.
"""
from __future__ import annotations

import functools
import heapq

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "GraphOracle",
    "graph_medoid",
    "grid_network",
    "landmark_energy_bounds",
    "largest_component",
    "sensor_network",
    "sweep_distances",
]


class GraphOracle:
    """Instrumented shortest-path oracle over a weighted graph.

    ``adj`` maps node -> list of (neighbor, weight); ``n`` is the node
    count. For undirected graphs the adjacency must list both directions
    (the generators here do). The host side answers ``row``/``pair``/
    ``subrow`` with (early-exit) Dijkstra; the device side exposes the
    same graph as CSR arrays (:meth:`csr`) and a flat relaxation edge
    list (:meth:`device_edges`) for :func:`sweep_distances`.

    Unreachable nodes get distance ``inf``; trimed handles this (the
    bound only ever grows, and an element with infinite energy is never
    a medoid candidate in a connected component).

    Accounting follows ``distances.elements_computed``: one *element* is
    one full ``(N,)`` row, so ``row`` charges ``n`` scalar distances and
    the early-exit paths charge the number of nodes they actually
    settled (``pair``/``subrow`` cost a fraction of a sweep, not a free
    scalar — Dijkstra has no O(1) point query).
    """

    def __init__(self, adj: dict[int, list[tuple[int, float]]], n: int,
                 directed: bool = False):
        self.adj = adj
        self.n = n
        self.directed = directed
        self.rows_computed = 0
        self.scalar_distances = 0
        self._csr = None
        self._dev = None

    @property
    def elements(self) -> float:
        """Computed elements so far (full-row units; distances.py)."""
        from .distances import elements_computed
        return elements_computed(self.scalar_distances, self.n)

    # -- device layout ------------------------------------------------------
    def csr(self):
        """Host CSR arrays ``(indptr, indices, weights)`` — indptr is
        ``(n+1,)`` int32, indices/weights are ``(E,)`` int32/float32."""
        if self._csr is None:
            counts = np.zeros(self.n + 1, np.int64)
            for u, edges in self.adj.items():
                counts[u + 1] = len(edges)
            indptr = np.cumsum(counts)
            m = int(indptr[-1])
            indices = np.empty(m, np.int32)
            weights = np.empty(m, np.float32)
            for u, edges in self.adj.items():
                lo = indptr[u]
                for k, (v, w) in enumerate(edges):
                    indices[lo + k] = v
                    weights[lo + k] = w
            self._csr = (indptr.astype(np.int32), indices, weights)
        return self._csr

    def device_edges(self):
        """Device-resident flat edge list ``(src, dst, w)`` for the
        Bellman-Ford relaxation — the COO view of :meth:`csr`, uploaded
        once and cached on the oracle."""
        if self._dev is None:
            indptr, indices, weights = self.csr()
            deg = np.diff(indptr.astype(np.int64))
            src = np.repeat(np.arange(self.n, dtype=np.int32), deg)
            self._dev = (jnp.asarray(src), jnp.asarray(indices),
                         jnp.asarray(weights))
        return self._dev

    # -- host Dijkstra (parity reference) -----------------------------------
    def row(self, i: int) -> np.ndarray:
        """One full SSSP sweep from ``i`` (one computed element)."""
        self.rows_computed += 1
        self.scalar_distances += self.n
        dist = np.full(self.n, np.inf)
        dist[i] = 0.0
        heap = [(0.0, i)]
        while heap:
            d, u = heapq.heappop(heap)
            if d > dist[u]:
                continue
            for v, w in self.adj.get(u, ()):
                nd = d + w
                if nd < dist[v]:
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
        return dist

    def pair(self, i: int, j: int) -> float:
        """Single-pair shortest path: Dijkstra from ``i`` that stops the
        moment ``j`` is settled (popped with its final distance), charged
        as the settled fraction of a sweep."""
        dist = {i: 0.0}
        heap = [(0.0, i)]
        settled = 0
        while heap:
            d, u = heapq.heappop(heap)
            if d > dist.get(u, np.inf):
                continue
            settled += 1
            if u == j:                       # early exit: j is final
                self.scalar_distances += settled
                return d
            for v, w in self.adj.get(u, ()):
                nd = d + w
                if nd < dist.get(v, np.inf):
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
        self.scalar_distances += settled     # exhausted: j unreachable
        return float("inf")

    def subrow(self, i: int, idx: np.ndarray) -> np.ndarray:
        """Distances from ``i`` to ``idx``: Dijkstra that stops once every
        (reachable) target is settled, charged by nodes settled."""
        idx = np.asarray(idx)
        targets = set(int(t) for t in idx)
        dist = np.full(self.n, np.inf)
        dist[i] = 0.0
        done = set()
        heap = [(0.0, i)]
        settled = 0
        while heap and len(done) < len(targets):
            d, u = heapq.heappop(heap)
            if d > dist[u]:
                continue
            settled += 1
            if u in targets:
                done.add(u)
            for v, w in self.adj.get(u, ()):
                nd = d + w
                if nd < dist[v]:
                    dist[v] = nd
                    heapq.heappush(heap, (nd, v))
        self.scalar_distances += settled
        return dist[idx]


# ---------------------------------------------------------------------------
# device sweep primitive — batched multi-source Bellman-Ford
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n",))
def _bf_sweep_jit(src, dst, w, sources, n):
    """(B,) sources -> (B, n) shortest-path distances + iteration count.

    Frontier relaxation over the whole edge list: per iteration, gather
    tentative distances at every edge tail (``dist[:, src] + w``) and
    scatter-min into the heads — all B sources in one ``(B, E)`` block.
    The while_loop runs until a full iteration changes nothing (at most
    ``n`` iterations: Bellman-Ford converges in <= n-1 rounds on any
    graph with non-negative weights). Unreachable nodes stay ``inf``
    (``inf + w`` never beats a finite candidate, and never terminates
    late: an all-inf frontier relaxes to itself and stops the loop).
    """
    b = sources.shape[0]
    dist = jnp.full((b, n), jnp.inf, jnp.float32)
    dist = dist.at[jnp.arange(b), sources].set(0.0)

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < n)

    def body(state):
        dist, _, it = state
        cand = dist[:, src] + w[None, :]          # (B, E) gather + relax
        new = dist.at[:, dst].min(cand)           # scatter-min into heads
        return new, jnp.any(new < dist), it + jnp.int32(1)

    dist, _, iters = jax.lax.while_loop(
        cond, body, (dist, jnp.array(True), jnp.int32(0)))
    return dist, iters


def sweep_distances(oracle: GraphOracle, sources) -> tuple[np.ndarray, int]:
    """Batched multi-source SSSP on device; the graph engine's column
    primitive. Returns ``(dist, iters)`` — ``dist`` is the ``(B, n)``
    f32 distance block, ``iters`` the relaxation iterations the
    while_loop ran. Charges one computed element per source on the
    oracle (one sweep == one full row)."""
    sources = np.asarray(sources, np.int32)
    src, dst, w = oracle.device_edges()
    dist, iters = _bf_sweep_jit(src, dst, w, jnp.asarray(sources),
                                oracle.n)
    oracle.rows_computed += len(sources)
    oracle.scalar_distances += len(sources) * oracle.n
    return np.asarray(dist), int(iters)


# ---------------------------------------------------------------------------
# landmark (ALT-style) energy lower bounds — DESIGN.md §16
# ---------------------------------------------------------------------------
def landmark_energy_bounds(L: np.ndarray) -> np.ndarray:
    """Initial energy lower bounds from landmark sweep rows.

    ``L`` is the ``(n_landmarks, N)`` matrix of exact distances from each
    landmark. For a true metric, ``d(i, j) >= |L[l, i] - L[l, j]|``
    (triangle, both ways), so summing over ``i`` gives a valid per-node
    energy bound per landmark; the returned bound is the max over
    landmarks, in the internal ``E = S/N`` convention. Each landmark's
    sum ``sum_i |x - v_i|`` for all ``x = v_j`` at once comes from the
    sorted order of ``v``: with ``k(j)`` values ``<= v_j`` and prefix
    sums ``P``, it equals ``v_j (2 k - N) - 2 P[k] + P[N]`` —
    O(N log N) per landmark instead of O(N^2). Requires finite ``L``
    (i.e. a connected graph)."""
    L = np.asarray(L, np.float64)
    nl, n = L.shape
    best = np.zeros(n)
    for v in L:
        sv = np.sort(v)
        prefix = np.concatenate(([0.0], np.cumsum(sv)))
        k = np.searchsorted(sv, v, side="right")
        sums = v * (2 * k - n) - 2 * prefix[k] + prefix[n]
        np.maximum(best, sums / n, out=best)
    return best


# margin covering f32 sweep rounding vs the f64 host reference: path
# sums accumulate ~eps32 per hop, energies average them — 1e-3 relative
# dwarfs that by orders of magnitude while keeping elimination sharp.
_REL_MARGIN = 1e-3


def graph_medoid(oracle: GraphOracle, *, n_landmarks: int = 8,
                 block: int = 64, seed: int = 0,
                 rel_margin: float = _REL_MARGIN):
    """Exact medoid of a connected undirected graph via batched sweeps.

    trimed's elimination with SSSP sweeps as the element: ``n_landmarks``
    farthest-point landmark sweeps seed ALT lower bounds (each landmark
    row is itself an exact energy, so no sweep is wasted), then rounds of
    up to ``block`` smallest-bound survivors run as one batched
    Bellman-Ford block, tightening every bound against every pivot
    (``l(j) <- max(l(j), |E(b) - d(b, j)|)``). All elimination decisions
    carry a ``rel_margin`` slack for f32 sweep rounding; the finalists
    within the margin of the best f32 energy are recomputed by the f64
    host Dijkstra, making the returned index bit-equal to the full-scan
    reference.

    Returns ``(MedoidResult, info)`` — ``info`` holds the sweep
    breakdown (landmark/pivot/certify), relaxation iterations and the
    landmark ids. Raises on directed oracles (quasi-metric: landmark
    bounds need symmetry) and on disconnected graphs (every energy is
    infinite — restrict to a component with :func:`largest_component`).
    """
    from repro.obs.metrics import REGISTRY, graph_metrics
    from .trimed import MedoidResult

    if getattr(oracle, "directed", False):
        raise ValueError(
            "graph_medoid: directed graphs are quasi-metrics (d(i,j) != "
            "d(j,i)) and landmark lower bounds need symmetry; use the "
            "host sequential engine (the planner does this for "
            "metric='graph' on a directed oracle)")
    n = int(oracle.n)
    if n == 1:
        return MedoidResult(0, 0.0, 1, 0, 0), {
            "landmarks": [], "landmark_sweeps": 0, "pivot_sweeps": 0,
            "certify_rows": 1, "relax_iters": 0, "finalists": 1}
    inst = graph_metrics(REGISTRY)
    rng = np.random.default_rng(seed)
    nl = max(1, min(int(n_landmarks), n))
    block = max(1, min(int(block), n))

    # -- landmark sweeps: farthest-point selection, one sweep each ----------
    L = np.empty((nl, n), np.float64)
    landmarks = np.empty(nl, np.int64)
    mind = None
    relax_iters = 0
    for t in range(nl):
        lm = int(rng.integers(n)) if t == 0 else int(np.argmax(mind))
        row, iters = sweep_distances(oracle, [lm])
        relax_iters += iters
        if not np.isfinite(row).all():
            bad = int(np.argmax(~np.isfinite(row[0])))
            raise ValueError(
                f"graph_medoid: node {bad} is unreachable from node {lm} "
                "— the graph is disconnected, so every energy is "
                "infinite and the medoid is undefined; restrict to a "
                "component first (repro.core.graph.largest_component)")
        L[t] = row[0]
        landmarks[t] = lm
        mind = L[t].copy() if mind is None else np.minimum(mind, L[t])
    inst["sweeps"].inc(nl, kind="landmark")

    # -- initial bounds + incumbent from the landmark rows ------------------
    l = landmark_energy_bounds(L)                 # ALT energy bounds (E=S/N)
    e = np.full(n, np.inf)
    computed = np.zeros(n, bool)
    e_lm = L.sum(axis=1) / n
    for t in range(nl):
        np.maximum(l, np.abs(e_lm[t] - L[t]), out=l)   # landmark = pivot
    e[landmarks] = e_lm
    computed[landmarks] = True
    l[computed] = e[computed]                      # computed bounds are tight
    b_best = int(np.argmin(e_lm))
    m_cl, e_cl = int(landmarks[b_best]), float(e_lm[b_best])

    # -- elimination rounds over batched pivot sweeps -----------------------
    pivot_sweeps = 0
    n_rounds = 0
    while True:
        margin = rel_margin * e_cl
        surv = ~computed & (l < e_cl + margin)
        live = int(surv.sum())
        if live == 0:
            break
        b = min(block, live)
        order = np.argsort(np.where(surv, l, np.inf), kind="stable")[:b]
        # fixed-width source batch: pad with the first pivot so the jit
        # program is shared across rounds (padding recomputes a known
        # row — no new information, not charged)
        sources = np.full(block, order[0], np.int64)
        sources[:b] = order
        D, iters = sweep_distances(oracle, sources)
        oracle.rows_computed -= block - b          # padding is not progress
        oracle.scalar_distances -= (block - b) * n
        relax_iters += iters
        D = D[:b].astype(np.float64)
        eb = D.sum(axis=1) / n
        r_best = int(np.argmin(eb))
        if eb[r_best] < e_cl:
            m_cl, e_cl = int(order[r_best]), float(eb[r_best])
        np.maximum(l, np.abs(eb[:, None] - D).max(axis=0), out=l)
        e[order] = eb
        computed[order] = True
        l[computed] = e[computed]
        pivot_sweeps += b
        n_rounds += 1
    inst["sweeps"].inc(pivot_sweeps, kind="pivot")
    inst["relax_iters"].inc(relax_iters)

    # -- f64 finalist certification (host Dijkstra, the parity path) --------
    margin = rel_margin * e_cl
    finalists = np.nonzero(computed & (e <= e_cl + 2 * margin))[0]
    best_i, best_e = -1, np.inf
    for i in finalists:                            # ascending: stable ties
        ei = oracle.row(int(i)).sum() / n
        if ei < best_e:
            best_i, best_e = int(i), float(ei)
    inst["sweeps"].inc(len(finalists), kind="certify")
    inst["solves"].inc()

    n_computed = nl + pivot_sweeps + len(finalists)
    result = MedoidResult(
        best_i, best_e * n / (n - 1), n_computed, n_rounds,
        n_distances=n_computed * n)
    info = {
        "landmarks": landmarks.tolist(),
        "landmark_sweeps": nl,
        "pivot_sweeps": pivot_sweeps,
        "certify_rows": len(finalists),
        "relax_iters": relax_iters,
        "finalists": len(finalists),
    }
    return result, info


def largest_component(
    adj: dict[int, list[tuple[int, float]]], n: int, directed: bool = False
) -> tuple[dict[int, list[tuple[int, float]]], np.ndarray]:
    """Restrict a graph to its largest (strongly) connected component and
    relabel nodes 0..m-1. The paper's network datasets are connected; random
    sensor nets near the connectivity threshold are not, and the medoid is
    undefined on a disconnected graph (all energies infinite)."""
    if not directed:
        # union-find
        parent = list(range(n))

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for u, edges in adj.items():
            for v, _ in edges:
                ru, rv = find(u), find(v)
                if ru != rv:
                    parent[ru] = rv
        comp: dict[int, list[int]] = {}
        for i in range(n):
            comp.setdefault(find(i), []).append(i)
        keep = max(comp.values(), key=len)
    else:
        # Kosaraju (iterative) for largest SCC
        order: list[int] = []
        seen = [False] * n
        for s in range(n):
            if seen[s]:
                continue
            stack = [(s, iter(adj.get(s, ())))]
            seen[s] = True
            while stack:
                u, it = stack[-1]
                advanced = False
                for v, _ in it:
                    if not seen[v]:
                        seen[v] = True
                        stack.append((v, iter(adj.get(v, ()))))
                        advanced = True
                        break
                if not advanced:
                    order.append(u)
                    stack.pop()
        radj: dict[int, list[int]] = {i: [] for i in range(n)}
        for u, edges in adj.items():
            for v, _ in edges:
                radj[v].append(u)
        comp_id = [-1] * n
        comps: list[list[int]] = []
        for s in reversed(order):
            if comp_id[s] != -1:
                continue
            cid = len(comps)
            comps.append([])
            stack2 = [s]
            comp_id[s] = cid
            while stack2:
                u = stack2.pop()
                comps[cid].append(u)
                for v in radj[u]:
                    if comp_id[v] == -1:
                        comp_id[v] = cid
                        stack2.append(v)
        keep = max(comps, key=len)

    keep_sorted = sorted(keep)
    remap = {old: new for new, old in enumerate(keep_sorted)}
    new_adj: dict[int, list[tuple[int, float]]] = {i: [] for i in range(len(keep_sorted))}
    for old in keep_sorted:
        for v, w in adj.get(old, ()):
            if v in remap:
                new_adj[remap[old]].append((remap[v], w))
    return new_adj, np.array(keep_sorted)


def sensor_network(
    n: int, seed: int = 0, directed: bool = False, radius_scale: float = 1.25
) -> tuple[GraphOracle, np.ndarray]:
    """The paper's U-/D-Sensor Net generator (SM-I): n points uniform in the
    unit square, edge when distance < radius_scale / sqrt(n) (the paper
    writes ``1.25 sqrt(N)`` — with unit-square density this is the
    connectivity-threshold scaling ``c / sqrt(N)``). Euclidean edge weights;
    directed edges get a random direction."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    r = radius_scale / np.sqrt(n)
    # grid binning for near-neighbour search
    cell = r
    grid: dict[tuple[int, int], list[int]] = {}
    for i, p in enumerate(pts):
        grid.setdefault((int(p[0] / cell), int(p[1] / cell)), []).append(i)
    adj: dict[int, list[tuple[int, float]]] = {i: [] for i in range(n)}
    for (cx, cy), members in grid.items():
        neigh = []
        for dx in (-1, 0, 1):
            for dy in (-1, 0, 1):
                neigh.extend(grid.get((cx + dx, cy + dy), ()))
        for i in members:
            for j in neigh:
                if j <= i:
                    continue
                w = float(np.linalg.norm(pts[i] - pts[j]))
                if w < r:
                    if directed:
                        if rng.random() < 0.5:
                            adj[i].append((j, w))
                        else:
                            adj[j].append((i, w))
                    else:
                        adj[i].append((j, w))
                        adj[j].append((i, w))
    adj, keep = largest_component(adj, n, directed=directed)
    return GraphOracle(adj, len(keep), directed=directed), pts[keep]


def grid_network(
    n: int, seed: int = 0, jitter: float = 0.35
) -> tuple[GraphOracle, np.ndarray]:
    """Road-like grid network: ``side = round(sqrt(n))`` squared nodes on
    a jittered lattice, 4-neighbour edges weighted by the Euclidean
    distance between the jittered positions. Connected by construction
    (every lattice stays one component under position jitter), so this
    is the deterministic-size workload the CI sweep gate runs on.
    Returns ``(GraphOracle, pts)`` with ``pts`` the (m, 2) positions."""
    side = max(2, int(round(np.sqrt(n))))
    m = side * side
    rng = np.random.default_rng(seed)
    gx, gy = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    pts = np.stack([gx, gy], axis=-1).reshape(m, 2).astype(np.float64)
    pts += rng.uniform(-jitter, jitter, size=pts.shape)
    pts /= side                                    # unit square, like SM-I
    adj: dict[int, list[tuple[int, float]]] = {i: [] for i in range(m)}

    def _link(a, b):
        w = float(np.linalg.norm(pts[a] - pts[b]))
        adj[a].append((b, w))
        adj[b].append((a, w))

    for r in range(side):
        for c in range(side):
            u = r * side + c
            if c + 1 < side:
                _link(u, u + 1)
            if r + 1 < side:
                _link(u, u + side)
    return GraphOracle(adj, m), pts
