"""Sharded survivor-compacted pipelined trimed engine (DESIGN.md §11).

The element set (= X's columns in the energy pass) is sharded in
contiguous slices over one mesh axis. Each round runs the pipelined
round of :mod:`repro.core.pipelined` *locally per shard* — bounds,
survivor buffers and the distance stream never leave their shard — and
exchanges only three tiny replicated quantities:

* **candidate election**: each shard proposes its local top-``B``
  surviving bounds; an ``all_gather`` of ``(B,)`` scores + ``(B, d)``
  pivot vectors followed by a replicated global ``top_k`` elects the
  round's pivot block (communication ``O(P*B*d)``, vanishing next to
  the ``B * N/P * d`` local distance block);
* **energy reduction**: per-shard chunk partials on the *fixed
  reduction grid* (``distances.REDUCE_CHUNKS`` chunks, independent of
  the shard count) are ``all_gather``-ed and combined by an explicit
  in-order fold — the same arithmetic, in the same order, as the
  single-device engine's :func:`~repro.core.distances.chunked_rowsum`.
  This is what makes the sharded engine **bit-identical** (pivot
  sequence, medoid index, energy, computed-element count) to the
  single-device pipelined engine for any shard count dividing
  ``REDUCE_CHUNKS``;
* **termination / ladder control**: ``psum`` (global live total) and
  ``pmax`` (max per-shard live, the quantity the host sizes the ladder
  rung from — gating recompaction on it guarantees every stage runs at
  least one round even when survivors skew across shards) of integer
  survivor counts — exact.

Per-shard survivor compaction keeps the fold, selection and loop
predicate ``O(M/P)`` per shard on the same power-of-two ladder as the
single-device engine; the energy pass keeps its exactness-mandated
full-``N`` floor, now streamed as ``N/P`` columns per shard.

``use_kernels=True`` runs the per-shard rounds through the Pallas
kernels: the column-validity mask of the sharded layout is encoded as
single-cluster membership so the assignment-masked kernels serve as the
masked partial-sum / fused round kernels (``kernels.ops.partial_energies``,
``masked_pipelined_round``) — one fused energy+bound-fold stream of the
local block per steady-state round, VMEM-resident pivot block included.
The kernel path is exact but not bit-level against the jnp path (the
kernel accumulates per tile, not on the fixed grid).

Entry points: the planner executes ``_trimed_sharded`` /
``_batched_medoids_sharded`` / ``_scan_rowsums_sharded`` behind
``MedoidQuery(device_policy="sharded")``; the pre-planner
``trimed_sharded`` symbol survives as a deprecated shim.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro import compat as _compat  # noqa: F401  (jax<0.5 shard_map/mesh)
from repro.api.metrics import require_metric
from repro.compat import make_1d_mesh
from repro.kernels import ops as _ops

from jax.sharding import NamedSharding, PartitionSpec as P

from .batched import BatchedMedoidResult
from .distances import (REDUCE_CHUNKS, chunk_partials, chunk_size,
                        fold_chunks, pairwise, pow2_at_least, sq_norms)
from .pipelined import (LADDER_MIN, NEG_INF, _budget_cap, _incumbent,
                        _masked_colmax, resolve_schedule)
from .trimed import MedoidResult

AXIS = "shard"          # default mesh axis name for the sharded engines


def shard_count_for(requested: int) -> int:
    """Largest shard count <= ``requested`` dividing ``REDUCE_CHUNKS``
    (the fixed reduction grid must tile evenly across shards)."""
    p = max(1, min(int(requested), REDUCE_CHUNKS))
    while REDUCE_CHUNKS % p:
        p -= 1
    return p


def _resolve_mesh(mesh, axis):
    if mesh is None:
        mesh = make_1d_mesh(shard_count_for(jax.device_count()), axis)
    if axis not in mesh.shape:
        raise ValueError(
            f"sharded engine: mesh has no axis {axis!r} (axes: "
            f"{list(mesh.shape)}); name the element axis via "
            "engine_opts={'axis': ...} on the query")
    p = mesh.shape[axis]
    if REDUCE_CHUNKS % p:
        raise ValueError(
            f"sharded engine: mesh axis {axis!r} has size {p}, which does "
            f"not divide the fixed reduction grid REDUCE_CHUNKS="
            f"{REDUCE_CHUNKS}; use a divisor shard count (see "
            "repro.core.distributed.shard_count_for)")
    return mesh, p


def _layout(n: int, p: int):
    """(chunk size, padded N, local columns, local chunks) of the fixed
    reduction grid laid out over ``p`` contiguous column shards."""
    s = chunk_size(n)
    n_pad = REDUCE_CHUNKS * s
    return s, n_pad, n_pad // p, REDUCE_CHUNKS // p


def effective_block(n: int, p: int, block: int) -> int:
    """Pivot-block width the sharded engines actually run: ``block``
    clamped to the per-shard column count of the reduction-grid layout.
    Candidates are elected from per-shard top-``B`` proposals, so one
    round can never compute more pivots than one shard holds columns.
    When the clamp bites (``block`` > per-shard width) results stay
    exact but the pivot sequence and work counters diverge from the
    single-device engine configured with the same ``block`` — the
    planner records the clamped width and the engines warn."""
    return int(min(block, n, _layout(n, p)[2]))


def _clamped_block(block, n, p, caller):
    requested = int(block)
    eff = effective_block(n, p, requested)
    if eff < min(requested, n):
        from repro.obs.logs import repro_warn
        repro_warn(
            f"{caller}: block={requested} exceeds the per-shard column "
            f"count {_layout(n, p)[2]} of the {p}-shard layout; round "
            f"width clamped to {eff}. Results stay exact but the pivot "
            "sequence and work counters diverge from the single-device "
            f"engine at block={requested}.",
            UserWarning, logger="repro.core.distributed", stacklevel=3)
    return eff


def _shard_base(axis, n_local):
    p_idx = jax.lax.axis_index(axis).astype(jnp.int32)
    return p_idx * n_local


def _global_rowsums(d_loc, col_valid, axis, c_loc, s):
    """Exact global row sums from a ``(B, n_local)`` local block: masked
    local chunk partials, gathered and folded in fixed chunk order —
    bit-identical to ``chunked_rowsum`` over the full ``(B, N)`` block."""
    dl = jnp.where(col_valid[None, :], d_loc, 0.0)
    parts = chunk_partials(dl, c_loc, s)                 # (B, C/P)
    allp = jax.lax.all_gather(parts, axis)               # (P, B, C/P)
    full = jnp.moveaxis(allp, 0, 1).reshape(d_loc.shape[0], REDUCE_CHUNKS)
    return fold_chunks(full)


def _kernel_rowsums(xb, xl, col_valid, axis, metric, interpret):
    """Kernel-path global row sums: one masked Pallas stream of the
    local block per shard, shard partials folded in shard order."""
    loc = _ops.partial_energies(xb, xl, col_valid, metric=metric,
                                interpret=interpret)
    allp = jax.lax.all_gather(loc, axis)                 # (P, B)
    return fold_chunks(jnp.moveaxis(allp, 0, 1))


def _merge_topk(score_loc, cand_sources, b, axis):
    """Global candidate election. Each shard proposes its local top-``b``
    (scores + per-candidate payloads); the replicated merge re-ranks the
    ``(P*b,)`` proposals. Tie-breaking matches a single-device ``top_k``
    over the concatenated domain: equal scores resolve to the lowest
    shard, then the lowest local index — i.e. the lowest global index.

    ``cand_sources`` maps payload name -> local ``(M, ...)`` array to
    gather at the proposed positions. Returns ``(valid, payloads, bpos,
    owner)`` where ``bpos`` is the winning candidate's position in its
    *own* shard's buffer and ``owner`` its shard index."""
    top, pos = jax.lax.top_k(score_loc, b)
    gathered = {}
    for name, arr in cand_sources.items():
        gathered[name] = jax.lax.all_gather(jnp.take(arr, pos, axis=0),
                                            axis)
    ts = jax.lax.all_gather(top, axis)                   # (P, b)
    ps = jax.lax.all_gather(pos.astype(jnp.int32), axis)
    flat_t = ts.reshape(-1)
    t2, fp = jax.lax.top_k(flat_t, b)
    valid = t2 > NEG_INF
    payloads = {}
    for name, g in gathered.items():
        flat = g.reshape((-1,) + g.shape[2:])
        payloads[name] = flat[fp]
    bpos = ps.reshape(-1)[fp]
    owner = (fp // b).astype(jnp.int32)
    return valid, payloads, bpos, owner


def _mark_owned(alive_loc, axis, owner, bpos, valid, size):
    """Mark the winning candidates dead in their owning shard's buffer;
    returns the updated buffer and this shard's owned-count increment."""
    mine = jnp.logical_and(valid,
                           owner == jax.lax.axis_index(axis).astype(
                               jnp.int32))
    tgt = jnp.where(mine, bpos, size)                    # foreign -> dropped
    alive_loc = alive_loc.at[tgt].set(False, mode="drop")
    return alive_loc, mine.sum()


# ---------------------------------------------------------------------------
# single-medoid engine
# ---------------------------------------------------------------------------
def _sh_round0(cfg, xl, sql, colv, base, budget, state, b):
    """One full-domain sharded pipelined round at static width ``b``.
    Mirrors ``pipelined._pipe_round0``: jnp path folds the carried
    previous block before selection; kernel path fuses the fold into the
    masked pipelined stream (select-then-fold, one-round lag)."""
    (axis, metric, n, n_local, c_loc, s, use_kernels, interpret) = cfg
    (l, alive, e_cl, m_cl, pe, pv, pvecs, psq, dprev, n_comp, n_rounds,
     own) = state

    if not use_kernels:
        l = jnp.maximum(l, _masked_colmax(jnp.abs(pe[:, None] - dprev), pv))

    score = jnp.where(jnp.logical_and(alive, l < e_cl), -l, NEG_INF)
    valid, pay, bpos, owner = _merge_topk(
        score, {"gidx": jnp.arange(n_local, dtype=jnp.int32) + base,
                "vecs": xl, "sq": sql},
        b, axis)
    valid = _budget_cap(valid, n_comp, budget)
    cand_gidx, xb, xsq = pay["gidx"], pay["vecs"], pay["sq"]

    if use_kernels:
        if pvecs.shape[0] == 0:      # first round: no previous block yet
            e_sums = _kernel_rowsums(xb, xl, colv, axis, metric, interpret)
        else:
            a_x = jnp.where(colv, 0, -1).astype(jnp.int32)
            s_loc, l = _ops.masked_pipelined_round(
                xb, pvecs, xl, jnp.zeros(b, jnp.int32),
                jnp.zeros(pvecs.shape[0], jnp.int32), a_x, pe,
                jnp.ones(pvecs.shape[0], xl.dtype), pv, l,
                metric=metric, interpret=interpret)
            allp = jax.lax.all_gather(s_loc, axis)
            e_sums = fold_chunks(jnp.moveaxis(allp, 0, 1))
        dnew = dprev                                  # unused carry (0, M)
    else:
        dnew = pairwise(xb, xl, metric, a_sq=xsq, b_sq=sql)
        e_sums = _global_rowsums(dnew, colv, axis, c_loc, s)

    e_blk = jnp.where(valid, e_sums / n, jnp.inf)
    e_cl, m_cl = _incumbent(e_blk, cand_gidx, e_cl, m_cl)
    alive, mine = _mark_owned(alive, axis, owner, bpos, valid, n_local)
    n_comp = n_comp + valid.sum()
    pe = jnp.where(valid, e_blk, 0.0)
    return (l, alive, e_cl, m_cl, pe, valid, xb, xsq, dnew, n_comp,
            n_rounds + 1, own + mine)


def _sh_pad_prev(state, block, has_carry):
    (l, alive, e_cl, m_cl, pe, pv, pvecs, psq, dprev, n_comp, n_rounds,
     own) = state
    pad = block - pe.shape[0]
    if pad:
        pe = jnp.pad(pe, (0, pad))
        pv = jnp.pad(pv, (0, pad))
        pvecs = jnp.pad(pvecs, ((0, pad), (0, 0)))
        psq = jnp.pad(psq, (0, pad))
        if has_carry:
            dprev = jnp.pad(dprev, ((0, pad), (0, 0)))
    return (l, alive, e_cl, m_cl, pe, pv, pvecs, psq, dprev, n_comp,
            n_rounds, own)


@functools.lru_cache(maxsize=None)
def _build_stage0(mesh, axis, n, d, block, warm, metric, use_kernels,
                  interpret, can_compact):
    p = mesh.shape[axis]
    s, n_pad, n_local, c_loc = _layout(n, p)
    cfg = (axis, metric, n, n_local, c_loc, s, use_kernels, interpret)

    def local_fn(xl, budget):
        base = _shard_base(axis, n_local)
        colv = (jnp.arange(n_local, dtype=jnp.int32) + base) < n
        sql = (sq_norms(xl) if metric in ("l2", "sqeuclidean")
               else jnp.zeros(n_local, xl.dtype))
        state = (
            jnp.zeros(n_local, xl.dtype),             # l
            colv,                                     # alive (pad cols dead)
            jnp.asarray(jnp.inf, xl.dtype),           # e_cl
            jnp.asarray(-1, jnp.int32),               # m_cl
            jnp.zeros(0, xl.dtype),                   # prev energies
            jnp.zeros(0, bool),                       # prev valid
            jnp.zeros((0, d), xl.dtype),              # prev pivot vectors
            jnp.zeros(0, xl.dtype),                   # prev pivot sq norms
            jnp.zeros((0, n_local), xl.dtype),        # prev rows (jnp carry)
            jnp.asarray(0, jnp.int32),                # n_computed
            jnp.asarray(0, jnp.int32),                # n_rounds
            jnp.asarray(0, jnp.int32),                # owned rows this shard
        )
        round_fn = functools.partial(_sh_round0, cfg, xl, sql, colv,
                                     _shard_base(axis, n_local), budget)
        for b in warm:                                # unrolled warm-up
            state = round_fn(state, b)
        state = _sh_pad_prev(state, block, has_carry=not use_kernels)

        def live_of(state):
            l, alive, e_cl = state[0], state[1], state[2]
            loc = jnp.logical_and(alive, l < e_cl).sum()
            return jax.lax.psum(loc, axis)

        def cond(state):
            live = live_of(state)
            go = jnp.logical_and(live > 0, state[9] < budget)
            if can_compact:
                return jnp.logical_and(go, 2 * live > n)
            return go

        state = jax.lax.while_loop(cond, lambda st: round_fn(st, block),
                                   state)
        (l, alive, e_cl, m_cl, pe, pv, pvecs, psq, _d, n_comp, n_rounds,
         own) = state
        live_loc = jnp.logical_and(alive, l < e_cl).sum()[None]
        return (l, alive, own[None], live_loc,
                (e_cl, m_cl, pe, pv, pvecs, psq, n_comp, n_rounds))

    return jax.jit(jax.shard_map(
        local_fn, mesh=mesh, in_specs=(P(axis), P()),
        out_specs=(P(axis), P(axis), P(axis), P(axis), P()),
        check_vma=False))


def _sh_stage_round(cfg, xl, sql, colv, base, Xs, xs_sq, lpos, surv_gidx,
                    budget, block, state):
    """One compacted-stage sharded round: fold the previous block over
    the local ``M/P`` survivor buffer, then stream the full local column
    block once for the new pivots' exact energies."""
    (axis, metric, n, n_local, c_loc, s, use_kernels, interpret) = cfg
    (l_s, alive_s, e_cl, m_cl, pe, pv, pvecs, psq, dprev_s, n_comp,
     n_rounds, own, fold_cols) = state
    m = Xs.shape[0]

    # 1. fold previous block — bound tightening over M/P local survivors
    if use_kernels:
        l_s = _ops.bound_update(pvecs, Xs, pe, pv, l_s, metric=metric,
                                interpret=interpret)
    else:
        l_s = jnp.maximum(
            l_s, _masked_colmax(jnp.abs(pe[:, None] - dprev_s), pv))
    fold_cols = fold_cols + jax.lax.psum(m, axis)

    # 2. candidate election over the compacted buffers
    score = jnp.where(jnp.logical_and(alive_s, l_s < e_cl), -l_s, NEG_INF)
    valid, pay, bpos, owner = _merge_topk(
        score, {"gidx": surv_gidx, "vecs": Xs, "sq": xs_sq},
        block, axis)
    valid = _budget_cap(valid, n_comp, budget)
    cand_gidx, xb, xsq = pay["gidx"], pay["vecs"], pay["sq"]

    # 3. exact energies — the one full stream of the local block
    if use_kernels:
        e_sums = _kernel_rowsums(xb, xl, colv, axis, metric, interpret)
        dnew_s = dprev_s                              # unused carry (0, M)
    else:
        d_full = pairwise(xb, xl, metric, a_sq=xsq, b_sq=sql)
        e_sums = _global_rowsums(d_full, colv, axis, c_loc, s)
        dnew_s = jnp.take(d_full, lpos, axis=1)       # rows at survivors
    e_blk = jnp.where(valid, e_sums / n, jnp.inf)

    e_cl, m_cl = _incumbent(e_blk, cand_gidx, e_cl, m_cl)
    alive_s, mine = _mark_owned(alive_s, axis, owner, bpos, valid, m)
    n_comp = n_comp + valid.sum()
    pe = jnp.where(valid, e_blk, 0.0)
    return (l_s, alive_s, e_cl, m_cl, pe, valid, xb, xsq, dnew_s, n_comp,
            n_rounds + 1, own + mine, fold_cols)


@functools.lru_cache(maxsize=None)
def _build_stage(mesh, axis, n, d, m_loc, block, metric, use_kernels,
                 interpret, is_floor):
    p = mesh.shape[axis]
    s, n_pad, n_local, c_loc = _layout(n, p)
    cfg = (axis, metric, n, n_local, c_loc, s, use_kernels, interpret)

    def local_fn(xl, surv_gidx, l_in, alive_in, own_in, budget, rep):
        (e_cl, m_cl, pe, pv, pvecs, psq, n_comp, n_rounds, fold_cols) = rep
        base = _shard_base(axis, n_local)
        colv = (jnp.arange(n_local, dtype=jnp.int32) + base) < n
        sql = (sq_norms(xl) if metric in ("l2", "sqeuclidean")
               else jnp.zeros(n_local, xl.dtype))

        # per-shard compaction onto the shared ladder rung m_loc
        keep = jnp.logical_and(alive_in, l_in < e_cl)
        posn = jnp.cumsum(keep.astype(jnp.int32)) - 1
        tgt = jnp.where(keep, posn, m_loc)            # dead -> dropped
        new_g = jnp.zeros(m_loc, jnp.int32).at[tgt].set(surv_gidx,
                                                        mode="drop")
        l_s = jnp.full(m_loc, jnp.inf, l_in.dtype).at[tgt].set(l_in,
                                                               mode="drop")
        alive_s = jnp.zeros(m_loc, bool).at[tgt].set(True, mode="drop")
        lpos = jnp.clip(new_g - base, 0, n_local - 1)
        Xs = jnp.take(xl, lpos, axis=0)
        xs_sq = (sq_norms(Xs) if metric in ("l2", "sqeuclidean")
                 else jnp.zeros(m_loc, Xs.dtype))
        if use_kernels:
            dprev_s = jnp.zeros((0, m_loc), xl.dtype)
        else:
            # one (B, M/P) block at stage entry re-seeds the carried rows
            dprev_s = pairwise(pvecs, Xs, metric, a_sq=psq, b_sq=xs_sq)
        state = (l_s, alive_s, e_cl, m_cl, pe, pv, pvecs, psq, dprev_s,
                 n_comp, n_rounds, own_in[0], fold_cols)

        def local_live(state):
            l_s, alive_s, e_cl = state[0], state[1], state[2]
            return jnp.logical_and(alive_s, l_s < e_cl).sum()

        def cond(state):
            loc = local_live(state)
            go = jnp.logical_and(jax.lax.psum(loc, axis) > 0,
                                 state[9] < budget)
            if is_floor:
                return go
            # The ladder gate must compare against the quantity the host
            # sized the rung from: the *max* per-shard live count. The
            # host picks m_loc = pow2_at_least(max_loc) < 2*max_loc, so
            # 4*pmax(loc) > 2*m_loc > m_loc holds at stage entry and
            # every stage runs at least one round. Gating on the global
            # total (4*live > m_loc*p) instead can already be false at
            # entry when survivors skew across shards (max >> mean, e.g.
            # sorted or clustered inputs) — a zero-round stage the host
            # loop would rebuild forever.
            return jnp.logical_and(go, 4 * jax.lax.pmax(loc, axis) > m_loc)

        body = functools.partial(_sh_stage_round, cfg, xl, sql, colv,
                                 base, Xs, xs_sq, lpos, new_g, budget,
                                 block)
        state = jax.lax.while_loop(cond, body, state)
        (l_s, alive_s, e_cl, m_cl, pe, pv, pvecs, psq, _d, n_comp,
         n_rounds, own, fold_cols) = state
        live_loc = jnp.logical_and(alive_s, l_s < e_cl).sum()[None]
        return (new_g, l_s, alive_s, own[None], live_loc,
                (e_cl, m_cl, pe, pv, pvecs, psq, n_comp, n_rounds,
                 fold_cols))

    return jax.jit(jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P(), P()),
        out_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P()),
        check_vma=False))


def _trimed_sharded(
    X,
    mesh=None,
    axis: str = AXIS,
    block: int = 128,
    metric: str = "l2",
    block_schedule=None,
    ladder_min: int = LADDER_MIN,
    use_kernels: bool = False,
    interpret=None,
    max_computed: int | None = None,
    seed: int = 0,
    trace=None,
):
    """Exact medoid via the sharded pipelined engine (DESIGN.md §11).

    Bit-identical — pivot sequence, medoid index, energy, computed
    elements — to :func:`repro.core.pipelined._trimed_pipelined` on the
    jnp path, for any ``mesh`` whose ``axis`` size divides
    ``REDUCE_CHUNKS`` (``_resolve_mesh`` rejects others) and any
    ``block`` no wider than the per-shard column count. A wider
    ``block`` is clamped to :func:`effective_block` with a
    ``UserWarning`` — the result stays exact but the pivot sequence and
    work counters follow the clamped width, not the single-device
    engine's. ``N`` need not divide the shard count:
    the fixed reduction grid pads the tail shard and masks the fake
    columns out of every sum and candidate election.

    Returns ``(MedoidResult, per_shard_rows)`` where ``per_shard_rows``
    counts the pivot rows each shard owned (summing to ``n_computed``).
    """
    del seed    # selection is deterministic (lowest-bound)
    require_metric(metric, need_triangle=True, caller="trimed_sharded")
    from repro.obs.trace import l_summary as _l_summary, resolve_trace
    tracer = resolve_trace(trace)
    X = jnp.asarray(X)
    n, d = X.shape
    mesh, p = _resolve_mesh(mesh, axis)
    if n == 1:
        per_shard = np.zeros(p, np.int64)
        per_shard[0] = 1                      # shard 0 owns the only row
        if tracer is not None:
            tracer.begin(engine="sharded", n=1, d=int(d), metric=metric,
                         block=int(block))
            tracer.end(engine="sharded", index=0, energy=0.0, elements=1,
                       rounds=0, certified=True, halt_reason="converged")
        return MedoidResult(0, 0.0, 1, 0, 1), per_shard
    s, n_pad, n_local, c_loc = _layout(n, p)
    block = _clamped_block(block, n, p, "trimed_sharded")
    warm = resolve_schedule(block_schedule, block)
    floor = max(int(ladder_min), block)
    can_compact = n_local > floor
    budget_host = (2**31 - 1 if max_computed is None
                   else max(int(max_computed), 0))
    budget = jnp.asarray(budget_host, jnp.int32)
    interpret = (bool(interpret) if interpret is not None
                 else jax.default_backend() == "cpu")

    Xp = jnp.pad(X, ((0, n_pad - n), (0, 0)))
    Xg = jax.device_put(Xp, NamedSharding(mesh, P(axis)))

    stage0 = _build_stage0(mesh, axis, n, d, block, warm, metric,
                           use_kernels, interpret, can_compact)
    l, alive, own, live_loc, rep = stage0(Xg, budget)
    (e_cl, m_cl, pe, pv, pvecs, psq, n_comp, n_rounds) = rep
    live = int(np.asarray(live_loc).sum())
    n_stages = 0
    fold_cols = jnp.asarray(0, jnp.int32)
    surv_gidx = jax.device_put(
        jnp.arange(n_pad, dtype=jnp.int32), NamedSharding(mesh, P(axis)))
    l_s, alive_s = l, alive
    d1 = max(n - 1, 1)

    def _trace_stage(phase, rung):
        # rides the loop's existing host sync (live_loc is already on the
        # host); the l/alive gather is tracing-only work
        if tracer is None:
            return
        e_h = float(e_cl)
        l_h = np.asarray(l_s, np.float64)
        mask = np.logical_and(np.asarray(alive_s, bool), l_h < e_h)
        tracer.segment(
            round=int(n_rounds), phase=phase, stage=n_stages, rung=rung,
            survivors=live, incumbent=int(m_cl),
            energy=(e_h * n / d1 if np.isfinite(e_h) else None),
            elements=int(n_comp), l_summary=_l_summary(l_h, mask))
        tracer.flush()

    if tracer is not None:
        tracer.begin(engine="sharded", n=n, d=int(d), metric=metric,
                     block=int(block), shards=p)
    _trace_stage("full", n)

    while live > 0 and int(n_comp) < budget_host:
        max_loc = int(np.asarray(live_loc).max())
        m_loc = max(pow2_at_least(max(max_loc, 1)), floor)
        is_floor = m_loc <= floor
        stage = _build_stage(mesh, axis, n, d, m_loc, block, metric,
                             use_kernels, interpret, is_floor)
        surv_gidx, l_s, alive_s, own, live_loc, rep2 = stage(
            Xg, surv_gidx, l_s, alive_s, own, budget,
            (e_cl, m_cl, pe, pv, pvecs, psq, n_comp, n_rounds, fold_cols))
        (e_cl, m_cl, pe, pv, pvecs, psq, n_comp, n_rounds,
         fold_cols) = rep2
        live = int(np.asarray(live_loc).sum())
        n_stages += 1
        _trace_stage("ladder", m_loc)

    n_rounds = int(n_rounds)
    n_comp = int(n_comp)
    e_paper = float(e_cl) * n / d1
    result = MedoidResult(
        int(m_cl), e_paper, n_comp, n_rounds, n_comp * n,
        n_stages=n_stages,
        x_cols_streamed=n_rounds * n + int(fold_cols),
        certified=(live == 0),
    )
    if tracer is not None:
        tracer.end(engine="sharded", index=int(m_cl), energy=e_paper,
                   elements=n_comp, rounds=n_rounds,
                   certified=(live == 0),
                   halt_reason="converged" if live == 0 else "budget",
                   survivors=live, stages=n_stages)
    return result, np.asarray(own, np.int64)


# ---------------------------------------------------------------------------
# batched multi-cluster engine (K concurrent searches, sharded columns)
# ---------------------------------------------------------------------------
def _sh_bround(cfg, xl, sql, a_loc, v, k, state, b):
    """One sharded multi-cluster pipelined round (full local domain;
    mirrors ``pipelined._bpipe_round0`` with elected candidates)."""
    (axis, metric, n, n_local, c_loc, s, use_kernels, interpret) = cfg
    (l, alive, s_best, m_best, ps, pv, pvecs, psq, pa, dprev, n_comp,
     n_rounds, own) = state
    v_prev = jnp.take(v, pa).astype(xl.dtype)

    if not use_kernels:
        same_prev = pa[:, None] == a_loc[None, :]
        gap = jnp.abs(dprev * v_prev[:, None] - ps[:, None])
        gap = jnp.where(same_prev, gap, NEG_INF)
        l = jnp.maximum(l, _masked_colmax(gap, pv))

    base = _shard_base(axis, n_local)
    thresh = jnp.take(s_best, a_loc)
    v_a = jnp.take(v, a_loc).astype(xl.dtype)
    score = jnp.where(jnp.logical_and(alive, l < thresh),
                      -l / jnp.maximum(v_a, 1.0), NEG_INF)
    valid, pay, bpos, owner = _merge_topk(
        score, {"gidx": jnp.arange(n_local, dtype=jnp.int32) + base,
                "vecs": xl, "sq": sql, "a": a_loc},
        b, axis)
    cand_gidx, xb, xsq, a_piv = (pay["gidx"], pay["vecs"], pay["sq"],
                                 pay["a"])

    if use_kernels:
        if pvecs.shape[0] == 0:
            s_loc = _ops.masked_energies(xb, xl, a_piv, a_loc,
                                         metric=metric, interpret=interpret)
        else:
            s_loc, l = _ops.masked_pipelined_round(
                xb, pvecs, xl, a_piv, pa, a_loc, ps, v_prev, pv, l,
                metric=metric, interpret=interpret)
        allp = jax.lax.all_gather(s_loc, axis)
        s_sums = fold_chunks(jnp.moveaxis(allp, 0, 1))
        dnew = dprev
    else:
        dnew = pairwise(xb, xl, metric, a_sq=xsq, b_sq=sql)
        same_new = a_piv[:, None] == a_loc[None, :]
        s_sums = _global_rowsums(jnp.where(same_new, dnew, 0.0),
                                 jnp.ones(n_local, bool), axis, c_loc, s)

    s_blk = jnp.where(valid, s_sums, jnp.inf)
    # per-cluster incumbent update (replicated (K, B) masked view)
    per_k = jnp.where(
        jnp.logical_and(a_piv[None, :] == jnp.arange(k)[:, None],
                        valid[None, :]),
        s_blk[None, :], jnp.inf)
    r_min = per_k.min(axis=1)
    r_arg = jnp.take(cand_gidx, per_k.argmin(axis=1))
    better = r_min < s_best
    s_best = jnp.where(better, r_min, s_best)
    m_best = jnp.where(better, r_arg, m_best)

    alive, mine = _mark_owned(alive, axis, owner, bpos, valid, n_local)
    n_comp = n_comp + valid.sum()
    ps = jnp.where(valid, s_blk, 0.0)
    return (l, alive, s_best, m_best, ps, valid, xb, xsq, a_piv, dnew,
            n_comp, n_rounds + 1, own + mine)


def _sh_bwarm_round(cfg, xl, sql, a_loc, v, k, state, warm_idx, bw):
    """Forced warm round: the seed pivots' vectors/clusters are owned by
    exactly one shard each, so a psum of one-hot contributions
    reconstructs the replicated pivot block exactly."""
    (axis, metric, n, n_local, c_loc, s, use_kernels, interpret) = cfg
    base = _shard_base(axis, n_local)
    # single-device semantics: lookups clip out-of-range seeds to the
    # domain (jnp.take's clip mode maps -1 -> element 0) ...
    wc = jnp.clip(warm_idx, 0, n - 1)
    lpos = wc - base
    owned = jnp.logical_and(lpos >= 0, lpos < n_local)
    safe = jnp.clip(lpos, 0, n_local - 1)
    zero = jnp.zeros((), xl.dtype)
    xb = jax.lax.psum(
        jnp.where(owned[:, None], jnp.take(xl, safe, axis=0), zero), axis)
    xsq = jax.lax.psum(jnp.where(owned, jnp.take(sql, safe), zero), axis)
    a_piv = jax.lax.psum(
        jnp.where(owned, jnp.take(a_loc, safe), 0).astype(jnp.int32), axis)
    valid = jnp.arange(bw) < jnp.minimum(k, bw)

    (l, alive, s_best, m_best, ps, pv, pvecs, psq, pa, dprev, n_comp,
     n_rounds, own) = state
    if use_kernels:
        s_loc = _ops.masked_energies(xb, xl, a_piv, a_loc, metric=metric,
                                     interpret=interpret)
        allp = jax.lax.all_gather(s_loc, axis)
        s_sums = fold_chunks(jnp.moveaxis(allp, 0, 1))
        dnew = dprev
    else:
        dnew = pairwise(xb, xl, metric, a_sq=xsq, b_sq=sql)
        same_new = a_piv[:, None] == a_loc[None, :]
        s_sums = _global_rowsums(jnp.where(same_new, dnew, 0.0),
                                 jnp.ones(n_local, bool), axis, c_loc, s)
    s_blk = jnp.where(valid, s_sums, jnp.inf)
    per_k = jnp.where(
        jnp.logical_and(a_piv[None, :] == jnp.arange(k)[:, None],
                        valid[None, :]),
        s_blk[None, :], jnp.inf)
    r_min = per_k.min(axis=1)
    r_arg = jnp.take(warm_idx, per_k.argmin(axis=1))
    better = r_min < s_best
    s_best = jnp.where(better, r_min, s_best)
    m_best = jnp.where(better, r_arg, m_best)

    # ... while the alive-scatter drops them (mode="drop" discards the
    # out-of-bounds index), so only in-range seeds die
    inrange = jnp.logical_and(warm_idx >= 0, warm_idx < n)
    mine = jnp.logical_and(owned, valid)
    kill = jnp.logical_and(mine, inrange)
    alive = alive.at[jnp.where(kill, safe, n_local)].set(False, mode="drop")
    n_comp = n_comp + valid.sum()
    ps = jnp.where(valid, s_blk, 0.0)
    return (l, alive, s_best, m_best, ps, valid, xb, xsq, a_piv, dnew,
            n_comp, n_rounds + 1, own + mine.sum())


def _sh_bpad_prev(state, block, d, has_carry):
    (l, alive, s_best, m_best, ps, pv, pvecs, psq, pa, dprev, n_comp,
     n_rounds, own) = state
    pad = block - ps.shape[0]
    if pad:
        ps = jnp.pad(ps, (0, pad))
        pv = jnp.pad(pv, (0, pad))
        pvecs = jnp.pad(pvecs, ((0, pad), (0, 0)))
        psq = jnp.pad(psq, (0, pad))
        pa = jnp.pad(pa, (0, pad))
        if has_carry:
            dprev = jnp.pad(dprev, ((0, pad), (0, 0)))
    return (l, alive, s_best, m_best, ps, pv, pvecs, psq, pa, dprev,
            n_comp, n_rounds, own)


@functools.lru_cache(maxsize=None)
def _build_batched(mesh, axis, n, d, k, block, warm, metric, use_kernels,
                   interpret, has_warm):
    p = mesh.shape[axis]
    s, n_pad, n_local, c_loc = _layout(n, p)
    cfg = (axis, metric, n, n_local, c_loc, s, use_kernels, interpret)

    def local_fn(xl, a_loc, warm_idx):
        a_loc = a_loc.astype(jnp.int32)
        sql = (sq_norms(xl) if metric in ("l2", "sqeuclidean")
               else jnp.zeros(n_local, xl.dtype))
        oob = jnp.logical_or(a_loc < 0, a_loc >= k)   # incl. pad columns
        v_loc = jnp.zeros(k, jnp.int32).at[
            jnp.where(oob, k, a_loc)].add(1, mode="drop")
        v = jax.lax.psum(v_loc, axis)                 # exact int sizes

        state = (
            jnp.zeros(n_local, xl.dtype),             # l
            ~oob,                                     # alive
            jnp.full((k,), jnp.inf, xl.dtype),        # s_best
            jnp.full((k,), -1, jnp.int32),            # m_best
            jnp.zeros(0, xl.dtype),                   # prev sums
            jnp.zeros(0, bool),                       # prev valid
            jnp.zeros((0, d), xl.dtype),              # prev pivot vectors
            jnp.zeros(0, xl.dtype),                   # prev pivot sq norms
            jnp.zeros(0, jnp.int32),                  # prev pivot clusters
            jnp.zeros((0, n_local), xl.dtype),        # prev rows (jnp carry)
            jnp.asarray(0, jnp.int32),                # n_computed
            jnp.asarray(0, jnp.int32),                # n_rounds
            jnp.asarray(0, jnp.int32),                # owned rows
        )
        round_fn = functools.partial(_sh_bround, cfg, xl, sql, a_loc, v, k)
        if has_warm:
            bw = warm_idx.shape[0]
            state = _sh_bwarm_round(cfg, xl, sql, a_loc, v, k, state,
                                    warm_idx, bw)
        for b in warm:                                # unrolled warm-up
            state = round_fn(state, b)
        state = _sh_bpad_prev(state, block, d, has_carry=not use_kernels)

        def cond(state):
            l, alive, s_best = state[0], state[1], state[2]
            thresh = jnp.take(s_best, a_loc)
            loc = jnp.logical_and(alive, l < thresh).sum()
            return jax.lax.psum(loc, axis) > 0

        state = jax.lax.while_loop(cond, lambda st: round_fn(st, block),
                                   state)
        (_l, _al, s_best, m_best, _ps, _pv, _pvec, _psq, _pa, _dp,
         n_comp, n_rounds, own) = state
        return own[None], (s_best, m_best, n_comp, n_rounds)

    return jax.jit(jax.shard_map(
        local_fn, mesh=mesh, in_specs=(P(axis), P(axis), P()),
        out_specs=(P(axis), P()),
        check_vma=False))


def _batched_medoids_sharded(
    X,
    assignment,
    k: int,
    mesh=None,
    axis: str = AXIS,
    block: int = 128,
    metric: str = "l2",
    block_schedule=None,
    use_kernels: bool = False,
    interpret=None,
    warm_idx=None,
):
    """Exact per-cluster medoids with X's columns sharded over
    ``mesh[axis]`` (DESIGN.md §11) — the sharded variant of
    ``batched_medoids_pipelined`` that lets ``kmedoids_jax`` scale K
    concurrent cluster searches across devices. Final medoids and
    in-cluster sums are bit-identical to the single-device pipelined
    engine (jnp path); rounds keep full-domain folds (the per-shard
    compaction ladder is single-medoid-only for now — each shard's fold
    is already only ``N/P`` columns wide).

    Returns ``(BatchedMedoidResult, per_shard_rows)``."""
    require_metric(metric, need_triangle=True,
                   caller="batched_medoids_sharded")
    X = jnp.asarray(X)
    n, d = X.shape
    mesh, p = _resolve_mesh(mesh, axis)
    s, n_pad, n_local, c_loc = _layout(n, p)
    block = _clamped_block(block, n, p, "batched_medoids_sharded")
    has_warm = warm_idx is not None
    warm = () if has_warm else resolve_schedule(block_schedule, block)
    interpret = (bool(interpret) if interpret is not None
                 else jax.default_backend() == "cpu")

    Xp = jnp.pad(X, ((0, n_pad - n), (0, 0)))
    ap = jnp.pad(jnp.asarray(assignment, jnp.int32), (0, n_pad - n),
                 constant_values=-1)
    Xg = jax.device_put(Xp, NamedSharding(mesh, P(axis)))
    ag = jax.device_put(ap, NamedSharding(mesh, P(axis)))
    if has_warm:
        bw = min(k, block)
        warm_arr = jnp.resize(jnp.asarray(warm_idx, jnp.int32), (bw,))
    else:
        warm_arr = jnp.zeros((1,), jnp.int32)

    fn = _build_batched(mesh, axis, n, d, k, block, warm, metric,
                        use_kernels, interpret, has_warm)
    own, (s_best, m_best, n_comp, n_rounds) = fn(Xg, ag, warm_arr)
    n_comp = int(n_comp)
    n_rounds = int(n_rounds)
    result = BatchedMedoidResult(
        np.asarray(m_best), np.asarray(s_best), n_comp, n_rounds,
        n_comp * n, n_stages=0, x_cols_streamed=n_rounds * n)
    return result, np.asarray(own, np.int64)


# ---------------------------------------------------------------------------
# sharded quadratic scan (non-triangle / registered user metrics)
# ---------------------------------------------------------------------------
def _scan_rowsums_sharded(X, metric: str = "l2", mesh=None,
                          axis: str = AXIS):
    """Exact ``(N,)`` distance row sums with the *columns* sharded over
    ``mesh[axis]`` — the sharded fallback the planner uses for exact
    queries on non-triangle (or any registered user) metrics; the
    metric's registered ``pairwise_fn`` runs unchanged inside the
    shard_map. Walks the same fixed-height pivot row blocks as
    :func:`repro.core.distances.scan_rowsums` (XLA matmul lowering is
    shape-specialised, so equal pivot-block shapes are required for
    reproducibility) and reduces on the fixed chunk grid — the result
    is bit-identical to the single-device scan."""
    from .distances import SCAN_ROW_BLOCK
    require_metric(metric, caller="scan_sharded")
    X = jnp.asarray(X)
    n = X.shape[0]
    mesh, p = _resolve_mesh(mesh, axis)
    s, n_pad, n_local, c_loc = _layout(n, p)
    blk = int(min(SCAN_ROW_BLOCK, n))
    r_pad = (-n) % blk
    Xg = jax.device_put(jnp.pad(X, ((0, n_pad - n), (0, 0))),
                        NamedSharding(mesh, P(axis)))
    Xr = jnp.pad(X, ((0, r_pad), (0, 0)))     # replicated pivot rows

    def local_fn(xl, xrows):
        base = _shard_base(axis, n_local)
        colv = (jnp.arange(n_local, dtype=jnp.int32) + base) < n
        out = []
        for start in range(0, n + r_pad, blk):
            d_loc = pairwise(xrows[start:start + blk], xl, metric)
            out.append(_global_rowsums(d_loc, colv, axis, c_loc, s))
        return jnp.concatenate(out)

    fn = jax.jit(jax.shard_map(
        local_fn, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(),
        check_vma=False))
    sums = fn(Xg, Xr)[:n]
    # per-shard cost in row units: N rows, each shard summing its own
    # real-column slice -> exactly its real column count
    per_shard = np.minimum(
        np.maximum(n - n_local * np.arange(p), 0), n_local)
    return sums, per_shard.astype(np.int64)


# ---------------------------------------------------------------------------
# legacy entrypoint shim (deprecated — repro.api.solve is the front door)
# ---------------------------------------------------------------------------
def trimed_sharded(
    X,
    mesh,
    axis: str = "data",
    block: int = 128,
    metric: str = "l2",
) -> MedoidResult:
    """**Deprecated** shim over ``solve(MedoidQuery(...,
    device_policy="sharded", mesh=...), plan="sharded")``. The pre-planner
    engine this symbol used to name is gone; the modern sharded engine
    accepts ragged ``N`` (``N`` need not divide the shard count) and
    returns the single-device pipelined engine's exact answer
    bit-for-bit. It does, however, require the mesh axis size to divide
    ``REDUCE_CHUNKS`` (= 48; see :func:`shard_count_for`) — a constraint
    the pre-planner engine did not have, the price of the bit-identity
    guarantee's fixed reduction grid."""
    from repro.api import MedoidQuery, solve, _warn_legacy
    _warn_legacy("trimed_sharded",
                 " (device_policy='sharded', plan='sharded')")
    q = MedoidQuery(X, metric=metric, block=block, device_policy="sharded",
                    mesh=mesh, engine_opts={"axis": axis})
    return solve(q, plan="sharded").extras["raw"]
