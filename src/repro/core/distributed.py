"""Distributed (sharded) block-trimed via shard_map.

The element set is sharded over one mesh axis (the ``data`` axis of the
production mesh). Per round (DESIGN.md §2):

* candidate selection: each shard proposes its local top-``B`` surviving
  bounds; an ``all_gather`` of ``(B,)`` scores + ``(B, d)`` vectors is
  followed by a replicated global top-``B`` — communication ``O(P·B·d)``,
  tiny next to the ``B·N/P·d`` local distance block;
* energies: local partial row-sums + ``psum`` over the axis;
* bound updates: fully local;
* termination: ``psum`` of local survivor counts.

Every shard finishes with identical ``(medoid_index, energy)``, so the
mapped function's outputs are replicated.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro import compat as _compat  # noqa: F401  (jax<0.5 shard_map/mesh)

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .distances import pairwise, sq_norms
from .trimed import MedoidResult


def _sharded_round(axis, metric, block, body_state):
    (xl, sql, l, computed, e_cl, m_cl, n_computed, n_rounds) = body_state
    n_local, d = xl.shape
    p_idx = jax.lax.axis_index(axis)
    n_shards = jax.lax.axis_size(axis)
    gbase = p_idx.astype(jnp.int32) * n_local

    # --- local candidate proposal ---
    survivor = jnp.logical_and(~computed, l < e_cl)
    score = jnp.where(survivor, -l, -jnp.inf)
    loc_top, loc_idx = jax.lax.top_k(score, block)

    # --- global candidate election (replicated on every shard) ---
    all_scores = jax.lax.all_gather(loc_top, axis)                 # (P, B)
    all_gidx = jax.lax.all_gather(loc_idx.astype(jnp.int32) + gbase, axis)
    all_vecs = jax.lax.all_gather(jnp.take(xl, loc_idx, axis=0), axis)
    flat_scores = all_scores.reshape(-1)
    top, flat_pos = jax.lax.top_k(flat_scores, block)              # (B,)
    valid = top > -jnp.inf
    cand_gidx = all_gidx.reshape(-1)[flat_pos]                     # (B,)
    xb = all_vecs.reshape(-1, d)[flat_pos]                         # (B, d)

    # --- distance block against local shard + global energy psum ---
    d_blk = pairwise(
        xb, xl, metric,
        a_sq=sq_norms(xb) if metric in ("l2", "sqeuclidean") else None,
        b_sq=sql if metric in ("l2", "sqeuclidean") else None,
    )                                                              # (B, n_local)
    e_blk = jax.lax.psum(d_blk.sum(axis=1), axis) / (n_local * n_shards)
    e_blk = jnp.where(valid, e_blk, jnp.inf)

    b_best = jnp.argmin(e_blk)
    better = e_blk[b_best] < e_cl
    e_cl = jnp.where(better, e_blk[b_best], e_cl)
    m_cl = jnp.where(better, cand_gidx[b_best], m_cl)

    # --- local bound update against all B pivots ---
    gap = jnp.abs(e_blk[:, None] - d_blk)
    gap = jnp.where(valid[:, None], gap, -jnp.inf)
    l = jnp.maximum(l, gap.max(axis=0))

    # --- mark computed candidates owned by this shard; tighten their bound
    owned = jnp.logical_and(
        valid,
        jnp.logical_and(cand_gidx >= gbase, cand_gidx < gbase + n_local),
    )
    local_pos = jnp.clip(cand_gidx - gbase, 0, n_local - 1)
    l = l.at[local_pos].set(
        jnp.where(owned, jnp.where(jnp.isfinite(e_blk), e_blk, l[local_pos]), l[local_pos])
    )
    computed = computed.at[local_pos].set(
        jnp.logical_or(computed[local_pos], owned)
    )
    n_computed = n_computed + valid.sum()
    return (xl, sql, l, computed, e_cl, m_cl, n_computed, n_rounds + 1)


def _trimed_sharded_fn(xl, axis, metric, block):
    n_local = xl.shape[0]
    sql = sq_norms(xl) if metric in ("l2", "sqeuclidean") else jnp.zeros(n_local, xl.dtype)
    state = (
        xl,
        sql,
        jnp.zeros(n_local, xl.dtype),            # l
        jnp.zeros(n_local, bool),                # computed
        jnp.asarray(jnp.inf, xl.dtype),          # e_cl
        jnp.asarray(-1, jnp.int32),              # m_cl
        jnp.asarray(0, jnp.int32),               # n_computed
        jnp.asarray(0, jnp.int32),               # n_rounds
    )

    def cond(state):
        _, _, l, computed, e_cl = state[:5]
        local_alive = jnp.logical_and(~computed, l < e_cl).sum()
        return jax.lax.psum(local_alive, axis) > 0

    state = jax.lax.while_loop(
        cond, functools.partial(_sharded_round, axis, metric, block), state
    )
    _, _, _, _, e_cl, m_cl, n_computed, n_rounds = state
    return m_cl, e_cl, n_computed, n_rounds


def trimed_sharded(
    X,
    mesh: Mesh,
    axis: str = "data",
    block: int = 128,
    metric: str = "l2",
) -> MedoidResult:
    """Exact medoid of ``X`` sharded over ``mesh[axis]``. ``X.shape[0]``
    must divide evenly by the axis size (pad upstream with +inf-energy
    sentinels if needed; `repro.data.coreset` does this)."""
    n, d = X.shape
    n_shards = mesh.shape[axis]
    if n % n_shards:
        raise ValueError(f"N={n} not divisible by axis size {n_shards}")
    spec_in = P(axis)
    fn = jax.shard_map(
        functools.partial(_trimed_sharded_fn, axis=axis, metric=metric,
                          block=int(min(block, n // n_shards))),
        mesh=mesh,
        in_specs=(spec_in,),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )
    X = jax.device_put(X, NamedSharding(mesh, spec_in))
    m, e, n_comp, n_rounds = jax.jit(fn)(X)
    e_paper = float(e) * n / max(n - 1, 1)
    return MedoidResult(int(m), e_paper, int(n_comp), int(n_rounds), int(n_comp) * n)
