"""trimed — the paper's sub-quadratic exact medoid algorithm.

Two implementations:

* :func:`trimed_sequential` — paper-faithful Alg. 1 (host-side, any metric
  via an oracle). This is the validation oracle and the *paper-faithful
  baseline* in EXPERIMENTS.md §Perf. One pivot per step, random shuffle
  order, bounds updated after every computed element.

* :func:`trimed_block` — the TPU-native block-synchronous adaptation
  (DESIGN.md §2): per round, the ``B`` surviving candidates with the
  smallest lower bounds are computed together as one matmul-shaped
  ``(B, N)`` distance block, energies are row-reductions, and all ``N``
  bounds are tightened against all ``B`` pivots in one fused update.
  Exactness is preserved — bounds only ever take values that Theorem 3.1's
  triangle-inequality argument proves are valid lower bounds — at a waste
  of at most ``B-1`` extra computed elements per round.

Energy normalisation is stated once, in ``distances.py``: internal
computations use the bound-exact ``E = S/N`` convention; ``.energy``
fields are rescaled to the paper's ``S/(N-1)`` at the API boundary.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from .distances import VectorOracle, pairwise, sq_norms


@dataclass
class MedoidResult:
    index: int                 # argmin element
    energy: float              # reported convention — see distances.py
    n_computed: int            # computed elements (full rows; distances.py)
    n_rounds: int = 0          # block rounds (block variant only)
    n_distances: int = 0       # scalar distance evaluations
    n_stages: int = 0          # compaction ladder stages (pipelined only)
    x_cols_streamed: int = 0   # X columns streamed from HBM (pipelined only)
    certified: bool = True     # elimination ran to completion (vs. budget-cut)
    lo_bound: float = float("nan")   # min live lower bound (uncertified only,
    #                                  paper scale) — the deterministic CI gap
    halt_reason: str = ""      # "" | "budget" | "deadline" | "stalled"


# ---------------------------------------------------------------------------
# Paper-faithful sequential algorithm (Alg. 1)
# ---------------------------------------------------------------------------
def _trimed_sequential(
    oracle_or_X,
    seed: int = 0,
    metric: str = "l2",
    eps: float = 0.0,
    order: np.ndarray | None = None,
    deadline_ts: float | None = None,
) -> MedoidResult:
    """Alg. 1 of the paper. ``eps > 0`` gives the §4 relaxation: element
    ``i`` is computed only if ``l(i) * (1 + eps) < E_cl``, guaranteeing a
    ``(1+eps)``-approximate medoid. ``deadline_ts`` (absolute, on the
    fault clock — DESIGN.md §13) halts the scan between elements and
    returns the incumbent as an anytime result (``certified=False``,
    ``halt_reason="deadline"``); at least one element is always
    computed, and a blown deadline never raises."""
    if isinstance(oracle_or_X, (np.ndarray, jnp.ndarray)):
        oracle = VectorOracle(np.asarray(oracle_or_X), metric)
    else:
        oracle = oracle_or_X
    n = oracle.n
    if n == 1:
        return MedoidResult(0, 0.0, 1, 0, oracle.scalar_distances)

    if deadline_ts is not None:
        from repro.runtime import faults as _faults
    rng = np.random.default_rng(seed)
    if order is None:
        order = rng.permutation(n)          # line 3: shuffle
    l = np.zeros(n)                          # line 1: lower bounds
    m_cl, e_cl = -1, np.inf                  # line 2
    n_computed = 0
    halt = ""
    for i in order:
        if (deadline_ts is not None and n_computed > 0
                and _faults.clock() >= deadline_ts):
            halt = "deadline"
            break
        if l[i] * (1.0 + eps) < e_cl:        # line 4 (+ §4 relaxation)
            d = oracle.row(i)                # lines 5-7
            n_computed += 1
            e_i = d.sum() / n                # line 8 (tight bound, E=S/N)
            l[i] = e_i
            if e_i < e_cl:                   # lines 9-11
                m_cl, e_cl = int(i), float(e_i)
            gap = np.abs(e_i - d)            # lines 12-14
            # inf-energy pivots carry no information about elements at
            # infinite distance (|inf - inf| = nan): drop those bounds.
            if not np.isfinite(e_i):
                gap = np.where(np.isnan(gap), 0.0, gap)
            np.maximum(l, gap, out=l)
            l[i] = e_i                       # keep own bound tight
    # left-to-right e*n/(n-1): other engines match this exact association
    energy = e_cl * n / (n - 1)              # report paper normalisation
    if not halt:
        return MedoidResult(m_cl, energy, n_computed, 0,
                            oracle.scalar_distances)
    # anytime exit: incumbent + the deterministic bound gap. An element
    # is still live if its bound leaves room below the incumbent (the
    # eps relaxation already certifies anything within (1+eps)).
    # (computed elements carry their exact energy as their bound, so the
    # incumbent itself is never live)
    live = l * (1.0 + eps) < e_cl
    lo = float(l[live].min()) if live.any() else e_cl
    return MedoidResult(m_cl, energy, n_computed, 0,
                        oracle.scalar_distances,
                        certified=not live.any(),
                        lo_bound=min(lo, e_cl) * n / (n - 1),
                        halt_reason=halt if live.any() else "")


# ---------------------------------------------------------------------------
# TPU block-synchronous algorithm
# ---------------------------------------------------------------------------
def _select_candidates(l, computed, e_cl, block, policy, key):
    """Pick up to ``block`` surviving candidates. Returns (idx, valid)."""
    survivor = jnp.logical_and(~computed, l < e_cl)
    if policy == "lowest_bound":
        score = jnp.where(survivor, -l, -jnp.inf)
    elif policy == "random":
        score = jnp.where(
            survivor, jax.random.uniform(key, l.shape), -jnp.inf
        )
    else:
        raise ValueError(f"unknown candidate policy {policy!r}")
    top, idx = jax.lax.top_k(score, block)
    valid = top > -jnp.inf
    return idx, valid


def _round_body(X, x_sq, metric, block, policy, distance_fn, fused_round_fn,
                state):
    l, computed, e_cl, m_cl, n_computed, n_rounds, key = state
    n = X.shape[0]
    key, sub = jax.random.split(key)
    idx, valid = _select_candidates(l, computed, e_cl, block, policy, sub)

    xb = jnp.take(X, idx, axis=0)                     # (B, d) pivot block
    if fused_round_fn is not None:
        # Pallas fused path: (B, N) block never materialised in HBM.
        e_blk, l = fused_round_fn(xb, X, l, valid)
        e_blk = jnp.where(valid, e_blk, jnp.inf)
    else:
        if distance_fn is None:
            d_blk = pairwise(xb, X, metric, a_sq=jnp.take(x_sq, idx), b_sq=x_sq)
        else:
            d_blk = distance_fn(xb, X)                # (B, N) — Pallas path
        e_blk = d_blk.sum(axis=1) / n                 # exact energies E=S/N
        e_blk = jnp.where(valid, e_blk, jnp.inf)
        # fused bound tightening: l(j) <- max(l(j), max_b |E(b) - D(b,j)|)
        gap = jnp.abs(e_blk[:, None] - d_blk)         # (B, N)
        gap = jnp.where(valid[:, None], gap, -jnp.inf)
        l = jnp.maximum(l, gap.max(axis=0))

    # best candidate in this round vs. incumbent
    b_best = jnp.argmin(e_blk)
    e_best = e_blk[b_best]
    better = e_best < e_cl
    e_cl = jnp.where(better, e_best, e_cl)
    m_cl = jnp.where(better, idx[b_best], m_cl)

    # computed candidates: bound is now tight (their exact energy)
    l = l.at[idx].set(jnp.where(valid, jnp.where(jnp.isinf(e_blk), l[idx], e_blk), l[idx]))
    computed = computed.at[idx].set(jnp.logical_or(computed[idx], valid))
    n_computed = n_computed + valid.sum()
    return (l, computed, e_cl, m_cl, n_computed, n_rounds + 1, key)


@functools.partial(
    jax.jit,
    static_argnames=("block", "metric", "policy", "distance_fn",
                     "fused_round_fn", "warm"),
)
def _trimed_block_jit(X, seed, block, metric, policy, distance_fn,
                      fused_round_fn, warm=()):
    n = X.shape[0]
    x_sq = sq_norms(X) if metric in ("l2", "sqeuclidean") else jnp.zeros(n)
    key = jax.random.PRNGKey(seed)

    state = (
        jnp.zeros(n, X.dtype),                    # l
        jnp.zeros(n, bool),                       # computed
        jnp.asarray(jnp.inf, X.dtype),            # e_cl
        jnp.asarray(-1, jnp.int32),               # m_cl
        jnp.asarray(0, jnp.int32),                # n_computed
        jnp.asarray(0, jnp.int32),                # n_rounds
        key,
    )

    # adaptive warm-up (DESIGN.md §4): small early blocks establish a
    # strong incumbent cheaply before full-width blocks commit
    for b in warm:
        state = _round_body(X, x_sq, metric, b, policy, distance_fn,
                            fused_round_fn, state)

    def cond(state):
        l, computed, e_cl = state[0], state[1], state[2]
        return jnp.any(jnp.logical_and(~computed, l < e_cl))

    body = functools.partial(
        _round_body, X, x_sq, metric, block, policy, distance_fn,
        fused_round_fn,
    )
    state = jax.lax.while_loop(cond, body, state)
    l, computed, e_cl, m_cl, n_computed, n_rounds, _ = state
    return m_cl, e_cl, n_computed, n_rounds


def _trimed_block(
    X,
    seed: int = 0,
    block: int = 128,
    metric: str = "l2",
    policy: str = "lowest_bound",
    distance_fn: Callable | None = None,
    fused_round_fn: Callable | None = None,
    block_schedule=None,
) -> MedoidResult:
    """Block-synchronous exact medoid on device. ``distance_fn`` overrides
    the ``(B, N)`` distance-block computation; ``fused_round_fn`` (see
    ``repro.kernels.ops.fused_round``) replaces the whole round with the
    Pallas distance-block-free kernels. ``block_schedule="geometric"``
    prepends a geometric warm-up of small blocks (adaptive schedule,
    DESIGN.md §4); schedules affect cost, never exactness."""
    from .pipelined import resolve_schedule

    X = jnp.asarray(X)
    n = X.shape[0]
    block = int(min(block, n))
    warm = resolve_schedule(block_schedule, block)
    m, e, n_comp, n_rounds = _trimed_block_jit(
        X, seed, block, metric, policy, distance_fn, fused_round_fn,
        warm=warm,
    )
    e_paper = float(e) * n / max(n - 1, 1)
    return MedoidResult(
        int(m), e_paper, int(n_comp), int(n_rounds), int(n_comp) * n
    )


def medoid(X, backend: str = "auto", **kw):
    """**Deprecated** dispatcher — now a shim over :func:`repro.api.solve`.

    ``backend`` maps to a planner override: ``"auto"`` lets the planner
    choose; ``"sequential"`` / ``"block"`` / ``"pipelined"`` force the
    exact engines; ``"bandit"`` routes to the anytime subsystem (returns
    its :class:`~repro.bandit.api.BanditMedoidResult`, with ``budget=`` /
    ``delta=`` honoured). Returns the chosen engine's native result."""
    from repro.api import MedoidQuery, solve, _warn_legacy
    _warn_legacy("medoid", " (plan=... to force a backend)")
    known = ("auto", "sequential", "block", "pipelined", "bandit")
    if backend not in known:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{known}")
    q_kw = {f: kw.pop(f) for f in ("metric", "seed", "block",
                                   "block_schedule", "budget", "delta",
                                   "use_kernels", "warm_idx")
            if f in kw}
    # legacy callers never opted into planner auto-kernels; keep the
    # pre-redesign jnp default unless they pass use_kernels themselves
    q_kw.setdefault("use_kernels", False)
    if backend == "bandit":
        q_kw["mode"] = "anytime"
    q = MedoidQuery(X, engine_opts=kw, **q_kw)
    plan = None if backend in ("auto", "bandit") else backend
    return solve(q, plan=plan).extras["raw"]


# ---------------------------------------------------------------------------
# Exact top-k ranking (the paper's §6 extension)
# ---------------------------------------------------------------------------
@dataclass
class TopKResult:
    indices: np.ndarray          # (k,) lowest-energy elements, ascending
    energies: np.ndarray         # (k,) paper normalisation S/(N-1)
    n_computed: int


def _trimed_topk(
    oracle_or_X,
    k: int,
    seed: int = 0,
    metric: str = "l2",
) -> TopKResult:
    """Exact k lowest-energy elements ("ranking of closeness centrality",
    TOPRANK's original task). Same bound machinery as trimed, with the
    elimination threshold being the k-th best computed energy: when
    ``l(i) >= E_k`` the true energy is also >= E_k, so ``i`` cannot enter
    the top-k. The paper's §6 notes this extension is immediate."""
    if isinstance(oracle_or_X, (np.ndarray, jnp.ndarray)):
        oracle = VectorOracle(np.asarray(oracle_or_X), metric)
    else:
        oracle = oracle_or_X
    n = oracle.n
    k = min(k, n)
    rng = np.random.default_rng(seed)
    l = np.zeros(n)
    best: list[tuple[float, int]] = []     # (energy, index), len <= k
    e_k = np.inf                           # k-th best energy so far
    n_computed = 0
    for i in rng.permutation(n):
        if l[i] < e_k:
            d = oracle.row(i)
            n_computed += 1
            e_i = d.sum() / n
            l[i] = e_i
            best.append((e_i, int(i)))
            best.sort()
            if len(best) > k:
                best.pop()
            if len(best) == k:
                e_k = best[-1][0]
            gap = np.abs(e_i - d)
            if not np.isfinite(e_i):
                gap = np.where(np.isnan(gap), 0.0, gap)
            np.maximum(l, gap, out=l)
            l[i] = e_i
    idx = np.array([i for _, i in best])
    en = np.array([e for e, _ in best]) * n / max(n - 1, 1)
    return TopKResult(idx, en, n_computed)


# ---------------------------------------------------------------------------
# legacy entrypoint shims (deprecated — repro.api.solve is the front door)
# ---------------------------------------------------------------------------
def trimed_sequential(
    oracle_or_X,
    seed: int = 0,
    metric: str = "l2",
    eps: float = 0.0,
    order: np.ndarray | None = None,
) -> MedoidResult:
    """**Deprecated** shim over ``solve(MedoidQuery(...), plan="sequential")``."""
    from repro.api import MedoidQuery, solve, _warn_legacy
    _warn_legacy("trimed_sequential", " (plan='sequential')")
    q = MedoidQuery(oracle_or_X, metric=metric, seed=seed,
                    engine_opts={"eps": eps, "order": order})
    return solve(q, plan="sequential").extras["raw"]


def trimed_block(
    X,
    seed: int = 0,
    block: int = 128,
    metric: str = "l2",
    policy: str = "lowest_bound",
    distance_fn: Callable | None = None,
    fused_round_fn: Callable | None = None,
    block_schedule=None,
) -> MedoidResult:
    """**Deprecated** shim over ``solve(MedoidQuery(...), plan="block")``."""
    from repro.api import MedoidQuery, solve, _warn_legacy
    _warn_legacy("trimed_block", " (plan='block')")
    opts = {"policy": policy}
    if distance_fn is not None:
        opts["distance_fn"] = distance_fn
    if fused_round_fn is not None:
        opts["fused_round_fn"] = fused_round_fn
    # use_kernels pinned False: the legacy kernel opt-in was
    # fused_round_fn=, and the shim contract is bit-identical results
    q = MedoidQuery(X, metric=metric, seed=seed, block=block,
                    block_schedule=block_schedule, use_kernels=False,
                    engine_opts=opts)
    return solve(q, plan="block").extras["raw"]


def trimed_topk(
    oracle_or_X,
    k: int,
    seed: int = 0,
    metric: str = "l2",
) -> TopKResult:
    """**Deprecated** shim over ``solve(MedoidQuery(..., topk=k))``."""
    from repro.api import MedoidQuery, solve, _warn_legacy
    _warn_legacy("trimed_topk", " (topk=k)")
    q = MedoidQuery(oracle_or_X, metric=metric, seed=seed, topk=k)
    return solve(q, plan="topk").extras["raw"]
