"""trikmeds — the paper's accelerated K-medoids (§4, SM-H, Algs. 6-11).

Voronoi iteration with two bound systems:

* **Assignment** (Alg. 9): Elkan-style lower bounds ``l_c(i, k)`` on the
  distance from element ``i`` to medoid ``k``, decayed by the distance
  ``p(k)`` each medoid moved ("teleported") in the last update.
* **Medoid update** (Alg. 8): trimed-style lower bounds ``l_s(i)`` on the
  *in-cluster sum* of distances ``sum_{i' in cluster} d(i, i')``, reused
  across iterations and decayed by cluster-flux terms (Alg. 10) when
  membership changes.

``eps`` gives trikmeds-ε (§4): the medoid-update bound test becomes
``l_s(i) * (1 + eps) < s(k)`` and the assignment test keeps an assignment
whenever the current medoid distance is within ``(1+eps)`` of the best
bound — trading exactness of each step for fewer distance computations.

This host-side implementation is the instrumented, paper-faithful version
used by the Table-2 benchmark. A device-side batched variant for TPU lives
in :func:`kmedoids_jax` (used by the HuBERT pseudo-labeller and MoE router
init); its medoid-update step runs the batched multi-cluster trimed
engine (:mod:`repro.core.batched`, DESIGN.md §3), so the device path is
sub-quadratic per iteration like the host path — ``kmedoids_batched``
exposes the distance-computation counters.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np

import jax
import jax.numpy as jnp

from repro.api.metrics import get_metric

from .batched import batched_medoids_jit
from .distances import (VectorOracle, elements_computed, pairwise,
                        sq_norms)


@dataclass
class TrikmedsResult:
    medoids: np.ndarray
    assignment: np.ndarray
    energy: float                # sum of distances to assigned medoids
    n_distances: int             # scalar distance computations
    n_iterations: int
    history: list = field(default_factory=list)


def trikmeds(
    X: np.ndarray,
    k: int,
    eps: float = 0.0,
    max_iter: int = 100,
    seed: int = 0,
    metric: str = "l2",
    init_medoids: np.ndarray | None = None,
) -> TrikmedsResult:
    oracle = VectorOracle(X, metric)
    n = oracle.n
    rng = np.random.default_rng(seed)

    # ---------------- initialise (Alg. 7) ----------------
    if init_medoids is None:
        m = rng.choice(n, size=k, replace=False)          # medoid indices
    else:
        m = np.array(init_medoids, dtype=int).copy()
    c = oracle.X[m].copy()                                # medoid vectors
    # tight lower bounds on element-to-medoid distances
    l_c = np.stack([oracle.subrow(int(mi), np.arange(n)) for mi in m]).T  # (N, K)
    a = np.argmin(l_c, axis=1)                            # assignment
    d = l_c[np.arange(n), a]                              # dist to own medoid
    v = np.bincount(a, minlength=k).astype(int)           # cluster sizes
    s = np.zeros(k)                                       # in-cluster sums at medoid
    for kk in range(k):
        s[kk] = d[a == kk].sum()
    l_s = np.zeros(n)                                     # bounds on in-cluster sums
    l_s[m] = s                                            # tight at medoids
    p = np.zeros(k)                                       # medoid move distances

    it = 0
    for it in range(1, max_iter + 1):
        # ---------------- update-medoids (Alg. 8) ----------------
        old_m = m.copy()
        moved = np.zeros(k, dtype=bool)
        for kk in range(k):
            members = np.flatnonzero(a == kk)
            if len(members) == 0:
                continue
            vk = len(members)
            for i in members:
                if l_s[i] * (1.0 + eps) < s[kk]:
                    d_tilde = oracle.subrow(int(i), members)
                    tight = d_tilde.sum()
                    if tight < s[kk]:
                        s[kk] = tight
                        m[kk] = i
                        d[members] = d_tilde
                    # tighten in-cluster sum bounds via |v*d_tilde - S(i)|
                    np.maximum(
                        l_s[members],
                        np.abs(d_tilde * vk - tight),
                        out=l_s[members],
                    )
                    l_s[i] = tight
            if m[kk] != old_m[kk]:
                p[kk] = float(np.linalg.norm(c[kk] - oracle.X[m[kk]]))
                c[kk] = oracle.X[m[kk]].copy()
                moved[kk] = True
            else:
                p[kk] = 0.0

        # ---------------- assign-to-clusters (Alg. 9) ----------------
        dn_in = np.zeros(k)
        dn_out = np.zeros(k)
        ds_in = np.zeros(k)
        ds_out = np.zeros(k)
        # decay bounds by medoid movement (d stays tight: Alg. 8 refreshed
        # it for every cluster whose medoid changed)
        l_c -= p[None, :]
        np.maximum(l_c, 0.0, out=l_c)
        l_c[np.arange(n), a] = d                          # tight own column
        changed = 0
        for i in range(n):
            a_old, d_old = a[i], d[i]
            for kk in range(k):
                if kk == a[i]:
                    continue
                if l_c[i, kk] < d[i] / (1.0 + eps):
                    dist = oracle.pair(i, int(m[kk]))
                    l_c[i, kk] = dist
                    if dist < d[i]:
                        a[i] = kk
                        d[i] = dist
            if a[i] != a_old:
                changed += 1
                v[a_old] -= 1
                v[a[i]] += 1
                l_s[i] = 0.0
                dn_in[a[i]] += 1
                dn_out[a_old] += 1
                ds_in[a[i]] += d[i]
                ds_out[a_old] += d_old

        # ---------------- update-sum-bounds (Alg. 10) ----------------
        js_abs = ds_in + ds_out
        js_net = ds_in - ds_out
        jn_abs = dn_in + dn_out
        jn_net = dn_in - dn_out
        for kk in range(k):
            members = np.flatnonzero(a == kk)
            if len(members) == 0:
                continue
            dec = np.minimum(
                js_abs[kk] - jn_net[kk] * d[members],
                jn_abs[kk] * d[members] - js_net[kk],
            )
            l_s[members] = np.maximum(l_s[members] - np.maximum(dec, 0.0), 0.0)
            # cluster membership changed -> medoid sum s(k) is stale;
            # recompute from scratch next update by resetting to the true sum
            s[kk] = d[members].sum()
            l_s[m[kk]] = s[kk]

        if changed == 0 and not moved.any():
            break

    energy = float(d.sum())
    return TrikmedsResult(
        m.copy(), a.copy(), energy, oracle.scalar_distances, it
    )


# ---------------------------------------------------------------------------
# Device-side batched K-medoids (TPU path)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _maximin_init(X, k, x_sq, seed, metric):
    """Farthest-point (maximin) seeding: covers well-separated clusters
    deterministically — random seeding routinely misses clusters and
    Voronoi iteration cannot recover (no empty-cluster splitting)."""
    n = X.shape[0]
    first = jax.random.randint(jax.random.PRNGKey(seed), (), 0, n)

    def step(carry, _):
        m_idx, dmin, i = carry
        last = jnp.take(X, m_idx[i], axis=0)[None]
        d = pairwise(last, X, metric, b_sq=x_sq)[0]
        dmin = jnp.minimum(dmin, d)
        nxt = jnp.argmax(dmin).astype(jnp.int32)
        m_idx = m_idx.at[i + 1].set(nxt)
        return (m_idx, dmin, i + 1), None

    m_idx = jnp.zeros((k,), jnp.int32).at[0].set(first.astype(jnp.int32))
    dmin = jnp.full((n,), jnp.inf, X.dtype)
    (m_idx, _, _), _ = jax.lax.scan(step, (m_idx, dmin, 0), None,
                                    length=k - 1)
    return m_idx


@dataclass
class KMedoidsJaxResult:
    """Instrumented device-side K-medoids outcome (``kmedoids_batched``)."""
    medoids: np.ndarray
    assignment: np.ndarray
    energy: float
    n_rows: int                  # full distance rows computed
    n_distances: int             # scalar distance evaluations (rows * N)
    n_iterations: int


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_iter", "metric", "medoid_update", "block",
                     "fused_round_fn", "warm_blocks"),
)
def _kmedoids_impl(X, k, seed, n_iter, metric, medoid_update, block,
                   fused_round_fn=None, warm_blocks=()):
    """Shared jitted body. Returns (m_idx, a, energy, n_rows) where
    ``n_rows`` counts full (N,) distance rows — multiply by N for scalar
    distances (kept in row units on device so the counter cannot overflow
    int32 at large N)."""
    n = X.shape[0]
    x_sq = sq_norms(X)
    m_idx = _maximin_init(X, k, x_sq, seed, metric)

    blk = min(1024, n)
    n_pad = (-n) % blk

    def step(carry, _):
        m_idx, _a, n_rows = carry
        centers = jnp.take(X, m_idx, axis=0)
        dc = pairwise(centers, X, metric, b_sq=x_sq)          # (K, N)
        a = jnp.argmin(dc, axis=0).astype(jnp.int32)          # assignment
        n_rows = n_rows + k

        if medoid_update == "trimed":
            # batched multi-cluster trimed engine (core.batched): K
            # concurrent bound-driven searches, warm-started from the
            # incumbent medoids — sub-quadratic in N per iteration.
            m_new, _s, n_comp, _r = batched_medoids_jit(
                X, a, k, block, metric, fused_round_fn=fused_round_fn,
                warm_idx=m_idx, warm=warm_blocks)
            new_m = jnp.where(m_new >= 0, m_new, m_idx).astype(jnp.int32)
            n_rows = n_rows + n_comp
        else:  # "scan": quadratic reference path (kept for benchmarks)
            onehot = jax.nn.one_hot(a, k, dtype=X.dtype)      # (N, K)
            # In-cluster sums for all elements, S(i) = sum_j [a(j)=a(i)]
            # d(i,j), computed blockwise so the (N, N) distance matrix is
            # never materialised: for each row block, D_blk @ onehot.
            Xp = jnp.pad(X, ((0, n_pad), (0, 0)))
            sqp = jnp.pad(x_sq, (0, n_pad))

            def block_sums(start):
                xb = jax.lax.dynamic_slice_in_dim(Xp, start, blk, 0)
                sb = jax.lax.dynamic_slice_in_dim(sqp, start, blk, 0)
                db = pairwise(xb, X, metric, a_sq=sb, b_sq=x_sq)
                return db @ onehot                            # (blk, K)

            starts = jnp.arange(0, n + n_pad, blk)
            S = jax.lax.map(block_sums, starts).reshape(-1, k)[:n]
            own = jnp.take_along_axis(S, a[:, None], axis=1)[:, 0]
            big = jnp.asarray(jnp.inf, X.dtype)
            masked = jnp.where(onehot.T > 0, own[None, :], big)
            new_m = jnp.argmin(masked, axis=1).astype(jnp.int32)
            n_rows = n_rows + n

        return (new_m, a, n_rows), None

    carry0 = (m_idx, jnp.zeros(n, jnp.int32),
              jnp.asarray(k - 1, jnp.int32))     # maximin init rows
    (m_idx, a, n_rows), _ = jax.lax.scan(step, carry0, None, length=n_iter)
    centers = jnp.take(X, m_idx, axis=0)
    dc = pairwise(centers, X, metric, b_sq=x_sq)
    a = jnp.argmin(dc, axis=0)
    n_rows = n_rows + k
    energy = jnp.take_along_axis(dc, a[None, :], axis=0).sum()
    return m_idx, a, energy, n_rows


def _resolve_medoid_update(medoid_update, metric: str):
    """Normalise ``medoid_update`` to an engine string plus option
    overrides. A nested :class:`repro.api.MedoidQuery` template is
    translated by the planner (``repro.api.resolve_update_plan``); legacy
    strings pass through. The trimed/pipelined engines' elimination
    bound is the triangle bound, so they are only exact for metrics the
    registry marks ``has_triangle`` — for the others fall back to the
    quadratic scan, which is metric-agnostic, keeping the update exact
    either way. The ``bandit`` update (the paper's relaxed K-medoids,
    §5) estimates by sampling and needs no triangle inequality, so it
    survives every metric."""
    overrides = {}
    if not isinstance(medoid_update, str):
        from repro.api.planner import resolve_update_plan
        medoid_update, overrides = resolve_update_plan(medoid_update, metric)
    if medoid_update not in ("trimed", "scan", "pipelined", "sharded",
                             "bandit"):
        raise ValueError(
            "medoid_update must be 'trimed', 'pipelined', 'sharded', "
            "'bandit', 'scan' or a MedoidQuery template, got "
            f"{medoid_update!r}")
    if (medoid_update in ("trimed", "pipelined", "sharded")
            and not get_metric(metric).has_triangle):
        return "scan", overrides
    return medoid_update, overrides


@functools.partial(jax.jit, static_argnames=("metric",))
def _assign_step(X, m_idx, x_sq, metric):
    centers = jnp.take(X, m_idx, axis=0)
    dc = pairwise(centers, X, metric, b_sq=x_sq)              # (K, N)
    a = jnp.argmin(dc, axis=0).astype(jnp.int32)
    d_own = jnp.take_along_axis(dc, a[None, :], axis=0)[0]
    return a, d_own


def _kmedoids_update_loop(X, k, seed, n_iter, metric, update_fn):
    """Shared Voronoi-iteration driver for the host-orchestrated
    medoid-update engines (pipelined / sharded — both need a Python loop
    over jitted stage programs rather than one ``lax.scan``: a few host
    syncs per iteration against an asymptotically smaller update step).
    ``update_fn(assignment, warm_idx)`` runs one medoid-update and
    returns its ``BatchedMedoidResult``."""
    n = X.shape[0]
    x_sq = sq_norms(X)
    m_idx = _maximin_init(X, k, x_sq, seed, metric)
    n_rows = k - 1                                            # maximin rows
    a = jnp.zeros(n, jnp.int32)
    for _ in range(n_iter):
        a, _ = _assign_step(X, m_idx, x_sq, metric)
        n_rows += k
        res = update_fn(a, np.asarray(m_idx))
        m_new = jnp.asarray(res.medoids, jnp.int32)
        m_idx = jnp.where(m_new >= 0, m_new, m_idx)
        n_rows += res.n_computed
    a, d_own = _assign_step(X, m_idx, x_sq, metric)
    n_rows += k
    energy = d_own.sum()
    return m_idx, a, energy, jnp.asarray(n_rows, jnp.int32)


def _kmedoids_pipelined_impl(X, k, seed, n_iter, metric, block,
                             block_schedule, use_kernels):
    """Voronoi iteration whose medoid-update step is the
    survivor-compacted pipelined engine (DESIGN.md §4)."""
    from .pipelined import _batched_medoids_pipelined

    def update(a, warm):
        return _batched_medoids_pipelined(
            X, a, k, block=block, metric=metric,
            block_schedule=block_schedule, use_kernels=use_kernels,
            warm_idx=warm)

    return _kmedoids_update_loop(X, k, seed, n_iter, metric, update)


def _kmedoids_sharded_impl(X, k, seed, n_iter, metric, block,
                           block_schedule, use_kernels, mesh, mesh_axis):
    """Voronoi iteration whose medoid-update step is the *sharded*
    multi-cluster engine (DESIGN.md §11): the K concurrent per-cluster
    searches shard X's columns across ``mesh`` (default: a 1-axis mesh
    over all local devices), with medoids bit-identical to the
    single-device pipelined update."""
    from .distributed import _batched_medoids_sharded

    kw = {} if mesh_axis is None else {"axis": mesh_axis}

    def update(a, warm):
        res, _per = _batched_medoids_sharded(
            X, a, k, mesh=mesh, block=block, metric=metric,
            block_schedule=block_schedule, use_kernels=use_kernels,
            warm_idx=warm, **kw)
        return res

    return _kmedoids_update_loop(X, k, seed, n_iter, metric, update)


def _kmedoids_bandit_impl(X, k, seed, n_iter, metric, bandit_budget,
                          use_kernels):
    """Voronoi iteration whose medoid-update step is the *budgeted
    bandit* (the paper's §5 relaxation, served by ``repro.bandit``):
    per cluster, a sampled-column race estimates the in-cluster medoid
    on ``bandit_budget * |cluster|`` computed elements. The update is
    approximate — the trade the paper makes "to obtain further
    computational gains with only a minor loss in cluster quality" —
    so it works for every metric (no triangle inequality required).
    Tiny clusters fall through to the exact engine inside
    ``bandit_medoid`` (its brute-force floor), the same auto-fallback
    discipline as the trimed/pipelined updates."""
    from repro.bandit.api import _bandit_medoid

    n = X.shape[0]
    x_sq = sq_norms(X)
    m_idx = _maximin_init(X, k, x_sq, seed, metric)
    n_rows = float(k - 1)                                 # maximin rows
    Xh = np.asarray(X)
    a = jnp.zeros(n, jnp.int32)
    for it in range(n_iter):
        a, _ = _assign_step(X, m_idx, x_sq, metric)
        n_rows += k
        a_h = np.asarray(a)
        m_new = np.asarray(m_idx).copy()
        for c in range(k):
            members = np.flatnonzero(a_h == c)
            if len(members) == 0:
                continue
            r = _bandit_medoid(
                Xh[members], budget=max(8.0, bandit_budget * len(members)),
                exact=None, engine="ucb", metric=metric,
                seed=seed + 1009 * it + c, use_kernels=use_kernels)
            m_new[c] = members[r.index]
            # unified accounting: cluster-local scalars in full-X rows
            n_rows += elements_computed(r.n_scalars, n)
        m_idx = jnp.asarray(m_new, jnp.int32)
    a, d_own = _assign_step(X, m_idx, x_sq, metric)
    n_rows += k
    energy = d_own.sum()
    return m_idx, a, energy, jnp.asarray(n_rows, jnp.float32)


def _engine_round_fn(metric: str, use_kernels: bool):
    if not use_kernels:
        return None
    hook = get_metric(metric).fused_masked_round_fn
    if hook is None:
        # only metrics with a registered fused masked-round hook can run
        # the Pallas round; others take the jnp round inside the engine
        from repro.api.metrics import available_metrics
        hooked = [n for n in available_metrics()
                  if get_metric(n).fused_masked_round_fn is not None]
        raise ValueError(
            f"use_kernels=True: metric {metric!r} has no fused "
            f"masked-round kernel hook; metrics with hooks: {hooked}")
    return hook


def kmedoids_jax(
    X: jnp.ndarray,
    k: int,
    seed: int = 0,
    n_iter: int = 10,
    metric: str = "l2",
    medoid_update: str = "trimed",
    block: int = 128,
    use_kernels: bool = False,
    block_schedule=None,
    bandit_budget: float = 0.25,
    mesh=None,
    mesh_axis=None,
):
    """Batched Voronoi-iteration K-medoids on device. The medoid-update
    step runs the batched multi-cluster trimed engine (DESIGN.md §3): K
    concurrent bound-driven per-cluster searches in one jitted program,
    warm-started from the incumbent medoids — the paper's §5 application
    made sub-quadratic on device. ``medoid_update="scan"`` selects the
    quadratic blockwise reference path instead (one ``(N, N)``-tiled
    masked computation per iteration; used by the benchmarks as the
    baseline, and the automatic fallback for non-triangle metrics where
    the engine's bounds would not be valid). ``use_kernels=True`` runs
    the engine rounds through the Pallas assignment-masked kernels
    (``kernels.ops.fused_masked_round``) instead of the jnp round. Used
    for HuBERT pseudo-labels and MoE router init.
    ``medoid_update="pipelined"`` selects the survivor-compacted
    pipelined engine (DESIGN.md §4; host-orchestrated compaction ladder);
    ``block_schedule`` threads the adaptive warm-up block schedule into
    whichever engine runs the update. ``medoid_update="bandit"`` selects
    the *approximate* budgeted update (the paper's §5 relaxation) served
    by :mod:`repro.bandit` — ``bandit_budget`` is the per-cluster element
    budget as a fraction of the cluster size (DESIGN.md §9); it is the
    only update that trades exactness of the step for cost, and the only
    one valid for non-triangle metrics without falling back to scan.
    ``medoid_update="sharded"`` runs the update step through the
    column-sharded multi-cluster engine (DESIGN.md §11) on ``mesh`` (or
    a default 1-axis mesh over all local devices) — K cluster searches
    scaled across devices, medoids bit-identical to the pipelined
    update. ``medoid_update`` may also be a nested
    :class:`repro.api.MedoidQuery` template describing the
    per-iteration update search declaratively (``mode="anytime"`` /
    ``budget`` selects the bandit update; its ``block`` /
    ``block_schedule`` / ``use_kernels`` override this call's).
    Returns (medoid_indices, assignment, energy).
    """
    from .pipelined import resolve_schedule

    medoid_update, ov = _resolve_medoid_update(medoid_update, metric)
    block = ov.get("block", block)
    block_schedule = ov.get("block_schedule", block_schedule)
    use_kernels = ov.get("use_kernels", use_kernels)
    bandit_budget = ov.get("bandit_budget", bandit_budget)
    block = int(min(block, X.shape[0]))
    if medoid_update == "sharded":
        m_idx, a, energy, _ = _kmedoids_sharded_impl(
            jnp.asarray(X), k, seed, n_iter, metric, block, block_schedule,
            use_kernels, mesh, mesh_axis)
        return m_idx, a, energy
    if medoid_update == "pipelined":
        m_idx, a, energy, _ = _kmedoids_pipelined_impl(
            jnp.asarray(X), k, seed, n_iter, metric, block, block_schedule,
            use_kernels)
        return m_idx, a, energy
    if medoid_update == "bandit":
        m_idx, a, energy, _ = _kmedoids_bandit_impl(
            jnp.asarray(X), k, seed, n_iter, metric, bandit_budget,
            use_kernels)
        return m_idx, a, energy
    m_idx, a, energy, _ = _kmedoids_impl(
        X, k, seed, n_iter, metric, medoid_update, block,
        fused_round_fn=_engine_round_fn(metric, use_kernels),
        warm_blocks=resolve_schedule(block_schedule, block))
    return m_idx, a, energy


def kmedoids_batched(
    X,
    k: int,
    seed: int = 0,
    n_iter: int = 10,
    metric: str = "l2",
    medoid_update: str = "trimed",
    block: int = 128,
    use_kernels: bool = False,
    block_schedule=None,
    bandit_budget: float = 0.25,
    mesh=None,
    mesh_axis=None,
) -> KMedoidsJaxResult:
    """Instrumented wrapper around the device K-medoids: same iteration
    as :func:`kmedoids_jax` plus distance-computation accounting, for the
    benchmarks and the data-pipeline callers that report costs (unified
    computed elements — fractional rows under the bandit update)."""
    from .pipelined import resolve_schedule

    medoid_update, ov = _resolve_medoid_update(medoid_update, metric)
    block = ov.get("block", block)
    block_schedule = ov.get("block_schedule", block_schedule)
    use_kernels = ov.get("use_kernels", use_kernels)
    bandit_budget = ov.get("bandit_budget", bandit_budget)
    X = jnp.asarray(X)
    n = X.shape[0]
    block = int(min(block, n))
    if medoid_update == "sharded":
        m_idx, a, energy, n_rows = _kmedoids_sharded_impl(
            X, k, seed, n_iter, metric, block, block_schedule, use_kernels,
            mesh, mesh_axis)
    elif medoid_update == "pipelined":
        m_idx, a, energy, n_rows = _kmedoids_pipelined_impl(
            X, k, seed, n_iter, metric, block, block_schedule, use_kernels)
    elif medoid_update == "bandit":
        m_idx, a, energy, n_rows = _kmedoids_bandit_impl(
            X, k, seed, n_iter, metric, bandit_budget, use_kernels)
    else:
        m_idx, a, energy, n_rows = _kmedoids_impl(
            X, k, seed, n_iter, metric, medoid_update, block,
            fused_round_fn=_engine_round_fn(metric, use_kernels),
            warm_blocks=resolve_schedule(block_schedule, block))
    n_rows = float(n_rows)
    if medoid_update != "bandit":
        n_rows = int(n_rows)
    return KMedoidsJaxResult(
        np.asarray(m_idx), np.asarray(a), float(energy), n_rows,
        int(round(n_rows * n)), n_iter,
    )
