"""SolveState — the pipelined engine's persisted elimination state.

The segmented pipelined engine (DESIGN.md §13) runs its elimination
loop in host-visible segments; between segments the entire loop state
can be snapshotted through :class:`repro.checkpoint.checkpoint
.Checkpointer` and later restored for a **bit-identical** resume: the
restored solve replays the exact same pivot sequence (the state round-
trips through ``.npy`` files losslessly, every round is a pure function
of the state, and compaction is *never* re-run on resume — ``top_k``
tie-breaking depends on the survivor-buffer layout, so re-compacting
would change the pivot order).

The state is a flat, fixed-order list of arrays (:data:`ARRAY_FIELDS`)
plus a few host scalars (:data:`AUX_FIELDS`) stored in the checkpoint's
``extra`` metadata next to a **config fingerprint**. A resume under a
different configuration (block width, metric, kernel flag, ladder
geometry, budget...) would silently diverge from bit-identity, so a
fingerprint mismatch refuses to resume (:class:`SolveStateMismatch`)
instead of guessing.

This is also the foundation the streaming index (``repro.stream``,
DESIGN.md §15) builds on: a finished solve's ``SolveState`` (bounds +
survivor buffer + incumbent) is exactly the index that insert/delete
repair starts from. Format 2 adds ``esum`` — the per-row **energy
cache**: the raw ``S(i)`` column sum of every computed pivot row,
scatter-updated inside the round loops. Churn repair delta-adjusts
these cached contributions instead of recomputing rows from scratch.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

import jax

PHASE_FULL = 0      # full-domain rounds (no survivor buffer yet)
PHASE_LADDER = 1    # compacted-buffer rounds on the pow2 ladder

ARRAY_FIELDS = ("surv_idx", "l", "alive", "e_cl", "m_cl", "pidx", "pe",
                "pv", "dprev", "n_comp", "n_rounds", "fold_cols", "esum")
AUX_FIELDS = ("phase", "n_stages", "m_out", "is_floor")

_FORMAT = 2          # bump on any layout change (2: + esum energy cache)


class SolveStateMismatch(ValueError):
    """A checkpoint exists but was written under a different solve
    configuration (or state-format version); resuming it would not be
    bit-identical."""


@dataclass
class SolveState:
    """One segment boundary of the pipelined engine, in host memory.

    ``phase`` is :data:`PHASE_FULL` or :data:`PHASE_LADDER`; in the full
    phase ``surv_idx`` is empty (the domain is implicit ``arange(N)``)
    and ``m_out``/``is_floor`` are unused. Array fields mirror the
    engine's while-loop carry; see ``core/pipelined.py``.
    """
    phase: int = PHASE_FULL
    n_stages: int = 0
    m_out: int = 0
    is_floor: bool = False
    surv_idx: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    l: np.ndarray | None = None
    alive: np.ndarray | None = None
    e_cl: np.ndarray | None = None
    m_cl: np.ndarray | None = None
    pidx: np.ndarray | None = None
    pe: np.ndarray | None = None
    pv: np.ndarray | None = None
    dprev: np.ndarray | None = None
    n_comp: np.ndarray | None = None
    n_rounds: np.ndarray | None = None
    fold_cols: np.ndarray | None = None
    esum: np.ndarray | None = None

    # ------------------------------------------------------- conversions
    def leaves(self) -> list:
        return [np.asarray(getattr(self, f)) for f in ARRAY_FIELDS]

    def aux(self) -> dict:
        return {"phase": int(self.phase), "n_stages": int(self.n_stages),
                "m_out": int(self.m_out), "is_floor": bool(self.is_floor)}

    @classmethod
    def from_leaves(cls, leaves, aux: dict) -> "SolveState":
        kw = dict(zip(ARRAY_FIELDS, leaves))
        kw.update({k: aux[k] for k in AUX_FIELDS})
        return cls(**kw)


def _flatten(s: SolveState):
    return tuple(getattr(s, f) for f in ARRAY_FIELDS), \
        tuple(getattr(s, f) for f in AUX_FIELDS)


def _unflatten(aux, children) -> SolveState:
    return SolveState(**dict(zip(AUX_FIELDS, aux)),
                      **dict(zip(ARRAY_FIELDS, children)))


jax.tree_util.register_pytree_node(SolveState, _flatten, _unflatten)


def state_fingerprint(**cfg) -> dict:
    """Canonical (JSON-round-trippable) solve-config fingerprint."""
    fp = {"format": _FORMAT}
    for k, v in sorted(cfg.items()):
        if isinstance(v, (tuple, list)):
            v = [int(x) for x in v]
        elif isinstance(v, (np.integer,)):
            v = int(v)
        elif isinstance(v, (np.floating,)):
            v = float(v)
        fp[k] = v
    return fp


def save_state(ck, state: SolveState, fingerprint: dict,
               blocking: bool = True) -> int:
    """Snapshot ``state`` at step ``n_rounds`` (monotone across a solve,
    so the LATEST pointer always names the furthest segment)."""
    step = int(np.asarray(state.n_rounds))
    ck.save(step, state.leaves(), blocking=blocking,
            extra_meta={"solve_state": state.aux(),
                        "fingerprint": fingerprint})
    return step


def load_state(ck, fingerprint: dict, step: int | None = None):
    """Load the latest (or ``step``-th) ``SolveState`` from ``ck``.
    Returns ``None`` when the directory holds no checkpoint at all;
    raises :class:`SolveStateMismatch` when one exists but is not a
    solve state or was written under a different configuration."""
    try:
        step, leaves, meta = ck.load(step)
    except FileNotFoundError:
        return None
    extra = meta.get("extra") or {}
    if "solve_state" not in extra:
        raise SolveStateMismatch(
            f"checkpoint step_{step} in {ck.dir} is not a SolveState "
            "snapshot")
    saved_fp = extra.get("fingerprint") or {}
    want = state_fingerprint(**{k: v for k, v in fingerprint.items()
                                if k != "format"})
    if saved_fp != want:
        diff = sorted(k for k in set(saved_fp) | set(want)
                      if saved_fp.get(k) != want.get(k))
        raise SolveStateMismatch(
            "checkpoint was written under a different solve configuration "
            f"(differing keys: {diff}); resuming it would not be "
            "bit-identical — delete the checkpoint directory or rerun "
            "with the original configuration")
    return SolveState.from_leaves(leaves, extra["solve_state"])
