"""Batched multi-cluster trimed engine (DESIGN.md §3).

Generalises the single-medoid block round of :mod:`repro.core.trimed` to
``K`` concurrent per-cluster searches inside one jitted device program —
the medoid-update step of K-medoids (the paper's §5 headline
application) without the per-cluster quadratic scan.

The search state is the logical per-cluster bound family ``l[K, N]``
masked by assignment: element ``i`` belongs to exactly one cluster
``a(i)``, so only the entry ``l[a(i), i]`` of its column is ever live and
the state is stored densely as one ``(N,)`` vector. ``l(i)`` lower-bounds
the *in-cluster sum* ``S(i) = sum_{j : a(j)=a(i)} d(i, j)`` via the
size-scaled triangle bound (the same inequality trikmeds' Alg. 8 uses
host-side):

    S(i) >= | v_k * d(p, i) - S(p) |     for any pivot p with a(p) = k,

where ``v_k`` is the cluster size. Per round:

* **shared candidate selection** — the ``B`` lowest-bound survivors
  *across all clusters* (bounds compared on the mean-distance scale
  ``l / v`` so large clusters do not starve small ones) are packed into
  one ``(B, d)`` pivot block;
* **masked energies** — one matmul-shaped ``(B, N)`` distance pass
  yields each pivot's exact in-cluster sum, with out-of-cluster columns
  masked to zero (fused in VMEM on the Pallas path);
* **scattered tightening** — each pivot's bound information lands only
  in its own cluster's row: elements of other clusters see ``-inf`` in
  the max-reduction.

Exactness per cluster follows from the single-cluster argument
(Theorem 3.1 of the paper applied cluster-wise): bounds only ever take
values the triangle inequality proves valid, and a cluster's search only
terminates when every unexplored member's bound is at or above the
cluster incumbent. Empty clusters report medoid ``-1``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from .distances import chunked_rowsum, pairwise, sq_norms


@dataclass
class BatchedMedoidResult:
    medoids: np.ndarray          # (K,) per-cluster medoid index (-1 = empty)
    sums: np.ndarray             # (K,) in-cluster sum at the medoid
    n_computed: int              # pivot rows computed across all clusters
    n_rounds: int                # shared block rounds
    n_distances: int             # scalar distance evaluations (rows * N)
    n_stages: int = 0            # compaction ladder stages (pipelined only)
    x_cols_streamed: int = 0     # X columns streamed (pipelined only)


def _select_candidates(l, computed, thresh, v_a, block):
    """Top-``block`` surviving candidates across all clusters, scored by
    the size-normalised bound (mean-distance scale). Returns (idx, valid)."""
    survivor = jnp.logical_and(~computed, l < thresh)
    score = jnp.where(survivor, -l / jnp.maximum(v_a, 1.0), -jnp.inf)
    top, idx = jax.lax.top_k(score, block)
    valid = top > -jnp.inf
    return idx, valid


def _round_core(X, x_sq, a, v, k, metric, fused_round_fn, state, idx, valid):
    """One engine round for an already-selected pivot block."""
    l, computed, s_best, m_best, n_computed, n_rounds = state
    a_piv = jnp.take(a, idx)                          # (B,) pivot clusters
    v_piv = jnp.take(v, a_piv).astype(X.dtype)        # (B,) cluster sizes
    xb = jnp.take(X, idx, axis=0)                     # (B, d) pivot block

    if fused_round_fn is not None:
        # Pallas fused path: masked (B, N) block never materialised in HBM.
        s_blk, l = fused_round_fn(xb, X, l, valid, a_piv, a, v_piv)
    else:
        d_blk = pairwise(xb, X, metric, a_sq=jnp.take(x_sq, idx), b_sq=x_sq)
        same = a_piv[:, None] == a[None, :]           # (B, N) cluster mask
        # in-cluster sums on the fixed reduction grid (distances.py §11)
        s_blk = chunked_rowsum(jnp.where(same, d_blk, 0.0))
        gap = jnp.abs(d_blk * v_piv[:, None] - s_blk[:, None])
        gap = jnp.where(jnp.logical_and(same, valid[:, None]), gap, -jnp.inf)
        l = jnp.maximum(l, gap.max(axis=0))

    s_blk = jnp.where(valid, s_blk, jnp.inf)
    n = X.shape[0]

    # per-cluster incumbent update: exact argmin over this round's pivots
    # via a (K, B) masked view (K and B are both small).
    per_k = jnp.where(
        jnp.logical_and(a_piv[None, :] == jnp.arange(k)[:, None],
                        valid[None, :]),
        s_blk[None, :], jnp.inf,
    )
    r_min = per_k.min(axis=1)
    r_arg = jnp.take(idx, per_k.argmin(axis=1))
    better = r_min < s_best
    s_best = jnp.where(better, r_min, s_best)
    m_best = jnp.where(better, r_arg, m_best)

    # computed pivots now carry their exact (tight) bound. Invalid slots
    # are routed to index n and dropped — a duplicate-index scatter with
    # conflicting values has an unspecified winner in XLA, and the warm
    # round tiles its seed list to the block width (duplicates, invalid).
    safe_idx = jnp.where(valid, idx, n)
    l = l.at[safe_idx].set(s_blk, mode="drop")
    computed = computed.at[safe_idx].set(True, mode="drop")
    n_computed = n_computed + valid.sum()
    return (l, computed, s_best, m_best, n_computed, n_rounds + 1)


def _round_body(X, x_sq, a, v, k, metric, block, fused_round_fn, state):
    l, computed, s_best, m_best = state[0], state[1], state[2], state[3]
    thresh = jnp.take(s_best, a)                      # per-element threshold
    v_a = jnp.take(v, a).astype(X.dtype)
    idx, valid = _select_candidates(l, computed, thresh, v_a, block)
    return _round_core(X, x_sq, a, v, k, metric, fused_round_fn, state,
                       idx, valid)


def batched_medoids_jit(X, a, k, block, metric="l2", fused_round_fn=None,
                        warm_idx=None, warm=()):
    """Traceable core (no jit wrapper of its own — callers embed it):
    returns ``(m_best, s_best, n_computed, n_rounds)`` as device values.
    ``warm_idx`` (K,) seeds round 0 with known-good pivots (e.g. the
    previous iteration's medoids inside K-medoids), giving a strong
    elimination threshold before any bound exists. ``warm`` (static
    tuple) prepends a geometric warm-up of small selection rounds — the
    adaptive block schedule of DESIGN.md §4 — used when no ``warm_idx``
    is available."""
    n = X.shape[0]
    x_sq = sq_norms(X) if metric in ("l2", "sqeuclidean") else jnp.zeros(
        n, X.dtype)
    a = a.astype(jnp.int32)
    # out-of-range labels start "computed": they belong to no cluster,
    # must never be selected as pivots, and can never be medoids. They
    # must also not count toward any cluster's size — a raw scatter
    # would wrap negative labels to k-1 (mode="drop" only drops
    # too-large indices), corrupting the size-scaled triangle bound.
    oob = jnp.logical_or(a < 0, a >= k)
    v = jnp.zeros(k, jnp.int32).at[jnp.where(oob, k, a)].add(
        1, mode="drop")                                    # cluster sizes
    state = (
        jnp.zeros(n, X.dtype),                        # l
        oob,                                          # computed
        jnp.full((k,), jnp.inf, X.dtype),             # s_best
        jnp.full((k,), -1, jnp.int32),                # m_best
        jnp.asarray(0, jnp.int32),                    # n_computed
        jnp.asarray(0, jnp.int32),                    # n_rounds
    )

    if warm_idx is not None:
        # warm round: pad/clip the K seeds to the block width
        w = jnp.resize(warm_idx.astype(jnp.int32), (block,))
        w_valid = jnp.arange(block) < min(k, block)
        # a seed for an empty cluster contributes nothing useful but is
        # harmless: its masked sum is a valid incumbent for whatever
        # cluster the seed actually belongs to
        state = _round_core(X, x_sq, a, v, k, metric, fused_round_fn,
                            state, w, w_valid)
    for b in warm:                                # unrolled warm-up rounds
        l, computed, s_best = state[0], state[1], state[2]
        thresh = jnp.take(s_best, a)
        v_a = jnp.take(v, a).astype(X.dtype)
        idx, valid = _select_candidates(l, computed, thresh, v_a, b)
        state = _round_core(X, x_sq, a, v, k, metric, fused_round_fn,
                            state, idx, valid)

    def cond(state):
        l, computed, s_best = state[0], state[1], state[2]
        thresh = jnp.take(s_best, a)
        return jnp.any(jnp.logical_and(~computed, l < thresh))

    body = functools.partial(_round_body, X, x_sq, a, v, k, metric, block,
                             fused_round_fn)
    state = jax.lax.while_loop(cond, body, state)
    _, _, s_best, m_best, n_computed, n_rounds = state
    return m_best, s_best, n_computed, n_rounds


@functools.partial(
    jax.jit,
    static_argnames=("k", "block", "metric", "fused_round_fn", "warm",
                     "warm_blocks"),
)
def _batched_medoids_entry(X, a, k, block, metric, fused_round_fn, warm,
                           warm_idx, warm_blocks=()):
    return batched_medoids_jit(X, a, k, block, metric, fused_round_fn,
                               warm_idx if warm else None,
                               warm=warm_blocks)


def _batched_medoids(
    X,
    assignment,
    k: int,
    block: int = 128,
    metric: str = "l2",
    fused_round_fn: Callable | None = None,
    warm_idx=None,
    block_schedule=None,
) -> BatchedMedoidResult:
    """Exact per-cluster medoids of ``X`` under ``assignment`` (values in
    ``[0, k)``; out-of-range labels are excluded from every cluster and
    never explored), all K searches batched into one device program.
    ``fused_round_fn`` (see ``repro.kernels.ops.fused_masked_round``)
    replaces the jnp round with the Pallas assignment-masked kernels.
    ``block_schedule="geometric"`` prepends the adaptive warm-up of small
    selection rounds (DESIGN.md §4; cost only, never exactness).

    Only triangle-inequality metrics are admissible — the elimination
    bound is the triangle bound. ``sqeuclidean`` and ``cosine`` (as
    1-cos) violate it and would silently return wrong medoids, so they
    are rejected here."""
    from repro.api.metrics import require_metric
    require_metric(metric, need_triangle=True, caller="batched_medoids")
    from .pipelined import resolve_schedule

    X = jnp.asarray(X)
    n = X.shape[0]
    block = int(min(block, n))
    warm = warm_idx is not None
    warm_arr = (jnp.asarray(warm_idx, jnp.int32) if warm
                else jnp.zeros((k,), jnp.int32))
    warm_blocks = resolve_schedule(block_schedule, block)
    m, s, n_comp, n_rounds = _batched_medoids_entry(
        X, jnp.asarray(assignment), k, block, metric, fused_round_fn,
        warm, warm_arr, warm_blocks=warm_blocks,
    )
    return BatchedMedoidResult(
        np.asarray(m), np.asarray(s), int(n_comp), int(n_rounds),
        int(n_comp) * n,
    )


# ---------------------------------------------------------------------------
# legacy entrypoint shim (deprecated — repro.api.solve is the front door)
# ---------------------------------------------------------------------------
def batched_medoids(
    X,
    assignment,
    k: int,
    block: int = 128,
    metric: str = "l2",
    fused_round_fn: Callable | None = None,
    warm_idx=None,
    block_schedule=None,
) -> BatchedMedoidResult:
    """**Deprecated** shim over ``solve(MedoidQuery(..., assignments=...),
    plan="batched")``."""
    from repro.api import MedoidQuery, solve, _warn_legacy
    _warn_legacy("batched_medoids", " (plan='batched')")
    opts = {}
    if fused_round_fn is not None:
        opts["fused_round_fn"] = fused_round_fn
    # use_kernels pinned False: the legacy kernel opt-in was
    # fused_round_fn=, and the shim contract is bit-identical results
    q = MedoidQuery(X, metric=metric, k=k, assignments=assignment,
                    block=block, block_schedule=block_schedule,
                    use_kernels=False, warm_idx=warm_idx, engine_opts=opts)
    return solve(q, plan="batched").extras["raw"]
