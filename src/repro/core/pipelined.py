"""Survivor-compacted, software-pipelined elimination engine (DESIGN.md §4).

The block engine in :mod:`repro.core.trimed` streams ``X`` from HBM twice
per round (energies, then bound tightening) and tightens bounds over all
``N`` columns even when only a sliver of survivors remains. This module
rebuilds that hot path in three composable layers:

1. **Software pipelining** — the bound update for a pivot block needs its
   energies only *after* the full row sums, so it is delayed one round:
   round ``t``'s single pass over ``X`` computes round-``t`` energies and
   folds round-``t-1``'s (now known) energies into the bound vector. One
   X-stream per steady-state round instead of two. Delayed bounds are
   still triangle-valid lower bounds, so exactness is untouched; the only
   cost is a bounded number of extra computed elements. On the Pallas
   path (``use_kernels=True``) the two halves share one fused kernel
   (``kernels.ops.pipelined_round``) and nothing ``(B, N)``-shaped ever
   touches HBM. The jnp path instead *carries* the previous round's
   distance block in the loop state (the materialise trade its block
   round already makes), which lets it fold before selection — same
   bound values, no selection lag, no recompute.

2. **Survivor compaction** — the survivor set shrinks geometrically (the
   paper's Theorem 3.2 is exactly this claim), so the engine keeps a
   device-side compacted survivor buffer: candidate ``top_k``, the loop
   predicate, and bound tightening run over ``M`` survivors instead of
   ``N``. The energy pass alone still streams all ``N`` columns — that is
   the exactness-mandated floor (an energy is a sum over *every*
   element). The buffer is re-compacted whenever the live count falls
   below half its size, onto a geometric ladder of power-of-two padded
   sizes so the number of distinct compiled stage shapes is ``O(log N)``.
   Once eliminated, always eliminated (the incumbent only tightens), so
   dropped entries never need revisiting.

3. **Adaptive block schedule** — an opt-in geometric warm-up
   (``block_schedule="geometric"``: blocks of 8, 16, ... up to the
   steady-state width) establishes an incumbent ``E_cl`` cheaply before
   wide blocks commit, matching the paper's observation that the first
   few anchors do most of the elimination. Measured on this container it
   pays on clustered data (fewer computed rows and lower wall-clock) and
   costs a few extra thin rounds on uniform data, hence opt-in.
   Schedules affect cost, never exactness.

Exactness oracle: parity with :func:`repro.core.trimed.trimed_sequential`
(pinned by ``tests/test_pipelined.py``). The multi-cluster variant
:func:`batched_medoids_pipelined` applies the same three layers to the
K-concurrent engine of :mod:`repro.core.batched`.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.api.metrics import require_metric
from repro.kernels import ops as _ops

from .batched import BatchedMedoidResult
from .distances import (chunked_rowsum, pairwise, pow2_at_least,
                        sq_norms)
from .trimed import MedoidResult

LADDER_MIN = 256     # survivor buffers never shrink below this size
NEG_INF = -jnp.inf


# ---------------------------------------------------------------------------
# adaptive block schedule
# ---------------------------------------------------------------------------
def warmup_schedule(block: int, start: int = 8) -> tuple:
    """Geometric warm-up block sizes ``(start, 2*start, ..., < block)``."""
    sizes = []
    b = start
    while b < block:
        sizes.append(b)
        b *= 2
    return tuple(sizes)


def resolve_schedule(block_schedule, block: int) -> tuple:
    """Normalise a schedule spec into a tuple of warm-up block widths.

    ``"geometric"`` -> doubling warm-up; ``"flat"``/``None`` -> no
    warm-up; an iterable of ints is taken verbatim (clipped to
    ``< block``)."""
    if block_schedule in (None, "flat"):
        return ()
    if block_schedule == "geometric":
        return warmup_schedule(block)
    if isinstance(block_schedule, str):
        raise ValueError(
            f"unknown block_schedule {block_schedule!r}; expected "
            "'geometric', 'flat'/None, or an iterable of block widths")
    return tuple(int(b) for b in block_schedule if 0 < int(b) < block)


def _masked_colmax(gap, mask_rows):
    """Row-masked column max that is safe for zero-row operands."""
    gap = jnp.where(mask_rows[:, None], gap, NEG_INF)
    return gap.max(axis=0, initial=NEG_INF)


# ---------------------------------------------------------------------------
# single-medoid engine
# ---------------------------------------------------------------------------
def _incumbent(e_blk, idx, e_cl, m_cl):
    b_best = jnp.argmin(e_blk)
    better = e_blk[b_best] < e_cl
    e_cl = jnp.where(better, e_blk[b_best], e_cl)
    m_cl = jnp.where(better, idx[b_best], m_cl)
    return e_cl, m_cl


def _budget_cap(valid, n_comp, budget):
    """Zero out the trailing valid pivots that would push the computed-row
    count past ``budget`` (top_k order: the most promising survive)."""
    rank = jnp.cumsum(valid.astype(jnp.int32))
    return jnp.logical_and(valid, n_comp + rank <= budget)


def _pipe_round0(X, x_sq, n, metric, use_kernels, interpret, budget, state,
                 b, forced_idx=None, forced_valid=None):
    """One full-domain pipelined round at (static) block width ``b``.

    Kernel path: a single fused stream of ``X`` computes this block's
    energies and folds the *previous* block's bounds (select-then-fold —
    bounds lag one round). jnp path: the previous block's distance rows
    ride the loop carry, so the fold is elementwise and happens *before*
    selection (no lag). ``forced_idx`` overrides candidate selection (the
    warm-seed round used by the bandit hybrid's finisher).

    The trailing carry slot ``esum`` is the per-row **energy cache**
    (DESIGN.md §15): every computed pivot's raw ``S(i)`` column sum,
    scattered as a side buffer so the persisted ``SolveState`` carries
    the exact contributions streaming churn repair delta-adjusts. It
    never feeds back into the round math — bit-identity of the
    elimination sequence is untouched."""
    (l, alive, e_cl, m_cl, pidx, pe, pv, dprev, n_comp, n_rounds,
     esum) = state

    if not use_kernels:
        # fold previous block from the carried rows, then select
        l = jnp.maximum(l, _masked_colmax(jnp.abs(pe[:, None] - dprev), pv))

    if forced_idx is None:
        score = jnp.where(jnp.logical_and(alive, l < e_cl), -l, NEG_INF)
        top, idx = jax.lax.top_k(score, b)
        valid = top > NEG_INF
    else:
        idx, valid = forced_idx, forced_valid
    valid = _budget_cap(valid, n_comp, budget)
    xb = jnp.take(X, idx, axis=0)

    if use_kernels:
        if pidx.shape[0] == 0:       # first round: no previous block yet
            e_sums = _ops.block_energies(xb, X, metric=metric,
                                         interpret=interpret)
        else:
            xbp = jnp.take(X, pidx, axis=0)
            e_sums, l = _ops.pipelined_round(xb, xbp, X, pe, pv, l,
                                             metric=metric,
                                             interpret=interpret)
        dnew = dprev                                  # unused carry (0, N)
    else:
        dnew = pairwise(xb, X, metric, a_sq=jnp.take(x_sq, idx), b_sq=x_sq)
        # fixed reduction geometry (distances.py): keeps energies
        # bit-identical to the sharded engine's gathered chunk partials
        e_sums = chunked_rowsum(dnew)

    e_blk = jnp.where(valid, e_sums / n, jnp.inf)
    e_cl, m_cl = _incumbent(e_blk, idx, e_cl, m_cl)
    alive = alive.at[idx].set(jnp.where(valid, False, alive[idx]))
    n_comp = n_comp + valid.sum()
    pe = jnp.where(valid, e_blk, 0.0)
    # energy cache: invalid slots route out of bounds and drop
    esum = esum.at[jnp.where(valid, idx, n)].set(e_sums, mode="drop")
    return (l, alive, e_cl, m_cl, idx, pe, valid, dnew, n_comp,
            n_rounds + 1, esum)


def _pad_prev(state, block, has_carry):
    """Pad the previous-block carry up to the steady-state width so the
    while_loop state shape is invariant."""
    (l, alive, e_cl, m_cl, pidx, pe, pv, dprev, n_comp, n_rounds,
     esum) = state
    pad = block - pidx.shape[0]
    if pad:
        pidx = jnp.pad(pidx, (0, pad))
        pe = jnp.pad(pe, (0, pad))
        pv = jnp.pad(pv, (0, pad))
        if has_carry:
            dprev = jnp.pad(dprev, ((0, pad), (0, 0)))
    return (l, alive, e_cl, m_cl, pidx, pe, pv, dprev, n_comp, n_rounds,
            esum)


def _live_count(l, alive, e_cl):
    return jnp.logical_and(alive, l < e_cl).sum()


# ---------------------------------------------------------------------------
# in-loop telemetry recording (DESIGN.md §14)
#
# Per-round trace events are recorded *inside* the jitted round loop into
# fixed-size device buffers (one slot per round of the segment) and
# drained by the host at the segment boundary it already synchronises
# on. This keeps tracing out of the host loop entirely: a traced solve
# segments every ``_SEG_DEFAULT`` rounds instead of every round, and the
# per-round values ride along for free. ``rec_len`` is static — when 0
# (tracing off) the loop functions compile to the exact program they
# were before telemetry existed.
# ---------------------------------------------------------------------------
_REC_SAMPLE = 256    # interior quartiles sort at most this many entries


def _rec_init(rec_len, dtype):
    """One traced segment's telemetry buffers, packed into two arrays
    (one int scatter + one float scatter per round keeps the recording
    out of the round's critical path): ``[live, incumbent, elements]``
    and ``[e_cl, l_mean, l_min, l_q25, l_q50, l_q75, l_max]``."""
    return (jnp.zeros((rec_len, 3), jnp.int32),
            jnp.zeros((rec_len, 7), dtype))


def _rec_write(state, rec, seg_start):
    """Record the just-finished round (slot ``n_rounds - seg_start - 1``;
    state indices 0-3/8-9 are shared by the full and ladder carries).

    The bound summary is the device-side analogue of
    :func:`repro.obs.trace.l_summary` over the live mask:
    ``min``/``max``/``mean`` are exact O(M) reductions (``l >= 0``, so
    the zero-filled select is max-safe); the interior quartiles
    interpolate a deterministic strided sample of at most
    ``_REC_SAMPLE`` entries — a full per-round sort would cost more
    than the round's own bound work. Ordering
    ``min <= q25 <= q50 <= q75 <= max`` still holds (sample values are
    bracketed by the exact extremes); if the sample misses every live
    entry (tiny tail of survivors) the quartiles collapse to the
    midpoint of the exact extremes."""
    i = state[9] - seg_start - 1
    l, alive, e_cl = state[0], state[1], state[2]
    mask = jnp.logical_and(alive, l < e_cl)
    live = mask.sum()
    vals = jnp.where(mask, l, jnp.inf)
    zeros = jnp.where(mask, l, 0)
    mn = vals.min()
    mx = zeros.max()
    mean = zeros.sum() / jnp.maximum(live, 1).astype(l.dtype)
    m = vals.shape[0]
    if m > _REC_SAMPLE:
        vals = vals[:: m // _REC_SAMPLE][:_REC_SAMPLE]
    s = jnp.sort(vals)
    live_s = (s < jnp.inf).sum()
    hi = jnp.maximum(live_s - 1, 0).astype(l.dtype)
    pos = jnp.asarray((0.25, 0.5, 0.75), l.dtype) * hi
    lo_i = jnp.floor(pos).astype(jnp.int32)
    hi_i = jnp.ceil(pos).astype(jnp.int32)
    frac = pos - lo_i.astype(l.dtype)
    q = s[lo_i] * (1 - frac) + s[hi_i] * frac
    q = jnp.where(live_s > 0, q, (mn + mx) / 2)
    ints = jnp.stack([live.astype(jnp.int32), state[3], state[8]])
    flts = jnp.concatenate(
        [jnp.stack([e_cl, mean, mn]).astype(l.dtype), q, mx[None]])
    return (rec[0].at[i].set(ints), rec[1].at[i].set(flts))


@functools.partial(
    jax.jit,
    static_argnames=("block", "warm", "metric", "use_kernels", "interpret",
                     "has_warm_idx"),
)
def _stage0_init(X, l0, warm_arr, budget, block, warm, metric, use_kernels,
                 interpret, has_warm_idx):
    """Full-domain stage prologue: initial state + warm-up rounds, padded
    to the steady-state carry shape. ``l0`` seeds the bound vector (zeros
    for the certified path; the bandit hand-off may seed probabilistic
    lower bounds); ``warm_arr`` forces the first pivot block."""
    n = X.shape[0]
    x_sq = (sq_norms(X) if metric in ("l2", "sqeuclidean")
            else jnp.zeros(n, X.dtype))
    state = (
        l0.astype(X.dtype),                       # l
        jnp.ones(n, bool),                        # alive (= not computed)
        jnp.asarray(jnp.inf, X.dtype),            # e_cl
        jnp.asarray(-1, jnp.int32),               # m_cl
        jnp.zeros(0, jnp.int32),                  # prev idx (empty: round 0)
        jnp.zeros(0, X.dtype),                    # prev energies
        jnp.zeros(0, bool),                       # prev valid
        jnp.zeros((0, n), X.dtype),               # prev rows (jnp carry)
        jnp.asarray(0, jnp.int32),                # n_computed
        jnp.asarray(0, jnp.int32),                # n_rounds
        jnp.zeros(n, X.dtype),                    # esum energy cache
    )
    round_fn = functools.partial(_pipe_round0, X, x_sq, n, metric,
                                 use_kernels, interpret, budget)
    if has_warm_idx:
        bw = warm_arr.shape[0]
        state = round_fn(state, bw, forced_idx=warm_arr,
                         forced_valid=jnp.ones(bw, bool))
    for b in warm:                                # unrolled warm-up
        state = round_fn(state, b)
    return _pad_prev(state, block, has_carry=not use_kernels)


@functools.partial(
    jax.jit,
    static_argnames=("block", "metric", "use_kernels", "interpret",
                     "can_compact", "rec_len"),
)
def _stage0_loop(X, state, budget, seg_cap, block, metric, use_kernels,
                 interpret, can_compact, rec_len=0):
    """One full-domain *segment*: steady rounds until the live count
    drops below N/2 (compaction trigger), the computed-row budget is
    spent, no survivor remains, or ``seg_cap`` rounds have run since
    entry (the host-visibility boundary — ``seg_cap`` is traced, so the
    segmented and straight-through paths share one compiled program and
    the per-round math is identical either way). Returns the final
    state plus the live count; ``rec_len > 0`` (tracing) additionally
    returns per-round telemetry buffers of that many slots."""
    n = X.shape[0]
    x_sq = (sq_norms(X) if metric in ("l2", "sqeuclidean")
            else jnp.zeros(n, X.dtype))
    round_fn = functools.partial(_pipe_round0, X, x_sq, n, metric,
                                 use_kernels, interpret, budget)
    seg_start = state[9]

    def cond(state):
        live = _live_count(state[0], state[1], state[2])
        go = jnp.logical_and(live > 0, state[8] < budget)
        go = jnp.logical_and(go, state[9] - seg_start < seg_cap)
        if can_compact:
            return jnp.logical_and(go, 2 * live > n)
        return go

    if rec_len:
        def body(carry):
            s, rec = carry
            s = round_fn(s, block)
            return s, _rec_write(s, rec, seg_start)

        state, rec = jax.lax.while_loop(
            lambda c: cond(c[0]), body,
            (state, _rec_init(rec_len, X.dtype)))
        return state, _live_count(state[0], state[1], state[2]), rec
    state = jax.lax.while_loop(cond, lambda s: round_fn(s, block), state)
    return state, _live_count(state[0], state[1], state[2])


def _compact(X, surv_idx, l_s, alive_s, e_cl, m_out):
    """Cumsum-scatter the live entries into a fresh ``m_out``-sized
    buffer and gather their vectors (the stage-resident ``Xs``)."""
    keep = jnp.logical_and(alive_s, l_s < e_cl)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    tgt = jnp.where(keep, pos, m_out)             # dead entries -> dropped
    new_idx = jnp.zeros(m_out, jnp.int32).at[tgt].set(surv_idx, mode="drop")
    new_l = jnp.full(m_out, jnp.inf, l_s.dtype).at[tgt].set(l_s, mode="drop")
    new_alive = jnp.zeros(m_out, bool).at[tgt].set(True, mode="drop")
    Xs = jnp.take(X, new_idx, axis=0)
    return new_idx, new_l, new_alive, Xs


def _stage_round(X, Xs, surv_idx, x_sq, n, metric, use_kernels,
                 interpret, budget, block, state):
    """One compacted-stage round: fold the previous block's bounds over
    the ``M`` survivor columns, then stream ``X`` once for the new
    block's exact energies."""
    (l_s, alive_s, e_cl, m_cl, pidx, pe, pv, dprev_s, n_comp, n_rounds,
     fold_cols, esum) = state
    m = Xs.shape[0]

    # 1. fold previous block — bound tightening over M, not N
    if use_kernels:
        xbp = jnp.take(X, pidx, axis=0)
        l_s = _ops.bound_update(xbp, Xs, pe, pv, l_s, metric=metric,
                                interpret=interpret)
    else:
        l_s = jnp.maximum(
            l_s, _masked_colmax(jnp.abs(pe[:, None] - dprev_s), pv))
    fold_cols = fold_cols + m

    # 2. candidate top_k over M survivors
    score = jnp.where(jnp.logical_and(alive_s, l_s < e_cl), -l_s, NEG_INF)
    top, pos = jax.lax.top_k(score, block)
    valid = _budget_cap(top > NEG_INF, n_comp, budget)
    idx = jnp.take(surv_idx, pos)
    xb = jnp.take(X, idx, axis=0)

    # 3. exact energies — the one full stream of X this round
    if use_kernels:
        e_sums = _ops.block_energies(xb, X, metric=metric,
                                     interpret=interpret)
        dnew_s = dprev_s                              # unused carry (0, M)
    else:
        dnew = pairwise(xb, X, metric, a_sq=jnp.take(x_sq, idx), b_sq=x_sq)
        e_sums = chunked_rowsum(dnew)                 # fixed grid (§11)
        dnew_s = jnp.take(dnew, surv_idx, axis=1)     # rows at survivors
    e_blk = jnp.where(valid, e_sums / n, jnp.inf)

    e_cl, m_cl = _incumbent(e_blk, idx, e_cl, m_cl)
    alive_s = alive_s.at[pos].set(jnp.where(valid, False, alive_s[pos]))
    n_comp = n_comp + valid.sum()
    pe = jnp.where(valid, e_blk, 0.0)
    # energy cache at *global* row indices (idx may alias the buffer's
    # empty-slot zeros when invalid — route those out of bounds)
    esum = esum.at[jnp.where(valid, idx, n)].set(e_sums, mode="drop")
    return (l_s, alive_s, e_cl, m_cl, idx, pe, valid, dnew_s, n_comp,
            n_rounds + 1, fold_cols, esum)


@functools.partial(
    jax.jit,
    static_argnames=("m_out", "metric", "use_kernels", "interpret"),
)
def _stage_enter(X, surv_idx, l_s, alive_s, e_cl, pidx, m_out, metric,
                 use_kernels, interpret):
    """Ladder-rung entry: compact the live survivors into an
    ``m_out``-sized buffer and re-seed the previous-block distance carry.
    Split from the round loop so a resume never re-runs compaction
    (``top_k`` tie-breaks by buffer position — re-compacting mid-rung
    would change the pivot sequence and break bit-identity)."""
    n = X.shape[0]
    surv_idx, l_s, alive_s, Xs = _compact(X, surv_idx, l_s, alive_s, e_cl,
                                          m_out)
    if use_kernels:
        dprev_s = jnp.zeros((0, m_out), X.dtype)
    else:
        x_sq = (sq_norms(X) if metric in ("l2", "sqeuclidean")
                else jnp.zeros(n, X.dtype))
        xs_sq = (sq_norms(Xs) if metric in ("l2", "sqeuclidean")
                 else jnp.zeros(m_out, Xs.dtype))
        # one (B, M) block at stage entry re-seeds the carried rows
        dprev_s = pairwise(jnp.take(X, pidx, axis=0), Xs, metric,
                           a_sq=jnp.take(x_sq, pidx), b_sq=xs_sq)
    return surv_idx, l_s, alive_s, dprev_s


@functools.partial(
    jax.jit,
    static_argnames=("block", "metric", "use_kernels", "interpret",
                     "is_floor", "rec_len"),
)
def _stage_loop(X, surv_idx, state, budget, seg_cap, block, metric,
                use_kernels, interpret, is_floor, rec_len=0):
    """One compacted-stage *segment*: rounds until the next ladder
    trigger, termination, or ``seg_cap`` rounds since entry (the
    host-visibility boundary). ``Xs`` is re-gathered from ``surv_idx``
    — a deterministic gather, bit-identical to the compaction's.
    ``rec_len > 0`` (tracing) additionally returns per-round telemetry
    buffers of that many slots."""
    n = X.shape[0]
    m = surv_idx.shape[0]
    Xs = jnp.take(X, surv_idx, axis=0)
    x_sq = (sq_norms(X) if metric in ("l2", "sqeuclidean")
            else jnp.zeros(n, X.dtype))
    seg_start = state[9]

    def cond(state):
        live = _live_count(state[0], state[1], state[2])
        go = jnp.logical_and(live > 0, state[8] < budget)
        go = jnp.logical_and(go, state[9] - seg_start < seg_cap)
        if is_floor:
            return go
        return jnp.logical_and(go, 4 * live > m)

    body = functools.partial(_stage_round, X, Xs, surv_idx, x_sq, n,
                             metric, use_kernels, interpret, budget, block)
    if rec_len:
        def body2(carry):
            s, rec = carry
            s = body(s)
            return s, _rec_write(s, rec, seg_start)

        state, rec = jax.lax.while_loop(
            lambda c: cond(c[0]), body2,
            (state, _rec_init(rec_len, X.dtype)))
        return state, _live_count(state[0], state[1], state[2]), rec
    state = jax.lax.while_loop(cond, body, state)
    return state, _live_count(state[0], state[1], state[2])


def _as_checkpointer(checkpoint):
    if checkpoint is None:
        return None
    from repro.checkpoint.checkpoint import Checkpointer
    if isinstance(checkpoint, Checkpointer):
        return checkpoint
    return Checkpointer(str(checkpoint))


_SEG_DEFAULT = 16    # rounds per segment when segmenting is on


def _trimed_pipelined(
    X,
    seed: int = 0,
    block: int = 128,
    metric: str = "l2",
    block_schedule=None,
    ladder_min: int = LADDER_MIN,
    use_kernels: bool = False,
    interpret=None,
    warm_idx=None,
    l_init=None,
    max_computed: int | None = None,
    checkpoint=None,
    checkpoint_every: int | None = None,
    resume: str = "auto",
    deadline_ts: float | None = None,
    heartbeat_timeout_s: float | None = None,
    trace=None,
) -> MedoidResult:
    """Exact medoid via the survivor-compacted, software-pipelined engine
    (DESIGN.md §4). One X-stream per steady-state round; bound
    tightening, candidate selection and the loop predicate shrink with
    the survivor set. ``use_kernels=True`` runs the rounds through the
    Pallas kernels (``kernels.ops.pipelined_round`` et al.); the jnp
    path computes identical bound values while carrying the previous
    distance block instead of recomputing it.

    Three hooks serve the bandit hybrid (DESIGN.md §9) — all of them
    affect *cost only*, never the triangle-bound elimination logic,
    except ``l_init`` which is the caller's promise:

    * ``warm_idx`` — force these elements (deduplicated, at most one
      block's worth) to be the first computed pivot block, establishing
      an incumbent before regular lowest-bound selection takes over.
    * ``l_init`` — seed the lower-bound vector. Entries must be valid
      lower bounds on the internal ``E = S/N`` energies for the result
      to stay exact; the bandit passes its (probabilistic) LCBs here
      only on the explicitly opt-in ``seed_bounds`` path.
    * ``max_computed`` — hard cap on computed rows. When the cap halts
      elimination early the result carries ``certified=False`` and the
      incumbent (whose energy is exact — its full row was computed) is
      returned as the best-so-far.

    Fault-tolerant runtime hooks (DESIGN.md §13) — when any is active
    the elimination loop runs in host-visible **segments** of
    ``checkpoint_every`` rounds (default: one round when a deadline or
    heartbeat asks for interruptibility, 16 for pure checkpointing);
    segmentation never changes the round sequence (the per-round math
    is an identical compiled program, only the host observes the state
    more often):

    * ``checkpoint`` — a directory path or
      :class:`~repro.checkpoint.checkpoint.Checkpointer`; every segment
      boundary snapshots the full :class:`~repro.core.solve_state
      .SolveState`, and a killed solve restarted with the same
      checkpoint resumes **bit-identically** (same pivot sequence, same
      index/energy/element count as the uninterrupted run).
    * ``resume`` — ``"auto"`` (resume if a state exists), ``"never"``
      (start fresh, overwriting), ``"require"`` (error if nothing to
      resume). A config-fingerprint mismatch always refuses.
    * ``deadline_ts`` — absolute time (``faults.clock()`` scale) after
      which the solve halts at the next segment boundary and returns
      the incumbent as an anytime result (``certified=False``,
      ``halt_reason="deadline"``, with the bound gap in ``lo_bound``).
      Never raises; at least one segment always runs.
    * ``heartbeat_timeout_s`` — arm a :class:`~repro.runtime.faults
      .RoundWatchdog`; if segments stop beating for this long (by the
      fault clock) the solve halts as ``halt_reason="stalled"``.
    * ``trace`` — a :class:`~repro.obs.trace.SolveTracer` (or path /
      ``True``, see :func:`~repro.obs.trace.resolve_trace`): emit one
      deterministic elimination-curve event per segment boundary
      (DESIGN.md §14). Tracing reuses the segment machinery — values
      are read at boundaries the host already observes, and with
      ``trace=None`` the segmentation condition (and hence the
      compiled program) is exactly what it was without this knob.

    Only triangle-inequality metrics are admissible (the elimination
    bound is the triangle bound)."""
    del seed  # selection is deterministic (lowest-bound); kept for API parity
    require_metric(metric, need_triangle=True, caller="trimed_pipelined")
    from repro.core.solve_state import (PHASE_FULL, PHASE_LADDER,
                                        SolveState, load_state, save_state,
                                        state_fingerprint)
    from repro.runtime import faults

    X = jnp.asarray(X)
    n = X.shape[0]
    if n == 1:
        return MedoidResult(0, 0.0, 1, 0, 1)
    block = int(min(block, n))
    warm = resolve_schedule(block_schedule, block)
    floor = max(int(ladder_min), block)
    can_compact = n > floor
    budget_host = (2**31 - 1 if max_computed is None
                   else max(int(max_computed), 0))
    budget_host = faults.effective_budget(budget_host)
    budget = jnp.asarray(budget_host, jnp.int32)
    l0 = (jnp.zeros(n, X.dtype) if l_init is None
          else jnp.maximum(jnp.asarray(l_init, X.dtype), 0.0))
    has_warm_idx = warm_idx is not None
    if has_warm_idx:
        # dedup preserving the caller's ranking (first occurrence wins) —
        # under a budget cap the leading pivots are the ones computed
        w = np.asarray(warm_idx, np.int64)
        _, first = np.unique(w, return_index=True)
        warm_arr = jnp.asarray(w[np.sort(first)][:block], jnp.int32)
    else:
        warm_arr = jnp.zeros((1,), jnp.int32)

    # ---- fault-tolerant runtime plumbing (all inert by default) ----
    ck = _as_checkpointer(checkpoint)
    if resume not in ("auto", "never", "require"):
        raise ValueError(f"resume must be 'auto', 'never' or 'require', "
                         f"got {resume!r}")
    from repro.obs.trace import _finite as _tfin
    from repro.obs.trace import resolve_trace
    tracer = resolve_trace(trace)
    segmented = (ck is not None or deadline_ts is not None
                 or heartbeat_timeout_s is not None or faults.active()
                 or tracer is not None)
    if checkpoint_every is None:
        # deadline/heartbeat callers asked for interruptibility: check
        # every round. A tracer records per-round telemetry *inside*
        # the jitted loop (rec_len below), so it only needs boundaries
        # at drain granularity — like pure checkpointing it amortises
        # the host sync over _SEG_DEFAULT rounds unless the tracer
        # asked for a specific cadence.
        if deadline_ts is not None or heartbeat_timeout_s is not None:
            checkpoint_every = 1
        elif tracer is not None:
            checkpoint_every = tracer.every or _SEG_DEFAULT
        else:
            checkpoint_every = _SEG_DEFAULT
    seg_cap = jnp.asarray(
        max(int(checkpoint_every), 1) if segmented else 2**31 - 1,
        jnp.int32)
    fp = state_fingerprint(
        n=n, d=int(X.shape[1]), dtype=str(X.dtype), metric=metric,
        block=block, use_kernels=bool(use_kernels),
        ladder_min=int(ladder_min), budget=budget_host, warm=warm,
        has_warm_idx=has_warm_idx)
    st = None
    if ck is not None and resume in ("auto", "require"):
        st = load_state(ck, fp)
        if st is None and resume == "require":
            raise FileNotFoundError(
                f"resume='require' but no SolveState checkpoint in "
                f"{ck.dir}")
    wd = (faults.RoundWatchdog(heartbeat_timeout_s, sink=tracer)
          if heartbeat_timeout_s is not None else None)
    d1 = max(n - 1, 1)
    if tracer is not None:
        tracer.begin(engine="pipelined", n=n, d=int(X.shape[1]),
                     metric=metric, block=block,
                     resumed=st is not None,
                     elements=int(st.n_comp) if st is not None else 0,
                     round_base=int(st.n_rounds) if st is not None else -1)

    rec_len = int(max(checkpoint_every, 1)) if tracer is not None else 0

    def _drain(phase, rec, r0, r1):
        """Emit one elimination-curve event per round recorded in the
        segment's device buffers. Runs after the checkpoint save and
        *before* the fault hook (like the save itself), so a kill at
        this boundary leaves the segment's events on disk and a resumed
        run appends the byte-identical continuation. The buffers are
        host pulls at an already-synchronised boundary — telemetry adds
        no new synchronisation points and no wall-clock."""
        if tracer is None or rec is None:
            return
        ints, flts = np.asarray(rec[0]), np.asarray(rec[1])
        rung = m_out if phase == "ladder" else n
        for j in range(int(r1) - int(r0)):
            liv, inc, ncmp = (int(v) for v in ints[j])
            e = float(flts[j, 0])
            s = liv
            ls = None
            if s > 0:
                f = flts[j]
                ls = {"min": _tfin(f[2]), "q25": _tfin(f[3]),
                      "q50": _tfin(f[4]), "q75": _tfin(f[5]),
                      "max": _tfin(f[6]), "mean": _tfin(f[1])}
            tracer.segment(
                round=int(r0) + 1 + j, phase=phase, stage=n_stages,
                rung=rung, survivors=s, incumbent=inc,
                energy=(e * n / d1 if np.isfinite(e) else None),
                elements=ncmp, l_summary=ls)
        tracer.flush()   # durable before the fault hook can kill us

    def _save(phase, surv_idx_d, state12):
        if ck is None:
            return
        (l_c, alive_c, e_cl, m_cl, pidx, pe, pv, dprev, n_comp, n_rounds,
         fold_cols, esum) = state12
        save_state(ck, SolveState(
            phase=phase, n_stages=n_stages, m_out=m_out, is_floor=is_floor,
            surv_idx=np.asarray(surv_idx_d) if phase == PHASE_LADDER
            else np.zeros(0, np.int32),
            l=np.asarray(l_c), alive=np.asarray(alive_c),
            e_cl=np.asarray(e_cl), m_cl=np.asarray(m_cl),
            pidx=np.asarray(pidx), pe=np.asarray(pe), pv=np.asarray(pv),
            dprev=np.asarray(dprev), n_comp=np.asarray(n_comp),
            n_rounds=np.asarray(n_rounds),
            fold_cols=np.asarray(fold_cols),
            esum=np.asarray(esum)), fp)

    def _halted_after(n_rounds_d):
        """Post-segment host checks, in order: checkpoint already saved,
        watchdog beat, injected faults (may raise — the simulated kill),
        then deadline/stall. Returns the halt reason or ''."""
        if wd is not None:
            wd.beat(int(n_rounds_d))
        faults.on_segment(int(n_rounds_d))
        if deadline_ts is not None and faults.clock() >= deadline_ts:
            return "deadline"
        if wd is not None and wd.stalled():
            return "stalled"
        return ""

    # ---- the segment state machine ----
    halt = ""
    n_stages = 0
    m_out, is_floor = 0, False
    fold_cols = jnp.asarray(0, jnp.int32)
    need_enter = True

    if st is not None and st.phase == PHASE_LADDER:
        # resumed mid-rung: re-enter the round loop directly — never
        # re-compact (top_k ties depend on buffer layout)
        n_stages, m_out, is_floor = st.n_stages, st.m_out, st.is_floor
        surv_idx = jnp.asarray(st.surv_idx)
        (l_c, alive_c, e_cl, m_cl, pidx, pe, pv, dprev, n_comp,
         n_rounds) = (jnp.asarray(st.l), jnp.asarray(st.alive),
                      jnp.asarray(st.e_cl), jnp.asarray(st.m_cl),
                      jnp.asarray(st.pidx), jnp.asarray(st.pe),
                      jnp.asarray(st.pv), jnp.asarray(st.dprev),
                      jnp.asarray(st.n_comp), jnp.asarray(st.n_rounds))
        fold_cols = jnp.asarray(st.fold_cols)
        esum = jnp.asarray(st.esum)
        live = int(np.logical_and(st.alive,
                                  st.l < float(st.e_cl)).sum())
        need_enter = False
    else:
        if st is not None:      # resumed in the full-domain phase
            n_stages = st.n_stages
            state_full = (jnp.asarray(st.l), jnp.asarray(st.alive),
                          jnp.asarray(st.e_cl), jnp.asarray(st.m_cl),
                          jnp.asarray(st.pidx), jnp.asarray(st.pe),
                          jnp.asarray(st.pv), jnp.asarray(st.dprev),
                          jnp.asarray(st.n_comp),
                          jnp.asarray(st.n_rounds),
                          jnp.asarray(st.esum))
            fold_cols = jnp.asarray(st.fold_cols)
        else:
            state_full = _stage0_init(X, l0, warm_arr, budget, block,
                                      warm, metric, use_kernels,
                                      interpret, has_warm_idx)
        while True:
            r0 = int(state_full[9])
            out = _stage0_loop(X, state_full, budget, seg_cap, block,
                               metric, use_kernels, interpret,
                               can_compact, rec_len)
            state_full, live_d = out[0], out[1]
            live = int(live_d)
            _save(PHASE_FULL, None,
                  state_full[:10] + (fold_cols, state_full[10]))
            _drain("full", out[2] if rec_len else None, r0,
                   int(state_full[9]))
            halt = _halted_after(state_full[9])
            if (halt or live == 0 or int(state_full[8]) >= budget_host
                    or (can_compact and 2 * live <= n)):
                break
            # segment cap hit mid-phase: keep streaming full-domain rounds
        (l_c, alive_c, e_cl, m_cl, pidx, pe, pv, dprev, n_comp,
         n_rounds, esum) = state_full
        surv_idx = jnp.arange(n, dtype=jnp.int32)

    # ---- compaction-ladder phase ----
    while not halt and live > 0 and int(n_comp) < budget_host:
        if need_enter:
            m_out = max(pow2_at_least(live), floor)
            is_floor = m_out <= floor
            surv_idx, l_c, alive_c, dprev = _stage_enter(
                X, surv_idx, l_c, alive_c, e_cl, pidx, m_out, metric,
                use_kernels, interpret)
            n_stages += 1
        need_enter = True
        while True:
            state_lad = (l_c, alive_c, e_cl, m_cl, pidx, pe, pv, dprev,
                         n_comp, n_rounds, fold_cols, esum)
            r0 = int(n_rounds)
            out = _stage_loop(X, surv_idx, state_lad, budget, seg_cap,
                              block, metric, use_kernels, interpret,
                              is_floor, rec_len)
            state_lad, live_d = out[0], out[1]
            (l_c, alive_c, e_cl, m_cl, pidx, pe, pv, dprev, n_comp,
             n_rounds, fold_cols, esum) = state_lad
            live = int(live_d)
            _save(PHASE_LADDER, surv_idx, state_lad)
            _drain("ladder", out[2] if rec_len else None, r0,
                   int(n_rounds))
            halt = _halted_after(n_rounds)
            if halt or live == 0 or int(n_comp) >= budget_host:
                break
            if not is_floor and 4 * live <= m_out:
                break               # ladder trigger: next rung compacts
            # segment cap hit mid-rung: keep rolling this rung

    # ---- finalize ----
    n_rounds_h = int(n_rounds)
    n_comp_h = int(n_comp)
    e_h = float(e_cl)
    l_h, alive_h = np.asarray(l_c), np.asarray(alive_c)
    live_mask = np.logical_and(alive_h, l_h < e_h)
    certified = not live_mask.any()
    # e * n / (n-1) evaluated left-to-right: the packed-many and sharded
    # engines reproduce this exact association, so any re-grouping here
    # breaks their bit-identity contracts by one ulp
    lo_int = float(l_h[live_mask].min()) if live_mask.any() else e_h
    halt_reason = "" if certified else (halt or "budget")
    if tracer is not None:
        tracer.end(engine="pipelined", index=int(m_cl),
                   energy=(e_h * n / d1 if np.isfinite(e_h) else None),
                   elements=n_comp_h, rounds=n_rounds_h,
                   certified=certified, halt_reason=halt_reason,
                   survivors=int(live_mask.sum()), stages=n_stages)
    return MedoidResult(
        int(m_cl), e_h * n / d1, n_comp_h, n_rounds_h, n_comp_h * n,
        n_stages=n_stages,
        x_cols_streamed=n_rounds_h * n + int(fold_cols),
        certified=certified,
        lo_bound=min(lo_int, e_h) * n / d1,
        halt_reason=halt_reason,
    )


# ---------------------------------------------------------------------------
# streaming repair: resume elimination over an injected survivor set
# (DESIGN.md §15 — the churn-repair half of repro.stream.MedoidIndex)
# ---------------------------------------------------------------------------
def resume_with_survivors(
    X,
    l,
    computed,
    e_cl,
    m_cl,
    esum,
    *,
    block: int = 128,
    metric: str = "l2",
    ladder_min: int = LADDER_MIN,
    use_kernels: bool = False,
    interpret=None,
    checkpoint=None,
    checkpoint_every: int | None = None,
    resume: str = "auto",
    fingerprint_extra: dict | None = None,
    trace=None,
    repair_info: dict | None = None,
):
    """Finish an elimination whose bounds were repaired out-of-band.

    The streaming index (:mod:`repro.stream`) delta-adjusts a persisted
    solve's bounds and energy cache after churn, elects an incumbent
    from the cache, and hands the *invalidated* rows — the ones whose
    repaired ``l`` fell back under the incumbent — to this entry point.
    It enters the compaction ladder directly: the injected survivor set
    is compacted onto the pow2 rung by :func:`_stage_enter` with a
    **neutralised previous-block carry** (``pv`` all-False, so the first
    fold is a provable no-op — ``max(l, -inf)`` on the jnp path, an
    all-masked column max in the kernel) and then runs the exact
    :func:`_stage_loop` segments a fresh solve would, with the same
    checkpoint / fault-injection / trace machinery (kill-and-resume
    mid-repair is bit-identical, same as DESIGN.md §13).

    ``l`` must hold valid lower bounds on the **current** internal
    ``S/N`` energies for every row, ``computed`` marks rows whose exact
    energy is cached in ``esum`` (raw ``S`` sums), and ``(e_cl, m_cl)``
    is the incumbent elected from that cache — its energy exact on the
    current set. Exactness then follows from the paper's argument
    unchanged: every row ends computed or bound-eliminated.

    Returns ``(result, final)``: a :class:`MedoidResult` whose counters
    cover only the repair work, and ``final`` — the repaired
    full-domain state ``{l, alive, esum, e_cl, m_cl}`` (numpy; ladder
    buffers scattered back through ``surv_idx``) that seeds the next
    repair."""
    require_metric(metric, need_triangle=True,
                   caller="resume_with_survivors")
    from repro.core.solve_state import (PHASE_LADDER, SolveState,
                                        SolveStateMismatch, load_state,
                                        save_state, state_fingerprint)
    from repro.runtime import faults

    X = jnp.asarray(X)
    n = X.shape[0]
    if n < 2:
        raise ValueError("resume_with_survivors needs n >= 2; tiny sets "
                         "re-solve from scratch")
    block = int(min(block, n))
    floor = max(int(ladder_min), block)
    budget_host = faults.effective_budget(2**31 - 1)
    budget = jnp.asarray(budget_host, jnp.int32)

    l_in = jnp.maximum(jnp.asarray(l, X.dtype), 0.0)
    alive_in = jnp.asarray(np.logical_not(np.asarray(computed, bool)))
    esum_in = jnp.asarray(esum, X.dtype)
    e0 = jnp.asarray(np.asarray(e_cl, X.dtype))
    m0 = jnp.asarray(int(m_cl), jnp.int32)

    ck = _as_checkpointer(checkpoint)
    if resume not in ("auto", "never", "require"):
        raise ValueError(f"resume must be 'auto', 'never' or 'require', "
                         f"got {resume!r}")
    from repro.obs.trace import _finite as _tfin
    from repro.obs.trace import resolve_trace
    tracer = resolve_trace(trace)
    segmented = ck is not None or faults.active() or tracer is not None
    if checkpoint_every is None:
        checkpoint_every = ((tracer.every or _SEG_DEFAULT)
                            if tracer is not None else _SEG_DEFAULT)
    seg_cap = jnp.asarray(
        max(int(checkpoint_every), 1) if segmented else 2**31 - 1,
        jnp.int32)
    fp = state_fingerprint(
        n=n, d=int(X.shape[1]), dtype=str(X.dtype), metric=metric,
        block=block, use_kernels=bool(use_kernels),
        ladder_min=int(ladder_min), entry="stream_repair",
        **(fingerprint_extra or {}))
    st = None
    if ck is not None and resume in ("auto", "require"):
        st = load_state(ck, fp)
        if st is None and resume == "require":
            raise FileNotFoundError(
                f"resume='require' but no SolveState checkpoint in "
                f"{ck.dir}")
    d1 = max(n - 1, 1)
    rec_len = int(max(checkpoint_every, 1)) if tracer is not None else 0
    if tracer is not None:
        tracer.begin(engine="stream_repair", n=n, d=int(X.shape[1]),
                     metric=metric, block=block,
                     resumed=st is not None,
                     elements=int(st.n_comp) if st is not None else 0,
                     round_base=int(st.n_rounds) if st is not None else -1)
        if repair_info and st is None:
            # op summary once per repair; a resumed continuation already
            # has it on disk (byte-identity across kill/resume)
            tracer.event("repair", **repair_info)

    halt = ""
    n_stages = 0
    m_out, is_floor = 0, False
    need_enter = True

    def _save(surv_idx_d, state12):
        if ck is None:
            return
        (l_c, alive_c, e_d, m_d, pidx, pe, pv, dprev, n_comp, n_rounds,
         fold_cols, esum_c) = state12
        save_state(ck, SolveState(
            phase=PHASE_LADDER, n_stages=n_stages, m_out=m_out,
            is_floor=is_floor, surv_idx=np.asarray(surv_idx_d),
            l=np.asarray(l_c), alive=np.asarray(alive_c),
            e_cl=np.asarray(e_d), m_cl=np.asarray(m_d),
            pidx=np.asarray(pidx), pe=np.asarray(pe), pv=np.asarray(pv),
            dprev=np.asarray(dprev), n_comp=np.asarray(n_comp),
            n_rounds=np.asarray(n_rounds),
            fold_cols=np.asarray(fold_cols),
            esum=np.asarray(esum_c)), fp)

    def _drain(rec, r0, r1):
        if tracer is None or rec is None:
            return
        ints, flts = np.asarray(rec[0]), np.asarray(rec[1])
        for j in range(int(r1) - int(r0)):
            liv, inc, ncmp = (int(v) for v in ints[j])
            e = float(flts[j, 0])
            ls = None
            if liv > 0:
                f = flts[j]
                ls = {"min": _tfin(f[2]), "q25": _tfin(f[3]),
                      "q50": _tfin(f[4]), "q75": _tfin(f[5]),
                      "max": _tfin(f[6]), "mean": _tfin(f[1])}
            tracer.segment(
                round=int(r0) + 1 + j, phase="repair", stage=n_stages,
                rung=m_out, survivors=liv, incumbent=inc,
                energy=(e * n / d1 if np.isfinite(e) else None),
                elements=ncmp, l_summary=ls)
        tracer.flush()

    if st is not None:
        if st.phase != PHASE_LADDER:
            raise SolveStateMismatch(
                "stream-repair checkpoints are always ladder-phase")
        n_stages, m_out, is_floor = st.n_stages, st.m_out, st.is_floor
        surv_idx = jnp.asarray(st.surv_idx)
        (l_c, alive_c, e_d, m_d, pidx, pe, pv, dprev, n_comp,
         n_rounds) = (jnp.asarray(st.l), jnp.asarray(st.alive),
                      jnp.asarray(st.e_cl), jnp.asarray(st.m_cl),
                      jnp.asarray(st.pidx), jnp.asarray(st.pe),
                      jnp.asarray(st.pv), jnp.asarray(st.dprev),
                      jnp.asarray(st.n_comp), jnp.asarray(st.n_rounds))
        fold_cols = jnp.asarray(st.fold_cols)
        esum_c = jnp.asarray(st.esum)
        live = int(np.logical_and(st.alive, st.l < float(st.e_cl)).sum())
        need_enter = False
    else:
        surv_idx = jnp.arange(n, dtype=jnp.int32)
        l_c, alive_c, e_d, m_d = l_in, alive_in, e0, m0
        # neutralised previous-block carry: all-False pv makes the
        # first fold an identity on both the jnp and kernel paths
        pidx = jnp.zeros(block, jnp.int32)
        pe = jnp.zeros(block, X.dtype)
        pv = jnp.zeros(block, bool)
        dprev = jnp.zeros((block, 0), X.dtype)
        n_comp = jnp.asarray(0, jnp.int32)
        n_rounds = jnp.asarray(0, jnp.int32)
        fold_cols = jnp.asarray(0, jnp.int32)
        esum_c = esum_in
        live = int(jnp.logical_and(alive_in, l_in < e0).sum())

    while not halt and live > 0 and int(n_comp) < budget_host:
        if need_enter:
            # unlike the fresh driver (which only ladders when n > floor)
            # this entry point always compacts, so clamp the rung to n
            m_out = min(max(pow2_at_least(live), floor), n)
            is_floor = m_out <= floor or m_out >= n
            surv_idx, l_c, alive_c, dprev = _stage_enter(
                X, surv_idx, l_c, alive_c, e_d, pidx, m_out, metric,
                use_kernels, interpret)
            n_stages += 1
        need_enter = True
        while True:
            state_lad = (l_c, alive_c, e_d, m_d, pidx, pe, pv, dprev,
                         n_comp, n_rounds, fold_cols, esum_c)
            r0 = int(n_rounds)
            out = _stage_loop(X, surv_idx, state_lad, budget, seg_cap,
                              block, metric, use_kernels, interpret,
                              is_floor, rec_len)
            state_lad, live_d = out[0], out[1]
            (l_c, alive_c, e_d, m_d, pidx, pe, pv, dprev, n_comp,
             n_rounds, fold_cols, esum_c) = state_lad
            live = int(live_d)
            _save(surv_idx, state_lad)
            _drain(out[2] if rec_len else None, r0, int(n_rounds))
            faults.on_segment(int(n_rounds))
            if halt or live == 0 or int(n_comp) >= budget_host:
                break
            if not is_floor and 4 * live <= m_out:
                break               # ladder trigger: next rung compacts

    # ---- finalize + scatter the compacted buffers back to (n,) ----
    n_rounds_h = int(n_rounds)
    n_comp_h = int(n_comp)
    e_h = float(e_d)
    l_np, alive_np = np.asarray(l_c), np.asarray(alive_c)
    live_mask = np.logical_and(alive_np, l_np < e_h)
    certified = not live_mask.any()
    lo_int = float(l_np[live_mask].min()) if live_mask.any() else e_h
    halt_reason = "" if certified else (halt or "budget")

    l_full = np.array(np.asarray(l_in))
    alive_full = np.array(np.asarray(alive_in))
    if n_stages > 0 or st is not None:        # ladder ran: buffers compacted
        sidx = np.asarray(surv_idx)
        slot = np.isfinite(l_np)              # empty slots stay +inf
        l_full[sidx[slot]] = l_np[slot]
        alive_full[sidx[slot]] = alive_np[slot]
    else:
        l_full, alive_full = l_np.copy(), alive_np.copy()

    if tracer is not None:
        tracer.end(engine="stream_repair", index=int(m_d),
                   energy=(e_h * n / d1 if np.isfinite(e_h) else None),
                   elements=n_comp_h, rounds=n_rounds_h,
                   certified=certified, halt_reason=halt_reason,
                   survivors=int(live_mask.sum()), stages=n_stages)
    result = MedoidResult(
        int(m_d), e_h * n / d1, n_comp_h, n_rounds_h, n_comp_h * n,
        n_stages=n_stages,
        x_cols_streamed=n_rounds_h * n + int(fold_cols),
        certified=certified,
        lo_bound=min(lo_int, e_h) * n / d1,
        halt_reason=halt_reason,
    )
    final = {"l": l_full, "alive": alive_full,
             "esum": np.asarray(esum_c), "e_cl": np.asarray(e_d),
             "m_cl": int(m_d)}
    return result, final


# ---------------------------------------------------------------------------
# batched multi-cluster engine (K concurrent per-cluster searches)
# ---------------------------------------------------------------------------
def _bincumbent(s_blk, idx, a_piv, valid, k, s_best, m_best):
    """Per-cluster incumbent update via a small (K, B) masked view."""
    per_k = jnp.where(
        jnp.logical_and(a_piv[None, :] == jnp.arange(k)[:, None],
                        valid[None, :]),
        s_blk[None, :], jnp.inf,
    )
    r_min = per_k.min(axis=1)
    r_arg = jnp.take(idx, per_k.argmin(axis=1))
    better = r_min < s_best
    s_best = jnp.where(better, r_min, s_best)
    m_best = jnp.where(better, r_arg, m_best)
    return s_best, m_best


def _bselect(l, alive, thresh, v_a, b):
    survivor = jnp.logical_and(alive, l < thresh)
    score = jnp.where(survivor, -l / jnp.maximum(v_a, 1.0), NEG_INF)
    top, idx = jax.lax.top_k(score, b)
    valid = top > NEG_INF
    return idx, valid


def _bpipe_round0(X, x_sq, a, v, k, metric, use_kernels, interpret, state,
                  b, forced_idx=None, forced_valid=None):
    """One full-domain pipelined multi-cluster round. ``forced_idx``
    overrides selection (the warm-seed round)."""
    (l, alive, s_best, m_best, pidx, ps, pv, dprev, n_comp,
     n_rounds) = state
    n = X.shape[0]
    a_prev = jnp.take(a, pidx)
    v_prev = jnp.take(v, a_prev).astype(X.dtype)

    if not use_kernels:
        same_prev = a_prev[:, None] == a[None, :]
        gap = jnp.abs(dprev * v_prev[:, None] - ps[:, None])
        gap = jnp.where(same_prev, gap, NEG_INF)
        l = jnp.maximum(l, _masked_colmax(gap, pv))

    if forced_idx is None:
        thresh = jnp.take(s_best, a)
        v_a = jnp.take(v, a).astype(X.dtype)
        idx, valid = _bselect(l, alive, thresh, v_a, b)
    else:
        idx, valid = forced_idx, forced_valid
    a_piv = jnp.take(a, idx)
    xb = jnp.take(X, idx, axis=0)

    if use_kernels:
        if pidx.shape[0] == 0:       # first round: no previous block yet
            s_sums = _ops.masked_energies(xb, X, a_piv, a, metric=metric,
                                          interpret=interpret)
        else:
            xbp = jnp.take(X, pidx, axis=0)
            s_sums, l = _ops.masked_pipelined_round(
                xb, xbp, X, a_piv, a_prev, a, ps, v_prev, pv, l,
                metric=metric, interpret=interpret)
        dnew = dprev                                  # unused carry (0, N)
    else:
        dnew = pairwise(xb, X, metric, a_sq=jnp.take(x_sq, idx), b_sq=x_sq)
        same_new = a_piv[:, None] == a[None, :]
        s_sums = chunked_rowsum(jnp.where(same_new, dnew, 0.0))

    s_blk = jnp.where(valid, s_sums, jnp.inf)
    s_best, m_best = _bincumbent(s_blk, idx, a_piv, valid, k, s_best,
                                 m_best)

    safe_idx = jnp.where(valid, idx, n)
    alive = alive.at[safe_idx].set(False, mode="drop")
    n_comp = n_comp + valid.sum()
    ps = jnp.where(valid, s_blk, 0.0)
    return (l, alive, s_best, m_best, idx, ps, valid, dnew, n_comp,
            n_rounds + 1)


def _bpad_prev(state, block, has_carry):
    (l, alive, s_best, m_best, pidx, ps, pv, dprev, n_comp,
     n_rounds) = state
    pad = block - pidx.shape[0]
    if pad:
        pidx = jnp.pad(pidx, (0, pad))
        ps = jnp.pad(ps, (0, pad))
        pv = jnp.pad(pv, (0, pad))
        if has_carry:
            dprev = jnp.pad(dprev, ((0, pad), (0, 0)))
    return (l, alive, s_best, m_best, pidx, ps, pv, dprev, n_comp,
            n_rounds)


@functools.partial(
    jax.jit,
    static_argnames=("k", "block", "warm", "metric", "use_kernels",
                     "interpret", "can_compact", "has_warm_idx"),
)
def _bstage0(X, a, warm_idx, k, block, warm, metric, use_kernels,
             interpret, can_compact, has_warm_idx):
    n = X.shape[0]
    x_sq = (sq_norms(X) if metric in ("l2", "sqeuclidean")
            else jnp.zeros(n, X.dtype))
    a = a.astype(jnp.int32)
    # negative labels must not wrap into cluster k-1's size (see
    # batched_medoids_jit): route them to the dropped index k
    oob = jnp.logical_or(a < 0, a >= k)
    v = jnp.zeros(k, jnp.int32).at[jnp.where(oob, k, a)].add(1, mode="drop")

    state = (
        jnp.zeros(n, X.dtype),                    # l
        ~oob,                                     # alive
        jnp.full((k,), jnp.inf, X.dtype),         # s_best
        jnp.full((k,), -1, jnp.int32),            # m_best
        jnp.zeros(0, jnp.int32),                  # prev idx
        jnp.zeros(0, X.dtype),                    # prev sums
        jnp.zeros(0, bool),                       # prev valid
        jnp.zeros((0, n), X.dtype),               # prev distance rows
        jnp.asarray(0, jnp.int32),                # n_computed
        jnp.asarray(0, jnp.int32),                # n_rounds
    )
    round_fn = functools.partial(_bpipe_round0, X, x_sq, a, v, k, metric,
                                 use_kernels, interpret)

    if has_warm_idx:
        bw = min(k, block)
        w = jnp.resize(warm_idx.astype(jnp.int32), (bw,))
        w_valid = jnp.arange(bw) < min(k, bw)
        state = round_fn(state, bw, forced_idx=w, forced_valid=w_valid)
    for b in warm:                                # unrolled warm-up
        state = round_fn(state, b)
    state = _bpad_prev(state, block, has_carry=not use_kernels)

    def live_of(state):
        l, alive, s_best = state[0], state[1], state[2]
        thresh = jnp.take(s_best, a)
        return jnp.logical_and(alive, l < thresh).sum()

    def cond(state):
        live = live_of(state)
        if can_compact:
            return jnp.logical_and(live > 0, 2 * live > n)
        return live > 0

    state = jax.lax.while_loop(cond, lambda s: round_fn(s, block), state)
    return state, live_of(state), v


def _bcompact(X, a, surv_idx, l_s, alive_s, s_best, m_out):
    thresh = jnp.take(s_best, jnp.take(a, surv_idx))
    keep = jnp.logical_and(alive_s, l_s < thresh)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    tgt = jnp.where(keep, pos, m_out)
    new_idx = jnp.zeros(m_out, jnp.int32).at[tgt].set(surv_idx, mode="drop")
    new_l = jnp.full(m_out, jnp.inf, l_s.dtype).at[tgt].set(l_s, mode="drop")
    new_alive = jnp.zeros(m_out, bool).at[tgt].set(True, mode="drop")
    Xs = jnp.take(X, new_idx, axis=0)
    a_s = jnp.take(a, new_idx).astype(jnp.int32)
    return new_idx, new_l, new_alive, Xs, a_s


def _bstage_round(X, Xs, surv_idx, a, a_s, v, k, x_sq, metric,
                  use_kernels, interpret, block, state):
    (l_s, alive_s, s_best, m_best, pidx, ps, pv, dprev_s, n_comp,
     n_rounds, fold_cols) = state
    m = Xs.shape[0]
    a_prev = jnp.take(a, pidx)
    v_prev = jnp.take(v, a_prev).astype(X.dtype)

    # 1. fold previous block over the M survivor columns
    if use_kernels:
        xbp = jnp.take(X, pidx, axis=0)
        l_s = _ops.masked_bound_update(xbp, Xs, ps, v_prev, pv, a_prev,
                                       a_s, l_s, metric=metric,
                                       interpret=interpret)
    else:
        same = a_prev[:, None] == a_s[None, :]
        gap = jnp.abs(dprev_s * v_prev[:, None] - ps[:, None])
        gap = jnp.where(same, gap, NEG_INF)
        l_s = jnp.maximum(l_s, _masked_colmax(gap, pv))
    fold_cols = fold_cols + m

    # 2. top_k over M survivors (mean-distance scale across clusters)
    thresh = jnp.take(s_best, a_s)
    v_s = jnp.take(v, a_s).astype(X.dtype)
    pos, valid = _bselect(l_s, alive_s, thresh, v_s, block)
    idx = jnp.take(surv_idx, pos)
    xb = jnp.take(X, idx, axis=0)
    a_piv = jnp.take(a, idx)

    # 3. exact in-cluster sums — the one full stream of X this round
    if use_kernels:
        s_sums = _ops.masked_energies(xb, X, a_piv, a, metric=metric,
                                      interpret=interpret)
        dnew_s = dprev_s                              # unused carry (0, M)
    else:
        dnew = pairwise(xb, X, metric, a_sq=jnp.take(x_sq, idx), b_sq=x_sq)
        same = a_piv[:, None] == a[None, :]
        s_sums = chunked_rowsum(jnp.where(same, dnew, 0.0))
        dnew_s = jnp.take(dnew, surv_idx, axis=1)
    s_blk = jnp.where(valid, s_sums, jnp.inf)

    s_best, m_best = _bincumbent(s_blk, idx, a_piv, valid, k, s_best,
                                 m_best)
    alive_s = alive_s.at[pos].set(jnp.where(valid, False, alive_s[pos]))
    n_comp = n_comp + valid.sum()
    ps = jnp.where(valid, s_blk, 0.0)
    return (l_s, alive_s, s_best, m_best, idx, ps, valid, dnew_s, n_comp,
            n_rounds + 1, fold_cols)


@functools.partial(
    jax.jit,
    static_argnames=("m_out", "k", "block", "metric", "use_kernels",
                     "interpret", "is_floor"),
)
def _bstage(X, surv_idx, a, v, l_s, alive_s, s_best, m_best,
            pidx, ps, pv, n_comp, n_rounds, fold_cols, m_out, k, block,
            metric, use_kernels, interpret, is_floor):
    """Compact the live survivors into an ``m_out``-sized buffer, then run
    rounds until the next ladder trigger (or termination)."""
    n = X.shape[0]
    a = a.astype(jnp.int32)
    surv_idx, l_s, alive_s, Xs, a_s = _bcompact(X, a, surv_idx, l_s,
                                                alive_s, s_best, m_out)
    m = m_out
    x_sq = (sq_norms(X) if metric in ("l2", "sqeuclidean")
            else jnp.zeros(n, X.dtype))
    xs_sq = (sq_norms(Xs) if metric in ("l2", "sqeuclidean")
             else jnp.zeros(m, Xs.dtype))
    if use_kernels:
        dprev_s = jnp.zeros((0, m), X.dtype)
    else:
        dprev_s = pairwise(jnp.take(X, pidx, axis=0), Xs, metric,
                           a_sq=jnp.take(x_sq, pidx), b_sq=xs_sq)
    state = (l_s, alive_s, s_best, m_best, pidx, ps, pv, dprev_s, n_comp,
             n_rounds, fold_cols)

    def live_of(state):
        l_s, alive_s, s_best = state[0], state[1], state[2]
        thresh = jnp.take(s_best, a_s)
        return jnp.logical_and(alive_s, l_s < thresh).sum()

    def cond(state):
        live = live_of(state)
        if is_floor:
            return live > 0
        return jnp.logical_and(live > 0, 4 * live > m)

    body = functools.partial(_bstage_round, X, Xs, surv_idx, a, a_s, v,
                             k, x_sq, metric, use_kernels, interpret,
                             block)
    state = jax.lax.while_loop(cond, body, state)
    return state, surv_idx, live_of(state)


def _batched_medoids_pipelined(
    X,
    assignment,
    k: int,
    block: int = 128,
    metric: str = "l2",
    block_schedule=None,
    ladder_min: int = LADDER_MIN,
    use_kernels: bool = False,
    interpret=None,
    warm_idx=None,
) -> BatchedMedoidResult:
    """Exact per-cluster medoids via the survivor-compacted, pipelined
    multi-cluster engine (DESIGN.md §4). Same contract as
    :func:`repro.core.batched.batched_medoids`; ``warm_idx`` seeds the
    incumbents (inside K-medoids: the previous iteration's medoids) and
    replaces the geometric warm-up."""
    require_metric(metric, need_triangle=True,
                   caller="batched_medoids_pipelined")
    X = jnp.asarray(X)
    a = jnp.asarray(assignment)
    n = X.shape[0]
    block = int(min(block, n))
    has_warm_idx = warm_idx is not None
    warm = () if has_warm_idx else resolve_schedule(block_schedule, block)
    floor = max(int(ladder_min), block)
    can_compact = n > floor
    warm_arr = (jnp.asarray(warm_idx, jnp.int32) if has_warm_idx
                else jnp.zeros((k,), jnp.int32))

    state, live, v = _bstage0(X, a, warm_arr, k, block, warm, metric,
                              use_kernels, interpret, can_compact,
                              has_warm_idx)
    (l, alive, s_best, m_best, pidx, ps, pv, _d, n_comp,
     n_rounds) = state
    live = int(live)
    n_stages = 0
    fold_cols = jnp.asarray(0, jnp.int32)
    surv_idx, l_s, alive_s = jnp.arange(n, dtype=jnp.int32), l, alive

    while live > 0:
        m_out = max(pow2_at_least(live), floor)
        is_floor = m_out <= floor
        out, surv_idx, live_d = _bstage(
            X, surv_idx, a, v, l_s, alive_s, s_best, m_best, pidx, ps, pv,
            n_comp, n_rounds, fold_cols, m_out, k, block, metric,
            use_kernels, interpret, is_floor)
        (l_s, alive_s, s_best, m_best, pidx, ps, pv, _d, n_comp,
         n_rounds, fold_cols) = out
        live = int(live_d)
        n_stages += 1

    n_rounds = int(n_rounds)
    n_comp = int(n_comp)
    return BatchedMedoidResult(
        np.asarray(m_best), np.asarray(s_best), n_comp, n_rounds,
        n_comp * n,
        n_stages=n_stages,
        x_cols_streamed=n_rounds * n + int(fold_cols),
    )


# ---------------------------------------------------------------------------
# legacy entrypoint shims (deprecated — repro.api.solve is the front door)
# ---------------------------------------------------------------------------
def trimed_pipelined(
    X,
    seed: int = 0,
    block: int = 128,
    metric: str = "l2",
    block_schedule=None,
    ladder_min: int = LADDER_MIN,
    use_kernels: bool = False,
    interpret=None,
    warm_idx=None,
    l_init=None,
    max_computed: int | None = None,
) -> MedoidResult:
    """**Deprecated** shim over ``solve(MedoidQuery(...), plan="pipelined")``."""
    from repro.api import MedoidQuery, solve, _warn_legacy
    _warn_legacy("trimed_pipelined", " (plan='pipelined')")
    opts = {"ladder_min": ladder_min, "interpret": interpret}
    if l_init is not None:
        opts["l_init"] = l_init
    if max_computed is not None:
        opts["max_computed"] = max_computed
    q = MedoidQuery(X, metric=metric, seed=seed, block=block,
                    block_schedule=block_schedule, use_kernels=use_kernels,
                    warm_idx=warm_idx, engine_opts=opts)
    return solve(q, plan="pipelined").extras["raw"]


def batched_medoids_pipelined(
    X,
    assignment,
    k: int,
    block: int = 128,
    metric: str = "l2",
    block_schedule=None,
    ladder_min: int = LADDER_MIN,
    use_kernels: bool = False,
    interpret=None,
    warm_idx=None,
) -> BatchedMedoidResult:
    """**Deprecated** shim over ``solve(MedoidQuery(..., assignments=...),
    plan="batched_pipelined")``."""
    from repro.api import MedoidQuery, solve, _warn_legacy
    _warn_legacy("batched_medoids_pipelined", " (plan='batched_pipelined')")
    q = MedoidQuery(X, metric=metric, k=k, assignments=assignment,
                    block=block, block_schedule=block_schedule,
                    use_kernels=use_kernels, warm_idx=warm_idx,
                    engine_opts={"ladder_min": ladder_min,
                                 "interpret": interpret})
    return solve(q, plan="batched_pipelined").extras["raw"]
