"""Distance backends for the medoid/K-medoids core.

Two families:

* **Oracles** (host-side, numpy) — expose ``row(i)`` returning the full
  distance row from element ``i``. They instrument the exact quantity the
  paper reports: the number of *computed elements* (full rows) and the
  number of scalar distance evaluations. Oracles work for any metric,
  including graph shortest-path (see :mod:`repro.core.graph`), which is how
  the paper handles spatial-network data.

* **Batched jnp functions** — matmul-shaped pairwise distances used by the
  TPU block algorithm and by the Pallas kernels' reference path.

**Energy normalisation (the single authoritative statement — every other
module cross-references here).** Internally, energies follow the
*sum-including-self* convention ``E(i) = S(i)/N`` with
``S(i) = sum_j dist(i, j)`` (``dist(i,i) = 0``). Under this convention the
triangle-inequality bound used by trimed is exactly
``E(j) >= |E(i) - dist(i, j)|`` (the paper's Eq. 4/5 argument goes through
without an ``N/(N-1)`` correction term). The argmin over elements is
identical under either convention; *reported* energies (``.energy``
fields on result dataclasses) are rescaled by ``N/(N-1)`` to the paper's
``E = S/(N-1)`` convention at the API boundary, and nowhere else.

**Cost accounting (the single shared definition).** All engines,
baselines and benchmarks report cost in *computed elements*: one element
is one full ``(N,)`` distance row, and partial rows/columns count
fractionally — :func:`elements_computed` converts a scalar-distance count
into this unit. The bandit engines (``repro.bandit``) compute sampled
partial columns, the host oracles mix full rows with ``subrow``/``pair``
calls, and the device engines compute full rows; dividing every
scalar-distance total by ``N`` puts them all on one axis.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

# validation lives in the Metric registry (repro.api.metrics); the
# builtin names keep fast paths below, anything else resolves through
# the registry's pairwise_fn
from repro.api.metrics import require_metric


def pow2_at_least(x: int) -> int:
    """Smallest power of two >= ``x`` — the shared rung function for the
    survivor/arm compaction ladders (pipelined engine, bandit racing),
    keeping every buffer on one family of compiled shapes."""
    p = 1
    while p < x:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# fixed reduction geometry (DESIGN.md §11)
# ---------------------------------------------------------------------------
# Energy row-sums in the device engines always reduce over this fixed,
# shard-count-independent column grid: REDUCE_CHUNKS chunks of
# ceil(N / REDUCE_CHUNKS) columns each, combined by an explicit in-order
# fold. A shard holding a contiguous slice of columns computes exactly a
# sub-range of the same chunk partials, so the sharded engine
# (core.distributed) reproduces single-device energies bit-for-bit for
# any shard count dividing REDUCE_CHUNKS. 48 is divisor-rich (1, 2, 3,
# 4, 6, 8, 12, 16, 24, 48), covering every host/pod shard count in use.
REDUCE_CHUNKS = 48


def chunk_size(n: int, chunks: int = REDUCE_CHUNKS) -> int:
    """Columns per chunk of the fixed reduction grid for ``n`` elements."""
    return -(-int(n) // chunks)


def fold_chunks(parts: jnp.ndarray) -> jnp.ndarray:
    """Combine chunk partials with an explicit left-to-right fold.

    A ``sum`` reduction's accumulation order is an XLA lowering detail
    that shifts with fusion context; a chain of individual adds has
    fixed fp semantics the compiler must preserve. This is what makes
    the final combine identical between the single-device engines and
    the gathered per-shard partials of the sharded engine."""
    acc = parts[..., 0]
    for i in range(1, parts.shape[-1]):
        acc = acc + parts[..., i]
    return acc


def chunk_partials(d: jnp.ndarray, chunks: int, size: int) -> jnp.ndarray:
    """``(B, chunks)`` per-chunk row sums of a zero-masked ``(B, M)``
    block with ``M == chunks * size``.

    The within-chunk accumulation is a ``lax.scan`` left fold rather
    than a ``sum`` reduction: a reduce op's accumulation order is an
    XLA lowering choice (SIMD-lane partials, context-dependent fusion)
    that differs between otherwise-identical programs, while a scan's
    sequential semantics must be preserved. This is what makes the
    partials bit-identical between the single-device engines and the
    shard_map programs of ``core.distributed``. The barrier pins the
    masked block's values first so producer fusion cannot specialise
    them either."""
    d, = jax.lax.optimization_barrier((d,))
    dr = d.reshape(d.shape[0], chunks, size)
    cols = jnp.moveaxis(dr, 2, 0)                 # (size, B, chunks)
    acc0 = jnp.zeros(dr.shape[:2], d.dtype)
    parts, _ = jax.lax.scan(lambda acc, c: (acc + c, None), acc0, cols)
    return parts


def chunked_rowsum(d: jnp.ndarray) -> jnp.ndarray:
    """Row sums of a dense ``(B, M)`` block over the fixed reduction
    grid (zero-padding the trailing partial chunk). Bit-reproducible
    against any conforming sharded evaluation of the same rows."""
    b, m = d.shape
    s = chunk_size(m)
    pad = REDUCE_CHUNKS * s - m
    if pad:
        d = jnp.pad(d, ((0, 0), (0, pad)))
    return fold_chunks(chunk_partials(d, REDUCE_CHUNKS, s))


SCAN_ROW_BLOCK = 1024   # fixed pivot-block height of the quadratic scan


def scan_rowsums(X, metric: str = "l2") -> jnp.ndarray:
    """Exact ``(N,)`` distance row sums, blockwise so the ``(N, N)``
    matrix never materialises — the quadratic path behind the planner's
    ``scan`` engine. Row blocks have a fixed padded height and column
    sums run on the fixed reduction grid, so the sharded scan
    (``core.distributed._scan_rowsums_sharded``) reproduces this
    bit-for-bit: both walk identical ``(blk, d)`` pivot blocks (XLA's
    matmul lowering is shape-specialised — equal operand shapes are part
    of the reproducibility contract, see DESIGN.md §11)."""
    X = jnp.asarray(X)
    n = X.shape[0]
    blk = int(min(SCAN_ROW_BLOCK, n))
    r_pad = (-n) % blk
    Xr = jnp.pad(X, ((0, r_pad), (0, 0)))
    sums = [chunked_rowsum(pairwise(Xr[s:s + blk], X, metric))
            for s in range(0, n + r_pad, blk)]
    return jnp.concatenate(sums)[:n]


def elements_computed(n_scalar_distances, n: int) -> float:
    """Unified 'computed elements' cost: scalar distance evaluations
    expressed in full-row units (one element = one full ``(N,)`` row;
    partial rows and sampled columns count fractionally). This is the
    one definition shared by the host oracles, the device engines, the
    bandit subsystem and the benchmarks — see the module docstring."""
    return float(n_scalar_distances) / max(int(n), 1)


# ---------------------------------------------------------------------------
# numpy oracles (host side, instrumented)
# ---------------------------------------------------------------------------
class VectorOracle:
    """Instrumented distance oracle over a dense ``(N, d)`` array."""

    def __init__(self, X: np.ndarray, metric: str = "l2"):
        # one capability source for the whole repo: the Metric registry
        # (repro.api.metrics). Registered non-builtin metrics run through
        # their pairwise_fn as a generic (slower) fallback.
        self._metric_obj = require_metric(metric, caller="VectorOracle")
        self.X = np.asarray(X, dtype=np.float64)
        self.metric = metric
        self.n = self.X.shape[0]
        self.rows_computed = 0
        self.scalar_distances = 0
        if metric == "cosine":
            norms = np.linalg.norm(self.X, axis=1, keepdims=True)
            self._Xn = self.X / np.maximum(norms, 1e-30)
        elif metric in ("l2", "sqeuclidean"):
            self._sq = np.einsum("nd,nd->n", self.X, self.X)

    @property
    def elements(self) -> float:
        """Total cost in unified 'computed elements' (fractional rows for
        ``subrow``/``pair`` calls — see :func:`elements_computed`)."""
        return elements_computed(self.scalar_distances, self.n)

    def row(self, i: int) -> np.ndarray:
        """All distances from element ``i`` (a 'computed element')."""
        from repro.runtime import faults
        faults.on_oracle_call()      # injection hook; no-op when disarmed
        self.rows_computed += 1
        self.scalar_distances += self.n
        if self.metric in ("l2", "sqeuclidean"):
            d2 = self._sq + self._sq[i] - 2.0 * (self.X @ self.X[i])
            np.maximum(d2, 0.0, out=d2)
            d2[i] = 0.0
            return d2 if self.metric == "sqeuclidean" else np.sqrt(d2)
        if self.metric == "l1":
            return np.abs(self.X - self.X[i]).sum(axis=1)
        if self.metric == "cosine":
            d = 1.0 - self._Xn @ self._Xn[i]
            d[i] = 0.0
            return np.maximum(d, 0.0)
        # registered non-builtin metric: generic pairwise_fn fallback
        d = np.asarray(self._metric_obj.pairwise_fn(self.X[i:i + 1], self.X),
                       np.float64)[0]
        d[i] = 0.0
        return d

    def pair(self, i: int, j: int) -> float:
        self.scalar_distances += 1
        if self.metric == "l2":
            return float(np.linalg.norm(self.X[i] - self.X[j]))
        if self.metric == "sqeuclidean":
            return float(((self.X[i] - self.X[j]) ** 2).sum())
        if self.metric == "l1":
            return float(np.abs(self.X[i] - self.X[j]).sum())
        if self.metric == "cosine":
            return float(1.0 - self._Xn[i] @ self._Xn[j])
        return float(np.asarray(self._metric_obj.pairwise_fn(
            self.X[i:i + 1], self.X[j:j + 1]))[0, 0])

    def subrow(self, i: int, idx: np.ndarray) -> np.ndarray:
        """Distances from ``i`` to the subset ``idx`` (used by trikmeds)."""
        self.scalar_distances += len(idx)
        if self.metric in ("l2", "sqeuclidean"):
            d2 = (
                self._sq[idx]
                + self._sq[i]
                - 2.0 * (self.X[idx] @ self.X[i])
            )
            np.maximum(d2, 0.0, out=d2)
            return d2 if self.metric == "sqeuclidean" else np.sqrt(d2)
        if self.metric == "l1":
            return np.abs(self.X[idx] - self.X[i]).sum(axis=1)
        if self.metric == "cosine":
            d = 1.0 - self._Xn[idx] @ self._Xn[i]
            return np.maximum(d, 0.0)
        return np.asarray(self._metric_obj.pairwise_fn(
            self.X[i:i + 1], self.X[idx]), np.float64)[0]


# ---------------------------------------------------------------------------
# jnp batched distances (device side)
# ---------------------------------------------------------------------------
def sq_norms(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("nd,nd->n", x, x)


def pairwise(
    a: jnp.ndarray,
    b: jnp.ndarray,
    metric: str = "l2",
    a_sq: jnp.ndarray | None = None,
    b_sq: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Dense ``(A, B)`` distance block. Matmul-shaped for l2/cosine."""
    if metric in ("l2", "sqeuclidean"):
        if a_sq is None:
            a_sq = sq_norms(a)
        if b_sq is None:
            b_sq = sq_norms(b)
        d2 = a_sq[:, None] + b_sq[None, :] - 2.0 * (a @ b.T)
        d2 = jnp.maximum(d2, 0.0)
        return d2 if metric == "sqeuclidean" else jnp.sqrt(d2)
    if metric == "l1":
        return jnp.abs(a[:, None, :] - b[None, :, :]).sum(-1)
    if metric == "cosine":
        an = a / jnp.maximum(jnp.linalg.norm(a, axis=-1, keepdims=True), 1e-30)
        bn = b / jnp.maximum(jnp.linalg.norm(b, axis=-1, keepdims=True), 1e-30)
        return jnp.maximum(1.0 - an @ bn.T, 0.0)
    # registered non-builtin metric (or the registry's canonical error)
    return require_metric(metric, caller="pairwise").pairwise_fn(a, b)


def exact_energies(X, metric: str = "l2") -> jnp.ndarray:
    """O(N^2) energies (sum-over-all / N). Testing / tiny-N reference."""
    D = pairwise(X, X, metric)
    n = X.shape[0]
    return D.sum(axis=1) / n


def exact_medoid(X, metric: str = "l2") -> tuple[int, float]:
    e = exact_energies(X, metric)
    i = int(jnp.argmin(e))
    return i, float(e[i])
