"""repro.stream — exact streaming medoid maintenance (DESIGN.md §15).

:class:`MedoidIndex` holds a solved dataset and absorbs churn
(``insert`` / ``delete`` / ``update``) by repairing the persisted
elimination state instead of re-solving; ``query()`` stays bit-for-bit
equal to a fresh ``solve()`` on the current rows.
:class:`SlidingWindowIndex` specialises it to the append-and-expire
pattern of the KV-compression serving workload.
"""
from repro.stream.index import MedoidIndex
from repro.stream.window import SlidingWindowIndex

__all__ = ["MedoidIndex", "SlidingWindowIndex"]
