"""Sliding-window medoid maintenance over an append-only stream.

The KV-compression serving workload (``repro.serve.kv_compress``)
tracks representatives of the most recent window of keys: each decode
step appends new rows and expires the oldest. That churn pattern is
exactly insert-at-the-tail plus delete-at-the-head, so
:class:`SlidingWindowIndex` is a thin policy layer over
:class:`repro.stream.index.MedoidIndex` — ``push`` appends the new
rows then expires overflow from the front, and ``query`` stays the
index's exact, bit-for-bit medoid of the current window.

Positions inside :class:`MedoidIndex` are *dense*: deletes shift later
rows down and inserts append at the end, so the oldest surviving rows
always occupy the lowest positions. Expiring ``k`` rows is therefore
always ``delete(arange(k))``, no bookkeeping needed.
"""
from __future__ import annotations

import numpy as np

from repro.core.trimed import MedoidResult
from repro.stream.index import MedoidIndex


class SlidingWindowIndex:
    """Exact medoid of the last ``window`` rows of a stream.

    ``push(rows)`` appends ``rows`` and expires whatever falls out of
    the window; ``query()`` delegates to the wrapped
    :class:`MedoidIndex` (same bit-for-bit contract against a fresh
    solve of the current window). All ``MedoidIndex`` configuration —
    metric, block, kernels, checkpoint, metrics, trace — passes
    through ``**cfg``.
    """

    def __init__(self, index: MedoidIndex, window: int):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.index = index
        self.window = int(window)

    @classmethod
    def from_data(cls, X, *, window: int, **cfg) -> "SlidingWindowIndex":
        """Solve the tail of ``X`` that fits in ``window`` and wrap it."""
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        X = np.asarray(X, np.float32)
        return cls(MedoidIndex.from_data(X[-window:], **cfg), window)

    # ------------------------------------------------------------ stream
    def push(self, rows) -> None:
        """Append ``rows`` to the stream, expiring the oldest overflow.

        Rows beyond ``window`` in a single push are dropped up front —
        only the tail can survive, so the index never has to absorb
        rows that would expire within the same call.
        """
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        if rows.shape[0] > self.window:
            rows = rows[-self.window:]
        self.index.insert(rows)
        overflow = self.index.n - self.window
        if overflow > 0:
            self.index.delete(np.arange(overflow))

    # ------------------------------------------------------------- reads
    @property
    def n(self) -> int:
        return self.index.n

    @property
    def X(self) -> np.ndarray:
        return self.index.X

    def query(self, *, trace=None) -> MedoidResult:
        return self.index.query(trace=trace)
